"""Distributed datalog materialisation under shard_map.

    PYTHONPATH=src python examples/distributed_reasoning.py

Runs the hash-partitioned semi-naive engine on the local device mesh and
checks the result against the flat oracle.  On a pod the identical code
runs over the (data=16) axis of the production mesh — the dry-run lowers
exactly this round function at 256/512 devices.
"""

import numpy as np

import jax
from jax.sharding import Mesh

from repro.core import flat_seminaive
from repro.core.distributed import DistributedEngine
from repro.core.generators import lubm_like


def main():
    program, dataset, _ = lubm_like(n_dept=8, n_students=120, n_courses=16)
    # the distributed engine handles <=2-atom bodies; restrict the program
    rules = [r for r in program if len(r.body) <= 2]
    program = type(program)(rules)

    n_dev = len(jax.devices())
    mesh = Mesh(np.asarray(jax.devices()).reshape(n_dev), ("data",))
    print(f"mesh: {n_dev} device(s) on axis 'data'")

    eng = DistributedEngine(program, mesh, capacity=1 << 13)
    result = eng.materialise(dataset)
    print(f"fixpoint after {eng.rounds} rounds")

    expected = flat_seminaive(program, dataset)
    for pred, rows in sorted(expected.items()):
        got = result.get(pred, np.zeros((0, rows.shape[1])))
        ok = {tuple(r) for r in got} == {tuple(r) for r in rows}
        print(f"    {pred:<20} {got.shape[0]:6d} facts  "
              f"{'OK' if ok else 'MISMATCH'}")
        assert ok
    print("distributed result == flat oracle")


if __name__ == "__main__":
    main()
