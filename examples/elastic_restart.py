"""Fault tolerance demo: failure injection, checkpoint/restart, and
elastic re-mesh planning.

    PYTHONPATH=src python examples/elastic_restart.py

1. trains a smoke model with failures injected at steps 7 and 15;
   the supervision loop restores the latest checkpoint and continues;
2. shows the ElasticPlan choosing a smaller mesh after losing hosts and
   resharding the state for it.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticCorpus
from repro.train import (
    ElasticPlan,
    StragglerMonitor,
    TrainConfig,
    init_train_state,
    make_train_step,
    run_with_recovery,
)


def main():
    cfg = get_config("llama3.2-1b", smoke=True)
    train_cfg = TrainConfig(total_steps=24, warmup_steps=2)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    corpus = SyntheticCorpus(data_cfg)

    state = init_train_state(jax.random.PRNGKey(0), cfg, train_cfg)
    step_fn = jax.jit(make_train_step(cfg, train_cfg))
    batches = [
        {k: jnp.asarray(v) for k, v in corpus.batch(s).items()} for s in range(24)
    ]

    with tempfile.TemporaryDirectory() as ckpt_dir:
        state, last, failures = run_with_recovery(
            step_fn, state, batches,
            ckpt_dir=ckpt_dir, ckpt_every=5,
            fail_at={7, 15},
        )
        print(f"trained to step {last} surviving {failures} injected failures")

    # --- elastic re-mesh planning --- #
    plan = ElasticPlan(total_hosts=128, chips_per_host=4, model_parallel=16)
    for surviving in (128, 120, 96, 65):
        data, model = plan.pick(surviving)
        print(f"hosts={surviving:4d}  -> mesh (data={data}, model={model}) "
              f"= {data*model} chips")

    # --- straggler detection (flags accrue per periodic check) --- #
    mon = StragglerMonitor(threshold=1.5, min_flags=3)
    rng = np.random.default_rng(0)
    flagged = []
    for step in range(12):
        for host in range(8):
            t = 1.0 + 0.05 * rng.standard_normal()
            if host == 3:
                t *= 2.2  # host 3 is slow
            mon.record(host, t)
        flagged = mon.stragglers()
    print("stragglers detected:", flagged)


if __name__ == "__main__":
    main()
