"""End-to-end driver: materialise a KB with the paper's engine, linearise
it into tokens, and train an LM on the stream for a few hundred steps.

    PYTHONPATH=src python examples/kb_train.py [--steps 300]

This is the 'train ~100M model for a few hundred steps' example: with
--full it uses the real qwen3-0.6b config (too slow for CPU CI; the smoke
config exercises the identical code path).
"""

import argparse

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    argv = [
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "64",
        "--lr", "3e-3",
        "--kb-corpus",
        "--log-every", "20",
    ]
    if not args.full:
        argv.append("--smoke")
    raise SystemExit(train_driver.main(argv))


if __name__ == "__main__":
    main()
