"""Serve a small model with batched requests (prefill + greedy decode).

    PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-1.2b]

Exercises the KV-cache / SSM-state decode path — the same ``serve_step``
the decode_32k / long_500k dry-run cells lower on the production mesh.
"""

import argparse

from repro.launch import serve as serve_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    raise SystemExit(
        serve_driver.main(
            [
                "--arch", args.arch,
                "--smoke",
                "--batch", str(args.batch),
                "--prompt-len", "16",
                "--gen-len", "16",
            ]
        )
    )


if __name__ == "__main__":
    main()
