"""Quickstart: the paper's running example (Section 3), end to end.

Builds the facts (1)-(4) and rules (5)-(6), materialises with the
compressed engine, and prints the meta-facts + mu mapping to compare with
the paper's equations (7)-(13), plus the O(n) vs O(n^2) storage claim.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import CMatEngine, flat_seminaive
from repro.core.generators import paper_example


def main():
    n, m = 4, 3
    program, dataset, dictionary = paper_example(n=n, m=m)

    print("Rules (paper (5)-(6)):")
    for rule in program:
        print("   ", rule)

    print(f"\nExplicit facts: P:{dataset['P'].shape[0]} R:{dataset['R'].shape[0]} "
          f"T:{dataset['T'].shape[0]}  (n={n}, m={m})")

    eng = CMatEngine(program)
    eng.load(dataset)
    stats = eng.materialise()
    print(f"\nmaterialised in {stats.rounds} rounds, "
          f"{stats.n_meta_facts} meta-facts for {stats.n_facts} facts")

    print("\nMeta-facts (compare paper eq. (7) + derived S/P):")
    for pred in sorted(eng.facts.predicates()):
        for mf in eng.facts.all(pred):
            cols = ", ".join(
                _render_column(eng.store, c, dictionary) for c in mf.columns
            )
            print(f"    {pred}({cols})   [{mf.length} facts, round {mf.round}]")

    rep = eng.report()
    print("\nRepresentation sizes (paper Section 4 metric):")
    print(f"    ||E||        = {rep['flat_size_E']}")
    print(f"    ||I||        = {rep['flat_size_I']}")
    print(f"    ||<M, mu>||  = {rep['compressed_size']}")
    print(f"    derived flat = {rep['flat_size_I'] - rep['flat_size_E']}, "
          f"derived compressed = "
          f"{rep['compressed_size'] - rep['flat_size_E']}")

    # cross-check against the flat oracle
    flat = flat_seminaive(program, dataset)
    mat = eng.materialisation()
    assert all(
        {tuple(r) for r in mat[p]} == {tuple(r) for r in flat[p]} for p in flat
    )
    print("\nOK: compressed materialisation == flat semi-naive oracle")


def _render_column(store, cid, dictionary, limit=8):
    vals = store.unfold(cid)
    names = [dictionary.term_of(int(v)) for v in vals[:limit]]
    body = ".".join(names) + ("..." if len(vals) > limit else "")
    return f"[{body}]"


if __name__ == "__main__":
    main()
