"""Query quickstart: ontology -> materialise -> ask BGP queries.

Builds a small university ontology with :class:`OntologyBuilder`,
materialises the compressed store once, then answers three queries
through :class:`repro.query.QueryEngine`, printing each plan and the
decoded answers.  The last section is the warm-start walkthrough
(DESIGN.md §Storage): snapshot the materialised store to disk, restore
it with :func:`repro.storage.load_frozen`, and answer the same queries
without re-running the fixpoint.  Next is the provenance walkthrough
(DESIGN.md §Provenance): the derivation journal is on for the
materialisation, so ``explain_fact`` can show a *verified* proof tree
for any derived fact, plus the per-rule cost table — the same
machinery ``serve_datalog --explain/--explain-sample/--hot-rules``
exposes from the command line.  The final section is the concurrent
serving walkthrough (DESIGN.md §Serving): a :class:`ServingTier` over
an :class:`IncrementalStore` serves threaded readers from pinned
epoch snapshots while a writer applies an update — a reader holding a
``tier.pin()`` lease keeps seeing its epoch unchanged, new queries see
the new one, and nobody blocks on the writer.

    PYTHONPATH=src python examples/query_kb.py
"""

import tempfile
import time

import numpy as np

from repro.core import CMatEngine, Dictionary
from repro.core.owl2rl import OntologyBuilder
from repro.query import QueryEngine
from repro.storage import load_frozen, snapshot_nbytes, write_snapshot


def build_kb():
    d = Dictionary()
    profs = d.intern_many([f"prof{i}" for i in range(4)])
    students = d.intern_many([f"student{i}" for i in range(12)])
    courses = d.intern_many([f"course{i}" for i in range(6)])
    depts = d.intern_many(["cs", "math"])

    rng = np.random.default_rng(7)
    dataset = {
        "teacherOf": np.stack(
            [profs[rng.integers(0, 4, 6)], courses], axis=1
        ),
        "takesCourse": np.stack(
            [np.repeat(students, 2), courses[rng.integers(0, 6, 24)]], axis=1
        ),
        "advisor": np.stack([students, profs[rng.integers(0, 4, 12)]], axis=1),
        "memberOf": np.stack([profs, depts[rng.integers(0, 2, 4)]], axis=1),
        "GraduateStudent": students[::2].reshape(-1, 1),
    }

    ontology = (
        OntologyBuilder()
        .sub_class_of("GraduateStudent", "Student")
        .sub_class_of("Student", "Person")
        .sub_class_of("Professor", "Person")
        .domain("teacherOf", "Professor")
        .range("teacherOf", "Course")
        .domain("advisor", "Student")
        .range("advisor", "Professor")
        .property_chain("advisor", "teacherOf", "advisedCourse")
        .sub_property_of("advisor", "knows")
    )
    return ontology.build(), dataset, d


def print_proof(node, indent="  "):
    mark = "✓" if node["verified"] else "?"
    via = f"  [R{node['rule_id']}: {node['rule']}]" if node.get(
        "rule_id"
    ) is not None and node["kind"] == "derived" else "  (explicit)"
    print(f"{indent}{mark} {node['fact']}{via}")
    for child in node["children"]:
        print_proof(child, indent + "  ")


def main():
    program, dataset, dictionary = build_kb()
    # provenance on: the journal records one compact record per rule
    # application, which explain_fact uses to find minimal proofs fast
    from repro.obs.provenance import get_journal

    journal = get_journal()
    journal.enabled = True
    journal.clear()
    eng = CMatEngine(program)
    eng.load(dataset)
    stats = eng.materialise()
    print(
        f"materialised: {stats.n_facts} facts in {stats.n_meta_facts} "
        f"meta-facts ({stats.rounds} rounds)\n"
    )

    qe = QueryEngine(eng, dictionary)
    queries = [
        # who teaches a course a grad student takes? (3-way join)
        '?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)',
        # derived-class lookup with a constant
        '?p <- Professor(?p), memberOf(?p, "cs")',
        # property-chain derived predicate
        '?s, ?c <- advisedCourse(?s, ?c), GraduateStudent(?s)',
    ]
    for text in queries:
        res = qe.answer(text)
        print(res.plan)
        print(f"  -> {res.n_answers} answers "
              f"(flat rows scanned: {sum(res.stats.rows_scanned.values())})")
        for row in qe.decode(res.answers)[:5]:
            print("     ", row)
        if res.n_answers > 5:
            print("      ...")
        print()

    # -- warm start: snapshot the store, restore, answer again -------- #
    with tempfile.TemporaryDirectory() as tmp:
        snap = f"{tmp}/snap"
        frozen = eng.facts.freeze()
        rows = {p: frozen.snapshot(p) for p in frozen.predicates()}
        manifest = write_snapshot(snap, eng.facts, kind="frozen", rows=rows)
        print(
            f"snapshot: {snapshot_nbytes(snap)} bytes on disk, "
            f"{manifest['store']['n_payloads']} leaf payloads for "
            f"{manifest['store']['n_leaves']} leaves "
            f"({manifest['store']['dedup_saved_bytes']}B shared by dedup)"
        )
        t0 = time.perf_counter()
        qe2 = QueryEngine(load_frozen(snap), dictionary)
        t_restore = time.perf_counter() - t0
        for text in queries:
            assert np.array_equal(
                qe2.answer(text).answers, qe.answer(text).answers
            )
        print(
            f"warm start: restored + re-answered all queries identically "
            f"in {t_restore * 1e3:.1f}ms (no fixpoint, no re-unfold)"
        )

    # -- provenance: why is a derived fact true? ---------------------- #
    # student0 is a Person only through GraduateStudent -> Student ->
    # Person: two taxonomic rule applications the proof tree makes
    # explicit, each step re-derived (never trusted) before ✓ is shown
    sid = dictionary.id_of("student0")
    node = eng.explain_fact("Person", (sid,), decode=dictionary.term_of)
    print("\nexplain Person(student0) — verified proof tree:")
    print_proof(node)

    print("\nhot rules (derivation cost attribution from the journal):")
    for h in journal.hot_rules(3):
        print(
            f"  R{h['rule_id']}: {h['derived']} derived, "
            f"{h['redundant']} redundant, {h['time_ns'] / 1e6:.2f}ms "
            f"over {h['rounds_active']} round(s) — {h['rule']}"
        )
    print(
        "\n(same machinery from the CLI: serve_datalog "
        "--explain 'Person(student0)' --explain-sample 3 --hot-rules)"
    )
    journal.enabled = False
    journal.clear()

    # -- concurrent serving: pinned epochs under live writes ---------- #
    # The MVCC tier wraps an IncrementalStore: readers pin an immutable
    # epoch snapshot, a single writer thread applies updates and
    # publishes new epochs, queries arriving together are folded into
    # shared-plan micro-batches.  (serve_datalog --mvcc --concurrency N
    # is this, plus a report; bench_serving is the load driver.)
    import threading

    from repro.incremental import IncrementalStore
    from repro.serving import ServingTier

    inc = IncrementalStore(program)
    inc.load(dataset)
    tier = ServingTier(inc, dictionary)
    tier.start()  # writer + admission threads (unstarted = inline)

    knows_q = '?s, ?p <- knows(?s, ?p)'
    # a reader pins epoch v0 and keeps it for several queries...
    with tier.pin() as lease:
        before = lease.answer(knows_q).n_answers
        # ...while the writer publishes a new epoch: a fresh advisor
        # edge derives one more knows() fact via the sub-property rule
        s_new = dictionary.id_of("student1")
        p_new = dictionary.id_of("prof3")
        tier.apply_sync(
            additions={"advisor": np.array([[s_new, p_new]])}
        )
        pinned = lease.answer(knows_q).n_answers   # still the old epoch
        fresh = tier.answer(knows_q).n_answers     # current epoch
        print(
            f"\nserving: lease pinned v{lease.version} sees {pinned} "
            f"knows() answers (was {before}), unpinned readers see "
            f"{fresh} at v{tier.registry.version}"
        )
        assert pinned == before and fresh >= before

    # concurrent closed-loop readers: contemporaries in the admission
    # queue that share a plan signature run as ONE batched scan/join
    def client(n):
        for _ in range(n):
            resp = tier.answer('?p <- Professor(?p), memberOf(?p, "cs")')
            assert not resp.stale

    threads = [threading.Thread(target=client, args=(25,)) for _ in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    st = tier.stats()
    print(
        f"serving: {st['queries']} queries in {st['batches']} "
        f"micro-batches (mean {st['mean_batch']:.1f}/batch, "
        f"{st['dedup_hits']} dedup + {st['cache_hits']} cache hits), "
        f"{st['stale_reads']} stale reads, "
        f"{st['epochs_published']} epochs published"
    )
    assert st["stale_reads"] == 0
    tier.close()


if __name__ == "__main__":
    main()
