"""Query quickstart: ontology -> materialise -> ask BGP queries.

Builds a small university ontology with :class:`OntologyBuilder`,
materialises the compressed store once, then answers three queries
through :class:`repro.query.QueryEngine`, printing each plan and the
decoded answers.

    PYTHONPATH=src python examples/query_kb.py
"""

import numpy as np

from repro.core import CMatEngine, Dictionary
from repro.core.owl2rl import OntologyBuilder
from repro.query import QueryEngine


def build_kb():
    d = Dictionary()
    profs = d.intern_many([f"prof{i}" for i in range(4)])
    students = d.intern_many([f"student{i}" for i in range(12)])
    courses = d.intern_many([f"course{i}" for i in range(6)])
    depts = d.intern_many(["cs", "math"])

    rng = np.random.default_rng(7)
    dataset = {
        "teacherOf": np.stack(
            [profs[rng.integers(0, 4, 6)], courses], axis=1
        ),
        "takesCourse": np.stack(
            [np.repeat(students, 2), courses[rng.integers(0, 6, 24)]], axis=1
        ),
        "advisor": np.stack([students, profs[rng.integers(0, 4, 12)]], axis=1),
        "memberOf": np.stack([profs, depts[rng.integers(0, 2, 4)]], axis=1),
        "GraduateStudent": students[::2].reshape(-1, 1),
    }

    ontology = (
        OntologyBuilder()
        .sub_class_of("GraduateStudent", "Student")
        .sub_class_of("Student", "Person")
        .sub_class_of("Professor", "Person")
        .domain("teacherOf", "Professor")
        .range("teacherOf", "Course")
        .domain("advisor", "Student")
        .range("advisor", "Professor")
        .property_chain("advisor", "teacherOf", "advisedCourse")
        .sub_property_of("advisor", "knows")
    )
    return ontology.build(), dataset, d


def main():
    program, dataset, dictionary = build_kb()
    eng = CMatEngine(program)
    eng.load(dataset)
    stats = eng.materialise()
    print(
        f"materialised: {stats.n_facts} facts in {stats.n_meta_facts} "
        f"meta-facts ({stats.rounds} rounds)\n"
    )

    qe = QueryEngine(eng, dictionary)
    queries = [
        # who teaches a course a grad student takes? (3-way join)
        '?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)',
        # derived-class lookup with a constant
        '?p <- Professor(?p), memberOf(?p, "cs")',
        # property-chain derived predicate
        '?s, ?c <- advisedCourse(?s, ?c), GraduateStudent(?s)',
    ]
    for text in queries:
        res = qe.answer(text)
        print(res.plan)
        print(f"  -> {res.n_answers} answers "
              f"(flat rows scanned: {sum(res.stats.rows_scanned.values())})")
        for row in qe.decode(res.answers)[:5]:
            print("     ", row)
        if res.n_answers > 5:
            print("      ...")
        print()


if __name__ == "__main__":
    main()
