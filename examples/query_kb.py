"""Query quickstart: ontology -> materialise -> ask BGP queries.

Builds a small university ontology with :class:`OntologyBuilder`,
materialises the compressed store once, then answers three queries
through :class:`repro.query.QueryEngine`, printing each plan and the
decoded answers.  The last section is the warm-start walkthrough
(DESIGN.md §Storage): snapshot the materialised store to disk, restore
it with :func:`repro.storage.load_frozen`, and answer the same queries
without re-running the fixpoint.  The final section is the provenance
walkthrough (DESIGN.md §Provenance): the derivation journal is on for
the materialisation, so ``explain_fact`` can show a *verified* proof
tree for any derived fact, plus the per-rule cost table — the same
machinery ``serve_datalog --explain/--explain-sample/--hot-rules``
exposes from the command line.

    PYTHONPATH=src python examples/query_kb.py
"""

import tempfile
import time

import numpy as np

from repro.core import CMatEngine, Dictionary
from repro.core.owl2rl import OntologyBuilder
from repro.query import QueryEngine
from repro.storage import load_frozen, snapshot_nbytes, write_snapshot


def build_kb():
    d = Dictionary()
    profs = d.intern_many([f"prof{i}" for i in range(4)])
    students = d.intern_many([f"student{i}" for i in range(12)])
    courses = d.intern_many([f"course{i}" for i in range(6)])
    depts = d.intern_many(["cs", "math"])

    rng = np.random.default_rng(7)
    dataset = {
        "teacherOf": np.stack(
            [profs[rng.integers(0, 4, 6)], courses], axis=1
        ),
        "takesCourse": np.stack(
            [np.repeat(students, 2), courses[rng.integers(0, 6, 24)]], axis=1
        ),
        "advisor": np.stack([students, profs[rng.integers(0, 4, 12)]], axis=1),
        "memberOf": np.stack([profs, depts[rng.integers(0, 2, 4)]], axis=1),
        "GraduateStudent": students[::2].reshape(-1, 1),
    }

    ontology = (
        OntologyBuilder()
        .sub_class_of("GraduateStudent", "Student")
        .sub_class_of("Student", "Person")
        .sub_class_of("Professor", "Person")
        .domain("teacherOf", "Professor")
        .range("teacherOf", "Course")
        .domain("advisor", "Student")
        .range("advisor", "Professor")
        .property_chain("advisor", "teacherOf", "advisedCourse")
        .sub_property_of("advisor", "knows")
    )
    return ontology.build(), dataset, d


def print_proof(node, indent="  "):
    mark = "✓" if node["verified"] else "?"
    via = f"  [R{node['rule_id']}: {node['rule']}]" if node.get(
        "rule_id"
    ) is not None and node["kind"] == "derived" else "  (explicit)"
    print(f"{indent}{mark} {node['fact']}{via}")
    for child in node["children"]:
        print_proof(child, indent + "  ")


def main():
    program, dataset, dictionary = build_kb()
    # provenance on: the journal records one compact record per rule
    # application, which explain_fact uses to find minimal proofs fast
    from repro.obs.provenance import get_journal

    journal = get_journal()
    journal.enabled = True
    journal.clear()
    eng = CMatEngine(program)
    eng.load(dataset)
    stats = eng.materialise()
    print(
        f"materialised: {stats.n_facts} facts in {stats.n_meta_facts} "
        f"meta-facts ({stats.rounds} rounds)\n"
    )

    qe = QueryEngine(eng, dictionary)
    queries = [
        # who teaches a course a grad student takes? (3-way join)
        '?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)',
        # derived-class lookup with a constant
        '?p <- Professor(?p), memberOf(?p, "cs")',
        # property-chain derived predicate
        '?s, ?c <- advisedCourse(?s, ?c), GraduateStudent(?s)',
    ]
    for text in queries:
        res = qe.answer(text)
        print(res.plan)
        print(f"  -> {res.n_answers} answers "
              f"(flat rows scanned: {sum(res.stats.rows_scanned.values())})")
        for row in qe.decode(res.answers)[:5]:
            print("     ", row)
        if res.n_answers > 5:
            print("      ...")
        print()

    # -- warm start: snapshot the store, restore, answer again -------- #
    with tempfile.TemporaryDirectory() as tmp:
        snap = f"{tmp}/snap"
        frozen = eng.facts.freeze()
        rows = {p: frozen.snapshot(p) for p in frozen.predicates()}
        manifest = write_snapshot(snap, eng.facts, kind="frozen", rows=rows)
        print(
            f"snapshot: {snapshot_nbytes(snap)} bytes on disk, "
            f"{manifest['store']['n_payloads']} leaf payloads for "
            f"{manifest['store']['n_leaves']} leaves "
            f"({manifest['store']['dedup_saved_bytes']}B shared by dedup)"
        )
        t0 = time.perf_counter()
        qe2 = QueryEngine(load_frozen(snap), dictionary)
        t_restore = time.perf_counter() - t0
        for text in queries:
            assert np.array_equal(
                qe2.answer(text).answers, qe.answer(text).answers
            )
        print(
            f"warm start: restored + re-answered all queries identically "
            f"in {t_restore * 1e3:.1f}ms (no fixpoint, no re-unfold)"
        )

    # -- provenance: why is a derived fact true? ---------------------- #
    # student0 is a Person only through GraduateStudent -> Student ->
    # Person: two taxonomic rule applications the proof tree makes
    # explicit, each step re-derived (never trusted) before ✓ is shown
    sid = dictionary.id_of("student0")
    node = eng.explain_fact("Person", (sid,), decode=dictionary.term_of)
    print("\nexplain Person(student0) — verified proof tree:")
    print_proof(node)

    print("\nhot rules (derivation cost attribution from the journal):")
    for h in journal.hot_rules(3):
        print(
            f"  R{h['rule_id']}: {h['derived']} derived, "
            f"{h['redundant']} redundant, {h['time_ns'] / 1e6:.2f}ms "
            f"over {h['rounds_active']} round(s) — {h['rule']}"
        )
    print(
        "\n(same machinery from the CLI: serve_datalog "
        "--explain 'Person(student0)' --explain-sample 3 --hot-rules)"
    )
    journal.enabled = False
    journal.clear()


if __name__ == "__main__":
    main()
