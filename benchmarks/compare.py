"""CI bench-regression gate: diff a ``--json`` bench run against the
committed baseline.

    PYTHONPATH=src python -m benchmarks.compare bench-results.json \
        [--baseline BENCH_BASELINE.json] [--tolerance 0.25] \
        [--min-seconds 1.0] [--json-out bench-diff.json] \
        [--update-baseline]

For every bench present in both files the gate compares

* **wall time** (``seconds``) — the hard gate: a regression beyond
  ``--tolerance`` (relative, default +25%) on any bench whose baseline
  took at least ``--min-seconds`` fails the run.  The floor keeps
  sub-second benches (pure jitter on shared CI runners) out of the gate
  while still reporting their drift.
* **counter metrics** — each bench's ``"metrics"`` registry snapshot
  (written by ``run.py``) is gated for the counters in
  :data:`METRIC_GATES` — ``rows_joined``, ``exchanges_skipped``,
  ``rule_applications_skipped``, plus the obs.memory byte gates
  ``peak_resident_bytes`` / ``compression_ratio`` — with per-metric
  relative tolerances (override with ``--metric-tolerance name=tol``;
  NAME may be a bare last segment, a full dotted name, or a glob such
  as ``mem.*=0.2``).  These counters are
  deterministic for a fixed seed, so movement in *either* direction
  beyond tolerance fails the gate: silently joining 2x more rows is a
  planner regression even when wall time hides it in CI jitter.
* **key metric rows** — rows are matched on their non-numeric cells
  (kb, mode, batch, ...) and every shared numeric metric is diffed.
  Row-metric drift is informational: it lands in the report and the
  JSON artifact so a reviewer sees *what* regressed.

Benches new in the results are reported as unbaselined (refresh with
``--update-baseline``); benches missing from the results fail the gate —
a silently dropped bench is how perf coverage rots.

``--update-baseline`` rewrites the baseline from the current results
(dropping per-run noise: only ``seconds``, ``status``, ``rows`` and
``metrics`` are kept); it refuses to refresh from a run with failed
benches.  Run it
and commit the file whenever a PR legitimately changes the performance
envelope.

**Baseline provenance.**  Wall times are machine-relative: a baseline
recorded on one host gates runs on another only up to their speed
difference.  If CI runners drift outside the tolerance with no code
change, download the ``bench-smoke-results`` artifact from a green CI
run and refresh the baseline from *that* file, so the committed numbers
are runner-measured rather than laptop-measured.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import sys

_NUM = (int, float)

#: gated registry counters (matched on the metric's last dotted
#: segment, so ``cmat.rule_applications_skipped`` and
#: ``dist.rule_applications_skipped`` both gate) -> relative tolerance.
#: These are deterministic work counters, not wall times: any change
#: beyond tolerance — more OR less — is an unexplained planner/engine
#: behaviour change and fails the gate.
METRIC_GATES: dict[str, float] = {
    "rows_joined": 0.10,
    "exchanges_skipped": 0.10,
    "rule_applications_skipped": 0.10,
    # eager Pallas dispatches (kernels.kernel_launches): a silent rise
    # means a fused path fell back to the per-step chain
    "kernel_launches": 0.10,
    # rounds served by the fused tail (flat.fused_rounds /
    # cmat.fused_rounds): dropping to zero means the fast path un-wired
    "fused_rounds": 0.10,
    # obs.memory gates: reporter-derived byte counts, deterministic for
    # a fixed seed (kernel RSS never enters the gated snapshots).  The
    # peak watermark catches a materialisation that silently starts
    # holding 2x the store; the per-predicate compression ratio catches
    # the mu-representation losing its edge over flat rows.
    "peak_resident_bytes": 0.10,
    "compression_ratio": 0.10,
    # provenance journal overhead verdict (prov.<kb>.overhead_ok): a
    # boolean gauge, 1.0 iff the measured journal overhead stayed under
    # bench_provenance.OVERHEAD_BUDGET — any flip to 0.0 fails the gate
    "overhead_ok": 0.10,
    # serving-tier invariant verdicts (serve.lubm.stale_ok /
    # serve.lubm.speedup_ok): 1.0 iff the load driver saw zero stale
    # reads / the concurrent closed loop out-ran the single client —
    # bench_serving raises on violation, so a 0.0 here means the gauge
    # itself un-wired
    "stale_ok": 0.10,
    "speedup_ok": 0.10,
}


def _gate_tolerance(name: str, gates: dict[str, float]) -> float | None:
    """Tolerance for a metric: exact dotted name first, then glob
    patterns (``mem.*``), then the bare last dotted segment."""
    tol = gates.get(name)
    if tol is not None:
        return tol
    for pat, t in gates.items():
        if any(ch in pat for ch in "*?[") and fnmatch.fnmatch(name, pat):
            return t
    return gates.get(name.rsplit(".", 1)[-1])


def _rows(bench: dict) -> list[dict]:
    rows = bench.get("rows")
    if rows is None:
        return []
    if isinstance(rows, dict):
        return [rows]
    return [r for r in rows if isinstance(r, dict)]


def _row_key(row: dict) -> tuple:
    """Rows are matched across runs by their non-numeric cells — the
    coordinates (kb, mode, batch is numeric but identifying...) — plus
    any cell named like an identifier."""
    key = []
    for k in sorted(row):
        v = row[k]
        if isinstance(v, bool) or not isinstance(v, _NUM):
            key.append((k, v))
        elif k in ("batch", "shards", "n", "scale", "size", "n_explicit"):
            # numeric coordinates, not metrics
            key.append((k, v))
    return tuple(key)


def _gated_metrics(new: dict, old: dict, gates: dict[str, float]):
    """Yield ``(name, tol, old_val, new_val)`` for every registry metric
    whose last dotted segment is gated, across both snapshots (a counter
    missing on either side reads as 0 — a metric that disappears is as
    suspicious as one that doubles)."""
    new_m = new.get("metrics") or {}
    old_m = old.get("metrics") or {}
    for name in sorted(set(new_m) | set(old_m)):
        tol = _gate_tolerance(name, gates)
        if tol is None:
            continue
        yield name, tol, float(old_m.get(name, 0)), float(new_m.get(name, 0))


def diff_results(results: dict, baseline: dict, *, tolerance: float,
                 min_seconds: float,
                 metric_gates: dict[str, float] | None = None) -> dict:
    """Structured diff + gate verdict (pure; the CLI prints it)."""
    if metric_gates is None:
        metric_gates = METRIC_GATES
    failures: list[str] = []
    notes: list[str] = []
    benches: dict[str, dict] = {}
    res_b = results.get("benches", {})
    base_b = baseline.get("benches", {})

    for name in sorted(set(res_b) | set(base_b)):
        new = res_b.get(name)
        old = base_b.get(name)
        entry: dict = {}
        if new is None:
            failures.append(
                f"{name}: present in baseline but missing from results "
                f"(bench dropped?)"
            )
            benches[name] = {"status": "missing"}
            continue
        if old is None:
            notes.append(
                f"{name}: no baseline entry (new bench — refresh with "
                f"--update-baseline)"
            )
            benches[name] = {"status": "unbaselined",
                             "seconds": new.get("seconds")}
            continue
        if new.get("status") != "ok":
            failures.append(f"{name}: bench failed ({new.get('error')})")
            benches[name] = {"status": "failed"}
            continue

        t_new = float(new.get("seconds", 0.0))
        t_old = float(old.get("seconds", 0.0))
        rel = (t_new - t_old) / t_old if t_old > 0 else 0.0
        entry = {
            "status": "ok",
            "seconds": t_new,
            "baseline_seconds": t_old,
            "rel_change": round(rel, 4),
            "gated": t_old >= min_seconds,
        }
        if t_old >= min_seconds and rel > tolerance:
            entry["status"] = "regressed"
            failures.append(
                f"{name}: wall time {t_old:.2f}s -> {t_new:.2f}s "
                f"(+{rel:.0%} > +{tolerance:.0%} tolerance)"
            )

        # gated work counters: deterministic, so drift in EITHER
        # direction beyond the per-metric tolerance fails the gate
        gate_entries: list[dict] = []
        for mname, tol, ov, nv in _gated_metrics(new, old, metric_gates):
            if ov > 0:
                mrel = (nv - ov) / ov
                bad = abs(mrel) > tol
            else:
                mrel = float("inf") if nv > 0 else 0.0
                bad = nv > 0
            gate_entries.append(
                {
                    "metric": mname,
                    "baseline": ov,
                    "current": nv,
                    "tolerance": tol,
                    "status": "regressed" if bad else "ok",
                }
            )
            if bad:
                entry["status"] = "regressed"
                failures.append(
                    f"{name}: counter {mname} {ov:g} -> {nv:g} "
                    f"({mrel:+.0%} beyond ±{tol:.0%} tolerance)"
                )
        if gate_entries:
            entry["metric_gates"] = gate_entries

        # informational metric drift over matched rows.  Rows match on
        # their non-numeric/coordinate cells plus an occurrence index,
        # so benches whose rows differ only in measured metrics still
        # pair up positionally instead of colliding on one key.
        old_rows: dict = {}
        for r in _rows(old):
            k = _row_key(r)
            old_rows[(k, sum(1 for kk in old_rows if kk[0] == k))] = r
        seen: dict = {}
        drifts: list[dict] = []
        for row in _rows(new):
            k = _row_key(row)
            occ = seen.get(k, 0)
            seen[k] = occ + 1
            prev = old_rows.get((k, occ))
            if prev is None:
                continue
            for k, v in row.items():
                pv = prev.get(k)
                if (
                    isinstance(v, _NUM) and not isinstance(v, bool)
                    and isinstance(pv, _NUM) and not isinstance(pv, bool)
                    and (k, v) not in _row_key(row)
                    and v != pv
                    and not (v != v and pv != pv)  # NaN == NaN here
                ):
                    drifts.append(
                        {
                            "row": dict(_row_key(row)),
                            "metric": k,
                            "baseline": pv,
                            "current": v,
                        }
                    )
        if drifts:
            entry["metric_drift"] = drifts
        benches[name] = entry

    return {
        "tolerance": tolerance,
        "min_seconds": min_seconds,
        "failures": failures,
        "notes": notes,
        "benches": benches,
        "ok": not failures,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("results", help="bench-results.json from benchmarks.run --json")
    ap.add_argument("--baseline", default="BENCH_BASELINE.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative wall-time regression that fails the "
                         "gate (default 0.25 = +25%%)")
    ap.add_argument("--min-seconds", type=float, default=1.0,
                    help="baseline wall-time floor below which a bench "
                         "is reported but never gates (CI jitter)")
    ap.add_argument("--metric-tolerance", action="append", default=[],
                    metavar="NAME=TOL",
                    help="override a gated counter's relative tolerance "
                         "(e.g. rows_joined=0.2); repeatable.  NAME is "
                         "the metric's last dotted segment, a full "
                         "dotted name, or a glob over full names "
                         "(e.g. 'mem.*=0.2')")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write the structured diff (CI uploads it)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from these results")
    args = ap.parse_args(argv)

    with open(args.results) as fh:
        results = json.load(fh)

    if args.update_baseline:
        not_ok = sorted(
            name
            for name, bench in results.get("benches", {}).items()
            if bench.get("status") != "ok"
        )
        if not_ok:
            # a bench silently dropped from the baseline would also
            # drop out of the missing-bench gate — refuse the refresh
            print(
                f"[compare] refusing to refresh baseline: bench(es) not "
                f"ok in the results: {', '.join(not_ok)}"
            )
            return 1
        slim = {
            "smoke": results.get("smoke", False),
            "failures": 0,
            "benches": {
                name: {
                    k: v for k, v in bench.items()
                    if k in ("status", "seconds", "rows", "metrics")
                }
                for name, bench in results.get("benches", {}).items()
            },
        }
        with open(args.baseline, "w") as fh:
            json.dump(slim, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"[compare] baseline refreshed: {args.baseline} "
              f"({len(slim['benches'])} benches)")
        return 0

    try:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
    except FileNotFoundError:
        print(f"[compare] no baseline at {args.baseline}; run with "
              f"--update-baseline to create one")
        return 1

    metric_gates = dict(METRIC_GATES)
    for spec in args.metric_tolerance:
        name, _, tol = spec.partition("=")
        try:
            metric_gates[name] = float(tol)
        except ValueError:
            ap.error(f"--metric-tolerance expects NAME=TOL, got {spec!r}")

    diff = diff_results(
        results, baseline,
        tolerance=args.tolerance, min_seconds=args.min_seconds,
        metric_gates=metric_gates,
    )
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(diff, fh, indent=2)
        print(f"[compare] diff written to {args.json_out}")

    for name, entry in diff["benches"].items():
        if entry.get("status") == "ok":
            mark = " " if entry.get("gated") else "~"
            print(
                f"[compare]{mark}{name}: {entry['baseline_seconds']:.2f}s "
                f"-> {entry['seconds']:.2f}s ({entry['rel_change']:+.0%})"
                + (f", {len(entry.get('metric_drift', []))} metric drifts"
                   if entry.get("metric_drift") else "")
            )
    for note in diff["notes"]:
        print(f"[compare] note: {note}")
    if diff["failures"]:
        print(f"[compare] FAILED ({len(diff['failures'])} regressions, "
              f"tolerance +{args.tolerance:.0%}):")
        for f in diff["failures"]:
            print(f"  - {f}")
        return 1
    print(f"[compare] OK: no bench regressed beyond +{args.tolerance:.0%} "
          f"(floor {args.min_seconds}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
