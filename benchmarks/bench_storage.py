"""Durable storage: cold start vs snapshot restore vs snapshot+WAL.

The serving questions the storage subsystem answers:

* **warm start** — how much faster is loading a snapshot (and replaying
  a short WAL tail) than re-materialising the fixpoint from the
  explicit facts?  ``restore_speedup`` is the acceptance criterion
  (≥5x on the lubm-like preset).
* **bounded memory under churn** — a delete/re-insert loop strands dead
  mu-nodes; the churn section reports the dead-node fraction and
  resident bytes before and after a compaction epoch, with a
  differential parity check that compaction changed neither the flat
  materialisation nor the maintained counts.

Snapshot bytes are also reported next to the flat-row bytes of the same
store, so the on-disk win of writing the *compressed* representation
(shared leaves deduplicated by content hash) stays visible.

Set ``BENCH_ARTIFACT_DIR`` to persist the final checkpoint directory
(CI uploads the manifest as a build artifact); by default everything
happens in a temp dir.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.generators import chain, lubm_like
from repro.incremental import IncrementalStore
from repro.storage import CheckpointManager, snapshot_nbytes


def _update_pool(dataset, seed: int):
    rng = np.random.default_rng(seed)
    pool = [
        (pred, tuple(int(v) for v in row))
        for pred, rows in dataset.items()
        for row in np.asarray(rows).reshape(len(rows), -1)
    ]
    rng.shuffle(pool)
    return pool


def _as_batch(items):
    out: dict[str, list] = {}
    for pred, row in items:
        out.setdefault(pred, []).append(row)
    return {p: np.asarray(r, dtype=np.int64) for p, r in out.items()}


def _assert_parity(a: dict, b: dict, context: str) -> None:
    if set(a) != set(b):
        raise AssertionError(f"{context}: predicate sets differ")
    for pred in a:
        if not np.array_equal(a[pred], b[pred]):
            raise AssertionError(f"{context}: rows differ for {pred!r}")


def _flat_nbytes(rows: dict[str, np.ndarray]) -> int:
    return sum(np.asarray(r).nbytes for r in rows.values())


def _bench_kb(
    name, program, dataset, root, *, wal_batches, churn_rounds, batch, rows_out
):
    ckpt_dir = os.path.join(root, f"ckpt-{name}")

    t0 = time.perf_counter()
    inc = IncrementalStore(program)
    inc.load(dataset)
    t_cold = time.perf_counter() - t0
    baseline = inc.to_dict()

    ckpt = CheckpointManager(ckpt_dir)
    t0 = time.perf_counter()
    ckpt.checkpoint(inc)
    t_snapshot = time.perf_counter() - t0
    snap_bytes = snapshot_nbytes(ckpt.latest())

    t0 = time.perf_counter()
    inc2, rec = ckpt.restore(program)
    t_restore = time.perf_counter() - t0
    _assert_parity(baseline, inc2.to_dict(), f"{name}: snapshot restore")

    # snapshot + WAL tail: log a few churn batches, recover through replay
    pool = _update_pool(dataset, seed=0)
    inc2.attach_wal(ckpt.wal)
    for i in range(wal_batches):
        b = _as_batch(pool[i * batch : (i + 1) * batch])
        inc2.apply(deletions=b)
        inc2.apply(additions=b)
    t0 = time.perf_counter()
    inc3, rec_wal = ckpt.restore(program)
    t_restore_wal = time.perf_counter() - t0
    _assert_parity(
        inc2.to_dict(), inc3.to_dict(), f"{name}: snapshot+WAL restore"
    )

    # churn loop -> dead nodes -> compaction epoch
    for i in range(churn_rounds):
        b = _as_batch(pool[(i * batch) % len(pool) :][:batch])
        inc3.apply(deletions=b)
        inc3.apply(additions=b)
    pre = inc3.to_dict()
    use_before = inc3.mu_usage()
    cs = inc3.compact()
    use_after = inc3.mu_usage()
    _assert_parity(pre, inc3.to_dict(), f"{name}: compaction")
    inc3.check_integrity()

    row = {
        "kb": name,
        "n_facts": int(sum(r.shape[0] for r in baseline.values())),
        "t_cold_ms": round(t_cold * 1e3, 2),
        "t_snapshot_ms": round(t_snapshot * 1e3, 2),
        "t_restore_ms": round(t_restore * 1e3, 2),
        "restore_speedup": round(t_cold / max(t_restore, 1e-9), 2),
        "t_restore_wal_ms": round(t_restore_wal * 1e3, 2),
        "wal_batches": int(rec_wal.wal_batches),
        "snapshot_kb": round(snap_bytes / 1024, 1),
        "flat_rows_kb": round(_flat_nbytes(baseline) / 1024, 1),
        "dead_frac_before": round(use_before.dead_fraction, 3),
        "dead_frac_after": round(use_after.dead_fraction, 3),
        "mu_kb_before": round(use_before.total_bytes / 1024, 1),
        "mu_kb_after": round(use_after.total_bytes / 1024, 1),
        "reshared_leaves": int(cs.reshared_leaves),
        "t_compact_ms": round(cs.time_s * 1e3, 2),
    }
    rows_out.append(row)
    print(
        "{kb},{n_facts},{t_cold_ms},{t_snapshot_ms},{t_restore_ms},"
        "{restore_speedup},{t_restore_wal_ms},{wal_batches},{snapshot_kb},"
        "{flat_rows_kb},{dead_frac_before},{dead_frac_after},"
        "{mu_kb_before},{mu_kb_after},{reshared_leaves},{t_compact_ms}"
        .format(**row)
    )
    return rows_out


def run(smoke: bool = False):
    """Cold vs restore vs restore+WAL, and churn -> compaction."""
    if smoke:
        kbs = [
            ("lubm", lubm_like(n_dept=4, n_students=60, n_courses=8, seed=0)),
            ("chain", chain(40)),
        ]
        wal_batches, churn_rounds, batch = 2, 6, 4
    else:
        kbs = [
            ("lubm", lubm_like(n_dept=8, n_students=200, n_courses=16, seed=0)),
            ("chain", chain(120)),
        ]
        wal_batches, churn_rounds, batch = 4, 24, 8

    artifact_dir = os.environ.get("BENCH_ARTIFACT_DIR")
    print(
        "kb,n_facts,t_cold_ms,t_snapshot_ms,t_restore_ms,restore_speedup,"
        "t_restore_wal_ms,wal_batches,snapshot_kb,flat_rows_kb,"
        "dead_frac_before,dead_frac_after,mu_kb_before,mu_kb_after,"
        "reshared_leaves,t_compact_ms"
    )
    rows: list[dict] = []
    with tempfile.TemporaryDirectory() as tmp:
        root = artifact_dir or tmp
        if artifact_dir:
            os.makedirs(artifact_dir, exist_ok=True)
        for name, (program, dataset, _dictionary) in kbs:
            _bench_kb(
                name, program, dataset, root,
                wal_batches=wal_batches, churn_rounds=churn_rounds,
                batch=batch, rows_out=rows,
            )

    lubm = [r for r in rows if r["kb"] == "lubm"]
    # smoke KBs are small enough that fixed snapshot overhead dominates;
    # the acceptance evidence (>=5x) is the full preset
    floor = 1.0 if smoke else 5.0
    ok_restore = all(r["restore_speedup"] > floor for r in lubm)
    ok_compact = all(r["mu_kb_after"] < r["mu_kb_before"] for r in rows)
    print(
        f"# snapshot restore beats cold materialisation on lubm "
        f"(> {floor}x): {'yes' if ok_restore else 'NO'} "
        f"(speedups {[r['restore_speedup'] for r in lubm]})"
    )
    print(
        f"# compaction reduced resident mu bytes on churn: "
        f"{'yes' if ok_compact else 'NO'} "
        f"({[(r['mu_kb_before'], r['mu_kb_after']) for r in rows]})"
    )
    return rows


if __name__ == "__main__":
    run()
