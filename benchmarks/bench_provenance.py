"""Provenance bench: what the derivation journal costs to keep on.

DESIGN.md §Provenance promises the journal is cheap enough to leave on
in serving builds — compact per-(rule, round) records, not per-fact
traces.  This bench measures that claim directly: the same CMat
materialisation runs with the journal off and on, interleaved (so
machine drift hits both modes equally), and the median wall times give
the journal's relative overhead.

The gateable result is the boolean gauge ``prov.<kb>.overhead_ok``
(1.0 iff the measured overhead is under :data:`OVERHEAD_BUDGET`, with a
small absolute floor so sub-20ms deltas on tiny smoke KBs never flap) —
:mod:`benchmarks.compare` holds it at ±10%, i.e. it must stay 1.0.  The
raw fraction is published ungated (``prov.<kb>.overhead_frac``) so the
artifact shows the trend before it breaches.
"""

from __future__ import annotations

import time

from repro.core import CMatEngine
from repro.core.generators import chain, lubm_like
from repro.obs import get_registry
from repro.obs.provenance import get_journal

#: relative journal overhead budget (DESIGN.md §Provenance)
OVERHEAD_BUDGET = 0.05
#: absolute wall-time floor: deltas under this never fail the gate
#: (timer jitter on a sub-second materialisation, not journal cost)
ABS_FLOOR_S = 0.02

WORKLOADS = [
    ("lubm-like", lambda: lubm_like(n_dept=10, n_students=400, n_courses=40)),
    ("chain-TC", lambda: chain(n=200)),
]

SMOKE_WORKLOADS = [
    ("lubm-like", lambda: lubm_like(n_dept=4, n_students=60, n_courses=10)),
    ("chain-TC", lambda: chain(n=60)),
]


def _materialise_once(program, dataset) -> float:
    t0 = time.perf_counter()
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    return time.perf_counter() - t0


def _median(xs: list[float]) -> float:
    xs = sorted(xs)
    n = len(xs)
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def measure_overhead(program, dataset, reps: int = 5) -> dict:
    """Interleaved off/on repeats -> median overhead of the journal."""
    journal = get_journal()
    was = journal.enabled
    off_s: list[float] = []
    on_s: list[float] = []
    records = journal_bytes = 0
    try:
        for _ in range(reps):
            journal.enabled = False
            off_s.append(_materialise_once(program, dataset))
            journal.enabled = True
            journal.clear()
            on_s.append(_materialise_once(program, dataset))
            rep = journal.memory_report()
            records = rep["n_records"]
            journal_bytes = rep["journal_bytes"]
    finally:
        journal.enabled = was
        journal.clear()
    med_off, med_on = _median(off_s), _median(on_s)
    delta = med_on - med_off
    frac = delta / med_off if med_off > 0 else 0.0
    ok = frac < OVERHEAD_BUDGET or delta < ABS_FLOOR_S
    return {
        "off_s": round(med_off, 4),
        "on_s": round(med_on, 4),
        "overhead_frac": round(frac, 4),
        "overhead_ok": bool(ok),
        "records": records,
        "journal_bytes": journal_bytes,
    }


def run(csv=True, smoke=False):
    reg = get_registry()
    rows = []
    for name, gen in (SMOKE_WORKLOADS if smoke else WORKLOADS):
        program, dataset, _ = gen()
        res = measure_overhead(program, dataset, reps=3 if smoke else 5)
        rows.append({"kb": name, **res})
        reg.gauge(f"prov.{name}.overhead_ok").set(1.0 if res["overhead_ok"] else 0.0)
        reg.gauge(f"prov.{name}.overhead_frac").set(max(res["overhead_frac"], 0.0))
    if csv:
        cols = ["kb", "off_s", "on_s", "overhead_frac", "overhead_ok",
                "records", "journal_bytes"]
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    run()
