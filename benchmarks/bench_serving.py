"""Closed/open-loop load driver for the MVCC serving tier.

Drives :class:`repro.serving.ServingTier` the way a deployment would
(DESIGN.md §Serving):

* **closed loop** — N client threads, each submitting its next query
  the moment the previous answer lands, while update batches flow
  through the tier's writer thread.  Rows at concurrency 1 and 8 make
  the micro-batch amortisation visible: the single-client row always
  executes batches of one, the concurrent row folds admission-queue
  contemporaries into shared-plan groups.
* **open loop** — one submitter thread with exponential (Poisson)
  inter-arrival gaps at a rate derived from the measured closed-loop
  capacity, so the p99 row reflects queueing delay under a target
  offered load instead of client back-pressure.

Every row discards warmup (snapshot/plan/cache build) before measuring
and reports throughput, p50/p99 latency, epoch lag, and the stale-read
count.  **Hard gates** (raise on violation, failing the bench):

* ``stale_reads == 0`` on every run — a served answer must never come
  from an epoch older than the one current at admission;
* closed-loop throughput at concurrency 8 strictly above concurrency 1
  on the lubm KB — the micro-batched admission path must amortise, not
  merely not-regress.

The registry's ``serve.*`` scope is reset at the end and replaced with
a small curated set of stable gauges (``serve.lubm.*``) for the CI
regression gate — raw batch/queue counters vary run to run with thread
scheduling and would flap any tolerance.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.core.generators import lubm_like
from repro.incremental import IncrementalStore
from repro.launch.serve_datalog import make_stream, make_update_batches
from repro.obs import get_registry
from repro.serving import ServingTier

WARMUP = 50


def _fresh_tier(program, dataset, dictionary):
    inc = IncrementalStore(program)
    inc.load(dataset)
    return ServingTier(inc, dictionary)


def _measure(tier, stream, batches, concurrency, update_at):
    """Warm up, then serve ``stream`` from ``concurrency`` closed-loop
    clients while the main thread feeds update batches to the writer.
    Returns (latencies_s, wall_s, stats)."""
    for text in dict.fromkeys(stream[: min(WARMUP, len(stream))]):
        tier.answer(text)
    tier.reset_counters()
    tier.start()

    lock = threading.Lock()
    latencies: list[float] = []
    served = [0]
    shards = [stream[i::concurrency] for i in range(concurrency)]

    def client(shard):
        local = []
        for text in shard:
            t0 = time.perf_counter()
            tier.answer(text)
            local.append(time.perf_counter() - t0)
            with lock:
                served[0] += 1
        with lock:
            latencies.extend(local)

    threads = [
        threading.Thread(target=client, args=(s,), daemon=True)
        for s in shards
        if s
    ]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    next_batch = 0
    while any(th.is_alive() for th in threads):
        if (
            next_batch < len(batches)
            and served[0] >= (next_batch + 1) * update_at
        ):
            deletions, additions = batches[next_batch]
            next_batch += 1
            tier.apply_sync(additions=additions, deletions=deletions)
        else:
            time.sleep(0.0005)
    for th in threads:
        th.join()
    return latencies, time.perf_counter() - t0, tier.stats()


def _closed_row(program, dataset, dictionary, stream, batches,
                concurrency, update_at):
    tier = _fresh_tier(program, dataset, dictionary)
    try:
        lat, wall, st = _measure(
            tier, stream, batches, concurrency, update_at
        )
    finally:
        tier.close()
    lat_ms = np.asarray(lat) * 1e3
    if st["stale_reads"]:
        raise AssertionError(
            f"closed loop c{concurrency}: {st['stale_reads']} stale reads"
        )
    return {
        "kb": "lubm",
        "mode": "closed",
        "concurrency": concurrency,
        "queries": len(lat),
        "qps": round(len(lat) / max(wall, 1e-9), 1),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 4),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 4),
        "mean_batch": round(st["mean_batch"], 2),
        "grouped": st["grouped_queries"],
        "dedup_hits": st["dedup_hits"],
        "cache_hits": st["cache_hits"],
        "applies": st["applies"],
        "epochs_published": st["epochs_published"],
        "epoch_lag_max": st["epoch_lag_max"],
        "stale_reads": st["stale_reads"],
    }


def _open_row(program, dataset, dictionary, stream, rate_qps,
              target_p99_ms, seed=0):
    """Open (Poisson) arrival at ``rate_qps``: a submitter thread injects
    requests on an exponential clock regardless of completions; waiter
    threads record completion latency per request."""
    import queue as _q

    tier = _fresh_tier(program, dataset, dictionary)
    try:
        for text in dict.fromkeys(stream[: min(WARMUP, len(stream))]):
            tier.answer(text)
        tier.reset_counters()
        tier.start()

        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / rate_qps, size=len(stream))
        pending: _q.Queue = _q.Queue()
        lock = threading.Lock()
        latencies: list[float] = []

        def waiter():
            while True:
                item = pending.get()
                if item is None:
                    return
                req, t0 = item
                req.wait(timeout=120.0)
                lat = time.perf_counter() - t0
                with lock:
                    latencies.append(lat)

        waiters = [
            threading.Thread(target=waiter, daemon=True) for _ in range(4)
        ]
        for th in waiters:
            th.start()
        t_start = time.perf_counter()
        for i, text in enumerate(stream):
            # absolute schedule, not sleep-per-gap: submit lateness must
            # not shift the offered load when a sleep overshoots
            due = t_start + float(np.sum(gaps[: i + 1]))
            delay = due - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            pending.put((tier.submit(text), time.perf_counter()))
        for _ in waiters:
            pending.put(None)
        for th in waiters:
            th.join()
        wall = time.perf_counter() - t_start
        st = tier.stats()
    finally:
        tier.close()
    lat_ms = np.asarray(latencies) * 1e3
    if st["stale_reads"]:
        raise AssertionError(f"open loop: {st['stale_reads']} stale reads")
    p99 = float(np.percentile(lat_ms, 99))
    return {
        "kb": "lubm",
        "mode": "open",
        "concurrency": 0,
        "queries": len(latencies),
        "offered_qps": round(rate_qps, 1),
        "qps": round(len(latencies) / max(wall, 1e-9), 1),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 4),
        "p99_ms": round(p99, 4),
        "target_p99_ms": target_p99_ms,
        "p99_met": bool(p99 <= target_p99_ms),
        "mean_batch": round(st["mean_batch"], 2),
        "stale_reads": st["stale_reads"],
    }


def run(smoke=False) -> list[dict]:
    if smoke:
        program, dataset, dictionary = lubm_like(
            n_dept=4, n_students=80, n_courses=10, seed=0
        )
        n_queries, update_at = 400, 120
    else:
        program, dataset, dictionary = lubm_like(
            n_dept=8, n_students=300, n_courses=20, seed=0
        )
        n_queries, update_at = 2000, 250
    stream = make_stream("lubm", 2, n_queries, 1.1, 0)
    batches = make_update_batches(
        dataset, n_queries // update_at + 1, 4, 0
    )

    print("kb,mode,concurrency,qps,p50_ms,p99_ms,mean_batch,"
          "epoch_lag_max,stale_reads")
    rows = []
    # two attempts damp scheduler noise on loaded CI runners: the gate
    # compares each concurrency level's best sustained throughput
    best = {1: None, 8: None}
    for _attempt in range(2):
        for conc in (1, 8):
            row = _closed_row(
                program, dataset, dictionary, stream, batches,
                conc, update_at,
            )
            if best[conc] is None or row["qps"] > best[conc]["qps"]:
                best[conc] = row
    for conc in (1, 8):
        row = best[conc]
        rows.append(row)
        print(
            f"{row['kb']},{row['mode']},{conc},{row['qps']},"
            f"{row['p50_ms']},{row['p99_ms']},{row['mean_batch']},"
            f"{row['epoch_lag_max']},{row['stale_reads']}"
        )

    # offered load at ~40% of measured closed-loop capacity: queueing
    # stays sub-saturation, so p99 reflects batch formation + service
    rate = max(200.0, 0.4 * best[8]["qps"])
    target_p99_ms = 50.0
    open_row = _open_row(
        program, dataset, dictionary, stream[: n_queries // 2],
        rate, target_p99_ms,
    )
    rows.append(open_row)
    print(
        f"{open_row['kb']},{open_row['mode']},-,{open_row['qps']},"
        f"{open_row['p50_ms']},{open_row['p99_ms']},"
        f"{open_row['mean_batch']},-,{open_row['stale_reads']}"
    )

    speedup = best[8]["qps"] / max(best[1]["qps"], 1e-9)
    print(f"closed-loop speedup c8/c1: {speedup:.2f}x")
    if best[8]["qps"] <= best[1]["qps"]:
        raise AssertionError(
            f"concurrency 8 must beat concurrency 1: "
            f"{best[8]['qps']} <= {best[1]['qps']} q/s"
        )

    # swap the run-to-run-noisy serve.* counters for curated, stable
    # gauges the CI regression gate can hold a tolerance against
    reg = get_registry()
    reg.reset("serve.")
    reg.gauge("serve.lubm.throughput_c1_qps").set(best[1]["qps"])
    reg.gauge("serve.lubm.throughput_c8_qps").set(best[8]["qps"])
    reg.gauge("serve.lubm.p99_c8_ms").set(best[8]["p99_ms"])
    reg.gauge("serve.lubm.speedup_c8_over_c1").set(speedup)
    # zero-invariant gates as 1.0-valued *_ok gauges (run.py drops
    # zero-valued metrics from the artifact)
    reg.gauge("serve.lubm.stale_ok").set(1.0)
    reg.gauge("serve.lubm.speedup_ok").set(1.0)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke)
