"""Benchmark harness: one module per paper table + system benchmarks.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke] \
        [--json PATH]

Prints one CSV block per benchmark.  ``--smoke`` runs tiny sizes for
benches that support it (CI keeps the drivers from rotting without
paying real benchmark time); benches without a ``smoke`` parameter run
at their normal size.  ``--json PATH`` writes a machine-readable result
file — per-bench status, wall time, and whatever structured rows the
bench returns — which CI uploads as a build artifact, and validates it
against the flat-rows-of-scalars schema (:func:`check_schema`) so
artifacts stay diffable across PRs.

The metrics registry (:mod:`repro.obs`) is **reset before every
bench**, so each suite sees only its own counters — the kernel meter
used to be module-global and cross-contaminated suites.  After each
bench the non-zero counters are snapshotted into the bench's
``"metrics"`` key (flat scalars, same contract as rows); the
regression gate (:mod:`benchmarks.compare`) checks selected counters
against the committed baseline with per-metric tolerances.
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import time

_SCALAR = (str, int, float, bool, type(None))


def check_schema(payload: dict) -> list[str]:
    """Violations of the bench-artifact contract.

    CI uploads ``--json`` output as a build artifact and diffs runs
    across PRs; that only works while every bench keeps emitting the
    same machine-comparable shape — flat rows of scalars.  Run with the
    check so a bench that starts returning nested objects (or a status
    typo) fails the build instead of silently breaking comparability.
    """
    errs: list[str] = []
    if set(payload) != {"smoke", "failures", "benches"}:
        errs.append(f"top-level keys {sorted(payload)}")
        return errs
    if not isinstance(payload["smoke"], bool):
        errs.append("'smoke' must be a bool")
    if not isinstance(payload["failures"], int):
        errs.append("'failures' must be an int")
    for name, bench in payload["benches"].items():
        if bench.get("status") not in ("ok", "failed"):
            errs.append(f"{name}: status {bench.get('status')!r}")
        if not isinstance(bench.get("seconds"), (int, float)):
            errs.append(f"{name}: 'seconds' missing or non-numeric")
        extra = set(bench) - {"status", "seconds", "rows", "error", "metrics"}
        if extra:
            errs.append(f"{name}: unexpected keys {sorted(extra)}")
        metrics = bench.get("metrics")
        if metrics is not None:
            if not isinstance(metrics, dict):
                errs.append(f"{name}: metrics must be a flat dict")
            else:
                bad = {
                    k: type(v).__name__
                    for k, v in metrics.items()
                    if not isinstance(k, str)
                    or isinstance(v, bool)
                    or not isinstance(v, (int, float))
                }
                if bad:
                    errs.append(f"{name}: non-numeric metrics {bad}")
        if bench.get("status") == "failed" and not isinstance(
            bench.get("error"), str
        ):
            errs.append(f"{name}: failed bench without an 'error' string")
        rows = bench.get("rows")
        if rows is None:
            continue
        if isinstance(rows, dict):
            rows = [rows]
        if not isinstance(rows, list):
            errs.append(f"{name}: rows must be a list or dict")
            continue
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                errs.append(f"{name}: rows[{i}] is not a dict")
                continue
            bad = {
                k: type(v).__name__
                for k, v in row.items()
                if not isinstance(k, str) or not isinstance(v, _SCALAR)
            }
            if bad:
                errs.append(f"{name}: rows[{i}] non-scalar cells {bad}")
    return errs


def write_history(payload: dict, history_dir: str,
                  now: float | None = None) -> str:
    """Append one timestamped ``BENCH_<UTC>.json`` artifact to
    ``history_dir`` (created if missing) and return its path.  CI
    uploads the directory, so green runs accumulate a dated series of
    bench results next to the latest ``bench-results.json``."""
    import datetime
    import os

    ts = datetime.datetime.fromtimestamp(
        time.time() if now is None else now, tz=datetime.timezone.utc
    )
    name = f"BENCH_{ts.strftime('%Y%m%dT%H%M%SZ')}.json"
    os.makedirs(history_dir, exist_ok=True)
    path = os.path.join(history_dir, name)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, default=str)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-bench results as JSON")
    ap.add_argument("--history", default=None, metavar="DIR",
                    help="also append a timestamped BENCH_<date>.json "
                         "copy of the results to this directory")
    args = ap.parse_args()

    from . import (
        bench_dedup,
        bench_distributed,
        bench_incremental,
        bench_kernels,
        bench_memory,
        bench_provenance,
        bench_query,
        bench_representation,
        bench_roofline,
        bench_runtime,
        bench_serving,
        bench_storage,
    )

    benches = {
        "representation": bench_representation.run,  # paper Table 1/3
        "runtime": bench_runtime.run,                # paper Table 2/4
        "dedup": bench_dedup.run,                    # beyond-paper ablation
        "kernels": bench_kernels.run,                # Pallas microbench
        "roofline": bench_roofline.run,              # deliverable (g)
        "query": bench_query.run,                    # compressed vs flat answering
        "incremental": bench_incremental.run,        # update vs rematerialise
        "storage": bench_storage.run,                # cold vs restore, compaction
        "distributed": bench_distributed.run,        # naive vs semi-naive shards
        "memory": bench_memory.run,                  # obs.memory accounting
        "provenance": bench_provenance.run,          # journal overhead gate
        "serving": bench_serving.run,                # MVCC tier load driver
    }
    from repro.obs import get_registry

    registry = get_registry()
    failures = 0
    results: dict[str, dict] = {}
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== bench:{name} ===", flush=True)
        # per-suite isolation: every bench starts from zeroed counters,
        # so its snapshot carries only its own work (the kernel meter
        # used to leak across suites)
        registry.reset()
        t0 = time.time()
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        try:
            rows = fn(**kwargs)
            dt = time.time() - t0
            print(f"=== bench:{name} done in {dt:.1f}s ===")
            results[name] = {"status": "ok", "seconds": round(dt, 2)}
            if isinstance(rows, (list, dict)):
                results[name]["rows"] = rows
            # best-effort memory roll-up: publish mem.* gauges from
            # whatever reporters the bench left alive (rss excluded —
            # kernel numbers are not comparable across runners)
            try:
                from repro.obs import sample_memory

                sample_memory(rss=False)
            except Exception:  # noqa: BLE001 — telemetry must not fail a bench
                pass
            metrics = {
                k: v for k, v in registry.snapshot().items() if v
            }
            if metrics:
                results[name]["metrics"] = metrics
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"=== bench:{name} FAILED: {type(e).__name__}: {e} ===")
            results[name] = {
                "status": "failed",
                "seconds": round(time.time() - t0, 2),
                "error": f"{type(e).__name__}: {e}",
            }
    if args.json or args.history:
        payload = {
            "smoke": bool(args.smoke),
            "failures": failures,
            "benches": results,
        }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"[json] wrote {args.json}")
        # round-trip through JSON so the check sees what a consumer sees
        schema_errs = check_schema(json.loads(json.dumps(payload, default=str)))
        if schema_errs:
            failures += 1
            print("[json] SCHEMA VIOLATIONS (bench artifacts must stay "
                  "machine-comparable across PRs):")
            for err in schema_errs:
                print(f"  - {err}")
    if args.history:
        path = write_history(payload, args.history)
        print(f"[json] history appended: {path}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
