"""Benchmark harness: one module per paper table + system benchmarks.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke] \
        [--json PATH]

Prints one CSV block per benchmark.  ``--smoke`` runs tiny sizes for
benches that support it (CI keeps the drivers from rotting without
paying real benchmark time); benches without a ``smoke`` parameter run
at their normal size.  ``--json PATH`` writes a machine-readable result
file — per-bench status, wall time, and whatever structured rows the
bench returns — which CI uploads as a build artifact.
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-bench results as JSON")
    args = ap.parse_args()

    from . import (
        bench_dedup,
        bench_incremental,
        bench_kernels,
        bench_query,
        bench_representation,
        bench_roofline,
        bench_runtime,
    )

    benches = {
        "representation": bench_representation.run,  # paper Table 1/3
        "runtime": bench_runtime.run,                # paper Table 2/4
        "dedup": bench_dedup.run,                    # beyond-paper ablation
        "kernels": bench_kernels.run,                # Pallas microbench
        "roofline": bench_roofline.run,              # deliverable (g)
        "query": bench_query.run,                    # compressed vs flat answering
        "incremental": bench_incremental.run,        # update vs rematerialise
    }
    failures = 0
    results: dict[str, dict] = {}
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== bench:{name} ===", flush=True)
        t0 = time.time()
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        try:
            rows = fn(**kwargs)
            dt = time.time() - t0
            print(f"=== bench:{name} done in {dt:.1f}s ===")
            results[name] = {"status": "ok", "seconds": round(dt, 2)}
            if isinstance(rows, (list, dict)):
                results[name]["rows"] = rows
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"=== bench:{name} FAILED: {type(e).__name__}: {e} ===")
            results[name] = {
                "status": "failed",
                "seconds": round(time.time() - t0, 2),
                "error": f"{type(e).__name__}: {e}",
            }
    if args.json:
        payload = {
            "smoke": bool(args.smoke),
            "failures": failures,
            "benches": results,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"[json] wrote {args.json}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
