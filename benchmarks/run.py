"""Benchmark harness: one module per paper table + system benchmarks.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]

Prints one CSV block per benchmark.  ``--smoke`` runs tiny sizes for
benches that support it (CI keeps the drivers from rotting without
paying real benchmark time); benches without a ``smoke`` parameter run
at their normal size.
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    from . import (
        bench_dedup,
        bench_kernels,
        bench_query,
        bench_representation,
        bench_roofline,
        bench_runtime,
    )

    benches = {
        "representation": bench_representation.run,  # paper Table 1/3
        "runtime": bench_runtime.run,                # paper Table 2/4
        "dedup": bench_dedup.run,                    # beyond-paper ablation
        "kernels": bench_kernels.run,                # Pallas microbench
        "roofline": bench_roofline.run,              # deliverable (g)
        "query": bench_query.run,                    # compressed vs flat answering
    }
    failures = 0
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== bench:{name} ===", flush=True)
        t0 = time.time()
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        try:
            fn(**kwargs)
            print(f"=== bench:{name} done in {time.time()-t0:.1f}s ===")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"=== bench:{name} FAILED: {type(e).__name__}: {e} ===")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
