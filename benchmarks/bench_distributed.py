"""Distributed semi-naive vs naive rounds, and update-vs-rematerialise
under sharding.

Two questions the delta exchange answers:

* **materialisation** — how much join work and exchange traffic does the
  delta restriction (+ planner-chosen exchange keys) save over the naive
  rounds the engine used to run?  Reported per KB preset as rows joined,
  all_to_all calls issued/elided, rounds, and wall time, naive vs
  semi-naive side by side.
* **maintenance** — once the store is sharded, is shipping
  overdelete/rederive/insert deltas through the exchange cheaper than
  re-materialising the updated EDB from scratch?  Reported as the
  crossover curve over growing batch sizes (the sharded twin of
  ``bench_incremental``).

Wall times are measured with warm traced-round caches (one untimed
warmup materialise/apply per engine), so the numbers compare fixpoint
work, not XLA compilation.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.generators import chain, lubm_like


def _mesh():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()), ("data",))


def _update_pool(dataset, seed: int):
    rng = np.random.default_rng(seed)
    pool = [
        (pred, tuple(int(v) for v in row))
        for pred, rows in dataset.items()
        for row in np.asarray(rows).reshape(len(rows), -1)
    ]
    rng.shuffle(pool)
    return pool


def _as_batch(items):
    out: dict[str, list] = {}
    for pred, row in items:
        out.setdefault(pred, []).append(row)
    return {p: np.asarray(r, dtype=np.int64) for p, r in out.items()}


def _bench_materialise(name, program, dataset, mesh, capacity, rows_out):
    from repro.core.distributed import DistributedEngine

    stats_by_mode = {}
    for mode in ("naive", "seminaive"):
        eng = DistributedEngine(
            program, mesh, capacity=capacity,
            seminaive=(mode == "seminaive"),
            planner_exchange_keys=(mode == "seminaive"),
        )
        eng.materialise(dataset)  # warm the traced-round cache
        t0 = time.perf_counter()
        eng.materialise(dataset)
        dt = time.perf_counter() - t0
        st = eng.stats
        stats_by_mode[mode] = st
        row = {
            "bench": "materialise",
            "kb": name,
            "mode": mode,
            "shards": int(mesh.shape["data"]),
            "rounds": st.rounds,
            "wall_ms": round(dt * 1e3, 2),
            "rule_applications": st.n_rule_applications,
            "skipped": st.rule_applications_skipped,
            "rows_joined": st.rows_joined,
            "exchanges": st.exchanges,
            "exchanges_elided": st.exchanges_skipped,
            "regrows": st.exchange_regrows,
        }
        rows_out.append(row)
        print(
            "{bench},{kb},{mode},{shards},{rounds},{wall_ms},"
            "{rule_applications},{skipped},{rows_joined},{exchanges},"
            "{exchanges_elided},{regrows}".format(**row)
        )
    return stats_by_mode


def _bench_update(name, program, dataset, mesh, capacity, batch_sizes, rows_out):
    from repro.core.distributed import DistributedEngine

    live = DistributedEngine(program, mesh, capacity=capacity)
    live.materialise(dataset)
    remat = DistributedEngine(program, mesh, capacity=capacity)
    remat.materialise(dataset)

    pool = _update_pool(dataset, seed=0)
    # warm the apply-phase traces off the measured path
    warm = _as_batch(pool[:1])
    live.apply(deletions=warm)
    live.apply(additions=warm)

    for k in batch_sizes:
        batch = _as_batch(pool[: min(k, len(pool))])
        t0 = time.perf_counter()
        st = live.apply(deletions=batch)
        t_del = time.perf_counter() - t0

        t0 = time.perf_counter()
        remat.materialise(live.explicit)
        t_remat = time.perf_counter() - t0

        t0 = time.perf_counter()
        live.apply(additions=batch)  # restore for the next batch size
        t_add = time.perf_counter() - t0

        row = {
            "bench": "update",
            "kb": name,
            "shards": int(mesh.shape["data"]),
            "batch": int(min(k, len(pool))),
            "t_apply_del_ms": round(t_del * 1e3, 2),
            "t_apply_add_ms": round(t_add * 1e3, 2),
            "t_remat_ms": round(t_remat * 1e3, 2),
            "speedup_del": round(t_remat / max(t_del, 1e-9), 2),
            "overdeleted": st.n_overdeleted,
            "rederived": st.n_rederived,
            "deleted": st.n_deleted,
        }
        rows_out.append(row)
        print(
            "{bench},{kb},{shards},{batch},{t_apply_del_ms},"
            "{t_apply_add_ms},{t_remat_ms},{speedup_del},{overdeleted},"
            "{rederived},{deleted}".format(**row)
        )


def run(smoke: bool = False):
    """Naive vs semi-naive sharded rounds + update-vs-rematerialise."""
    mesh = _mesh()
    if smoke:
        kbs = [
            ("lubm", lubm_like(n_dept=3, n_students=40, n_courses=6, seed=0),
             1 << 12),
            ("chain", chain(20), 1 << 11),
        ]
        batch_sizes = [1, 2]
    else:
        kbs = [
            ("lubm", lubm_like(n_dept=4, n_students=100, n_courses=8, seed=0),
             1 << 13),
            ("chain", chain(60), 1 << 13),
        ]
        batch_sizes = [1, 4, 16]

    print(
        "bench,kb,mode/shards,...  (materialise: rounds,wall_ms,apps,"
        "skipped,rows_joined,exchanges,elided,regrows; update: batch,"
        "del_ms,add_ms,remat_ms,speedup,over,rederived,deleted)"
    )
    rows: list[dict] = []
    evidence = {}
    from repro.core.distributed import DistributedEngine

    for name, (program, dataset, _dictionary), capacity in kbs:
        program = DistributedEngine.supported_program(program)
        evidence[name] = _bench_materialise(
            name, program, dataset, mesh, capacity, rows
        )
        _bench_update(
            name, program, dataset, mesh, capacity, batch_sizes, rows
        )

    # acceptance evidence: the delta restriction strictly shrinks the
    # join work, and the lubm preset skips (rule, pivot) probes
    fewer = all(
        st["seminaive"].rows_joined < st["naive"].rows_joined
        for st in evidence.values()
    )
    skips = evidence["lubm"]["seminaive"].rule_applications_skipped
    print(
        f"# semi-naive joins strictly fewer rows than naive: "
        f"{'yes' if fewer else 'NO'} "
        f"({ {k: (st['seminaive'].rows_joined, st['naive'].rows_joined) for k, st in evidence.items()} })"
    )
    print(f"# lubm rule applications skipped without a probe: {skips}")
    return rows


if __name__ == "__main__":
    run()
