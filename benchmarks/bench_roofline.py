"""Roofline table: three terms per (arch x shape) from the dry-run
artifacts (run ``python -m repro.launch.dryrun`` first).

When no artifacts exist the bench no longer silently returns an empty
row list (which read as "ran, measured nothing" in the JSON artifact):
it emits one explicit ``skipped`` row naming the missing input, so CI
diffs distinguish "not run" from "regressed to zero rows".
"""

from __future__ import annotations

import dataclasses
import os

from repro.roofline.analysis import format_table, full_table


def _skip_row(reason: str) -> list[dict]:
    print(f"(skipped: {reason})")
    return [{"skipped": True, "reason": reason}]


def run(csv=True, directory="experiments/dryrun"):
    if not os.path.isdir(directory):
        return _skip_row(
            f"no dry-run artifacts in {directory}; run "
            f"`python -m repro.launch.dryrun` first"
        )
    rows = full_table(directory, mesh="single")
    if not rows:
        return _skip_row(f"no OK single-mesh records in {directory}")
    if csv:
        print(format_table(rows))
    # flatten dataclasses to scalar dicts (the bench-artifact contract)
    return [dataclasses.asdict(r) for r in rows]


if __name__ == "__main__":
    run()
