"""Roofline table: three terms per (arch x shape) from the dry-run
artifacts (run ``python -m repro.launch.dryrun`` first)."""

from __future__ import annotations

import os

from repro.roofline.analysis import format_table, full_table


def run(csv=True, directory="experiments/dryrun"):
    if not os.path.isdir(directory):
        print(f"(no dry-run artifacts in {directory}; run "
              f"`python -m repro.launch.dryrun` first)")
        return []
    rows = full_table(directory, mesh="single")
    if not rows:
        print("(no OK single-mesh records yet)")
        return []
    if csv:
        print(format_table(rows))
    return rows


if __name__ == "__main__":
    run()
