"""Paper Table 2/4 analog: load + materialisation wall-clock, CompMat vs
the flat (RDFox/VLog-style) engine, with the per-phase breakdown that
supports the paper's 'dedup dominates' observation."""

from __future__ import annotations

import time

from repro.core import CMatEngine, FlatEngine
from repro.core.generators import bipartite, chain, lubm_like, paper_example, star

WORKLOADS = [
    ("paper-example", lambda: paper_example(n=300, m=200)),
    ("lubm-like", lambda: lubm_like(n_dept=25, n_students=1000, n_courses=100)),
    ("chain-TC", lambda: chain(n=250)),
    ("star", lambda: star(n_spokes=3000, n_hubs=4)),
    ("bipartite", lambda: bipartite(n_left=200, n_right=200)),
]


def run_one(name, gen):
    program, dataset, _ = gen()

    t0 = time.perf_counter()
    cmat = CMatEngine(program)
    cmat.load(dataset)
    t_load_c = time.perf_counter() - t0
    cmat.materialise()
    rep = cmat.report()

    # beyond-paper: persistent sorted dedup index (speed/memory tradeoff)
    t0 = time.perf_counter()
    cmat_idx = CMatEngine(program, dedup_index=True)
    cmat_idx.load(dataset)
    cmat_idx.materialise()
    t_index = time.perf_counter() - t0

    t0 = time.perf_counter()
    flat = FlatEngine(program)
    flat.load(dataset)
    t_load_f = time.perf_counter() - t0
    flat.materialise()

    n_c = rep["n_facts_materialised"]
    n_f = sum(v.shape[0] for v in flat.facts.values())
    assert n_c == n_f, f"{name}: fact count mismatch {n_c} != {n_f}"
    return {
        "workload": name,
        "cmat_tl": round(t_load_c, 3),
        "cmat_tm": round(rep["time_total"], 3),
        "cmat_total": round(t_load_c + rep["time_total"], 3),
        "cmat_indexed_total": round(t_index, 3),
        "flat_tl": round(t_load_f, 3),
        "flat_tm": round(flat.time_total, 3),
        "flat_total": round(t_load_f + flat.time_total, 3),
        "cmat_dedup_frac": round(
            rep["time_dedup"] / max(rep["time_total"], 1e-9), 2
        ),
        "cmat_dominant_phase": rep["dominant_phase"],
        "n_facts": n_c,
    }


def run(csv=True):
    rows = [run_one(name, gen) for name, gen in WORKLOADS]
    if csv:
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    run()
