"""Paper Table 2/4 analog: load + materialisation wall-clock, CompMat vs
the flat (RDFox/VLog-style) engine, with the per-phase breakdown that
supports the paper's 'dedup dominates' observation.

Since the one-body-compiler refactor the CompMat engine is measured in
two configurations, printed side by side:

* ``cmat_lr`` — strict left-to-right body order, no stratification (the
  pre-refactor evaluation, kept as the reference mode),
* ``cmat`` — delta-anchored selectivity-ordered plans + SCC-stratified
  fixpoint, with ``apps``/``skipped`` counting how many (rule, pivot)
  evaluations the delta prefilter avoided without a match probe.
"""

from __future__ import annotations

import time

from repro.core import CMatEngine, FlatEngine
from repro.core.generators import bipartite, chain, lubm_like, paper_example, star

WORKLOADS = [
    ("paper-example", lambda: paper_example(n=300, m=200)),
    ("lubm-like", lambda: lubm_like(n_dept=25, n_students=1000, n_courses=100)),
    ("chain-TC", lambda: chain(n=250)),
    ("star", lambda: star(n_spokes=3000, n_hubs=4)),
    ("bipartite", lambda: bipartite(n_left=200, n_right=200)),
]

SMOKE_WORKLOADS = [
    ("paper-example", lambda: paper_example(n=20, m=10)),
    ("lubm-like", lambda: lubm_like(n_dept=4, n_students=60, n_courses=10)),
    ("chain-TC", lambda: chain(n=30)),
]


def _run_cmat(program, dataset, **kwargs):
    t0 = time.perf_counter()
    eng = CMatEngine(program, **kwargs)
    eng.load(dataset)
    eng.materialise()
    return eng, time.perf_counter() - t0


def _run_flat(program, dataset, fused):
    t0 = time.perf_counter()
    eng = FlatEngine(program, fused=fused)
    eng.load(dataset)
    eng.materialise()
    return eng, time.perf_counter() - t0


def run_one(name, gen):
    program, dataset, _ = gen()

    # planned + stratified (the default engine)
    cmat, t_cmat = _run_cmat(program, dataset)
    rep = cmat.report()

    # left-to-right, unstratified reference (pre-refactor behaviour)
    cmat_lr, t_lr = _run_cmat(
        program, dataset, plan_bodies=False, stratify_program=False
    )

    # beyond-paper: persistent sorted dedup index (speed/memory tradeoff)
    _, t_index = _run_cmat(program, dataset, dedup_index=True)

    # fused fast path (PR 7): flat-tail xjoin emission + packed-code
    # dedup against the persistent FactBuffers index
    cmat_fused, t_cmat_fused = _run_cmat(program, dataset, fused=True)

    # flat engine, per-step (legacy round tail) vs fused round tail —
    # the per-step run is the differential oracle for both fused paths
    flat, t_flat = _run_flat(program, dataset, fused=False)
    flat_fused, t_flat_fused = _run_flat(program, dataset, fused=True)

    n_c = rep["n_facts_materialised"]
    n_lr = sum(v.shape[0] for v in cmat_lr.materialisation().values())
    n_f = sum(v.shape[0] for v in flat.facts.values())
    assert n_c == n_f, f"{name}: fact count mismatch {n_c} != {n_f}"
    assert n_c == n_lr, f"{name}: planned vs left-to-right mismatch {n_c} != {n_lr}"
    # fused paths must be answer-identical, not just count-identical
    for pred, rows in flat.facts.items():
        fr = flat_fused.facts[pred]
        assert rows.shape == fr.shape and (rows == fr).all(), (
            f"{name}/{pred}: fused flat rows differ from per-step"
        )
    cf_mat = cmat_fused.materialisation()
    n_cf = sum(v.shape[0] for v in cf_mat.values())
    assert n_c == n_cf, f"{name}: cmat fused mismatch {n_c} != {n_cf}"
    return {
        "workload": name,
        "cmat_total": round(t_cmat, 3),
        "cmat_lr_total": round(t_lr, 3),
        "cmat_indexed_total": round(t_index, 3),
        "cmat_fused_total": round(t_cmat_fused, 3),
        "cmat_fused_speedup": round(t_cmat / max(t_cmat_fused, 1e-9), 2),
        "flat_total": round(t_flat, 3),
        "flat_fused_total": round(t_flat_fused, 3),
        "flat_fused_speedup": round(t_flat / max(t_flat_fused, 1e-9), 2),
        "strata": rep["n_strata"],
        "apps": rep["rule_applications"],
        "apps_lr": cmat_lr.stats.n_rule_applications,
        "rule_applications_skipped": rep["rule_applications_skipped"],
        "plan_replans": rep["plan_cache"]["plan_replans"],
        "cmat_dedup_frac": round(
            rep["time_dedup"] / max(rep["time_total"], 1e-9), 2
        ),
        "cmat_dominant_phase": rep["dominant_phase"],
        "n_facts": n_c,
    }


def run(csv=True, smoke=False):
    workloads = SMOKE_WORKLOADS if smoke else WORKLOADS
    rows = [run_one(name, gen) for name, gen in workloads]
    if csv:
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    run()
