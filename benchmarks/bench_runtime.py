"""Paper Table 2/4 analog: load + materialisation wall-clock, CompMat vs
the flat (RDFox/VLog-style) engine, with the per-phase breakdown that
supports the paper's 'dedup dominates' observation.

Since the one-body-compiler refactor the CompMat engine is measured in
two configurations, printed side by side:

* ``cmat_lr`` — strict left-to-right body order, no stratification (the
  pre-refactor evaluation, kept as the reference mode),
* ``cmat`` — delta-anchored selectivity-ordered plans + SCC-stratified
  fixpoint, with ``apps``/``skipped`` counting how many (rule, pivot)
  evaluations the delta prefilter avoided without a match probe.
"""

from __future__ import annotations

import time

from repro.core import CMatEngine, FlatEngine
from repro.core.generators import bipartite, chain, lubm_like, paper_example, star

WORKLOADS = [
    ("paper-example", lambda: paper_example(n=300, m=200)),
    ("lubm-like", lambda: lubm_like(n_dept=25, n_students=1000, n_courses=100)),
    ("chain-TC", lambda: chain(n=250)),
    ("star", lambda: star(n_spokes=3000, n_hubs=4)),
    ("bipartite", lambda: bipartite(n_left=200, n_right=200)),
]

SMOKE_WORKLOADS = [
    ("paper-example", lambda: paper_example(n=20, m=10)),
    ("lubm-like", lambda: lubm_like(n_dept=4, n_students=60, n_courses=10)),
    ("chain-TC", lambda: chain(n=30)),
]


def _run_cmat(program, dataset, **kwargs):
    t0 = time.perf_counter()
    eng = CMatEngine(program, **kwargs)
    eng.load(dataset)
    eng.materialise()
    return eng, time.perf_counter() - t0


def run_one(name, gen):
    program, dataset, _ = gen()

    # planned + stratified (the default engine)
    cmat, t_cmat = _run_cmat(program, dataset)
    rep = cmat.report()

    # left-to-right, unstratified reference (pre-refactor behaviour)
    cmat_lr, t_lr = _run_cmat(
        program, dataset, plan_bodies=False, stratify_program=False
    )

    # beyond-paper: persistent sorted dedup index (speed/memory tradeoff)
    _, t_index = _run_cmat(program, dataset, dedup_index=True)

    t0 = time.perf_counter()
    flat = FlatEngine(program)
    flat.load(dataset)
    t_load_f = time.perf_counter() - t0
    flat.materialise()

    n_c = rep["n_facts_materialised"]
    n_lr = sum(v.shape[0] for v in cmat_lr.materialisation().values())
    n_f = sum(v.shape[0] for v in flat.facts.values())
    assert n_c == n_f, f"{name}: fact count mismatch {n_c} != {n_f}"
    assert n_c == n_lr, f"{name}: planned vs left-to-right mismatch {n_c} != {n_lr}"
    return {
        "workload": name,
        "cmat_total": round(t_cmat, 3),
        "cmat_lr_total": round(t_lr, 3),
        "cmat_indexed_total": round(t_index, 3),
        "flat_total": round(t_load_f + flat.time_total, 3),
        "strata": rep["n_strata"],
        "apps": rep["rule_applications"],
        "apps_lr": cmat_lr.stats.n_rule_applications,
        "rule_applications_skipped": rep["rule_applications_skipped"],
        "plan_replans": rep["plan_cache"]["plan_replans"],
        "cmat_dedup_frac": round(
            rep["time_dedup"] / max(rep["time_total"], 1e-9), 2
        ),
        "cmat_dominant_phase": rep["dominant_phase"],
        "n_facts": n_c,
    }


def run(csv=True, smoke=False):
    workloads = SMOKE_WORKLOADS if smoke else WORKLOADS
    rows = [run_one(name, gen) for name, gen in workloads]
    if csv:
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    run()
