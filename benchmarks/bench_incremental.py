"""Incremental maintenance vs from-scratch rematerialisation.

The serving question the incremental subsystem answers: *given an update
batch of size k, is it cheaper to maintain the materialisation in place
or to rebuild it?*  For each KB preset and batch size this bench times

* ``t_apply_del`` — ``IncrementalStore.apply(deletions=batch)``
  (DRed/counting maintenance over meta-facts),
* ``t_apply_add`` — re-inserting the same batch (restores the KB, so
  every batch size starts from the same state),
* ``t_scratch`` — ``CMatEngine`` load + materialise on the post-delete
  explicit set (what a non-incremental server would do per update),

and prints the crossover evidence: small batches should beat
rematerialisation outright (the acceptance criterion for the lubm-like
preset), with the advantage shrinking as the batch grows — transitive
closure loses earliest because deleting one chain edge genuinely kills
O(n^2) paths.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import CMatEngine
from repro.core.generators import chain, lubm_like

from repro.incremental import IncrementalStore


def _update_pool(dataset, seed: int):
    rng = np.random.default_rng(seed)
    pool = [
        (pred, tuple(int(v) for v in row))
        for pred, rows in dataset.items()
        for row in np.asarray(rows).reshape(len(rows), -1)
    ]
    rng.shuffle(pool)
    return pool


def _as_batch(items):
    out: dict[str, list] = {}
    for pred, row in items:
        out.setdefault(pred, []).append(row)
    return {p: np.asarray(r, dtype=np.int64) for p, r in out.items()}


def _bench_kb(name, program, dataset, batch_sizes, rows_out):
    inc = IncrementalStore(program)
    t0 = time.perf_counter()
    inc.load(dataset)
    t_build = time.perf_counter() - t0
    pool = _update_pool(dataset, seed=0)
    n_explicit = len(pool)

    for k in batch_sizes:
        batch = _as_batch(pool[: min(k, n_explicit)])
        t0 = time.perf_counter()
        st_del = inc.apply(deletions=batch)
        t_del = time.perf_counter() - t0

        t0 = time.perf_counter()
        eng = CMatEngine(program)
        eng.load(inc.explicit)
        eng.materialise()
        t_scratch = time.perf_counter() - t0

        t0 = time.perf_counter()
        inc.apply(additions=batch)  # restore for the next batch size
        t_add = time.perf_counter() - t0

        row = {
            "kb": name,
            "n_explicit": n_explicit,
            "batch": int(min(k, n_explicit)),
            "t_build_ms": round(t_build * 1e3, 2),
            "t_apply_del_ms": round(t_del * 1e3, 2),
            "t_apply_add_ms": round(t_add * 1e3, 2),
            "t_scratch_ms": round(t_scratch * 1e3, 2),
            "speedup_del": round(t_scratch / max(t_del, 1e-9), 2),
            "overdeleted": st_del.n_overdeleted,
            "rederived": st_del.n_rederived,
            "deleted": st_del.n_deleted,
            "counting_strata": st_del.counting_strata,
            "dred_strata": st_del.dred_strata,
        }
        rows_out.append(row)
        print(
            "{kb},{n_explicit},{batch},{t_apply_del_ms},{t_apply_add_ms},"
            "{t_scratch_ms},{speedup_del},{overdeleted},{rederived},"
            "{deleted},{counting_strata},{dred_strata}".format(**row)
        )
    return rows_out


def run(smoke: bool = False):
    """Update-vs-rematerialise crossover on lubm-like and chain-TC."""
    if smoke:
        kbs = [
            ("lubm", lubm_like(n_dept=4, n_students=60, n_courses=8, seed=0)),
            ("chain", chain(40)),
        ]
        batch_sizes = [1, 4]
    else:
        kbs = [
            ("lubm", lubm_like(n_dept=8, n_students=200, n_courses=16, seed=0)),
            ("chain", chain(120)),
        ]
        batch_sizes = [1, 4, 16, 64, 256]

    print(
        "kb,n_explicit,batch,t_apply_del_ms,t_apply_add_ms,t_scratch_ms,"
        "speedup_del,overdeleted,rederived,deleted,counting_strata,dred_strata"
    )
    rows: list[dict] = []
    for name, (program, dataset, _dictionary) in kbs:
        _bench_kb(name, program, dataset, batch_sizes, rows)

    # smoke sizes shrink the KB until fixed per-apply overhead rivals a
    # full rebuild; the acceptance evidence is the full preset, so the
    # smoke check only pins the batch=1 win
    max_batch = 1 if smoke else 4
    lubm_small = [
        r for r in rows if r["kb"] == "lubm" and r["batch"] <= max_batch
    ]
    beats = all(r["speedup_del"] > 1.0 for r in lubm_small)
    print(
        f"# small-delete maintenance beats rematerialisation on lubm: "
        f"{'yes' if beats else 'NO'} "
        f"(speedups {[r['speedup_del'] for r in lubm_small]})"
    )
    return rows


if __name__ == "__main__":
    run()
