"""Query answering over the compressed store vs the unfolded flat store.

For each KB x query: answers + latency from

* ``compressed``: :class:`repro.query.QueryEngine` on the frozen
  ``<M, mu>`` store (result cache disabled — every run evaluates),
* ``flat``: :func:`repro.query.answer_flat` joining the fully unfolded
  materialisation arrays.

Asserts byte-for-byte equal answers, and prints the compressed-answering
evidence per query: ``scan_frac`` (max fraction of any predicate's rows
materialised whole by indexed scans), ``join_frac`` (max fraction of any
predicate's cells fed flat into joins — key columns for semi-joins,
every column for cross-joins), and ``full_unfolds``, the predicates
larger than the answer set that were fully materialised either way.
The selective multi-join queries answer with ``full_unfolds`` empty —
the store never pays the decompression the flat baseline starts from.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import CMatEngine
from repro.core.generators import chain, lubm_like, paper_example
from repro.query import QueryEngine, answer_flat, parse_query

REPEATS = 5


def _bench_kb(kb_name: str, program, dataset, dictionary, query_texts):
    eng = CMatEngine(program, dedup_index=True)
    eng.load(dataset)
    eng.materialise()
    flat = eng.materialisation()
    qe = QueryEngine(eng, dictionary, result_cache_size=0)

    print(
        "kb,query,n_answers,t_compressed_ms,t_flat_ms,"
        "scan_frac,join_frac,full_unfolds"
    )
    for text in query_texts:
        query = parse_query(text, dictionary)
        # warmup builds snapshots + plan off the measured path
        res = qe.answer(query)

        t0 = time.perf_counter()
        for _ in range(REPEATS):
            res = qe.answer(query)
        t_comp = (time.perf_counter() - t0) / REPEATS

        t0 = time.perf_counter()
        for _ in range(REPEATS):
            ref = answer_flat(query, flat)
        t_flat = (time.perf_counter() - t0) / REPEATS

        if not np.array_equal(res.answers, ref):
            raise AssertionError(f"answer mismatch for {text!r}")

        scan_fracs = res.stats.unfold_fractions()
        join_fracs = res.stats.join_cell_fractions()
        scan_frac = max(scan_fracs.values()) if scan_fracs else 0.0
        join_frac = max(join_fracs.values()) if join_fracs else 0.0
        # predicates larger than the answer set that were fully
        # materialised flat — the acceptance evidence is this staying
        # empty for the selective multi-join queries
        offenders = [
            p
            for p in res.stats.fully_unfolded()
            if res.stats.pred_rows[p] > res.n_answers
        ]
        print(
            f"{kb_name},\"{text}\",{res.n_answers},"
            f"{t_comp * 1e3:.3f},{t_flat * 1e3:.3f},"
            f"{scan_frac:.3f},{join_frac:.3f},{';'.join(offenders) or '-'}"
        )


def run(smoke=False) -> None:
    if smoke:
        program, dataset, d = lubm_like(
            n_dept=4, n_students=60, n_courses=10, seed=0
        )
    else:
        program, dataset, d = lubm_like(
            n_dept=12, n_students=600, n_courses=40, seed=0
        )
    _bench_kb(
        "lubm",
        program,
        dataset,
        d,
        [
            '?s, ?c <- memberOf(?s, "dept3"), takesCourse(?s, ?c)',
            '?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)',
            '?s <- takesCourse(?s, "course7"), GraduateStudent(?s)',
            '?x, ?u <- memberOf(?x, ?dv), subOrganizationOf(?dv, ?u)',
        ],
    )

    program, dataset, d = chain(n=30 if smoke else 150)
    _bench_kb(
        "chain",
        program,
        dataset,
        d,
        [
            '?y <- path("v000003", ?y)',
            '?x, ?z <- edge(?x, ?y), path(?y, ?z)',
        ],
    )

    program, dataset, d = paper_example(n=32, m=12)
    _bench_kb(
        "paper",
        program,
        dataset,
        d,
        [
            "?x, ?y <- S(?x, ?y)",
            '?x, ?z <- P(?x, ?y), T(?y, ?z)',
        ],
    )


if __name__ == "__main__":
    run()
