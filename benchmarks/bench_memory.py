"""Memory bench: per-predicate compressed-vs-flat bytes + peak watermarks.

The paper's Tables 1/3 argue by *final* representation size; this bench
adds the obs.memory view of the same runs — what the store costs per
predicate (mu-DAG bytes vs the flat-equivalent rows x arity x 8, the
cross-predicate sharing factor, the RLE run length) and what the
materialisation costs at its *peak* (the high-water resident bytes the
:class:`repro.obs.memory.MemorySampler` records at round boundaries).

Rows come in two shapes, keyed by ``pred``:

- one row per predicate (plus the ``_total`` cross-predicate summary)
  with the compression-effectiveness columns,
- one ``_peak`` row per KB with resident/peak-resident bytes and the
  sampler's self-metered overhead.

Peaks are reporter-derived byte counts (``rss=False``), so the numbers
are deterministic and the regression gate can hold them to ±10%
(``peak_resident_bytes`` / ``compression_ratio`` in
:mod:`benchmarks.compare`); kernel RSS never enters the rows.
"""

from __future__ import annotations

import gc

from repro.core import CMatEngine
from repro.core.generators import chain, lubm_like
from repro.obs.memory import (
    MemorySampler,
    predicate_effectiveness,
    publish_predicate_effectiveness,
    sample_memory,
)

WORKLOADS = [
    ("lubm-like", lambda: lubm_like(n_dept=30, n_students=1500, n_courses=120)),
    ("chain-TC", lambda: chain(n=300)),
]

SMOKE_WORKLOADS = [
    ("lubm-like", lambda: lubm_like(n_dept=4, n_students=60, n_courses=10)),
    ("chain-TC", lambda: chain(n=30)),
]


def run_one(name, gen):
    program, dataset, _ = gen()
    with MemorySampler(rss=False) as sampler:
        eng = CMatEngine(program)
        eng.load(dataset)
        eng.materialise()
    final = sample_memory(rss=False)
    eff = predicate_effectiveness(eng.facts)
    publish_predicate_effectiveness(eng.facts)  # mem.pred.* for the gate
    rows = [
        {
            "kb": name,
            "pred": pred,
            "flat_bytes": int(e["flat_bytes"]),
            "mu_bytes": int(e["mu_bytes"]),
            "compression_ratio": round(e["compression_ratio"], 4),
            "sharing_factor": round(e["sharing_factor"], 4),
            "rle_ratio": round(e["rle_ratio"], 4),
        }
        for pred, e in sorted(eff.items())
    ]
    peak_row = {
        "kb": name,
        "pred": "_peak",
        "resident_bytes": int(final["resident_bytes"]),
        "peak_resident_bytes": int(
            max([*sampler.peaks.values(), final["resident_bytes"]])
        ),
        "samples": sampler.samples,
        "sampler_s": round(sampler.time_ns / 1e9, 4),
    }
    return rows, peak_row


def run(csv=True, smoke=False):
    # previous benches' engines register weakly with the accountant;
    # collect them so this bench's resident/peak numbers start clean
    gc.collect()
    rows: list[dict] = []
    peaks: list[dict] = []
    for name, gen in (SMOKE_WORKLOADS if smoke else WORKLOADS):
        pred_rows, peak_row = run_one(name, gen)
        rows.extend(pred_rows)
        peaks.append(peak_row)
    if csv:
        cols = ["kb", "pred", "flat_bytes", "mu_bytes", "compression_ratio",
                "sharing_factor", "rle_ratio"]
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
        for p in peaks:
            print(
                f"{p['kb']}: resident {p['resident_bytes']}B, "
                f"peak {p['peak_resident_bytes']}B "
                f"({p['samples']} samples, {p['sampler_s']}s in sampler)"
            )
    return rows + peaks


if __name__ == "__main__":
    run()
