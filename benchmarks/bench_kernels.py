"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle vs numpy,
with an achieved-vs-peak bandwidth column per kernel and a launch-count
comparison of the fused join→dedup→merge chain vs its unfused steps.

interpret-mode timings do NOT reflect TPU performance (the kernel body
runs in Python), so the ``peak_pct`` column is only meaningful on real
hardware; on CPU it documents the bytes model, not the roofline.  The
bandwidth math uses the same ``HBM_BW`` peak as the roofline table
(:mod:`repro.roofline.analysis`) so no dry-run artifacts are needed.

Launch counts are structural (device dispatches per round of the
chain), not sampled: the unfused path needs span-probe + pair-expand +
sort + dedup-probe + merge-sort dispatches where the fused path needs
exactly two (``join_dedup`` + ``merge_unique``); the bench asserts the
>= 2x reduction and that both chains produce identical codes.
"""

from __future__ import annotations

import time

import numpy as np

from repro.roofline.analysis import HBM_BW


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    try:
        out.block_until_ready()
    except AttributeError:
        pass
    return (time.perf_counter() - t0) / reps


def _bw(nbytes: int, seconds: float) -> tuple[float, float]:
    """(achieved GB/s, % of HBM peak) for a kernel touching nbytes."""
    gbps = nbytes / max(seconds, 1e-12) / 1e9
    return round(gbps, 3), round(100.0 * gbps * 1e9 / HBM_BW, 4)


def _row(kernel, n, t_kernel, nbytes, t_ref=float("nan"),
         t_np=float("nan")):
    gbps, pct = _bw(nbytes, t_kernel)
    return {
        "kernel": kernel, "n": n,
        "pallas_interpret_ms": round(1e3 * t_kernel, 2),
        "jnp_ref_ms": round(1e3 * t_ref, 2),
        "numpy_ms": round(1e3 * t_np, 2),
        "achieved_gbps": gbps,
        "peak_pct": pct,
    }


def _fused_chain_comparison(rng, n: int) -> dict:
    """One join→dedup→merge round both ways; returns the launch counts.

    The unfused chain is the pre-fusion dataflow: ``group_spans`` (1),
    ``expand_rle`` pair→left-row expansion (2), device sort of the
    packed pairs (3), ``member`` dedup probe against the buffer (4) and
    the merge re-sort (5).  The fused chain is ``join_dedup`` (1) +
    ``merge_unique`` (2).  Both must produce the same sorted-unique
    packed codes."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.fused import BIG

    l_keys = rng.integers(0, n // 4, size=n).astype(np.int32)
    l_payload = rng.integers(0, 2**14, size=n).astype(np.int32)
    r_keys = np.sort(rng.integers(0, n // 4, size=n).astype(np.int32))
    r_payload = rng.integers(0, 2**15, size=n).astype(np.int32)
    buf_codes = np.unique(
        rng.integers(0, 2**30, size=n).astype(np.int32)
    )

    # --- unfused chain (5 device dispatches + a host round-trip) ------ #
    def unfused():
        lo, hi = ops.group_spans(l_keys, r_keys)           # launch 1
        lo_h, hi_h = np.asarray(lo), np.asarray(hi)        # host trip
        counts = (hi_h - lo_h).astype(np.int32)
        total = int(counts.sum())
        nz = counts > 0
        li = np.asarray(ops.expand_rle(                    # launch 2
            np.flatnonzero(nz).astype(np.int32), counts[nz], total
        ))
        offs = np.concatenate([[0], np.cumsum(counts)[:-1]])
        rj = lo_h[li] + (np.arange(total) - offs[li])
        packed = (
            l_payload[li].astype(np.int32) << 16
        ) | (r_payload[rj].astype(np.int32) & 0xFFFF)
        s = np.asarray(jnp.sort(jnp.asarray(packed)))      # launch 3
        uniq = s[np.concatenate([[True], s[1:] != s[:-1]])]
        fresh = uniq[
            np.asarray(ops.anti_join_mask(uniq, buf_codes))  # launch 4
        ]
        merged = np.asarray(                               # launch 5
            jnp.sort(jnp.concatenate(
                [jnp.asarray(buf_codes), jnp.asarray(fresh)]
            ))
        )
        return merged

    # --- fused chain (2 launches, no host trip between them) --------- #
    total = int((np.searchsorted(r_keys, l_keys, "right")
                 - np.searchsorted(r_keys, l_keys, "left")).sum())
    cap = 1 << max(7, int(np.ceil(np.log2(max(total, 1) + 1))))

    def fused():
        out, cnt, tot = ops.join_dedup(
            l_keys, l_payload, r_keys, r_payload, capacity=cap
        )                                                  # launch 1
        assert int(tot[0]) <= cap, "bench capacity too small"
        buf_cap = 1 << int(
            np.ceil(np.log2(buf_codes.shape[0] + int(cnt[0]) + 1))
        )
        buf = np.full(max(buf_cap, 128), BIG, np.int32)
        buf[: buf_codes.shape[0]] = buf_codes
        merged, mcnt, _ = ops.merge_unique(buf, out)       # launch 2
        return np.asarray(merged)[: int(mcnt[0])]

    a, b = unfused(), fused()
    assert a.shape == b.shape and (a == b).all(), (
        "fused and unfused chains disagree"
    )
    launches_unfused, launches_fused = 5, 2
    assert launches_unfused >= 2 * launches_fused
    t_unfused = _time(unfused)
    t_fused = _time(fused)
    return {
        "kernel": "fused_chain", "n": n,
        "launches_unfused": launches_unfused,
        "launches_fused": launches_fused,
        "launch_ratio": round(launches_unfused / launches_fused, 2),
        "unfused_ms": round(1e3 * t_unfused, 2),
        "fused_ms": round(1e3 * t_fused, 2),
    }


def run(csv=True, smoke=False):
    from repro.kernels import ops, ref
    from repro.kernels.fused import BIG

    rng = np.random.default_rng(0)
    rows = []
    sizes = (4_096,) if smoke else (4_096, 65_536)
    for n in sizes:
        a = rng.integers(0, 1_000_000, size=n).astype(np.int32)
        b = np.sort(rng.integers(0, 1_000_000, size=n).astype(np.int32))
        t_kernel = _time(lambda: np.asarray(ops.member(a, b)))
        t_ref = _time(lambda: np.asarray(ref.sorted_member_ref(a, b)))
        t_np = _time(lambda: np.isin(a, b))
        # reads a + b (int32), writes a bool mask
        rows.append(_row("sorted_member", n, t_kernel,
                         4 * n + 4 * n + n, t_ref, t_np))

        vals = rng.integers(0, 1000, size=n // 16).astype(np.int32)
        cnts = rng.integers(1, 32, size=n // 16).astype(np.int32)
        total = int(cnts.sum())
        t_kernel = _time(lambda: np.asarray(ops.expand_rle(vals, cnts, total)))
        t_np = _time(lambda: np.repeat(vals, cnts))
        rows.append(_row("rle_expand", total, t_kernel,
                         8 * vals.size + 4 * total, t_np=t_np))

        l = rng.integers(0, 1_000_000, size=n).astype(np.int32)
        t_kernel = _time(lambda: np.asarray(ops.group_spans(l, b)[0]))
        t_ref = _time(lambda: np.asarray(ref.join_bounds_ref(l, b)[0]))
        rows.append(_row("join_bounds", n, t_kernel,
                         4 * n + 4 * n + 8 * n, t_ref))

        # --- fused kernels vs their numpy references ------------------ #
        lk = rng.integers(0, n // 4, size=n).astype(np.int32)
        lp = rng.integers(0, 2**14, size=n).astype(np.int32)
        rk = np.sort(rng.integers(0, n // 4, size=n).astype(np.int32))
        rp = rng.integers(0, 2**15, size=n).astype(np.int32)
        total_pairs = int((np.searchsorted(rk, lk, "right")
                           - np.searchsorted(rk, lk, "left")).sum())
        cap = 1 << max(7, int(np.ceil(np.log2(total_pairs + 1))))
        t_kernel = _time(lambda: np.asarray(
            ops.join_dedup(lk, lp, rk, rp, capacity=cap)[0]
        ))
        t_np = _time(
            lambda: ref.fused_join_dedup_ref(lk, lp, rk, rp, capacity=cap)[0]
        )
        rows.append(_row("fused_join_dedup", n, t_kernel,
                         4 * (2 * n + 2 * n) + 4 * cap + 8, t_np=t_np))

        bufc = 1 << int(np.ceil(np.log2(2 * n)))
        buf = np.full(bufc, BIG, np.int32)
        seed = np.unique(rng.integers(0, 2**30, size=n // 2).astype(np.int32))
        buf[: seed.size] = seed
        fresh = np.unique(rng.integers(0, 2**30, size=n // 4).astype(np.int32))
        fresh = np.setdiff1d(fresh, seed)
        t_kernel = _time(lambda: np.asarray(ops.merge_unique(buf, fresh)[0]))
        t_np = _time(lambda: ref.merge_sorted_unique_ref(buf, fresh)[0])
        rows.append(_row("merge_sorted_unique", bufc, t_kernel,
                         4 * (bufc + fresh.size) + 4 * bufc + 8, t_np=t_np))

        rows.append(_fused_chain_comparison(rng, n))

    if csv:
        cols: list[str] = []
        for r in rows:  # union of keys, first-seen order
            cols.extend(k for k in r if k not in cols)
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in cols))
    return rows


if __name__ == "__main__":
    run()
