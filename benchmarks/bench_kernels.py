"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle vs numpy.

interpret-mode timings do NOT reflect TPU performance (the kernel body
runs in Python); the benchmark validates plumbing + records the work
shapes that the BlockSpecs tile for v5e."""

from __future__ import annotations

import time

import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    try:
        out.block_until_ready()
    except AttributeError:
        pass
    return (time.perf_counter() - t0) / reps


def run(csv=True):
    rng = np.random.default_rng(0)
    rows = []
    for n in (4_096, 65_536):
        a = rng.integers(0, 1_000_000, size=n).astype(np.int32)
        b = np.sort(rng.integers(0, 1_000_000, size=n).astype(np.int32))
        t_kernel = _time(lambda: np.asarray(ops.member(a, b)))
        t_ref = _time(lambda: np.asarray(ref.sorted_member_ref(a, b)))
        t_np = _time(lambda: np.isin(a, b))
        rows.append({
            "kernel": "sorted_member", "n": n,
            "pallas_interpret_ms": round(1e3 * t_kernel, 2),
            "jnp_ref_ms": round(1e3 * t_ref, 2),
            "numpy_ms": round(1e3 * t_np, 2),
        })

        vals = rng.integers(0, 1000, size=n // 16).astype(np.int32)
        cnts = rng.integers(1, 32, size=n // 16).astype(np.int32)
        total = int(cnts.sum())
        t_kernel = _time(lambda: np.asarray(ops.expand_rle(vals, cnts, total)))
        t_np = _time(lambda: np.repeat(vals, cnts))
        rows.append({
            "kernel": "rle_expand", "n": total,
            "pallas_interpret_ms": round(1e3 * t_kernel, 2),
            "jnp_ref_ms": float("nan"),
            "numpy_ms": round(1e3 * t_np, 2),
        })

        l = rng.integers(0, 1_000_000, size=n).astype(np.int32)
        t_kernel = _time(lambda: np.asarray(ops.group_spans(l, b)[0]))
        t_ref = _time(lambda: np.asarray(ref.join_bounds_ref(l, b)[0]))
        rows.append({
            "kernel": "join_bounds", "n": n,
            "pallas_interpret_ms": round(1e3 * t_kernel, 2),
            "jnp_ref_ms": round(1e3 * t_ref, 2),
            "numpy_ms": float("nan"),
        })
    if csv:
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    run()
