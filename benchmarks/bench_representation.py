"""Paper Table 1/3 analog: representation sizes before/after materialisation.

Columns mirror the paper: |E|, |I| (fact counts), ||E||, ||I|| (flat
representation sizes), ||<E,mu>||, ||<M,mu>|| (compressed sizes), the
derived-fact deltas, and the mu statistics (avg/max unfold length, max
depth).  Datasets are synthetic analogs of the paper's benchmarks (LUBM
regular / chain a.k.a. Claros_LE-difficult / star / bipartite).
"""

from __future__ import annotations

import numpy as np

from repro.core import CMatEngine, flat_repr_size
from repro.core.engine import MaterialisationStats  # noqa: F401
from repro.core.generators import bipartite, chain, lubm_like, paper_example, star

WORKLOADS = [
    ("paper-example", lambda: paper_example(n=400, m=300)),
    ("lubm-like", lambda: lubm_like(n_dept=30, n_students=1500, n_courses=120)),
    ("chain-TC", lambda: chain(n=300)),
    ("star", lambda: star(n_spokes=4000, n_hubs=4)),
    ("bipartite", lambda: bipartite(n_left=250, n_right=250)),
]

SMOKE_WORKLOADS = [
    ("paper-example", lambda: paper_example(n=20, m=12)),
    ("lubm-like", lambda: lubm_like(n_dept=4, n_students=60, n_courses=10)),
    ("chain-TC", lambda: chain(n=30)),
]


def run_one(name, gen):
    program, dataset, _ = gen()
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    rep = eng.report()
    e_size = rep["flat_size_E"]
    i_size = rep["flat_size_I"]
    comp = rep["compressed_size"]
    mu = rep["mu_stats"]
    # compressed size of E alone (paper's ||<E, mu>||)
    eng_e = CMatEngine(program.__class__([]))
    eng_e.load(dataset)
    e_comp = eng_e.facts.total_repr_size()
    return {
        "workload": name,
        "n_E": rep["n_facts_explicit"],
        "n_I": rep["n_facts_materialised"],
        "flat_E": e_size,
        "flat_I": i_size,
        "flat_diff": i_size - e_size,
        "comp_E": e_comp,
        "comp_M": comp,
        "comp_diff": comp - e_comp,
        "compression_of_derived": (
            (i_size - e_size) / max(comp - e_comp, 1)
        ),
        "avg_len_mu": round(mu["avg_len"], 1),
        "max_len_mu": mu["max_len"],
        "max_depth_mu": mu["max_depth"],
        "rounds": rep["rounds"],
    }


def run(csv=True, smoke=False):
    rows = [run_one(name, gen)
            for name, gen in (SMOKE_WORKLOADS if smoke else WORKLOADS)]
    if csv:
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
    return rows


if __name__ == "__main__":
    run()
