"""Dedup ablation (beyond-paper): the paper reports duplicate elimination
as CompMat's dominant cost (O(n^2)-ish merge anti-join).  Our vectorised
sorted anti-join replaces it; this benchmark quantifies the win by timing
both implementations on the same candidate sets."""

from __future__ import annotations

import time

import numpy as np

from repro.core.util import factorize_rows, first_occurrence_mask, sorted_member


def serial_style_dedup(new_rows: np.ndarray, m_rows: np.ndarray) -> np.ndarray:
    """Paper-style merge anti-join (two sorted pointers, per element)."""
    new_sorted_idx = np.lexsort(new_rows.T[::-1])
    m_sorted_idx = np.lexsort(m_rows.T[::-1])
    ns, ms = new_rows[new_sorted_idx], m_rows[m_sorted_idx]
    keep = np.zeros(len(ns), dtype=bool)
    j = 0
    prev = None
    for i in range(len(ns)):
        row = tuple(ns[i])
        while j < len(ms) and tuple(ms[j]) < row:
            j += 1
        is_dup = (j < len(ms) and tuple(ms[j]) == row) or row == prev
        keep[i] = not is_dup
        prev = row
    out = np.zeros(len(ns), dtype=bool)
    out[new_sorted_idx] = keep
    return out


def vectorised_dedup(new_rows: np.ndarray, m_rows: np.ndarray) -> np.ndarray:
    codes_new, codes_m = factorize_rows(new_rows, m_rows)
    not_in_m = ~sorted_member(codes_new, np.sort(codes_m))
    return not_in_m & first_occurrence_mask(codes_new)


def run(csv=True, smoke=False):
    rng = np.random.default_rng(0)
    rows_out = []
    for n in (1_000, 5_000) if smoke else (1_000, 10_000, 100_000, 400_000):
        m_rows = rng.integers(0, n, size=(n, 2)).astype(np.int64)
        new_rows = rng.integers(0, n, size=(n // 2, 2)).astype(np.int64)

        t0 = time.perf_counter()
        a = serial_style_dedup(new_rows, m_rows)
        t_serial = time.perf_counter() - t0

        t0 = time.perf_counter()
        b = vectorised_dedup(new_rows, m_rows)
        t_vec = time.perf_counter() - t0

        assert (a == b).all()
        rows_out.append({
            "n_facts": n,
            "serial_ms": round(1e3 * t_serial, 2),
            "vectorised_ms": round(1e3 * t_vec, 2),
            "speedup": round(t_serial / max(t_vec, 1e-9), 1),
        })
    if csv:
        cols = list(rows_out[0].keys())
        print(",".join(cols))
        for r in rows_out:
            print(",".join(str(r[c]) for c in cols))
    return rows_out


if __name__ == "__main__":
    run()
