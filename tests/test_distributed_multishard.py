"""Distributed engine on a REAL multi-shard mesh (4 devices): exercises
the hash-partition + all_to_all exchange path — semi-naive delta rounds,
planner-keyed exchange elision, and the incremental delta exchange — not
just the 1-shard degenerate case.  Subprocess-isolated (forced device
count)."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import flat_seminaive
from repro.core.distributed import DistributedEngine
from repro.core.generators import chain, lubm_like, paper_example

mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("data",))

engines = {}
datasets = {}
for name, gen in [
    ("chain", lambda: chain(15)),
    ("paper", lambda: paper_example(4, 3)),
    ("lubm", lambda: lubm_like(n_dept=4, n_students=50, n_courses=8)),
]:
    program, dataset, _ = gen()
    rules = [r for r in program if len(r.body) <= 2]
    program = type(program)(rules)
    want = {p: {tuple(map(int, r)) for r in rows}
            for p, rows in flat_seminaive(program, dataset).items()}
    eng = DistributedEngine(program, mesh, capacity=1 << 11)
    got = eng.materialise(dataset)
    got = {p: {tuple(map(int, r)) for r in rows}
           for p, rows in got.items() if rows.shape[0]}
    assert got == want, f"{name}: mismatch"
    engines[name], datasets[name] = eng, dataset
    print(f"{name} OK rounds={eng.rounds} "
          f"skipped={eng.stats.rule_applications_skipped} "
          f"exchanges={eng.stats.exchanges} "
          f"elided={eng.stats.exchanges_skipped}")

# semi-naive skips work and the planner elides aligned exchanges at 4 shards
assert engines["lubm"].stats.rule_applications_skipped > 0
assert engines["chain"].stats.exchanges_skipped > 0
assert engines["chain"].stats.exchanges > 0

# incremental deltas through the 4-shard exchange: delete a chain edge
# (DRed overdelete/rederive), re-add it, compare against re-materialisation
eng, dataset = engines["chain"], datasets["chain"]
program = eng.program
dels = {"edge": np.asarray(dataset["edge"][5:7], np.int64)}
st = eng.apply(deletions=dels)
assert st.n_overdeleted > 0 and st.n_deleted > 0
kept = {"edge": np.asarray(
    [r for r in dataset["edge"].tolist()
     if tuple(r) not in {tuple(x) for x in dels["edge"].tolist()}],
    np.int64)}
eng.check_integrity(flat_seminaive(program, kept))
eng.apply(additions=dels)
eng.check_integrity(flat_seminaive(program, dataset))
print("APPLY OK")
print("MULTISHARD OK")
"""


def test_distributed_engine_four_shards():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr[-3000:]}"
    assert "MULTISHARD OK" in out.stdout
