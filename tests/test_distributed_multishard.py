"""Distributed engine on a REAL multi-shard mesh (4 devices): exercises
the hash-partition + all_to_all exchange path, not just the 1-shard
degenerate case.  Subprocess-isolated (forced device count)."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import flat_seminaive
from repro.core.distributed import DistributedEngine
from repro.core.generators import chain, lubm_like, paper_example

mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("data",))

for name, gen in [
    ("chain", lambda: chain(15)),
    ("paper", lambda: paper_example(4, 3)),
    ("lubm", lambda: lubm_like(n_dept=4, n_students=50, n_courses=8)),
]:
    program, dataset, _ = gen()
    rules = [r for r in program if len(r.body) <= 2]
    program = type(program)(rules)
    want = {p: {tuple(map(int, r)) for r in rows}
            for p, rows in flat_seminaive(program, dataset).items()}
    eng = DistributedEngine(program, mesh, capacity=1 << 11)
    got = eng.materialise(dataset)
    got = {p: {tuple(map(int, r)) for r in rows}
           for p, rows in got.items() if rows.shape[0]}
    assert got == want, f"{name}: mismatch"
    print(f"{name} OK rounds={eng.rounds}")
print("MULTISHARD OK")
"""


def test_distributed_engine_four_shards():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr[-3000:]}"
    assert "MULTISHARD OK" in out.stdout
