"""PR 7 device fast path: fused kernels vs ref oracles, buffer
donation/watermarks, the autotuner cache, and backend interpret
resolution.

Differential tests deliberately include the degenerate shapes the
kernels must contract over: empty sides, all-duplicate pair sets, and
totals that overflow the static capacity (the regrow protocol).  The
randomised sweeps here are seeded loops so they run without hypothesis;
the hypothesis property versions live in ``test_fused_property.py``."""

import os

import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.kernels import backend, ref, tune
from repro.kernels.buffers import BIG_NP, FactBuffers
from repro.kernels.fused import fused_join_dedup, merge_sorted_unique
from repro.obs import get_registry


def _i32(xs):
    return np.asarray(xs, dtype=np.int32)


class TestFusedJoinDedup:
    @pytest.mark.parametrize("capacity", [1, 7, 64, 256, 1000])
    def test_matches_ref(self, capacity):
        rng = np.random.default_rng(capacity)
        for trial in range(20):
            n = int(rng.integers(0, 80))
            m = int(rng.integers(0, 80))
            l_keys = rng.integers(0, 50, size=n).astype(np.int32)
            r_keys = np.sort(rng.integers(0, 50, size=m).astype(np.int32))
            l_pay = rng.integers(0, 2**15, size=n).astype(np.int32)
            r_pay = rng.integers(0, 2**16, size=m).astype(np.int32)
            out, cnt, tot = fused_join_dedup(
                l_keys, l_pay, r_keys, r_pay, capacity=capacity
            )
            r_out, r_cnt, r_tot = ref.fused_join_dedup_ref(
                l_keys, l_pay, r_keys, r_pay, capacity=capacity
            )
            assert int(tot[0]) == r_tot
            assert int(cnt[0]) == r_cnt
            assert_array_equal(np.asarray(out), r_out)

    def test_empty_sides(self):
        empty = np.zeros(0, np.int32)
        some = _i32([1, 2, 3])
        for l, r in [(empty, some), (some, empty), (empty, empty)]:
            out, cnt, tot = fused_join_dedup(
                l, l.copy(), np.sort(r), r.copy(), capacity=64
            )
            assert int(cnt[0]) == 0 and int(tot[0]) == 0
            assert (np.asarray(out) == BIG_NP).all()

    def test_all_duplicates_collapse_to_one(self):
        # every (l, r) match packs to the identical code
        l_keys = np.full(37, 5, np.int32)
        r_keys = np.full(11, 5, np.int32)
        l_pay = np.full(37, 9, np.int32)
        r_pay = np.full(11, 3, np.int32)
        out, cnt, tot = fused_join_dedup(
            l_keys, l_pay, r_keys, r_pay, capacity=512
        )
        assert int(tot[0]) == 37 * 11
        assert int(cnt[0]) == 1
        assert int(np.asarray(out)[0]) == (9 << 16) | 3

    def test_overflow_reports_total_and_regrow_recovers(self):
        # 20x20 all-matching -> 400 pairs; capacity 64 truncates
        rng = np.random.default_rng(0)
        l_keys = np.zeros(20, np.int32)
        r_keys = np.zeros(20, np.int32)
        l_pay = rng.integers(0, 2**15, size=20).astype(np.int32)
        r_pay = rng.integers(0, 2**16, size=20).astype(np.int32)
        out, cnt, tot = fused_join_dedup(
            l_keys, l_pay, r_keys, r_pay, capacity=64
        )
        assert int(tot[0]) == 400 > 64  # caller sees the overflow
        # regrow to >= total and retry: the full dedup'd pair set
        out2, cnt2, tot2 = fused_join_dedup(
            l_keys, l_pay, r_keys, r_pay, capacity=512
        )
        assert int(tot2[0]) == 400
        expect = np.unique(
            (l_pay.astype(np.int64)[:, None] << 16)
            | r_pay.astype(np.int64)[None, :]
        )
        assert int(cnt2[0]) == expect.size
        assert_array_equal(
            np.asarray(out2)[: expect.size], expect.astype(np.int32)
        )


class TestMergeSortedUnique:
    def test_matches_ref(self):
        rng = np.random.default_rng(7)
        for trial in range(40):
            nb = int(rng.integers(0, 61))
            nf = int(rng.integers(0, 61))
            buf = np.full(128, BIG_NP, np.int32)
            sv = np.unique(rng.integers(0, 2**30, size=nb).astype(np.int32))
            buf[: sv.size] = sv
            fresh = np.unique(rng.integers(0, 2**30, size=nf).astype(np.int32))
            merged, cnt, n_new = merge_sorted_unique(buf, fresh)
            r_merged, r_cnt, r_new = ref.merge_sorted_unique_ref(buf, fresh)
            assert int(cnt[0]) == r_cnt
            assert int(n_new[0]) == r_new
            assert_array_equal(np.asarray(merged), r_merged)

    def test_capacity_must_be_lane_multiple(self):
        with pytest.raises(ValueError, match="multiple of 128"):
            merge_sorted_unique(
                np.full(100, BIG_NP, np.int32), _i32([1, 2])
            )

    def test_merge_is_idempotent(self):
        buf = np.full(128, BIG_NP, np.int32)
        buf[:3] = [1, 5, 9]
        fresh = _i32([1, 5, 9])
        merged, cnt, n_new = merge_sorted_unique(buf, fresh)
        assert int(cnt[0]) == 3 and int(n_new[0]) == 0


class TestFactBuffersDevice:
    def _reg(self):
        reg = get_registry()
        reg.reset("kernels.")
        return reg

    def test_steady_state_allocates_nothing(self):
        """The donation contract: after the first allocation, rounds
        that fit in capacity must not allocate (kernels.buffers.
        allocations stays flat while merges keep counting)."""
        reg = self._reg()
        fb = FactBuffers(device=True, donate=False, initial_capacity=1024)
        fb.ensure("P", 1024)
        snap = reg.snapshot("kernels.")
        assert snap.get("kernels.buffers.allocations", 0) == 1
        rng = np.random.default_rng(1)
        for i in range(6):
            fresh = np.unique(
                rng.integers(0, 2**20, size=50).astype(np.int32)
            )
            fb.merge("P", fresh)
        snap = reg.snapshot("kernels.")
        assert snap["kernels.buffers.allocations"] == 1  # still just one
        assert snap["kernels.buffers.merges"] == 6
        assert snap["kernels.kernel_launches"] >= 6
        # watermark invariant 1: sorted unique below count, BIG above
        buf = np.asarray(fb._buf["P"])
        n = fb.count("P")
        assert (np.diff(buf[:n]) > 0).all()
        assert (buf[n:] == BIG_NP).all()

    def test_regrow_before_merge_preserves_codes(self):
        reg = self._reg()
        fb = FactBuffers(device=True, donate=False, initial_capacity=128)
        rng = np.random.default_rng(2)
        seen = np.zeros(0, np.int32)
        for i in range(5):
            fresh = np.unique(
                rng.integers(0, 2**20, size=100).astype(np.int32)
            )
            fb.merge("P", fresh)
            seen = np.union1d(seen, fresh).astype(np.int32)
        assert_array_equal(fb.codes("P"), seen)
        assert fb.capacity("P") >= seen.size
        assert reg.snapshot("kernels.")["kernels.buffers.regrows"] >= 1

    def test_donating_merge_same_result(self):
        fb_d = FactBuffers(device=True, donate=True, initial_capacity=256)
        fb_p = FactBuffers(device=True, donate=False, initial_capacity=256)
        rng = np.random.default_rng(3)
        for _ in range(4):
            fresh = np.unique(
                rng.integers(0, 2**20, size=40).astype(np.int32)
            )
            n_d = fb_d.merge("P", fresh)
            n_p = fb_p.merge("P", fresh)
            assert n_d == n_p
        assert_array_equal(fb_d.codes("P"), fb_p.codes("P"))


class TestFactBuffersHost:
    def test_fresh_mask_matches_dedup_index(self):
        from repro.core.dedup import DedupIndex

        rng = np.random.default_rng(4)
        fb, di = FactBuffers(), DedupIndex()
        seed = rng.integers(0, 1000, size=(50, 2)).astype(np.int64)
        fb.seed("P", seed)
        di.seed("P", seed)
        for _ in range(5):
            rows = rng.integers(0, 1000, size=(80, 2)).astype(np.int64)
            assert_array_equal(fb.fresh_mask("P", rows), di.fresh_mask("P", rows))

    def test_wide_rows_fall_back(self):
        fb = FactBuffers()
        rows = np.zeros((4, 3), dtype=np.int64)
        assert fb.fresh_mask("P", rows) is None


class TestBackendResolution:
    def test_default_is_cpu_detected(self, monkeypatch):
        monkeypatch.delenv(backend.ENV_VAR, raising=False)
        # this container is CPU-only, so None resolves to True
        assert backend.backend_name() == "cpu"
        assert backend.resolve_interpret(None) is True
        # explicit bools pass straight through
        assert backend.resolve_interpret(False) is False
        assert backend.resolve_interpret(True) is True

    @pytest.mark.parametrize("val,expect", [
        ("1", True), ("true", True), ("YES", True), ("on", True),
        ("0", False), ("false", False), ("No", False), ("off", False),
    ])
    def test_env_override(self, monkeypatch, val, expect):
        monkeypatch.setenv(backend.ENV_VAR, val)
        assert backend.resolve_interpret(None) is expect

    def test_env_garbage_raises(self, monkeypatch):
        monkeypatch.setenv(backend.ENV_VAR, "maybe")
        with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
            backend.resolve_interpret(None)


class TestTuneCache:
    @pytest.fixture(autouse=True)
    def _tmp_cache(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
        tune._cache = None
        yield
        tune._cache = None

    def test_interpret_mode_returns_defaults_without_cache(self):
        reg = get_registry()
        reg.reset("kernels.tune.")
        blocks = tune.get_blocks("sorted_member", n=5000, interpret=True)
        assert blocks == tune.DEFAULTS["sorted_member"]
        assert not os.path.exists(tune.cache_path())  # no sweep, no file
        snap = reg.snapshot("kernels.tune.")
        assert snap["kernels.tune.defaults"] == 1

    def test_sweep_writes_cache_then_hits(self):
        import json

        reg = get_registry()
        reg.reset("kernels.tune.")
        b1 = tune.get_blocks("rle_expand", n=300, interpret=False)
        assert os.path.exists(tune.cache_path())
        b2 = tune.get_blocks("rle_expand", n=300, interpret=False)
        assert b1 == b2
        snap = reg.snapshot("kernels.tune.")
        assert snap["kernels.tune.sweeps"] == 1
        assert snap["kernels.tune.cache_hits"] == 1
        raw = json.load(open(tune.cache_path()))
        assert raw["version"] == tune.CACHE_VERSION
        key = f"rle_expand|int32|{tune.size_bucket(300)}|cpu"
        assert raw["entries"][key] == b1

    def test_version_mismatch_discards(self):
        import json

        tune.get_blocks("rle_expand", n=300, interpret=False)
        raw = json.load(open(tune.cache_path()))
        raw["version"] = tune.CACHE_VERSION + 1
        json.dump(raw, open(tune.cache_path(), "w"))
        tune._cache = None
        assert tune._load_cache() == {}

    def test_corrupt_cache_is_cold(self):
        with open(tune.cache_path(), "w") as fh:
            fh.write("{not json")
        tune._cache = None
        assert tune._load_cache() == {}

    def test_size_bucket(self):
        assert tune.size_bucket(1) == 256
        assert tune.size_bucket(256) == 256
        assert tune.size_bucket(257) == 512
        assert tune.size_bucket(5000) == 8192

    def test_unknown_kernel_raises(self):
        with pytest.raises(KeyError):
            tune.get_blocks("nope", n=10, interpret=True)
