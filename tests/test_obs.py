"""Observability subsystem tests: span tracer, metrics registry,
Chrome-trace export, adapter parity, and the tracing-is-inert
differential guarantee."""

import json

import numpy as np
import pytest

from repro.core import CMatEngine
from repro.core.generators import lubm_like, paper_example
from repro.obs import (
    Histogram,
    MetricsRegistry,
    Tracer,
    chrome_trace,
    get_registry,
    get_tracer,
    instant,
    publish_materialisation,
    set_registry,
    set_tracer,
    span,
    write_chrome_trace,
    write_metrics,
)
from repro.obs.adapters import (
    MATERIALISATION_COUNTERS,
    MATERIALISATION_GAUGES,
)


@pytest.fixture
def tracer():
    """Fresh enabled tracer installed as the process tracer."""
    t = Tracer(enabled=True)
    prev = set_tracer(t)
    yield t
    set_tracer(prev)


@pytest.fixture
def registry():
    """Fresh registry installed as the process registry, so tests see
    only their own metrics (engines publish into the global)."""
    r = MetricsRegistry()
    prev = set_registry(r)
    yield r
    set_registry(prev)


# --------------------------------------------------------------------- #
# tracer
# --------------------------------------------------------------------- #
class TestTracer:
    def test_nesting_and_program_order(self, tracer):
        with span("a.outer", k=1):
            with span("a.child1"):
                pass
            with span("a.child2"):
                pass
        # exits append children before parents ...
        assert [r.name for r in tracer.events] == [
            "a.child1", "a.child2", "a.outer",
        ]
        # ... sorted_events recovers program (start-time) order
        ordered = tracer.sorted_events()
        assert [r.name for r in ordered] == [
            "a.outer", "a.child1", "a.child2",
        ]
        assert [r.depth for r in ordered] == [0, 1, 1]
        assert ordered[0].args == {"k": 1}
        # parent encloses children on the clock
        outer = ordered[0]
        for child in ordered[1:]:
            assert child.start_ns >= outer.start_ns
            assert child.start_ns + child.dur_ns <= (
                outer.start_ns + outer.dur_ns
            )

    def test_set_attaches_late_attributes(self, tracer):
        with span("x.s") as sp:
            sp.set(hit=True, n=3)
        assert tracer.events[0].args == {"hit": True, "n": 3}

    def test_instant_marker(self, tracer):
        instant("x.marker", factor=2)
        (rec,) = tracer.events
        assert rec.dur_ns == -1 and rec.args == {"factor": 2}

    def test_disabled_is_shared_noop(self, tracer):
        tracer.disable()
        s1, s2 = span("a"), span("b", k=1)
        assert s1 is s2  # shared singleton: no per-call allocation
        with s1 as sp:
            sp.set(ignored=1)  # the no-op twin accepts attributes
        instant("a.i")
        assert tracer.events == []

    def test_enable_mid_process_via_module_function(self, tracer):
        tracer.disable()
        with span("x.off"):
            pass
        tracer.enable()
        with span("x.on"):
            pass
        assert [r.name for r in tracer.events] == ["x.on"]

    def test_max_events_drops_and_counts(self):
        t = Tracer(enabled=True, max_events=2)
        prev = set_tracer(t)
        try:
            for i in range(5):
                with span("x.s", i=i):
                    pass
        finally:
            set_tracer(prev)
        assert len(t.events) == 2 and t.dropped == 3

    def test_misnested_exit_recovers(self, tracer):
        a = tracer.span("x.a")
        b = tracer.span("x.b")
        a.__enter__()
        b.__enter__()
        a.__exit__(None, None, None)  # out of LIFO order
        b.__exit__(None, None, None)
        assert tracer.misnested == 1
        assert len(tracer.events) == 2  # both still recorded

    def test_reset_clears_events_keeps_enabled(self, tracer):
        with span("x.s"):
            pass
        tracer.reset()
        assert tracer.events == [] and tracer.enabled


# --------------------------------------------------------------------- #
# metrics registry
# --------------------------------------------------------------------- #
class TestRegistry:
    def test_counter_gauge_roundtrip(self, registry):
        registry.counter("a.c").inc()
        registry.counter("a.c").inc(4)
        registry.gauge("a.g").set(7.5)
        snap = registry.snapshot()
        assert snap["a.c"] == 5 and snap["a.g"] == 7.5

    def test_scoped_reset_zeroes_in_place(self, registry):
        registry.counter("kernels.member.calls").inc(3)
        registry.counter("cmat.rounds").inc(2)
        registry.reset("kernels.")
        snap = registry.snapshot()
        # kernel scope zeroed but still registered; other scopes intact
        assert snap["kernels.member.calls"] == 0
        assert snap["cmat.rounds"] == 2

    def test_name_type_conflict_rejected(self, registry):
        registry.counter("a.x")
        with pytest.raises(ValueError):
            registry.gauge("a.x")
        with pytest.raises(ValueError):
            registry.histogram("a.x")

    def test_histogram_quantiles_vs_numpy(self):
        rng = np.random.default_rng(0)
        samples = rng.uniform(1e-3, 1.0, size=500)
        h = Histogram()
        for v in samples:
            h.observe(float(v))
        # bucket edges are 10**(1/10) apart, so the interpolated
        # quantile is exact to one bucket's relative width (~26%)
        for q in (0.50, 0.95, 0.99):
            exact = float(np.percentile(samples, q * 100))
            est = h.quantile(q)
            assert abs(est - exact) <= 0.30 * exact, (q, est, exact)
        assert h.count == 500
        assert h.min == samples.min() and h.max == samples.max()
        assert h.sum == pytest.approx(samples.sum())

    def test_histogram_single_observation(self):
        h = Histogram()
        h.observe(0.25)
        assert h.quantile(0.5) == pytest.approx(0.25)
        assert h.quantile(0.99) == pytest.approx(0.25)

    def test_empty_histogram_snapshot(self, registry):
        registry.histogram("a.h")
        snap = registry.snapshot("a.")
        assert snap["a.h.count"] == 0 and snap["a.h.p99"] == 0.0
        assert snap["a.h.max"] == 0.0

    def test_snapshot_expands_histograms_flat(self, registry):
        registry.histogram("serve.query_s").observe(0.01)
        snap = registry.snapshot("serve.")
        assert set(snap) == {
            "serve.query_s.count", "serve.query_s.sum",
            "serve.query_s.p50", "serve.query_s.p95",
            "serve.query_s.p99", "serve.query_s.max",
        }
        # every value JSON-serialisable scalar
        json.dumps(snap)


# --------------------------------------------------------------------- #
# exporters
# --------------------------------------------------------------------- #
class TestChromeTrace:
    def test_schema(self, tracer):
        with span("cmat.materialise", n_strata=2):
            with span("cmat.round", round=1):
                pass
        instant("dist.exchange_regrow", factor=2)
        doc = chrome_trace(tracer)
        json.loads(json.dumps(doc))  # valid JSON
        assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
        assert doc["otherData"]["dropped_events"] == 0
        assert doc["otherData"]["misnested_spans"] == 0
        assert doc["otherData"]["origin_unix_s"] > 0
        events = doc["traceEvents"]
        meta = [e for e in events if e["ph"] == "M"]
        assert {m["name"] for m in meta} == {"process_name", "thread_name"}
        complete = [e for e in events if e["ph"] == "X"]
        assert [e["name"] for e in complete] == [
            "cmat.materialise", "cmat.round",
        ]
        for e in complete:
            assert e["cat"] == e["name"].split(".", 1)[0]
            assert isinstance(e["ts"], float) and e["dur"] >= 0
            assert e["pid"] == 1 and isinstance(e["tid"], int)
        (inst,) = [e for e in events if e["ph"] == "i"]
        assert inst["s"] == "t" and "dur" not in inst
        assert inst["args"] == {"factor": 2}

    def test_write_returns_event_count(self, tracer, tmp_path):
        with span("x.a"):
            pass
        instant("x.b")
        path = tmp_path / "trace.json"
        n = write_chrome_trace(str(path), tracer)
        assert n == 2
        doc = json.loads(path.read_text())
        assert sum(1 for e in doc["traceEvents"] if e["ph"] != "M") == 2

    def test_write_metrics(self, registry, tmp_path):
        registry.counter("a.c").inc(3)
        path = tmp_path / "metrics.json"
        snap = write_metrics(str(path), registry)
        assert json.loads(path.read_text()) == snap == {"a.c": 3}


# --------------------------------------------------------------------- #
# adapters: registry parity with the legacy stats dataclasses
# --------------------------------------------------------------------- #
class TestAdapterParity:
    def test_cmat_snapshot_matches_stats_on_lubm(self, registry):
        program, dataset, _ = lubm_like(
            n_dept=2, n_students=20, n_courses=4, seed=0
        )
        eng = CMatEngine(program)
        eng.load(dataset)
        stats = eng.materialise()  # publishes into the registry itself
        snap = registry.snapshot("cmat.")
        for f in MATERIALISATION_COUNTERS + MATERIALISATION_GAUGES:
            assert snap[f"cmat.{f}"] == pytest.approx(getattr(stats, f)), f

    def test_counters_accumulate_gauges_overwrite(self, registry):
        program, dataset, _ = paper_example()
        eng = CMatEngine(program)
        eng.load(dataset)
        stats = eng.materialise()
        publish_materialisation(stats)  # second publish, same scope
        snap = registry.snapshot("cmat.")
        assert snap["cmat.rounds"] == 2 * stats.rounds
        assert snap["cmat.n_facts"] == stats.n_facts  # gauge: last write


# --------------------------------------------------------------------- #
# kernel meter through the registry
# --------------------------------------------------------------------- #
class TestKernelMeter:
    def test_meter_scoped_reset(self, registry):
        from repro.kernels import ops

        ops.meter_reset()
        registry.counter("cmat.rounds").inc(9)
        ops.member(np.array([1, 2, 3]), np.array([2, 3, 5]))
        m = ops.meter()
        assert m["member"]["calls"] == 1 and m["member"]["elements"] == 3
        ops.meter_reset()
        assert ops.meter() == {}  # zeroed ops drop out of the dict
        # the reset was scoped: other subsystems' counters survive
        assert registry.snapshot("cmat.")["cmat.rounds"] == 9


# --------------------------------------------------------------------- #
# differential: tracing must not change engine results
# --------------------------------------------------------------------- #
class TestTracingIsInert:
    def test_materialisation_identical_with_tracing(self, registry):
        def run():
            program, dataset, _ = lubm_like(
                n_dept=2, n_students=15, n_courses=3, seed=1
            )
            eng = CMatEngine(program)
            eng.load(dataset)
            stats = eng.materialise()
            return stats, eng.facts.to_dict()

        prev = set_tracer(Tracer(enabled=False))
        try:
            stats_off, facts_off = run()
            get_tracer().enable()
            stats_on, facts_on = run()
            assert get_tracer().events  # tracing actually recorded
        finally:
            set_tracer(prev)
        assert sorted(facts_on) == sorted(facts_off)
        for pred in facts_on:
            np.testing.assert_array_equal(facts_on[pred], facts_off[pred])
        assert stats_on.n_facts == stats_off.n_facts
        assert stats_on.rounds == stats_off.rounds
        assert (
            stats_on.n_rule_applications == stats_off.n_rule_applications
        )


# --------------------------------------------------------------------- #
# memory accountant & sampler (DESIGN.md §Observability / Memory)
# --------------------------------------------------------------------- #
import gc
import time

from repro.obs.memory import (
    MemoryAccountant,
    MemorySampler,
    array_is_backed,
    rss_bytes,
    split_owned_backed,
)


class _Reporter:
    """Minimal MemoryReporter with mutable parts."""

    def __init__(self, **parts):
        self.parts = {k: int(v) for k, v in parts.items()}

    def memory_report(self):
        return dict(self.parts)


class TestMemoryAccountant:
    def test_kind_part_sums_and_resident_rule(self, registry):
        acc = MemoryAccountant()
        a = _Reporter(nodes_bytes=100, n_nodes=7)
        b = _Reporter(
            nodes_bytes=50,
            wal_disk_bytes=9000,
            nodes_snapshot_backed_bytes=400,
        )
        acc.register("t", a)
        acc.register("t", b)
        collected = acc.collect()
        assert collected["t"]["nodes_bytes"] == 150
        assert collected["t"]["n_nodes"] == 7
        # disk and snapshot-backed parts are published but NOT resident
        assert acc.resident_bytes(collected) == 150
        flat = acc.sample(registry=registry, rss=False)
        assert flat["resident_bytes"] == 150
        assert flat["snapshot_backed_bytes"] == 400
        snap = registry.snapshot("mem.")
        assert snap["mem.t.nodes_bytes"] == 150
        assert snap["mem.t.wal_disk_bytes"] == 9000
        assert snap["mem.resident_bytes"] == 150
        assert snap["mem.snapshot_backed_bytes"] == 400

    def test_weakref_pruning_and_stale_part_zeroing(self, registry):
        acc = MemoryAccountant()
        rep = _Reporter(x_bytes=64)
        acc.register("t", rep)
        acc.sample(registry=registry, rss=False)
        assert registry.snapshot("mem.")["mem.t.x_bytes"] == 64
        del rep
        gc.collect()
        # registration is weak: the dead reporter leaves the roll-up and
        # its gauge is driven back to zero, not left stale
        assert acc.live()["t"] == []
        acc.sample(registry=registry, rss=False)
        snap = registry.snapshot("mem.")
        assert snap["mem.t.x_bytes"] == 0
        assert snap["mem.resident_bytes"] == 0

    def test_peak_gauges_are_max_updated(self, registry):
        acc = MemoryAccountant()
        rep = _Reporter(x_bytes=1000)
        acc.register("t", rep)
        acc.sample(registry=registry, phase="apply", rss=False)
        rep.parts["x_bytes"] = 10
        acc.sample(registry=registry, phase="apply", rss=False)
        snap = registry.snapshot("mem.")
        assert snap["mem.resident_bytes"] == 10  # current tracks down
        assert snap["mem.peak_resident_bytes"] == 1000  # peak holds
        assert snap["mem.peak.apply.resident_bytes"] == 1000

    def test_rss_bytes_positive(self):
        assert rss_bytes() > 0

    def test_array_backed_classification(self):
        owned = np.arange(12, dtype=np.int64)
        view = np.frombuffer(owned.tobytes(), dtype=np.int64)[2:]
        assert not array_is_backed(owned)
        assert array_is_backed(view)
        o, b = split_owned_backed([owned, view, None])
        assert o == owned.nbytes
        assert b == view.nbytes


class TestMemorySampler:
    def test_attach_detach_restores_tracer_state(self, registry):
        t = Tracer(enabled=False)
        s = MemorySampler(registry=registry, rss=False)
        s.attach(t)
        assert t.enabled and len(t.hooks) == 1
        s.detach()
        assert not t.enabled and len(t.hooks) == 0

    def test_phase_attribution_and_detach_publish(self, tracer, registry):
        acc = MemoryAccountant()
        rep = _Reporter(x_bytes=100)
        acc.register("t", rep)
        # budget=0 disables throttling: every boundary samples, so the
        # attribution assertions are deterministic
        s = MemorySampler(
            accountant=acc, registry=registry, rss=False, budget=0
        )
        s.attach()
        with span("cmat.materialise"):
            rep.parts["x_bytes"] = 1000  # peak lives INSIDE the fixpoint
            with span("cmat.round"):
                pass  # round exit samples, attributed to materialise
            rep.parts["x_bytes"] = 300
        s.detach()
        assert s.peaks["materialise"] == 1000
        assert s.throttled == 0
        snap = registry.snapshot("mem.")
        assert snap["mem.peak.materialise.resident_bytes"] == 1000
        assert snap["mem.peak_resident_bytes"] == 1000
        assert snap["mem.resident_bytes"] == 300  # detach re-samples
        assert snap["mem.sampler.samples"] == s.samples
        assert tracer.hook_errors == 0

    def test_throttle_skips_when_cadence_outpaces_budget(
        self, tracer, registry
    ):
        acc = MemoryAccountant()
        acc.register("t", _Reporter(x_bytes=1))
        # microscopic budget => after the first hook sample the next one
        # is pushed far into the future; the rest of the spans skip
        s = MemorySampler(
            accountant=acc, registry=registry, rss=False, budget=1e-9
        )
        s.attach()
        for _ in range(20):
            with span("cmat.round"):
                pass
        s.detach()
        assert s.throttled > 0
        assert s.samples + s.throttled >= 20

    def test_overhead_under_two_percent_of_lubm_materialise(
        self, tracer, registry
    ):
        # the ISSUE acceptance budget: sampling at span boundaries must
        # cost <2% of a LUBM materialisation.  The sampler self-meters
        # (time_ns) and self-throttles (budget=1% of wall), so this
        # holds by construction once per-sample cost is bounded.
        program, dataset, _ = lubm_like(30, 1500, 120)
        s = MemorySampler(rss=False)
        t0 = time.perf_counter_ns()
        s.attach()
        eng = CMatEngine(program)
        eng.load(dataset)
        eng.materialise()
        s.detach()
        wall = time.perf_counter_ns() - t0
        assert s.samples > 0
        assert s.time_ns < 0.02 * wall, (
            f"sampler took {s.time_ns / wall:.2%} of materialise "
            f"({s.samples} samples, {s.throttled} throttled)"
        )
