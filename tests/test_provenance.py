"""Derivation provenance: journal differential inertness, verified
``explain()`` proof trees, per-rule cost attribution, and the
checkpoint sidecar (DESIGN.md §Provenance).

The journal's contract has three legs, each tested here:

* **off by default / differentially inert** — enabling the journal must
  not change a single materialised fact, on any engine, for any
  generator workload;
* **verified explanations** — every proof tree ``explain()`` returns is
  re-derived step by step (``_check_step`` re-runs each rule on exactly
  the claimed body facts), so a test only has to check the ``verified``
  flag, including after a DRed deletion batch and after
  checkpoint -> restore;
* **bounded** — a journal capped far below the workload still explains
  (the journal only *orders* candidate rules; eviction degrades to
  exhaustive search, never to wrong proofs).
"""

from __future__ import annotations

import json

import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.core import CMatEngine, FlatEngine
from repro.core.datalog import Atom, Program, Rule
from repro.core.generators import (
    bipartite,
    chain,
    lubm_like,
    paper_example,
    star,
)
from repro.incremental import IncrementalStore
from repro.obs import get_registry
from repro.obs.provenance import (
    DerivationJournal,
    get_journal,
    proof_to_dot,
    proof_to_json,
)

WORKLOADS = [
    ("paper", lambda: paper_example(n=30, m=20)),
    ("chain", lambda: chain(n=60)),
    ("lubm", lambda: lubm_like(n_dept=4, n_students=60, n_courses=10)),
    ("star", lambda: star(n_spokes=80, n_hubs=3)),
    ("bipartite", lambda: bipartite(n_left=30, n_right=30)),
]

TC_PROGRAM = Program([
    Rule(head=Atom("path", ("X", "Y")), body=(Atom("edge", ("X", "Y")),)),
    Rule(
        head=Atom("path", ("X", "Z")),
        body=(Atom("path", ("X", "Y")), Atom("edge", ("Y", "Z"))),
    ),
])


@pytest.fixture
def journal():
    j = get_journal()
    was = j.enabled
    j.enabled = True
    j.clear()
    j.configure(max_records=100_000)
    yield j
    j.enabled = was
    j.clear()
    j.configure(max_records=100_000)
    get_registry().reset("rule.")
    get_registry().reset("prov.")


def _cmat(program, dataset):
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    return eng


def _derived_facts(mat, explicit, limit=None):
    """(pred, terms) pairs in the materialisation but not explicit."""
    out = []
    for pred in sorted(mat):
        rows = np.asarray(mat[pred]).reshape(len(mat[pred]), -1)
        exp = {
            tuple(int(v) for v in r)
            for r in np.asarray(explicit.get(pred, np.zeros((0, 1)))).reshape(
                -1, rows.shape[1] if rows.shape[0] else 1
            )
        } if pred in explicit else set()
        for row in rows:
            t = tuple(int(v) for v in row)
            if t not in exp:
                out.append((pred, t))
    return out if limit is None else out[:limit]


def _assert_all_verified(node):
    assert node is not None
    assert node["verified"] is True
    for child in node["children"]:
        _assert_all_verified(child)


# --------------------------------------------------------------------- #
# off by default + differential inertness
# --------------------------------------------------------------------- #
class TestDifferential:
    def test_journal_off_by_default(self):
        j = get_journal()
        assert j.enabled is False

    @pytest.mark.parametrize("name,gen", WORKLOADS)
    def test_cmat_identical_with_journal(self, name, gen, journal):
        program, dataset, _ = gen()
        journal.enabled = False
        base = _cmat(program, dataset).materialisation()
        journal.enabled = True
        journal.clear()
        on = _cmat(program, dataset).materialisation()
        assert sorted(base) == sorted(on)
        for pred in base:
            assert_array_equal(
                np.unique(base[pred], axis=0), np.unique(on[pred], axis=0)
            )
        assert journal.records, "journal enabled but nothing recorded"

    @pytest.mark.parametrize("name,gen", WORKLOADS)
    def test_flat_identical_with_journal(self, name, gen, journal):
        program, dataset, _ = gen()
        journal.enabled = False
        eng = FlatEngine(program)
        eng.load(dataset)
        base = eng.materialise()
        journal.enabled = True
        journal.clear()
        eng2 = FlatEngine(program)
        eng2.load(dataset)
        on = eng2.materialise()
        assert sorted(base) == sorted(on)
        for pred in base:
            assert_array_equal(base[pred], on[pred])


# --------------------------------------------------------------------- #
# verified proof trees
# --------------------------------------------------------------------- #
class TestExplain:
    def test_chain_tc_all_derived_facts_verified(self, journal):
        program, dataset, _ = chain(n=20)
        eng = _cmat(program, dataset)
        explicit = {p: np.asarray(r) for p, r in dataset.items()}
        targets = _derived_facts(eng.materialisation(), explicit)
        assert targets
        for pred, terms in targets:
            _assert_all_verified(eng.explain_fact(pred, terms))

    def test_paper_example_verified(self, journal):
        program, dataset, _ = paper_example(n=10, m=8)
        eng = _cmat(program, dataset)
        explicit = {p: np.asarray(r) for p, r in dataset.items()}
        for pred, terms in _derived_facts(
            eng.materialisation(), explicit, limit=40
        ):
            _assert_all_verified(eng.explain_fact(pred, terms))

    def test_lubm_verified(self, journal):
        program, dataset, _ = lubm_like(
            n_dept=3, n_students=30, n_courses=6
        )
        eng = _cmat(program, dataset)
        explicit = {p: np.asarray(r) for p, r in dataset.items()}
        targets = _derived_facts(eng.materialisation(), explicit, limit=60)
        assert targets
        for pred, terms in targets:
            _assert_all_verified(eng.explain_fact(pred, terms))

    def test_flat_engine_explains(self, journal):
        program, dataset, _ = chain(n=15)
        eng = FlatEngine(program)
        eng.load(dataset)
        mat = eng.materialise()
        explicit = {p: np.asarray(r) for p, r in dataset.items()}
        for pred, terms in _derived_facts(mat, explicit, limit=30):
            _assert_all_verified(eng.explain_fact(pred, terms))

    def test_explicit_fact_is_leaf(self, journal):
        program, dataset, _ = chain(n=10)
        eng = _cmat(program, dataset)
        row = tuple(int(v) for v in np.asarray(dataset["edge"])[0])
        node = eng.explain_fact("edge", row)
        assert node["kind"] == "explicit" and node["children"] == []

    def test_absent_fact_returns_none(self, journal):
        program, dataset, _ = chain(n=10)
        eng = _cmat(program, dataset)
        assert eng.explain_fact("path", (999, 998)) is None

    def test_exports(self, journal):
        program, dataset, _ = chain(n=8)
        eng = _cmat(program, dataset)
        explicit = {p: np.asarray(r) for p, r in dataset.items()}
        pred, terms = _derived_facts(eng.materialisation(), explicit)[-1]
        node = eng.explain_fact(pred, terms)
        payload = json.loads(proof_to_json(node))
        assert payload["fact"] == node["fact"]
        dot = proof_to_dot(node)
        assert dot.startswith("digraph") and node["fact"] in dot

    def test_journal_guided_proof_is_minimal_depth(self, journal):
        """With the journal, the chain fact path(0, k) explains through
        the recorded first-derivation rounds — proof depth tracks the
        round structure instead of the longest rule chain."""
        program, dataset, _ = chain(n=12)
        eng = _cmat(program, dataset)
        node = eng.explain_fact("path", (0, 5))
        _assert_all_verified(node)
        assert node["round"] >= 1


# --------------------------------------------------------------------- #
# incremental maintenance: DRed survival + insertion epochs
# --------------------------------------------------------------------- #
class TestIncrementalExplain:
    DIAMOND = np.array([[0, 1], [0, 2], [1, 3], [2, 3]], np.int64)

    def test_survivor_explained_after_dred_delete(self, journal):
        inc = IncrementalStore(TC_PROGRAM)
        inc.load({"edge": self.DIAMOND})
        inc.apply(deletions={"edge": np.array([[1, 3]], np.int64)})
        inc.check_integrity()
        # path(0, 3) survives via 0 -> 2 -> 3; its proof must re-derive
        node = inc.explain_fact("path", (0, 3))
        _assert_all_verified(node)
        kinds = {r.kind for r in journal.records}
        assert "overdelete" in kinds
        assert {"survive_explicit", "survive_backward", "rederive"} & kinds

    def test_deleted_fact_not_explainable(self, journal):
        inc = IncrementalStore(TC_PROGRAM)
        inc.load({"edge": np.array([[0, 1], [1, 2]], np.int64)})
        inc.apply(deletions={"edge": np.array([[1, 2]], np.int64)})
        assert inc.explain_fact("path", (0, 2)) is None
        _assert_all_verified(inc.explain_fact("path", (0, 1)))

    def test_explain_after_insertion_epoch(self, journal):
        inc = IncrementalStore(TC_PROGRAM)
        inc.load({"edge": np.array([[0, 1]], np.int64)})
        inc.apply(additions={"edge": np.array([[1, 2], [2, 3]], np.int64)})
        node = inc.explain_fact("path", (0, 3))
        _assert_all_verified(node)
        assert any(r.epoch == 1 for r in journal.records)


# --------------------------------------------------------------------- #
# checkpoint -> restore
# --------------------------------------------------------------------- #
class TestCheckpointRestore:
    def test_explain_after_restore(self, tmp_path, journal):
        from repro.storage import CheckpointManager

        root = str(tmp_path / "ckpt")
        inc = IncrementalStore(TC_PROGRAM)
        inc.load({"edge": np.array([[i, i + 1] for i in range(8)], np.int64)})
        mgr = CheckpointManager(root)
        mgr.checkpoint(inc)
        # the sidecar rides in the snapshot directory
        assert (tmp_path / "ckpt").is_dir()
        snap = mgr.latest()
        assert snap is not None
        import os

        assert os.path.exists(os.path.join(snap, "provenance.json"))

        journal.clear()  # a fresh process would start empty
        inc2, _ = mgr.restore(TC_PROGRAM)
        assert journal.records, "sidecar not loaded on restore"
        node = inc2.explain_fact("path", (0, 4))
        _assert_all_verified(node)

    def test_restore_without_sidecar_still_explains(self, tmp_path, journal):
        from repro.storage import CheckpointManager

        journal.enabled = False  # checkpoint written with journal off
        inc = IncrementalStore(TC_PROGRAM)
        inc.load({"edge": np.array([[i, i + 1] for i in range(6)], np.int64)})
        mgr = CheckpointManager(str(tmp_path / "ck2"))
        mgr.checkpoint(inc)
        journal.enabled = True
        journal.clear()
        inc2, _ = mgr.restore(TC_PROGRAM)
        assert not journal.records  # nothing to load — fallback search
        _assert_all_verified(inc2.explain_fact("path", (0, 3)))

    def test_explain_after_restore_and_dred_delete(self, tmp_path, journal):
        from repro.storage import CheckpointManager

        inc = IncrementalStore(TC_PROGRAM)
        inc.load({"edge": TestIncrementalExplain.DIAMOND})
        mgr = CheckpointManager(str(tmp_path / "ck3"))
        mgr.checkpoint(inc)
        inc2, _ = mgr.restore(TC_PROGRAM)
        inc2.apply(deletions={"edge": np.array([[1, 3]], np.int64)})
        inc2.check_integrity()
        _assert_all_verified(inc2.explain_fact("path", (0, 3)))


# --------------------------------------------------------------------- #
# bounded journal: eviction degrades search, never correctness
# --------------------------------------------------------------------- #
class TestBoundedJournal:
    def test_eviction_keeps_explains_verified(self, journal):
        journal.configure(max_records=4)
        program, dataset, _ = chain(n=25)
        eng = _cmat(program, dataset)
        assert journal.dropped > 0
        explicit = {p: np.asarray(r) for p, r in dataset.items()}
        for pred, terms in _derived_facts(
            eng.materialisation(), explicit, limit=20
        ):
            _assert_all_verified(eng.explain_fact(pred, terms))

    def test_payload_roundtrip(self, journal):
        program, dataset, _ = chain(n=10)
        _cmat(program, dataset)
        payload = journal.to_payload()
        j2 = DerivationJournal()
        j2.enabled = True
        j2.load_payload(payload)
        assert len(j2.records) == len(journal.records)
        assert [r.to_list() for r in j2.records] == [
            r.to_list() for r in journal.records
        ]
        assert j2.costs.keys() == journal.costs.keys()

    def test_memory_report(self, journal):
        program, dataset, _ = chain(n=10)
        _cmat(program, dataset)
        rep = journal.memory_report()
        assert rep["n_records"] == len(journal.records)
        assert rep["journal_bytes"] > 0


# --------------------------------------------------------------------- #
# per-rule cost attribution + adapter rule scope
# --------------------------------------------------------------------- #
class TestCostMetrics:
    def test_rule_gauges_published(self, journal):
        reg = get_registry()
        reg.reset("rule.")
        program, dataset, _ = chain(n=15)
        _cmat(program, dataset)
        snap = reg.snapshot("rule.")
        assert snap.get("rule.1.derived", 0) > 0
        assert "rule.1.time_ns" in snap
        assert snap.get("rule.journal.records", 0) == len(journal.records)

    def test_hot_rules_table(self, journal):
        program, dataset, _ = chain(n=15)
        _cmat(program, dataset)
        hot = journal.hot_rules(5)
        assert hot and hot[0]["time_ns"] >= hot[-1]["time_ns"]
        assert all("rule" in h and "derived" in h for h in hot)

    def test_adapter_stratum_scope(self):
        # published regardless of the journal: the adapters mirror the
        # engine's per_stratum stats under rule.*
        reg = get_registry()
        reg.reset("rule.")
        program, dataset, _ = lubm_like(
            n_dept=2, n_students=20, n_courses=4
        )
        _cmat(program, dataset)
        snap = reg.snapshot("rule.")
        assert snap.get("rule.stratum0.rules", 0) > 0
        assert "rule.stratum0.rule_applications" in snap
        assert "rule.applications_skipped" in snap
        reg.reset("rule.")

    def test_cmat_rule_span_carries_rule_id(self, journal):
        from repro.obs import get_tracer

        tr = get_tracer()
        was = tr.enabled
        tr.enable()
        try:
            tr.events.clear()
            program, dataset, _ = chain(n=8)
            _cmat(program, dataset)
            spans = [e for e in tr.events if e.name == "cmat.rule"]
            assert spans
            for e in spans:
                assert "rule_id" in e.args and "stratum" in e.args
        finally:
            tr.events.clear()
            if not was:
                tr.disable()


# --------------------------------------------------------------------- #
# distributed: shard-tagged records, merged at verify
# --------------------------------------------------------------------- #
class TestDistributed:
    def test_shard_records_and_merge(self, journal):
        import jax
        from jax.sharding import Mesh

        from repro.core.distributed import DistributedEngine

        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        dataset = {
            "edge": np.array([[i, i + 1] for i in range(10)], np.int64)
        }
        dist = DistributedEngine(TC_PROGRAM, mesh, capacity=512)
        dist.materialise(dict(dataset))
        kinds = {r.kind for r in journal.records}
        assert {"apply", "schedule"} <= kinds
        inc = IncrementalStore(TC_PROGRAM)
        inc.load(dict(dataset))
        dist.check_integrity(inc)  # merges shard records
        applies = [r for r in journal.records if r.kind == "apply"]
        keys = [r.key() for r in applies]
        assert len(keys) == len(set(keys)), "shard records not coalesced"


# --------------------------------------------------------------------- #
# journal overhead (the <5% budget the bench gates in CI)
# --------------------------------------------------------------------- #
class TestOverhead:
    def test_overhead_under_budget(self):
        import sys

        sys.path.insert(0, ".")
        try:
            from benchmarks.bench_provenance import measure_overhead
        finally:
            sys.path.pop(0)
        program, dataset, _ = lubm_like(
            n_dept=4, n_students=60, n_courses=10
        )
        res = measure_overhead(program, dataset, reps=3)
        assert res["overhead_ok"], (
            f"journal overhead {res['overhead_frac']:.1%} over budget "
            f"(off {res['off_s']}s -> on {res['on_s']}s)"
        )


# --------------------------------------------------------------------- #
# bench history artifacts
# --------------------------------------------------------------------- #
class TestBenchHistory:
    def test_write_history_timestamped(self, tmp_path):
        import sys

        sys.path.insert(0, ".")
        try:
            from benchmarks.run import write_history
        finally:
            sys.path.pop(0)
        payload = {"smoke": True, "failures": 0, "benches": {}}
        path = write_history(payload, str(tmp_path / "hist"), now=0.0)
        assert path.endswith("BENCH_19700101T000000Z.json")
        with open(path) as fh:
            assert json.load(fh) == payload
        # a second run appends, never overwrites
        path2 = write_history(payload, str(tmp_path / "hist"), now=60.0)
        assert path2 != path
        import os

        assert len(os.listdir(tmp_path / "hist")) == 2


# --------------------------------------------------------------------- #
# property: every explained proof re-derives (hypothesis when present,
# a seeded random sweep otherwise — the module must not skip wholesale)
# --------------------------------------------------------------------- #
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _check_random_graph(edges, journal):
    journal.clear()
    dataset = {"edge": np.asarray(sorted(set(edges)), np.int64)}
    eng = _cmat(TC_PROGRAM, dict(dataset))
    explicit = {p: np.asarray(r) for p, r in dataset.items()}
    for pred, terms in _derived_facts(
        eng.materialisation(), explicit, limit=25
    ):
        _assert_all_verified(eng.explain_fact(pred, terms))


if HAVE_HYPOTHESIS:

    class TestProofRoundTrip:
        @settings(
            max_examples=15, deadline=None,
            suppress_health_check=[HealthCheck.function_scoped_fixture],
        )
        @given(
            edges=st.lists(
                st.tuples(st.integers(0, 7), st.integers(0, 7)),
                min_size=2, max_size=14, unique=True,
            )
        )
        def test_random_graph_explains_verified(self, edges, journal):
            _check_random_graph(edges, journal)

else:

    class TestProofRoundTrip:
        @pytest.mark.parametrize("seed", range(8))
        def test_random_graph_explains_verified(self, seed, journal):
            rng = np.random.default_rng(seed)
            n = int(rng.integers(2, 15))
            edges = [
                (int(a), int(b))
                for a, b in rng.integers(0, 8, size=(n, 2))
            ]
            _check_random_graph(edges, journal)
