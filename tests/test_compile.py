"""Differential property tests for the shared body compiler.

The invariants the one-body-compiler refactor must hold:

* planner-ordered, stratified rule evaluation produces exactly the same
  materialisation as the strict left-to-right reference and as the flat
  engine, on random programs/KBs;
* a plan-cache hit cannot change results (a warm cache driven through a
  second engine reproduces the cold run bit-for-bit);
* the delta pivot anchors the plan and fixes the old/delta/all sources;
* stratification is a topologically-ordered partition of the rules.
"""

import numpy as np
import pytest

from repro.core import CMatEngine, FlatEngine
from repro.core.compile import (
    SRC_ALL,
    SRC_DELTA,
    SRC_OLD,
    ArrayStats,
    PlanCache,
    compile_body,
    stats_bucket,
)
from repro.core.datalog import parse_program
from repro.core.generators import lubm_like, paper_example, random_kb
from repro.core.program_graph import condensation, explain_strata, stratify


def _materialise_cmat(program, dataset, **kwargs):
    eng = CMatEngine(program, **kwargs)
    eng.load(dataset)
    eng.materialise()
    return eng


def _assert_same_materialisation(a, b, context=""):
    assert set(a) == set(b), f"{context}: predicate sets differ"
    for pred in a:
        assert np.array_equal(a[pred], b[pred]), f"{context}: {pred} differs"


# --------------------------------------------------------------------- #
# differential: planner+strata == left-to-right reference == flat
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(15))
def test_random_programs_planner_matches_reference_and_flat(seed):
    rng = np.random.default_rng(seed)
    program, dataset = random_kb(rng)
    planned = _materialise_cmat(program, dataset)
    reference = _materialise_cmat(
        program, dataset, plan_bodies=False, stratify_program=False
    )
    flat = FlatEngine(program)
    flat.load(dataset)
    flat_mat = {p: np.unique(r, axis=0) for p, r in flat.materialise().items()}

    _assert_same_materialisation(
        planned.materialisation(), reference.materialisation(),
        f"seed={seed} planned vs reference",
    )
    _assert_same_materialisation(
        planned.materialisation(), flat_mat, f"seed={seed} planned vs flat"
    )


@pytest.mark.parametrize("stratify_program", [True, False])
@pytest.mark.parametrize("plan_bodies", [True, False])
def test_lubm_all_engine_modes_agree(plan_bodies, stratify_program):
    program, dataset, _ = lubm_like(n_dept=4, n_students=50, n_courses=8)
    eng = _materialise_cmat(
        program, dataset,
        plan_bodies=plan_bodies, stratify_program=stratify_program,
    )
    flat = FlatEngine(program)
    flat.load(dataset)
    flat_mat = {p: np.unique(r, axis=0) for p, r in flat.materialise().items()}
    _assert_same_materialisation(eng.materialisation(), flat_mat)


def test_lubm_skips_rule_applications_without_probes():
    program, dataset, _ = lubm_like(n_dept=4, n_students=50, n_courses=8)
    eng = _materialise_cmat(program, dataset)
    assert eng.stats.rule_applications_skipped > 0
    assert eng.stats.n_strata > 1
    assert sum(s["rounds"] for s in eng.stats.per_stratum) == eng.stats.rounds


# --------------------------------------------------------------------- #
# plan cache
# --------------------------------------------------------------------- #
def test_plan_cache_hit_does_not_change_results():
    program, dataset, _ = paper_example(n=20, m=12)
    shared = PlanCache()
    cold = _materialise_cmat(program, dataset, plan_cache=shared)
    hits_before = shared.hits
    warm = _materialise_cmat(program, dataset, plan_cache=shared)
    assert shared.hits > hits_before, "second run must hit the warm cache"
    _assert_same_materialisation(
        cold.materialisation(), warm.materialisation(), "cold vs warm cache"
    )


def test_plan_cache_replans_on_bucket_shift():
    program = parse_program("P(x, y), Q(y, z) -> R(x, z)")
    (rule,) = program.rules
    small = ArrayStats({"P": np.zeros((4, 2), np.int64),
                        "Q": np.zeros((4, 2), np.int64)})
    big = ArrayStats({"P": np.zeros((4, 2), np.int64),
                      "Q": np.zeros((4096, 2), np.int64)})
    cache = PlanCache()
    build = 0

    def make(stats):
        nonlocal build
        build += 1
        return compile_body(rule.body, stats, pivot=0)

    p1 = cache.get((rule, 0), stats_bucket(small, rule.body), lambda: make(small))
    p2 = cache.get((rule, 0), stats_bucket(small, rule.body), lambda: make(small))
    assert p1 is p2 and build == 1 and cache.hits == 1
    cache.get((rule, 0), stats_bucket(big, rule.body), lambda: make(big))
    assert build == 2 and cache.replans == 1


def test_flat_engine_shares_plan_cache_type():
    program, dataset, _ = paper_example(n=10, m=6)
    shared = PlanCache()
    f1 = FlatEngine(program, plan_cache=shared)
    f1.load(dataset)
    m1 = f1.materialise()
    f2 = FlatEngine(program, plan_cache=shared)
    f2.load(dataset)
    m2 = f2.materialise()
    assert shared.hits > 0
    _assert_same_materialisation(m1, m2, "flat warm cache")


# --------------------------------------------------------------------- #
# plan shape: pivot anchoring + sources
# --------------------------------------------------------------------- #
def test_pivot_anchors_plan_and_sets_sources():
    program = parse_program("P(x, y), Q(y, z), R(z, w) -> S(x, w)")
    (rule,) = program.rules
    stats = ArrayStats({
        "P": np.zeros((100, 2), np.int64),
        "Q": np.zeros((1000, 2), np.int64),
        "R": np.zeros((10, 2), np.int64),
    })
    for pivot in range(3):
        plan = compile_body(rule.body, stats, pivot=pivot)
        assert plan.first.atom == rule.body[pivot]
        assert plan.first.source == SRC_DELTA
        sources = {s.body_index: s.source
                   for s in [plan.first] + [j.scan for j in plan.joins]}
        for j in range(3):
            expected = (SRC_DELTA if j == pivot
                        else SRC_OLD if j < pivot else SRC_ALL)
            assert sources[j] == expected, (pivot, j)


def test_left_to_right_mode_keeps_body_order():
    program = parse_program("P(x, y), Q(y, z), R(z, w) -> S(x, w)")
    (rule,) = program.rules
    stats = ArrayStats({
        "P": np.zeros((1000, 2), np.int64),
        "Q": np.zeros((10, 2), np.int64),
        "R": np.zeros((1, 2), np.int64),
    })
    plan = compile_body(rule.body, stats, pivot=1, reorder=False)
    assert tuple(plan.atom_order()) == rule.body


def test_empty_body_rule_is_a_noop():
    """Fact rules with no body parse fine and must not crash the
    (naive-round) pivot loop — they simply derive nothing."""
    from repro.core.datalog import Atom, Program, Rule

    program = Program([Rule((), Atom("P", (1, 2)))])
    for kwargs in ({}, {"plan_bodies": False, "stratify_program": False}):
        eng = CMatEngine(program, **kwargs)
        eng.load({"Q": np.asarray([[1, 2]], dtype=np.int64)})
        eng.materialise()
        assert "P" not in eng.materialisation()
    assert compile_body((), ArrayStats({})).is_empty


def test_rule_plan_explain_is_printable():
    program = parse_program("P(x, y), Q(y, z) -> R(x, z)")
    (rule,) = program.rules
    stats = ArrayStats({"P": np.zeros((10, 2), np.int64),
                        "Q": np.zeros((10, 2), np.int64)})
    text = compile_body(rule.body, stats, pivot=1).explain()
    assert "pivot=1" in text and "delta" in text and "scan[" in text


# --------------------------------------------------------------------- #
# stratification
# --------------------------------------------------------------------- #
def test_stratify_partitions_rules_topologically():
    program = parse_program(
        """
        E(x, y) -> P(x, y)
        P(x, y), P(y, z) -> P(x, z)
        P(x, y) -> Q(x)
        Q(x), R(x) -> T(x)
        """
    )
    strata = stratify(program)
    flat = [r for s in strata for r in s]
    assert sorted(map(str, flat)) == sorted(map(str, program.rules))
    comps = condensation(program)
    order = {p: k for k, comp in enumerate(comps) for p in comp}
    # every rule's body predicates live in components no later than its head
    for rules in strata:
        for rule in rules:
            for atom in rule.body:
                assert order[atom.predicate] <= order[rule.head.predicate]
    # the mutually recursive P-rules share a stratum; Q after P, T after Q
    def stratum_of(head):
        return next(
            k for k, rules in enumerate(strata)
            if any(r.head.predicate == head for r in rules)
        )

    assert stratum_of("P") < stratum_of("Q") < stratum_of("T")
    assert "recursive" in explain_strata(program)


def test_partition_key_annotates_single_key_joins():
    """The compiler picks the distributed exchange key: each single-key
    equi-join step carries the join variable; multi-key and cartesian
    steps carry None."""

    class Stats:
        def n_rows(self, pred):
            return 100

        def arity(self, pred):
            return 2

        def selectivity(self, pred, pos, value):
            return 0.1

    program = parse_program(
        """
        path(x, y), edge(y, z) -> path(x, z)
        P(x, y), Q(x, y) -> R(x, y)
        """
    )
    tc, multi = program.rules
    plan = compile_body(tc.body, Stats(), pivot=0)
    assert plan.first.atom.predicate == "path"  # pivot anchors
    assert plan.joins[0].key_vars == ("y",)
    assert plan.joins[0].partition_key == "y"

    plan2 = compile_body(multi.body, Stats(), pivot=0)
    assert plan2.joins[0].key_vars == ("x", "y")
    assert plan2.joins[0].partition_key is None
