"""Hypothesis property tests for the fused Pallas kernels vs the
numpy oracles in :mod:`repro.kernels.ref` (the seeded-loop versions in
``test_fused_kernels.py`` cover containers without hypothesis)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_array_equal

from repro.kernels import ref
from repro.kernels.buffers import BIG_NP
from repro.kernels.fused import fused_join_dedup, merge_sorted_unique

keys_st = st.lists(st.integers(0, 50), min_size=0, max_size=80)


@given(
    lk=keys_st,
    rk=keys_st,
    seed=st.integers(0, 2**31 - 1),
    capacity=st.sampled_from([1, 7, 64, 256, 1000]),
)
@settings(max_examples=60, deadline=None)
def test_fused_join_dedup_matches_ref(lk, rk, seed, capacity):
    rng = np.random.default_rng(seed)
    l_keys = np.asarray(lk, dtype=np.int32)
    r_keys = np.sort(np.asarray(rk, dtype=np.int32))
    l_pay = rng.integers(0, 2**15, size=l_keys.size).astype(np.int32)
    r_pay = rng.integers(0, 2**16, size=r_keys.size).astype(np.int32)
    out, cnt, tot = fused_join_dedup(
        l_keys, l_pay, r_keys, r_pay, capacity=capacity
    )
    r_out, r_cnt, r_tot = ref.fused_join_dedup_ref(
        l_keys, l_pay, r_keys, r_pay, capacity=capacity
    )
    assert int(tot[0]) == r_tot
    assert int(cnt[0]) == r_cnt
    assert_array_equal(np.asarray(out), r_out)


@given(
    buf_vals=st.lists(st.integers(0, 2**30), max_size=60, unique=True),
    fresh_vals=st.lists(st.integers(0, 2**30), max_size=60, unique=True),
)
@settings(max_examples=60, deadline=None)
def test_merge_sorted_unique_matches_ref(buf_vals, fresh_vals):
    buf = np.full(128, BIG_NP, np.int32)
    sv = np.sort(np.asarray(buf_vals, dtype=np.int32))
    buf[: sv.size] = sv
    fresh = np.sort(np.asarray(fresh_vals, dtype=np.int32))
    merged, cnt, n_new = merge_sorted_unique(buf, fresh)
    r_merged, r_cnt, r_new = ref.merge_sorted_unique_ref(buf, fresh)
    assert int(cnt[0]) == r_cnt
    assert int(n_new[0]) == r_new
    assert_array_equal(np.asarray(merged), r_merged)
