"""MVCC serving tier: epoch registry, micro-batched admission, writer.

Concurrency invariants under test (DESIGN.md §Serving):

* a pinned epoch always answers from the snapshot it pinned, however
  many epochs the writer publishes meanwhile (differential against the
  sequential :func:`flat_seminaive` oracle);
* an epoch entry is never retired while a lease pins it, and is retired
  as soon as the last lease releases a non-current entry;
* compaction (which swaps the mu-node table) is deferred while any
  epoch is pinned, and runs once the pins drain;
* checkpoint pruning and WAL truncation respect pinned epochs;
* responses are never stale: a query admitted at registry version V is
  answered at a version >= V;
* ``ReportSink.emit`` is thread-safe (one JSON line per emit, no torn
  records) — the regression test for the serving-driver bugfix.
"""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro.core import flat_seminaive
from repro.core.generators import chain, lubm_like, paper_example
from repro.incremental import IncrementalStore
from repro.query import QueryEngine, answer_flat, parse_query
from repro.serving import EpochRegistry, ServingTier


def as_sets(facts):
    return {
        p: frozenset(map(tuple, np.asarray(r).tolist()))
        for p, r in facts.items()
        if len(r)
    }


def rows_set(arr):
    return frozenset(map(tuple, np.asarray(arr).tolist()))


def make_chain_store(n=8):
    program, dataset, dictionary = chain(n=n)
    inc = IncrementalStore(program)
    inc.load(dataset)
    return program, dataset, dictionary, inc


# --------------------------------------------------------------------- #
# epoch registry
# --------------------------------------------------------------------- #
def test_registry_pin_publish_retire():
    retired = []
    reg = EpochRegistry(on_retire=lambda e: retired.append(e.version))
    with pytest.raises(RuntimeError):
        reg.pin()

    reg.publish(0, frozen="f0", engine="e0")
    assert reg.version == 0 and reg.n_live() == 1

    # unpinned previous entry retires at the next publish
    reg.publish(1, frozen="f1", engine="e1")
    assert retired == [0] and reg.n_live() == 1

    lease = reg.pin()
    assert lease.version == 1 and lease.engine == "e1"
    reg.publish(2, frozen="f2", engine="e2")
    # v1 is pinned: still live, not retired
    assert reg.n_live() == 2 and retired == [0]
    assert reg.pinned_epochs() == {1}

    lease.release()
    assert retired == [0, 1] and reg.n_live() == 1
    # release is idempotent
    lease.release()
    assert reg.stats() == {
        "published": 3, "retired": 2, "live": 1, "pinned": 0,
        "version": 2, "epoch": 2,
    }


def test_registry_refcounts_and_current_pin():
    reg = EpochRegistry()
    reg.publish(0, frozen=None, engine=None)
    l1, l2 = reg.pin(), reg.pin()
    assert reg.n_pinned() == 2
    l1.release()
    # the current entry survives its last release (it is still current)
    l2.release()
    assert reg.n_live() == 1 and reg.version == 0
    # ...and retires normally at the next publish
    reg.publish(1, frozen=None, engine=None)
    assert reg.n_live() == 1 and reg.retired == 1


# --------------------------------------------------------------------- #
# tier read path
# --------------------------------------------------------------------- #
def test_tier_answers_match_query_engine():
    program, dataset, dictionary, inc = make_chain_store()
    tier = ServingTier(inc, dictionary)
    try:
        engine = QueryEngine(inc.freeze(), dictionary)
        for text in (
            "?x, ?y <- path(?x, ?y)",
            '?y <- path("v000000", ?y)',
            '<- edge("v000000", "v000001")',
        ):
            resp = tier.answer(text)
            want = engine.answer(text)
            assert np.array_equal(resp.answers, want.answers), text
            assert not resp.stale
    finally:
        tier.close()


def test_pinned_epoch_isolated_from_writer():
    program, dataset, dictionary, inc = make_chain_store()
    tier = ServingTier(inc, dictionary)
    query = "?x, ?y <- path(?x, ?y)"
    try:
        want_v0 = rows_set(
            flat_seminaive(program, inc.explicit)["path"]
        )
        lease = tier.pin()
        # writer deletes the middle edge: the current view's closure
        # splits, the pinned view must not move
        dels = {"edge": np.asarray(dataset["edge"])[3:4]}
        tier.apply_sync(deletions=dels)
        want_v1 = rows_set(
            flat_seminaive(program, inc.explicit)["path"]
        )
        assert want_v1 != want_v0, "update must change the closure"

        assert rows_set(lease.answer(query).answers) == want_v0
        assert rows_set(tier.answer(query).answers) == want_v1
        # the lease keeps answering v0 even after more churn
        tier.apply_sync(additions=dels)
        assert rows_set(lease.answer(query).answers) == want_v0
        lease.release()
    finally:
        tier.close()


def test_no_retire_while_pinned():
    program, dataset, dictionary, inc = make_chain_store()
    tier = ServingTier(inc, dictionary)
    try:
        lease = tier.pin()
        entry = lease._lease._entry
        dels = {"edge": np.asarray(dataset["edge"])[:1]}
        tier.apply_sync(deletions=dels)
        tier.apply_sync(additions=dels)
        assert not entry.retired, "entry retired while pinned"
        assert tier.registry.n_live() == 2
        lease.release()
        assert entry.retired, "entry must retire on last unpin"
        assert tier.registry.n_live() == 1
    finally:
        tier.close()


def test_compaction_deferred_while_pinned():
    # n=20 keeps the store above maybe_compact's min_nodes floor
    program, dataset, dictionary, inc = make_chain_store(n=20)
    # threshold tiny: any deletion churn qualifies for compaction
    tier = ServingTier(inc, dictionary, compact_threshold=0.01)
    query = "?x, ?y <- path(?x, ?y)"
    try:
        lease = tier.pin()
        v0 = rows_set(flat_seminaive(program, inc.explicit)["path"])
        dels = {"edge": np.asarray(dataset["edge"])[4:6]}
        tier.apply_sync(deletions=dels)
        assert tier.compactions == 0 and tier.compactions_deferred >= 1
        # pinned snapshot still reads pre-churn state through the
        # un-swapped node table
        assert rows_set(lease.answer(query).answers) == v0
        lease.release()

        tier.apply_sync(additions=dels)
        assert tier.compactions >= 1, "compaction must run once unpinned"
        want = rows_set(flat_seminaive(program, inc.explicit)["path"])
        assert rows_set(tier.answer(query).answers) == want
    finally:
        tier.close()


# --------------------------------------------------------------------- #
# micro-batch shared-plan execution
# --------------------------------------------------------------------- #
def test_answer_batch_equivalence():
    program, dataset, dictionary = lubm_like(
        n_dept=4, n_students=40, n_courses=8, seed=0
    )
    inc = IncrementalStore(program)
    inc.load(dataset)
    frozen = inc.freeze()
    texts = [
        # one-constant template group (batched generalised)
        '?s, ?c <- memberOf(?s, "dept0"), takesCourse(?s, ?c)',
        '?s, ?c <- memberOf(?s, "dept1"), takesCourse(?s, ?c)',
        '?s, ?c <- memberOf(?s, "dept2"), takesCourse(?s, ?c)',
        # exact duplicate (deduped in-batch)
        '?s, ?c <- memberOf(?s, "dept0"), takesCourse(?s, ?c)',
        # no-constant query (single)
        "?x, ?u <- memberOf(?x, ?dv), subOrganizationOf(?dv, ?u)",
        # ask queries, one grouped pair
        '<- memberOf(?x, "dept0")',
        '<- memberOf(?x, "dept3")',
        # two-constant query (not single-slot: single path)
        '?c <- memberOf("student0", ?s), takesCourse("student1", ?c)',
    ]
    batch_engine = QueryEngine(frozen, dictionary, result_cache_size=64)
    results, stats = batch_engine.answer_batch(
        [parse_query(t, dictionary) for t in texts]
    )
    assert len(results) == len(texts)
    assert stats.n_queries == len(texts) - 1  # one exact duplicate
    assert stats.n_groups >= 1 and stats.n_grouped >= 3

    for text, res in zip(texts, results):
        # fresh engine per query: no shared caches with the batch path
        ref = QueryEngine(frozen, dictionary, result_cache_size=0).answer(
            text
        )
        assert np.array_equal(res.answers, ref.answers), text
    # duplicates resolve to the same answers object
    assert results[0] is results[3] or np.array_equal(
        results[0].answers, results[3].answers
    )


def test_answer_batch_absent_constant_and_seeded_cache():
    program, dataset, dictionary, inc = make_chain_store(n=6)
    frozen = inc.freeze()
    engine = QueryEngine(frozen, dictionary, result_cache_size=64)
    texts = [
        '?y <- path("v000000", ?y)',
        '?y <- path("v000003", ?y)',
        '?y <- path("v000006", ?y)',  # sink node: no outgoing path
    ]
    queries = [parse_query(t, dictionary) for t in texts]
    results, stats = engine.answer_batch(queries)
    assert stats.n_groups == 1 and stats.n_grouped == 3
    assert results[2].n_answers == 0
    for q, res in zip(queries, results):
        ref = answer_flat(q, flat_seminaive(program, inc.explicit))
        assert np.array_equal(res.answers, ref), str(q)
    # split answers were seeded into the result cache: a re-ask hits
    again, stats2 = engine.answer_batch(queries)
    assert stats2.n_cached == 3 and stats2.n_groups == 0
    for res, res2 in zip(results, again):
        assert np.array_equal(res.answers, res2.answers)


# --------------------------------------------------------------------- #
# threaded stress: readers + writer, per-version differential oracle
# --------------------------------------------------------------------- #
def test_threaded_closed_loop_stress():
    program, dataset, dictionary, inc = make_chain_store(n=12)
    tier = ServingTier(inc, dictionary, max_batch=8)

    # record every published version's explicit set (the subscriber runs
    # after the tier's own publish hook, so registry.version is fresh)
    explicit_by_version = {
        tier.registry.version: {
            p: np.array(r, copy=True) for p, r in inc.explicit.items()
        }
    }

    def record(store, stats):
        explicit_by_version[tier.registry.version] = {
            p: np.array(r, copy=True) for p, r in store.explicit.items()
        }

    inc.subscribe_publish(record)
    texts = [
        "?x, ?y <- path(?x, ?y)",
        '?y <- path("v000000", ?y)',
        '?y <- path("v000005", ?y)',
        "?x, ?y <- edge(?x, ?y)",
    ]
    n_clients, per_client = 8, 30
    out_lock = threading.Lock()
    observations = []
    errors = []

    def client(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(per_client):
                text = texts[int(rng.integers(0, len(texts)))]
                resp = tier.answer(text, timeout=60.0)
                with out_lock:
                    observations.append((text, resp))
        except Exception as e:  # noqa: BLE001 — surfaced after join
            with out_lock:
                errors.append(e)

    edges = np.asarray(dataset["edge"])
    try:
        tier.start()
        threads = [
            threading.Thread(target=client, args=(i,), daemon=True)
            for i in range(n_clients)
        ]
        for th in threads:
            th.start()
        # writer churn concurrent with the clients
        for i in range(6):
            dels = {"edge": edges[i % len(edges): i % len(edges) + 1]}
            tier.apply_sync(deletions=dels)
            tier.apply_sync(additions=dels)
        for th in threads:
            th.join(timeout=120.0)
            assert not th.is_alive(), "client thread hung"
    finally:
        tier.close()
        inc.unsubscribe_publish(record)

    assert not errors, errors
    assert len(observations) == n_clients * per_client
    assert tier.stats()["stale_reads"] == 0

    # every response must match the sequential oracle of the exact
    # version it was served at
    oracle_cache: dict[int, dict] = {}
    for text, resp in observations:
        assert not resp.stale
        assert resp.version in explicit_by_version, resp.version
        if resp.version not in oracle_cache:
            oracle_cache[resp.version] = flat_seminaive(
                program, explicit_by_version[resp.version]
            )
        ref = answer_flat(
            parse_query(text, dictionary), oracle_cache[resp.version]
        )
        assert np.array_equal(resp.answers, ref), (
            f"{text} at version {resp.version}"
        )


def test_malformed_query_fails_alone():
    program, dataset, dictionary, inc = make_chain_store()
    tier = ServingTier(inc, dictionary)
    try:
        tier.start()
        good = tier.submit("?x, ?y <- path(?x, ?y)")
        bad = tier.submit("this is not a query")
        good2 = tier.submit('?y <- path("v000000", ?y)')
        with pytest.raises(ValueError):
            bad.wait(timeout=30.0)
        assert good.wait(timeout=30.0).n_answers > 0
        assert good2.wait(timeout=30.0).n_answers > 0
    finally:
        tier.close()


# --------------------------------------------------------------------- #
# storage integration: pins gate pruning/truncation
# --------------------------------------------------------------------- #
def test_checkpoint_prune_respects_pins(tmp_path):
    from repro.storage import CheckpointManager

    program, dataset, dictionary, inc = make_chain_store()
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=1, label="t")
    inc.attach_wal(mgr.wal)
    edges = np.asarray(dataset["edge"])

    inc.apply(deletions={"edge": edges[:1]})   # epoch 1
    mgr.checkpoint(inc)
    pinned_epoch = inc.epoch
    mgr.pin_epoch(pinned_epoch)

    inc.apply(additions={"edge": edges[:1]})   # epoch 2
    inc.apply(deletions={"edge": edges[1:2]})  # epoch 3
    mgr.checkpoint(inc)
    # keep=1 would normally leave only snap-3; the pin saves snap-1 and
    # the WAL records after epoch 1 (a pinned reader must stay
    # recoverable: snapshot + replay-forward)
    assert mgr.snapshots() == [
        f"snap-{pinned_epoch:08d}", f"snap-{inc.epoch:08d}"
    ]
    replayable = [
        r for r in mgr.wal.records() if r["epoch"] > pinned_epoch
    ]
    assert len(replayable) == 2, "WAL suffix after the pin truncated"

    mgr.unpin_epoch(pinned_epoch)
    inc.apply(additions={"edge": edges[1:2]})  # epoch 4
    mgr.checkpoint(inc)
    assert mgr.snapshots() == [f"snap-{inc.epoch:08d}"]
    assert mgr.wal.records() == []


def test_tier_epoch_source_feeds_checkpoint(tmp_path):
    from repro.storage import CheckpointManager

    program, dataset, dictionary, inc = make_chain_store()
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=1, label="t")
    inc.attach_wal(mgr.wal)
    tier = ServingTier(inc, dictionary, checkpoint=mgr, checkpoint_every=1)
    edges = np.asarray(dataset["edge"])
    try:
        lease = tier.pin()
        pinned_epoch = lease.epoch
        tier.apply_sync(deletions={"edge": edges[:1]})
        tier.apply_sync(additions={"edge": edges[:1]})
        # the registry's pinned epochs flow through attach_epoch_source:
        # WAL records after the pinned store epoch survive truncation
        assert {pinned_epoch} == tier.registry.pinned_epochs()
        assert all(
            r["epoch"] > pinned_epoch for r in mgr.wal.records()
        )
        assert len(mgr.wal.records()) == 2 - pinned_epoch
        lease.release()
        tier.apply_sync(deletions={"edge": edges[1:2]})
        assert mgr.wal.records() == [], "unpinned WAL prefix kept"
    finally:
        tier.close()


# --------------------------------------------------------------------- #
# ReportSink thread-safety (serving-driver bugfix regression)
# --------------------------------------------------------------------- #
def test_report_sink_concurrent_emits(tmp_path, capsys):
    from repro.launch.serve_datalog import ReportSink

    path = tmp_path / "report.jsonl"
    sink = ReportSink(str(path))
    n_threads, per_thread = 8, 200

    def emitter(tid):
        for i in range(per_thread):
            sink.emit(
                f"t{tid}", f"payload {i}",
                {"thread": tid, "i": i, "filler": "x" * 64},
            )

    threads = [
        threading.Thread(target=emitter, args=(t,)) for t in range(n_threads)
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    sink.close()

    lines = path.read_text().splitlines()
    assert len(lines) == n_threads * per_thread
    seen = set()
    for line in lines:
        rec = json.loads(line)  # a torn/interleaved write fails here
        assert rec["block"] == f"t{rec['thread']}"
        assert rec["filler"] == "x" * 64
        seen.add((rec["thread"], rec["i"]))
    assert len(seen) == n_threads * per_thread, "lost or duplicated emits"
    capsys.readouterr()  # swallow the 1600 printed lines


# --------------------------------------------------------------------- #
# driver end-to-end (in-process)
# --------------------------------------------------------------------- #
def test_serve_datalog_mvcc_smoke(tmp_path, capsys):
    from repro.launch.serve_datalog import main

    report = tmp_path / "report.jsonl"
    rc = main([
        "--kb", "paper", "--scale", "1", "--n-queries", "120",
        "--mvcc", "--concurrency", "4", "--live", "--live-verify",
        "--update-every", "40", "--update-size", "2",
        "--report-json", str(report),
    ])
    capsys.readouterr()
    assert rc == 0
    blocks = [json.loads(line) for line in report.read_text().splitlines()]
    servings = [b for b in blocks if b["block"] == "serving"]
    assert len(servings) == 1
    s = servings[0]
    assert s["concurrency"] == 4
    assert s["qps"] > 0 and s["p99_ms"] > 0
    assert s["stale_reads"] == 0
    assert s["epochs_published"] >= 2
    verifies = [b for b in blocks if b["block"] == "live-verify"]
    assert len(verifies) == 1 and verifies[0]["ok"]


def test_mvcc_rejects_distributed(capsys):
    from repro.launch.serve_datalog import main

    with pytest.raises(SystemExit):
        main(["--mvcc", "--distributed"])
    capsys.readouterr()


# --------------------------------------------------------------------- #
# hypothesis: random reader/writer interleavings vs sequential oracle
# --------------------------------------------------------------------- #
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements-dev
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @hst.composite
    def interleavings(draw):
        """Op sequences over a chain KB: writer applies, reader pins,
        unpins, and queries against pinned or current views."""
        ops = []
        for _ in range(draw(hst.integers(min_value=3, max_value=12))):
            kind = draw(hst.sampled_from(
                ["apply", "pin", "unpin", "query_current", "query_pinned"]
            ))
            if kind == "apply":
                ops.append((
                    "apply",
                    draw(hst.integers(min_value=0, max_value=9)),
                    draw(hst.booleans()),
                ))
            elif kind in ("unpin", "query_pinned"):
                ops.append((kind, draw(hst.integers(min_value=0, max_value=4))))
            else:
                ops.append((kind,))
        return ops

    @settings(
        max_examples=20,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(interleavings())
    def test_epoch_pinning_interleavings(ops):
        program, dataset, dictionary, inc = make_chain_store(n=10)
        tier = ServingTier(inc, dictionary)
        query = "?x, ?y <- path(?x, ?y)"
        edges = np.asarray(dataset["edge"])

        def oracle():
            mat = flat_seminaive(program, inc.explicit)
            return rows_set(mat.get("path", np.zeros((0, 2), np.int64)))

        pinned: list = []  # (lease, expected path set at pin time)
        try:
            for op in ops:
                if op[0] == "apply":
                    _, i, delete = op
                    batch = {"edge": edges[i % len(edges): i % len(edges) + 1]}
                    if delete:
                        tier.apply_sync(deletions=batch)
                    else:
                        tier.apply_sync(additions=batch)
                elif op[0] == "pin":
                    pinned.append((tier.pin(), oracle()))
                elif op[0] == "unpin" and pinned:
                    lease, _ = pinned.pop(op[1] % len(pinned))
                    lease.release()
                elif op[0] == "query_pinned" and pinned:
                    lease, want = pinned[op[1] % len(pinned)]
                    got = rows_set(lease.answer(query).answers)
                    assert got == want, "pinned view drifted"
                elif op[0] == "query_current":
                    got = rows_set(tier.answer(query).answers)
                    assert got == oracle(), "current view stale"
                # standing invariants after every op
                for lease, _ in pinned:
                    assert not lease._lease._entry.retired, (
                        "entry retired while pinned"
                    )
                assert tier.registry.n_live() >= 1
        finally:
            for lease, _ in pinned:
                lease.release()
            tier.close()
        # every non-current epoch drained: only the current entry lives
        assert tier.registry.n_live() == 1
        assert tier.registry.n_pinned() == 0
