"""Shared fixtures: the observability leak check.

Every tier-1 module runs under ``leak_check``: the obs singletons
(metrics registry, span tracer, memory accountant) are process-wide,
so a test that swaps one out, leaves the tracer enabled, forgets a
sampler hook, or keeps ``FactBuffers`` capacity alive would silently
tax every module that runs after it.  The fixture pins the baseline at
module entry and asserts it is restored at module exit (after a
``gc.collect()`` so weakly-registered reporters whose owners died are
actually gone), then clears the ``mem.`` gauge scope so one module's
watermarks never masquerade as the next module's.
"""

from __future__ import annotations

import gc

import pytest


@pytest.fixture(autouse=True, scope="module")
def leak_check():
    from repro.obs import get_registry, get_tracer
    from repro.obs.memory import get_accountant

    gc.collect()
    reg = get_registry()
    tr = get_tracer()
    acc = get_accountant()
    from repro.obs.provenance import get_journal

    journal = get_journal()
    prov_was = journal.enabled
    was_enabled = tr.enabled
    n_hooks = len(tr.hooks)
    cap0 = sum(b.capacity_bytes() for b in acc.live().get("buffers", []))

    yield

    gc.collect()
    from repro.obs import get_registry as gr
    from repro.obs import get_tracer as gt
    from repro.obs.memory import get_accountant as ga

    assert gr() is reg, "metrics registry singleton swapped mid-module"
    assert gt() is tr, "span tracer singleton swapped mid-module"
    assert ga() is acc, "memory accountant singleton swapped mid-module"
    assert tr.enabled == was_enabled, "tracer enable state leaked"
    assert journal.enabled == prov_was, (
        "provenance journal enable state leaked"
    )
    assert len(tr.hooks) == n_hooks, "tracer hooks leaked (sampler not detached?)"
    cap1 = sum(b.capacity_bytes() for b in acc.live().get("buffers", []))
    assert cap1 <= cap0, (
        f"FactBuffers capacity leaked across the module: "
        f"{cap0}B at entry -> {cap1}B at exit"
    )
    reg.reset("mem.")
