"""Query subsystem tests: parser, planner, differential answering,
frozen-store snapshots, scratch reclamation, and serving caches."""

import numpy as np
import pytest

from repro.core import CMatEngine, Dictionary
from repro.core.generators import (
    chain,
    lubm_like,
    paper_example,
    random_kb,
    star,
)
from repro.query import (
    Query,
    QueryEngine,
    answer_flat,
    parse_query,
    plan_query,
)
from repro.query.exec import execute
from repro.query.plan import SCAN_INDEX, SCAN_SHARE


def materialised_engine(gen, **kw):
    program, dataset, d = gen(**kw)
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    return eng, d


# --------------------------------------------------------------------- #
# parser
# --------------------------------------------------------------------- #
class TestParser:
    def test_variable_projection(self):
        q = parse_query("?x, ?y <- P(?x, ?y), R(?x)")
        assert q.projection == ("x", "y")
        assert [a.predicate for a in q.body] == ["P", "R"]

    def test_atom_style_head(self):
        q = parse_query("Q(?x, ?y) <- P(?x, ?y)")
        assert q.projection == ("x", "y")

    def test_constants_interned(self):
        d = Dictionary()
        q = parse_query('?x <- P(?x, "dept3")', d)
        assert q.body[0].terms[1] == d.id_of("dept3")

    def test_ask_query(self):
        q = parse_query("<- P(?x, ?y)")
        assert q.is_ask and q.projection == ()

    def test_unbound_projection_rejected(self):
        with pytest.raises(ValueError):
            parse_query("?z <- P(?x, ?y)")

    def test_missing_arrow_rejected(self):
        with pytest.raises(ValueError):
            parse_query("P(?x, ?y)")

    def test_empty_body_rejected(self):
        with pytest.raises(ValueError):
            parse_query("?x <- ")

    def test_roundtrip_str(self):
        q = parse_query("?x <- P(?x, ?y), R(?x)")
        assert parse_query(str(q)) == q

    def test_constant_roundtrip_via_id_literals(self):
        # str() renders interned constants as numeric id literals, which
        # parse back as the same int constants — never as variables
        d = Dictionary()
        q = parse_query('?x <- P(?x, "dept3")', d)
        assert parse_query(str(q)) == q

    def test_garbage_term_rejected(self):
        with pytest.raises(ValueError):
            parse_query("?x <- P(?x, #4)")

    def test_to_text_roundtrips_constants(self):
        d = Dictionary()
        q = parse_query('?x <- P(?x, "dept3"), R(?x)', d)
        assert parse_query(q.to_text(d), d) == q


# --------------------------------------------------------------------- #
# planner
# --------------------------------------------------------------------- #
class TestPlanner:
    @pytest.fixture(scope="class")
    def lubm(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=6, n_students=100, n_courses=12, seed=1
        )
        return eng.facts.freeze(), d

    def test_constant_atom_ordered_first(self, lubm):
        frozen, d = lubm
        # takesCourse is much larger than the constant-bound memberOf atom
        q = parse_query('?s, ?c <- takesCourse(?s, ?c), memberOf(?s, "dept2")', d)
        plan = plan_query(q, frozen)
        assert plan.first.atom.predicate == "memberOf"
        assert plan.first.mode == SCAN_INDEX

    def test_order_is_selectivity_sorted(self, lubm):
        frozen, d = lubm
        q = parse_query(
            "?s, ?p, ?c <- takesCourse(?s, ?c), teacherOf(?p, ?c), advisor(?s, ?p)",
            d,
        )
        plan = plan_query(q, frozen)
        order = [a.predicate for a in plan.atom_order()]
        # teacherOf (smallest) first; takesCourse (largest) last
        assert order[0] == "teacherOf"
        assert order[-1] == "takesCourse"

    def test_share_scan_for_pure_variable_atom(self, lubm):
        frozen, d = lubm
        plan = plan_query(parse_query("?s, ?c <- takesCourse(?s, ?c)", d), frozen)
        assert plan.first.mode == SCAN_SHARE

    def test_unknown_predicate_gives_empty_plan(self, lubm):
        frozen, d = lubm
        plan = plan_query(parse_query("?x <- noSuchPred(?x, ?y)", d), frozen)
        assert plan.is_empty
        answers, _ = execute(plan, frozen)
        assert answers.shape == (0, 1)

    def test_connected_atoms_preferred_over_cartesian(self, lubm):
        frozen, d = lubm
        q = parse_query(
            '?s, ?c, ?p <- Professor(?p), memberOf(?s, "dept1"), takesCourse(?s, ?c)',
            d,
        )
        plan = plan_query(q, frozen)
        order = [a.predicate for a in plan.atom_order()]
        # constant-bound memberOf anchors the plan; the disconnected
        # Professor atom is deferred to the end (cartesian last)
        assert order[0] == "memberOf"
        assert order[-1] == "Professor"
        assert plan.joins[-1].kind == "xjoin"
        assert plan.joins[-1].key_vars == ()

    def test_explain_is_printable(self, lubm):
        frozen, d = lubm
        text = plan_query(
            parse_query('?s <- memberOf(?s, "dept1")', d), frozen
        ).explain()
        assert "scan[index]" in text and "project" in text


# --------------------------------------------------------------------- #
# differential: compressed answers == flat-join reference
# --------------------------------------------------------------------- #
LUBM_QUERIES = [
    '?s, ?c <- memberOf(?s, "dept3"), takesCourse(?s, ?c)',
    "?s, ?p <- advisor(?s, ?p), GraduateStudent(?s)",
    "?x, ?u <- memberOf(?x, ?dv), subOrganizationOf(?dv, ?u)",
    "?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)",
    '?s <- takesCourse(?s, "course2"), GraduateStudent(?s)',
    "?x <- knows(?x, ?x)",
    '<- Professor("prof1")',
    "?x, ?y <- GraduateStudent(?x), Course(?y)",  # cartesian
    "?p <- worksWith(?s, ?p), Faculty(?p)",
    '?q <- noSuchPred(?q)',
]

PAPER_QUERIES = [
    "?x, ?y <- S(?x, ?y)",
    '?x <- P(?x, "e2")',
    "?x, ?z <- P(?x, ?y), T(?y, ?z)",
    '<- S("a2", "d")',
    "?x <- R(?x), P(?x, ?y)",
]

CHAIN_QUERIES = [
    '?y <- path("v000002", ?y)',
    '?x <- path(?x, "v000030")',
    "?x, ?z <- edge(?x, ?y), path(?y, ?z)",
    "?x <- path(?x, ?x)",
]

STAR_QUERIES = [
    '?y <- S("s000004", ?y)',
    "?x, ?z <- S(?x, ?y), T(?y, ?z)",
    "?x <- P(?x, ?y), R(?x)",
]


class TestDifferential:
    def _check(self, eng, d, queries):
        qe = QueryEngine(eng, d)
        flat = eng.materialisation()
        for text in queries:
            query = parse_query(text, d)
            got = qe.answer(query).answers
            want = answer_flat(query, flat)
            np.testing.assert_array_equal(
                got, want, err_msg=f"query {text!r} diverged"
            )

    def test_lubm(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=6, n_students=100, n_courses=12, seed=1
        )
        self._check(eng, d, LUBM_QUERIES)

    def test_paper_example(self):
        eng, d = materialised_engine(paper_example, n=6, m=4)
        self._check(eng, d, PAPER_QUERIES)

    def test_chain(self):
        eng, d = materialised_engine(chain, n=40)
        self._check(eng, d, CHAIN_QUERIES)

    def test_star(self):
        eng, d = materialised_engine(star, n_spokes=60, n_hubs=3)
        self._check(eng, d, STAR_QUERIES)

    def test_random_kbs(self):
        rng = np.random.default_rng(0)
        for trial in range(5):
            program, dataset = random_kb(rng, n_constants=10, n_facts=30)
            eng = CMatEngine(program)
            eng.load(dataset)
            eng.materialise()
            qe = QueryEngine(eng)
            flat = eng.materialisation()
            for text in [
                "?x, ?y <- P(?x, ?y)",
                "?x <- P(?x, ?y), Q(?y, ?z)",
                "?x <- P(?x, ?x)",
                "?x, ?z <- P(?x, ?y), Q(?x, ?z)",
            ]:
                query = parse_query(text)
                got = qe.answer(query).answers
                want = answer_flat(query, flat)
                np.testing.assert_array_equal(
                    got, want, err_msg=f"trial {trial}, query {text!r}"
                )

    def test_pallas_lookup_path(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=4, n_students=60, n_courses=8, seed=2
        )
        qe = QueryEngine(eng, d, use_pallas=True)
        flat = eng.materialisation()
        # two constants in ONE atom: the non-anchor constant must filter
        # through the in_set kernel path (a single-constant atom would be
        # answered entirely by the index anchor and never reach it)
        row = flat["takesCourse"][0]
        s, c = d.term_of(int(row[0])), d.term_of(int(row[1]))
        query = parse_query(f'<- takesCourse("{s}", "{c}")', d)
        assert qe.answer(query).ask
        query = parse_query('?p <- advisor("student3", ?p), teacherOf(?p, "course2")', d)
        np.testing.assert_array_equal(
            qe.answer(query).answers, answer_flat(query, flat)
        )


# --------------------------------------------------------------------- #
# compressed-answering evidence + store hygiene
# --------------------------------------------------------------------- #
class TestExecutionStats:
    def test_multijoin_does_not_fully_unfold_large_predicates(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=6, n_students=200, n_courses=16, seed=0
        )
        qe = QueryEngine(eng, d, result_cache_size=0)
        res = qe.answer(
            parse_query('?s, ?c <- memberOf(?s, "dept2"), takesCourse(?s, ?c)', d)
        )
        assert res.n_answers > 0
        offenders = [
            p
            for p in res.stats.fully_unfolded()
            if res.stats.pred_rows[p] > res.n_answers
        ]
        assert offenders == [], f"fully unfolded: {offenders}"
        # takesCourse enters the semi-join through its key column only:
        # no whole rows, at most half its cells
        assert res.stats.rows_scanned.get("takesCourse", 0) == 0
        assert (
            res.stats.join_cells["takesCourse"]
            <= res.stats.pred_cells["takesCourse"] // 2
        )

    def test_xjoin_inputs_metered_honestly(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=6, n_students=200, n_courses=16, seed=0
        )
        qe = QueryEngine(eng, d, result_cache_size=0)
        res = qe.answer(
            parse_query(
                "?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)",
                d,
            )
        )
        assert res.n_answers > 0
        # no indexed scan materialises rows wholesale...
        assert sum(res.stats.rows_scanned.values()) == 0
        # ...but cross-join inputs are honestly counted as full-column
        # materialisation rather than hidden from the evidence
        assert any(v > 0 for v in res.stats.join_cells.values())

    def test_repeated_var_scan_reports_full_unfold(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=6, n_students=200, n_courses=16, seed=0
        )
        qe = QueryEngine(eng, d, result_cache_size=0)
        res = qe.answer(parse_query("?x <- knows(?x, ?x)", d))
        # a repeated-variable-only atom has no index anchor: the whole
        # snapshot is scanned and the stats must say so
        assert "knows" in res.stats.fully_unfolded()

    def test_indexed_scan_touches_only_matching_rows(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=6, n_students=200, n_courses=16, seed=0
        )
        qe = QueryEngine(eng, d, result_cache_size=0)
        res = qe.answer(parse_query('?s, ?c <- memberOf(?s, "dept2"), takesCourse(?s, ?c)', d))
        scanned = res.stats.rows_scanned["memberOf"]
        assert 0 < scanned < res.stats.pred_rows["memberOf"]

    def test_scratch_released_after_query(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=4, n_students=80, n_courses=8, seed=1
        )
        qe = QueryEngine(eng, d, result_cache_size=0)
        text = '?s, ?c <- memberOf(?s, "dept1"), takesCourse(?s, ?c)'
        qe.answer(text)  # builds snapshots
        n0 = qe.frozen.store.n_nodes()
        next0 = qe.frozen.store._next_id
        for _ in range(10):
            qe.answer(text)
        assert qe.frozen.store.n_nodes() == n0
        assert qe.frozen.store._next_id == next0

    def test_snapshot_built_once(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=4, n_students=80, n_courses=8, seed=1
        )
        qe = QueryEngine(eng, d, result_cache_size=0)
        text = '?s <- memberOf(?s, "dept1")'
        qe.answer(text)
        cells = qe.frozen.snapshot_cells
        assert cells > 0
        qe.answer(text)
        assert qe.frozen.snapshot_cells == cells


class TestFrozenFacts:
    def test_freeze_api(self):
        eng, _ = materialised_engine(paper_example)
        frozen = eng.facts.freeze()
        rows = frozen.snapshot("P")
        assert rows.shape == np.unique(eng.materialisation()["P"], axis=0).shape
        assert frozen.n_rows("P") >= rows.shape[0]

    def test_count_eq_matches_snapshot(self):
        eng, d = materialised_engine(paper_example)
        frozen = eng.facts.freeze()
        rows = frozen.snapshot("P")
        value = int(rows[0, 1])
        assert frozen.count_eq("P", 1, value) == int(
            (rows[:, 1] == value).sum()
        )
        np.testing.assert_array_equal(
            np.sort(frozen.eq_slice("P", 1, value), axis=0),
            np.sort(rows[rows[:, 1] == value], axis=0),
        )

    def test_release_reclaims_scratch_nodes(self):
        eng, _ = materialised_engine(paper_example)
        store = eng.store
        mark = store.mark()
        a = store.new_constant(7, 5)
        b = store.new_leaf(np.arange(4))
        store.new_concat([a, b])
        assert store.n_nodes() > mark or store._next_id > mark
        store.release(mark)
        assert store._next_id == mark
        assert all(cid < mark for cid in store._nodes)


class TestServingCaches:
    def test_result_cache_hit_returns_equal_answers(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=4, n_students=60, n_courses=8, seed=0
        )
        qe = QueryEngine(eng, d)
        text = '?s, ?c <- memberOf(?s, "dept1"), takesCourse(?s, ?c)'
        first = qe.answer(text)
        second = qe.answer(text)
        assert not first.from_cache and second.from_cache
        np.testing.assert_array_equal(first.answers, second.answers)
        assert qe.cache_stats()["result_hits"] == 1

    def test_plan_cache(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=4, n_students=60, n_courses=8, seed=0
        )
        qe = QueryEngine(eng, d, result_cache_size=0)
        text = "?s, ?p <- advisor(?s, ?p)"
        p1 = qe.plan(text)
        p2 = qe.plan(text)
        assert p1 is p2
        assert qe.plan_hits == 1

    def test_cached_answers_immune_to_caller_mutation(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=4, n_students=60, n_courses=8, seed=0
        )
        qe = QueryEngine(eng, d)
        text = "?s, ?p <- advisor(?s, ?p)"
        first = qe.answer(text)
        with pytest.raises(ValueError):
            first.answers[:] = -1  # cached arrays are read-only
        np.testing.assert_array_equal(qe.answer(text).answers, first.answers)

    def test_empty_dictionary_is_still_a_dictionary(self):
        # an empty Dictionary is falsy; the engine must not mistake it
        # for 'no dictionary' and lose the unknown-constant sentinel
        program, dataset = None, None
        from repro.core.generators import random_kb

        rng = np.random.default_rng(3)
        program, dataset = random_kb(rng, n_constants=8, n_facts=20)
        eng = CMatEngine(program)
        eng.load(dataset)
        eng.materialise()
        qe = QueryEngine(eng, Dictionary())
        res = qe.answer('?x <- P(?x, "unknownTerm")')
        assert res.n_answers == 0

    def test_unknown_constant_does_not_grow_dictionary(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=4, n_students=60, n_courses=8, seed=0
        )
        qe = QueryEngine(eng, d)
        n0 = len(d)
        for i in range(20):
            res = qe.answer(f'?s <- memberOf(?s, "nosuch{i}")')
            assert res.n_answers == 0
        assert len(d) == n0

    def test_lru_eviction(self):
        eng, d = materialised_engine(
            lubm_like, n_dept=4, n_students=60, n_courses=8, seed=0
        )
        qe = QueryEngine(eng, d, result_cache_size=2)
        texts = [f'?s <- memberOf(?s, "dept{i}")' for i in range(3)]
        for t in texts:
            qe.answer(t)
        assert len(qe._result_cache) == 2
        # oldest entry evicted -> re-answering it is a miss
        qe.answer(texts[0])
        assert qe.cache_stats()["result_hits"] == 0


class TestAsk:
    def test_ask_true_false(self):
        eng, d = materialised_engine(paper_example)
        qe = QueryEngine(eng, d)
        assert qe.answer(parse_query('<- S("a2", "d")', d)).ask
        assert not qe.answer(parse_query('<- S("a1", "d")', d)).ask
