"""Distributed semi-naive + incremental delta exchange: parity against
the host engines, work-skipping evidence (the acceptance criteria of the
delta-restricted rounds), exchange regrow, and differential ``apply``
against a host IncrementalStore.

Runs on whatever mesh the session has (1 CPU device locally; the CI
multi-device matrix forces 4, exercising real ``all_to_all``)."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
from jax.sharding import Mesh  # noqa: E402

from repro.core import CMatEngine, flat_seminaive  # noqa: E402
from repro.core.distributed import DistributedEngine  # noqa: E402
from repro.core.generators import chain, lubm_like, random_kb  # noqa: E402
from repro.incremental import IncrementalStore  # noqa: E402


def make_mesh():
    devs = np.asarray(jax.devices())
    return Mesh(devs, ("data",))


def as_sets(facts):
    return {
        p: frozenset(map(tuple, np.asarray(r).astype(np.int64).tolist()))
        for p, r in facts.items()
        if np.asarray(r).shape[0]
    }


def two_atom(program):
    rules = [r for r in program if len(r.body) <= 2]
    return type(program)(rules)


def supported(program):
    """The engine's own fragment filter (shared with serve/benches)."""
    return DistributedEngine.supported_program(program)


def subtract(dataset, dels):
    out = {}
    for pred, rows in dataset.items():
        rows = np.asarray(rows, dtype=np.int64).reshape(len(rows), -1)
        drop = {
            tuple(r)
            for r in np.asarray(
                dels.get(pred, np.zeros((0, rows.shape[1])))
            ).astype(np.int64).reshape(-1, rows.shape[1]).tolist()
        }
        keep = [r for r in rows.tolist() if tuple(r) not in drop]
        if keep:
            out[pred] = np.asarray(keep, dtype=np.int64)
    return out


def union(dataset, adds):
    out = {p: np.asarray(r, dtype=np.int64) for p, r in dataset.items()}
    for pred, rows in adds.items():
        rows = np.asarray(rows, dtype=np.int64).reshape(len(rows), -1)
        prev = out.get(pred)
        merged = rows if prev is None else np.concatenate([prev, rows])
        out[pred] = np.unique(merged, axis=0)
    return out


def pick_batch(dataset, k, seed=0):
    rng = np.random.default_rng(seed)
    pool = [
        (p, tuple(int(v) for v in row))
        for p, rows in dataset.items()
        for row in np.asarray(rows).reshape(len(rows), -1)
    ]
    rng.shuffle(pool)
    out: dict[str, list] = {}
    for p, row in pool[:k]:
        out.setdefault(p, []).append(row)
    return {p: np.asarray(r, dtype=np.int64) for p, r in out.items()}


KBS = [
    ("chain", lambda: chain(15)),
    ("lubm", lambda: lubm_like(n_dept=3, n_students=40, n_courses=6, seed=0)),
]


# --------------------------------------------------------------------- #
# semi-naive parity + work skipping (the tentpole acceptance criteria)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name,gen", KBS)
def test_seminaive_parity_and_skips_work(name, gen):
    """Delta-restricted rounds reach the same fixpoint as FlatEngine and
    CMatEngine, skip (rule, pivot) pairs without a probe, and join
    strictly fewer rows than the naive distributed path."""
    program, dataset, _ = gen()
    program = two_atom(program)
    want = as_sets(flat_seminaive(program, dataset))
    cmat = CMatEngine(program)
    cmat.load(dataset)
    cmat.materialise()
    assert as_sets(cmat.materialisation()) == want

    sn = DistributedEngine(program, make_mesh(), capacity=1 << 12)
    got = as_sets(sn.materialise(dataset))
    assert got == want

    nv = DistributedEngine(
        program, make_mesh(), capacity=1 << 12,
        seminaive=False, planner_exchange_keys=False,
    )
    assert as_sets(nv.materialise(dataset)) == want

    assert sn.stats.rows_joined < nv.stats.rows_joined
    assert sn.stats.rule_applications_skipped > 0
    if name == "lubm":
        # acceptance: the lubm preset demonstrably skips work
        assert sn.stats.per_stratum  # stratified fixpoint ran
        assert sn.stats.n_strata > 1


def test_round_deltas_strictly_shrink_on_acyclic_data():
    """On transitive closure over an acyclic chain, per-round deltas
    shrink monotonically — the delta restriction is doing its job."""
    program, dataset, _ = chain(20)
    eng = DistributedEngine(program, make_mesh(), capacity=1 << 11)
    eng.materialise(dataset)
    news = [r["new_facts"] for r in eng.stats.per_round]
    # drop the trailing empty fixpoint round(s)
    while news and news[-1] == 0:
        news.pop()
    assert len(news) >= 3
    assert all(a > b for a, b in zip(news, news[1:])), news


def test_planner_exchange_keys_skip_aligned_sides():
    """chain TC: ``edge(y, z)`` stores y first, so the planner-keyed join
    never re-exchanges the edge side (visible whenever the mesh has >1
    shard; on 1 shard no exchange is scheduled at all)."""
    program, dataset, _ = chain(12)
    mesh = make_mesh()
    eng = DistributedEngine(program, mesh, capacity=1 << 11)
    eng.materialise(dataset)
    if mesh.shape["data"] > 1:
        assert eng.stats.exchanges_skipped > 0
        assert eng.stats.exchanges > 0
    else:
        assert eng.stats.exchanges == 0


def test_merge_block_exact_fill_keeps_last_row():
    """Appending exactly up to capacity must not lose the row written to
    the final slot: parked non-fresh writes are dropped out of bounds,
    never scattered onto slot cap-1 (duplicate-index scatter order is
    undefined)."""
    import jax.numpy as jnp

    program, dataset, _ = chain(3)
    eng = DistributedEngine(program, make_mesh(), capacity=8)
    trows = jnp.asarray(
        np.concatenate(
            [np.arange(12).reshape(6, 2), np.full((2, 2), -1)]
        ).astype(np.int32)
    )
    # candidates: fresh, fresh, duplicate (parked) — 6 + 2 == capacity
    cand = jnp.asarray(np.asarray([[50, 50], [9, 9], [50, 50]], np.int32))
    valid = jnp.asarray([True, True, True])
    nrows, ncnt, n_fresh, overflow = eng._merge_block(
        trows, jnp.int32(6), cand, valid
    )
    got = np.asarray(nrows).tolist()
    assert int(ncnt) == 8 and int(n_fresh) == 2 and int(overflow) == 0
    assert [9, 9] in got and [50, 50] in got


def test_exchange_regrow_instead_of_abort():
    """A join bigger than join_capacity regrows padding and retries the
    round (counted in stats) instead of raising mid-fixpoint."""
    program, dataset, _ = chain(30)
    eng = DistributedEngine(
        program, make_mesh(), capacity=1 << 10, join_capacity=8
    )
    got = as_sets(eng.materialise(dataset))
    assert got == as_sets(flat_seminaive(program, dataset))
    assert eng.stats.exchange_regrows > 0
    # variants traced at superseded padding factors are evicted, not
    # stranded (long-running update loops would leak executables)
    stale = [
        k for k in eng._variants
        if isinstance(k[-1], int) and k[-1] != eng._factor
    ]
    assert not stale


def test_constants_out_of_packing_range_are_rejected():
    """pack_pairs keys are 15/16-bit halves; ids >= MAX_DIST_CONST (or
    negative rows) must raise instead of silently corrupting joins."""
    program, dataset, _ = chain(5)
    eng = DistributedEngine(program, make_mesh(), capacity=1 << 9)
    bad = dict(dataset)
    bad["edge"] = np.asarray([[40000, 1]], np.int64)
    with pytest.raises(ValueError, match="constants"):
        eng.materialise(bad)
    eng2 = DistributedEngine(program, make_mesh(), capacity=1 << 9)
    eng2.materialise(dataset)
    with pytest.raises(ValueError, match="constants"):
        eng2.apply(additions={"edge": np.asarray([[1, 40000]], np.int64)})


# --------------------------------------------------------------------- #
# incremental deltas through the exchange
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name,gen", KBS)
def test_apply_differential_vs_host_incremental(name, gen):
    """apply(adds, dels) lands on the host IncrementalStore's exact fact
    set (differential check_integrity) and round-trips back."""
    program, dataset, _ = gen()
    program = two_atom(program)
    dist = DistributedEngine(program, make_mesh(), capacity=1 << 12)
    dist.materialise(dataset)
    original = dist.to_dict()
    inc = IncrementalStore(program)
    inc.load(dataset)
    dist.check_integrity(inc)

    dels = pick_batch(dataset, 5, seed=1)
    arity_of = {
        p: np.asarray(r).reshape(len(r), -1).shape[1]
        for p, r in dataset.items()
    }
    adds = {
        p: (np.arange(2 * arity_of[p]).reshape(2, arity_of[p]) + 900).astype(
            np.int64
        )
        for p in list(dataset)[:2]
    }
    st = dist.apply(additions=adds, deletions=dels)
    inc.apply(additions=adds, deletions=dels)
    inc.check_integrity()
    dist.check_integrity(inc)
    assert st.epoch == 1
    assert st.n_del_explicit > 0 and st.n_add_explicit > 0

    # inverse batch restores the original materialisation bit for bit
    dist.apply(additions=dels, deletions=adds)
    inc.apply(additions=dels, deletions=adds)
    dist.check_integrity(inc)
    assert as_sets(dist.to_dict()) == as_sets(original)


def test_apply_delete_all_drains_the_shards():
    program, dataset, _ = chain(12)
    dist = DistributedEngine(program, make_mesh(), capacity=1 << 11)
    dist.materialise(dataset)
    st = dist.apply(deletions=dataset)
    assert dist.to_dict() == {}
    assert st.n_deleted > 0 and st.n_rederived == 0
    dist.apply(additions=dataset)
    assert as_sets(dist.to_dict()) == as_sets(
        flat_seminaive(program, dataset)
    )


def test_apply_requires_materialise_first():
    program, dataset, _ = chain(4)
    eng = DistributedEngine(program, make_mesh())
    with pytest.raises(RuntimeError, match="materialise"):
        eng.apply(additions=dataset)


def test_random_batches_match_rematerialisation():
    """Randomised add/delete batches applied sequentially: the sharded
    store equals a from-scratch re-materialisation of the updated EDB
    and stays in lockstep with the host IncrementalStore."""
    rng = np.random.default_rng(7)
    program, dataset = random_kb(
        rng, n_constants=8, n_facts=18, n_rules=4
    )
    program = supported(program)
    if not len(program.rules):
        pytest.skip("random draw produced no supported rules")
    dist = DistributedEngine(program, make_mesh(), capacity=1 << 11)
    dist.materialise(dataset)
    inc = IncrementalStore(program)
    inc.load(dataset)
    explicit = {p: np.asarray(r, np.int64) for p, r in dataset.items()}
    for trial in range(6):
        dels = {
            p: rows[
                rng.choice(
                    rows.shape[0],
                    size=int(rng.integers(1, rows.shape[0] + 1)),
                    replace=False,
                )
            ]
            for p, rows in explicit.items()
            if rows.shape[0] and rng.random() < 0.7
        }
        adds = {
            p: rng.integers(20, 26, size=(2, rows.shape[1])).astype(np.int64)
            for p, rows in dataset.items()
            if rng.random() < 0.5
        }
        dist.apply(additions=adds, deletions=dels)
        inc.apply(additions=adds, deletions=dels)
        explicit = union(subtract(explicit, dels), adds)
        want = as_sets(flat_seminaive(program, explicit))
        assert as_sets(dist.to_dict()) == want, f"trial {trial}"
        dist.check_integrity(inc)


# --------------------------------------------------------------------- #
# hypothesis round-trip (random batches on a fixed recursive program)
# --------------------------------------------------------------------- #
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements-dev
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core import parse_program

    HYP_PROGRAM = parse_program(
        """
        edge(x, y) -> path(x, y)
        path(x, y), edge(y, z) -> path(x, z)
        edge(x, y) -> node(x)
        edge(x, y) -> node(y)
        """
    )

    @hst.composite
    def hyp_edges(draw):
        n = draw(hst.integers(min_value=2, max_value=8))
        rows = draw(
            hst.lists(
                hst.tuples(
                    hst.integers(min_value=0, max_value=6),
                    hst.integers(min_value=0, max_value=6),
                ),
                min_size=n,
                max_size=n,
            )
        )
        return np.unique(np.asarray(rows, dtype=np.int64), axis=0)

    @settings(
        max_examples=8,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(data=hst.data(), edges=hyp_edges())
    def test_hypothesis_apply_round_trip(data, edges):
        """apply(adds, dels); apply(dels, adds) round-trips the sharded
        store bit-identically, with each intermediate state equal to a
        re-materialisation of the updated EDB."""
        dataset = {"edge": edges}
        dist = DistributedEngine(
            HYP_PROGRAM, make_mesh(), capacity=1 << 10
        )
        dist.materialise(dataset)
        original = dist.to_dict()

        k = data.draw(
            hst.integers(min_value=0, max_value=edges.shape[0])
        )
        dels = {"edge": edges[:k]} if k else {}
        n_add = data.draw(hst.integers(min_value=0, max_value=3))
        adds = {}
        if n_add:
            rows = data.draw(
                hst.lists(
                    hst.tuples(
                        hst.integers(min_value=100, max_value=104),
                        hst.integers(min_value=100, max_value=104),
                    ),
                    min_size=n_add,
                    max_size=n_add,
                )
            )
            adds = {"edge": np.unique(np.asarray(rows, np.int64), axis=0)}

        dist.apply(additions=adds, deletions=dels)
        want_mid = as_sets(
            flat_seminaive(
                HYP_PROGRAM, union(subtract(dataset, dels), adds)
            )
        )
        assert as_sets(dist.to_dict()) == want_mid

        dist.apply(additions=dels, deletions=adds)
        assert as_sets(dist.to_dict()) == as_sets(original)
