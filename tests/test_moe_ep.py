"""EP shard_map MoE vs gather-path equivalence on a multi-device CPU mesh.

Runs in a subprocess so the forced device count never leaks into other
tests (jax locks the device count at first init).
"""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh

from repro.compat import set_mesh

from repro.configs import get_config
from repro.models import moe
from repro.models.sharding_policy import clear_policy, set_policy_from_mesh
from dataclasses import replace

cfg = get_config("qwen2-moe-a2.7b", smoke=True)
# generous capacity so neither path drops tokens -> exact comparison
cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))

key = jax.random.PRNGKey(0)
params = moe.moe_init(key, cfg)
x = (jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.1
     ).astype(jnp.bfloat16)

clear_policy()
y_ref, aux_ref = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg))(params, x)

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
set_policy_from_mesh(mesh)
with set_mesh(mesh):
    y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_apply(p, x, cfg))(params, x)

np.testing.assert_allclose(
    np.asarray(y_ref, np.float32), np.asarray(y_ep, np.float32),
    rtol=0.05, atol=0.05,
)
# aux differs slightly: per-data-shard load statistics vs global (mean of
# products != product of means); it is a regularizer, 5% is fine
np.testing.assert_allclose(float(aux_ref), float(aux_ep), rtol=5e-2)

# gradient flows through the EP path
with set_mesh(mesh):
    def loss(p):
        y, aux = moe.moe_apply(p, x, cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2) + aux
    g = jax.jit(jax.grad(loss))(params)
leaves = jax.tree_util.tree_leaves(g)
assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)
assert any(float(jnp.abs(l.astype(jnp.float32)).max()) > 0 for l in leaves)
print("EP==GATHER OK")
"""


def test_moe_ep_matches_gather():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr[-3000:]}"
    assert "EP==GATHER OK" in out.stdout
