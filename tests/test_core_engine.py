"""Correctness tests for the compressed materialisation engine."""

import numpy as np
import pytest

from repro.core import CMatEngine, flat_seminaive, parse_program
from repro.core.generators import (
    bipartite,
    chain,
    lubm_like,
    paper_example,
    star,
)


def _as_sets(facts):
    return {
        p: {tuple(r) for r in rows}
        for p, rows in facts.items()
        if rows.shape[0]
    }


def assert_same_materialisation(program, dataset, **engine_kw):
    expected = _as_sets(flat_seminaive(program, dataset))
    eng = CMatEngine(program, **engine_kw)
    eng.load(dataset)
    eng.materialise()
    actual = _as_sets(eng.materialisation())
    assert actual == expected
    return eng


class TestPaperExample:
    def test_materialisation_matches_flat(self):
        program, dataset, _ = paper_example(n=4, m=3)
        assert_same_materialisation(program, dataset)

    def test_round_structure(self):
        """Fixpoint in <= 4 rounds + final empty round (paper §3)."""
        program, dataset, _ = paper_example(n=5, m=4)
        eng = CMatEngine(program)
        eng.load(dataset)
        stats = eng.materialise()
        assert stats.rounds <= 4

    def test_derived_predicates(self):
        n, m = 6, 4
        program, dataset, _ = paper_example(n=n, m=m)
        eng = CMatEngine(program)
        eng.load(dataset)
        eng.materialise()
        mat = eng.materialisation()
        # S(a_2i, d) for i in 1..n  plus  S(a_2i, e_j) from round 3
        assert mat["S"].shape[0] == n + n * m
        # P gains a_2i x e_j pairs
        assert mat["P"].shape[0] == 2 * n + m + n * m

    def test_compression_is_linear_not_quadratic(self):
        """Paper §3 'Termination': derived storage is O(n), flat is O(n*m)."""
        program, dataset, _ = paper_example(n=50, m=40)
        eng = CMatEngine(program)
        eng.load(dataset)
        eng.materialise()
        rep = eng.report()
        derived_flat = rep["flat_size_I"] - rep["flat_size_E"]
        derived_compressed = rep["compressed_size"] - rep["flat_size_E"]
        # compressed derivations must be well below the flat blow-up
        assert derived_compressed < 0.5 * derived_flat


class TestWorkloads:
    @pytest.mark.parametrize("n,m", [(1, 1), (2, 3), (8, 5)])
    def test_paper_example_sizes(self, n, m):
        program, dataset, _ = paper_example(n=n, m=m)
        assert_same_materialisation(program, dataset)

    def test_lubm_like(self):
        program, dataset, _ = lubm_like(n_dept=5, n_students=40, n_courses=8)
        assert_same_materialisation(program, dataset)

    def test_chain_transitive_closure(self):
        program, dataset, _ = chain(n=25)
        eng = assert_same_materialisation(program, dataset)
        mat = eng.materialisation()
        n = 25
        assert mat["path"].shape[0] == n * (n + 1) // 2

    def test_star(self):
        program, dataset, _ = star(n_spokes=64, n_hubs=3)
        assert_same_materialisation(program, dataset)

    def test_bipartite_cross_join(self):
        program, dataset, _ = bipartite(n_left=20, n_right=30)
        eng = assert_same_materialisation(program, dataset)
        assert eng.materialisation()["C"].shape[0] == 20 * 30

    def test_copy_mode_matches_inplace(self):
        program, dataset, _ = lubm_like(n_dept=4, n_students=30, n_courses=6)
        a = assert_same_materialisation(program, dataset, inplace_splits=True)
        b = assert_same_materialisation(program, dataset, inplace_splits=False)
        assert _as_sets(a.materialisation()) == _as_sets(b.materialisation())

    @pytest.mark.parametrize("gen", [
        lambda: chain(30),
        lambda: lubm_like(n_dept=4, n_students=40, n_courses=8),
        lambda: paper_example(5, 4),
        lambda: star(n_spokes=50, n_hubs=3),
    ])
    def test_dedup_index_equivalent(self, gen):
        """The persistent dedup index must not change the materialisation."""
        program, dataset, _ = gen()
        assert_same_materialisation(program, dataset, dedup_index=True)


class TestRuleFeatures:
    def test_constant_in_body(self):
        program = parse_program("edge(x, 7) -> hasSeven(x)")
        # note: numeric constants are not parsed; build manually
        from repro.core.datalog import Atom, Program, Rule

        program = Program([Rule((Atom("edge", ("x", 7)),), Atom("hasSeven", ("x",)))])
        dataset = {"edge": np.asarray([[1, 7], [2, 8], [3, 7]], dtype=np.int64)}
        assert_same_materialisation(program, dataset)

    def test_repeated_variable_in_body(self):
        from repro.core.datalog import Atom, Program, Rule

        program = Program([Rule((Atom("edge", ("x", "x")),), Atom("selfloop", ("x",)))])
        dataset = {
            "edge": np.asarray([[1, 1], [1, 2], [3, 3], [4, 5]], dtype=np.int64)
        }
        assert_same_materialisation(program, dataset)

    def test_repeated_variable_in_head(self):
        from repro.core.datalog import Atom, Program, Rule

        program = Program([Rule((Atom("node", ("x",)),), Atom("eq", ("x", "x")))])
        dataset = {"node": np.asarray([[1], [2], [5]], dtype=np.int64)}
        assert_same_materialisation(program, dataset)

    def test_constant_in_head(self):
        from repro.core.datalog import Atom, Program, Rule

        program = Program([Rule((Atom("node", ("x",)),), Atom("typed", ("x", 99)))])
        dataset = {"node": np.asarray([[1], [2]], dtype=np.int64)}
        assert_same_materialisation(program, dataset)

    def test_cartesian_product_body(self):
        from repro.core.datalog import Atom, Program, Rule

        program = Program(
            [Rule((Atom("A", ("x",)), Atom("B", ("y",))), Atom("pair", ("x", "y")))]
        )
        dataset = {
            "A": np.asarray([[1], [2]], dtype=np.int64),
            "B": np.asarray([[7], [8], [9]], dtype=np.int64),
        }
        eng = assert_same_materialisation(program, dataset)
        assert eng.materialisation()["pair"].shape[0] == 6

    def test_three_atom_body(self):
        from repro.core.datalog import Atom, Program, Rule

        program = Program(
            [
                Rule(
                    (
                        Atom("E", ("x", "y")),
                        Atom("E", ("y", "z")),
                        Atom("E", ("z", "w")),
                    ),
                    Atom("tri", ("x", "w")),
                )
            ]
        )
        rng = np.random.default_rng(0)
        dataset = {
            "E": np.unique(rng.integers(0, 8, size=(30, 2)), axis=0).astype(np.int64)
        }
        assert_same_materialisation(program, dataset)
