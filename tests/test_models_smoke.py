"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and absence of NaNs; plus a decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models.model import Model
from repro.models import transformer

ARCHS = [
    "qwen3-0.6b",
    "granite-20b",
    "deepseek-7b",
    "llama3.2-1b",
    "qwen2-moe-a2.7b",
    "deepseek-v3-671b",
    "falcon-mamba-7b",
    "zamba2-1.2b",
    "seamless-m4t-large-v2",
    "qwen2-vl-72b",
]

B, S = 2, 32


def _smoke_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size, jnp.int32)
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = (
            jax.random.normal(ks[1], (B, 16, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        batch["src_embeds"] = (
            jax.random.normal(ks[2], (B, 2 * S, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
    return batch


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_configs())
    assert len(list_configs()) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True)
    )(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(metrics["xent"]) > 0
    # gradient sanity: finite and at least one non-zero
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_logits_shape(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, _ = jax.jit(model.logits)(params, batch)
    total = S + (16 if cfg.family == "vlm" else 0)
    assert logits.shape == (B, total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, max_len=16)
    token = jnp.zeros((B, 1), jnp.int32)
    memory = None
    if cfg.family == "encdec":
        memory = (
            jax.random.normal(jax.random.PRNGKey(2), (B, 8, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)

    step = jax.jit(
        lambda p, t, c, n: model.decode_step(p, t, c, n, memory=memory)
    )
    logits, cache = step(params, token, cache, jnp.int32(0))
    logits2, cache = step(params, token, cache, jnp.int32(1))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all()


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_config("llama3.2-1b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size, jnp.int32)
    full_logits, _ = model.logits(params, {"tokens": tokens})

    cache = model.init_cache(1, max_len=8)
    outs = []
    for t in range(8):
        logits, cache = model.decode_step(
            params, tokens[:, t : t + 1], cache, jnp.int32(t)
        )
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32),
        np.asarray(dec_logits, np.float32),
        rtol=2e-2,
        atol=2e-2,
    )


def test_decode_matches_forward_ssm():
    cfg = get_config("falcon-mamba-7b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size, jnp.int32)
    full_logits, _ = model.logits(params, {"tokens": tokens})
    cache = model.init_cache(1, max_len=8)
    outs = []
    for t in range(8):
        logits, cache = model.decode_step(
            params, tokens[:, t : t + 1], cache, jnp.int32(t)
        )
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    # bf16 compute: one rounding difference in a d-dim dot product shifts a
    # logit by ~0.01-0.08; state propagation errors would *grow* with
    # position (verified flat in debugging), so a flat tolerance suffices.
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32),
        np.asarray(dec_logits, np.float32),
        rtol=0.1,
        atol=0.12,
    )
