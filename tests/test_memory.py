"""obs.memory accounting invariants.

Four families, mirroring the double-count rules in DESIGN.md:

* **running counters** — ``ColumnStore`` maintains owned/backed/cache
  byte counters incrementally; after any workload they must equal a
  from-scratch recount and sum to ``total_nbytes()``,
* **part sums** — every ``memory_report()`` splits a subsystem into
  disjoint parts, so the parts must sum back to the subsystem's own
  total (``SortedRows.nbytes``, ``FrozenFacts.snapshot_*_bytes``),
* **the snapshot double-count rule** — rows restored as ``frombuffer``
  views over a decompressed blob are *backed*, never resident: a
  restored store reports them under ``*_snapshot_backed_bytes`` and the
  accountant's resident roll-up excludes them,
* **conservation** — the fact set's flat-equivalent bytes are invariant
  across freeze / save-snapshot / restore / compact (compaction may
  only shrink the mu side), property-tested over random KBs when
  hypothesis is available.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import CMatEngine
from repro.core.frozen import SortedRows
from repro.core.generators import lubm_like, paper_example
from repro.incremental import IncrementalStore
from repro.obs.memory import (
    MemoryAccountant,
    array_is_backed,
    predicate_effectiveness,
    split_owned_backed,
)
from repro.storage import compact_store, restore_incremental, write_snapshot


def _pick_batch(dataset, k, seed=0):
    rng = np.random.default_rng(seed)
    pred = sorted(dataset)[0]
    rows = np.asarray(dataset[pred]).reshape(len(dataset[pred]), -1)
    sel = rng.choice(rows.shape[0], size=min(k, rows.shape[0]), replace=False)
    return {pred: rows[sel]}


def _assert_counters_in_sync(store):
    """Running owned/backed/cache counters == a from-scratch recount."""
    before = (store._nbytes_owned, store._nbytes_backed, store._cache_nbytes)
    store.recount_bytes()
    after = (store._nbytes_owned, store._nbytes_backed, store._cache_nbytes)
    assert before == after, f"running counters drifted: {before} != {after}"
    assert store._nbytes_owned + store._nbytes_backed == store.total_nbytes()


# --------------------------------------------------------------------- #
# running counters
# --------------------------------------------------------------------- #
def test_column_counters_survive_materialise_and_churn():
    program, dataset, _ = lubm_like(3, 40, 8)
    inc = IncrementalStore(program)
    inc.load(dataset)
    _assert_counters_in_sync(inc.store)
    batch = _pick_batch(dataset, 4)
    inc.apply(deletions=batch)  # copy-splits redefine + add nodes
    inc.apply(additions=batch)
    _assert_counters_in_sync(inc.store)


def test_column_counters_after_release_and_cache_drop():
    program, dataset, _ = paper_example(n=6, m=4)
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    store = eng.facts.store
    _assert_counters_in_sync(store)
    store.drop_caches()
    assert store._cache_nbytes == 0
    _assert_counters_in_sync(store)


# --------------------------------------------------------------------- #
# part sums
# --------------------------------------------------------------------- #
def test_sorted_rows_parts_sum_to_nbytes():
    rows = np.arange(24, dtype=np.int64).reshape(12, 2).copy()
    sr = SortedRows(rows)
    sr.col_order(1)  # build a lazy order so lazy_order_bytes is non-zero
    parts = sr.memory_report()
    assert sum(parts.values()) == sr.nbytes
    assert parts["rows_snapshot_backed_bytes"] == 0
    assert parts["lazy_order_bytes"] > 0


def test_sorted_rows_backed_parts_sum_to_nbytes():
    owned = np.arange(24, dtype=np.int64).reshape(12, 2).copy()
    backed = np.frombuffer(owned.tobytes(), dtype=np.int64).reshape(12, 2)
    assert array_is_backed(backed) and not array_is_backed(owned)
    sr = SortedRows(backed)
    parts = sr.memory_report()
    assert sum(parts.values()) == sr.nbytes
    assert parts["rows_bytes"] == 0
    assert parts["rows_snapshot_backed_bytes"] == backed.nbytes


def test_split_owned_backed_partitions():
    owned = np.arange(10, dtype=np.int64)
    backed = np.frombuffer(owned.tobytes(), dtype=np.int64)
    o, b = split_owned_backed([owned, backed])
    assert o == owned.nbytes and b == backed.nbytes


def test_frozen_report_matches_per_snapshot_sums():
    program, dataset, _ = lubm_like(3, 40, 8)
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    frozen = eng.facts.freeze()
    for pred in frozen.predicates():
        frozen.sorted_rows(pred)  # build every snapshot
    parts = frozen.memory_report()
    assert parts["snapshots_bytes"] == frozen.snapshot_resident_bytes()
    assert (
        parts["snapshots_snapshot_backed_bytes"]
        == frozen.snapshot_backed_bytes()
    )
    total = sum(
        frozen.sorted_rows(p).nbytes for p in frozen.predicates()
    )
    assert (
        frozen.snapshot_resident_bytes() + frozen.snapshot_backed_bytes()
        == total
    )


# --------------------------------------------------------------------- #
# the snapshot double-count rule
# --------------------------------------------------------------------- #
def test_restored_store_reports_blob_views_as_backed(tmp_path):
    program, dataset, _ = lubm_like(3, 40, 8)
    inc = IncrementalStore(program)
    inc.load(dataset)
    write_snapshot(
        str(tmp_path / "snap"), inc.facts,
        epoch=inc.epoch, round_tag=inc._round,
        rows=inc.rows.to_dict(), counts=inc.counts,
        explicit=inc.explicit, arities=inc.arities,
    )
    inc2, _ = restore_incremental(program, str(tmp_path / "snap"))
    _assert_counters_in_sync(inc2.store)
    col = inc2.store.memory_report()
    assert col["nodes_snapshot_backed_bytes"] > 0, "restore must adopt views"
    row = inc2.memory_report()
    assert row["index_snapshot_backed_bytes"] > 0

    # the accountant's resident roll-up excludes every backed part, so a
    # restored store no longer double-counts the blob it shares with the
    # side tables (each blob region counts at most once, as backed)
    acc = MemoryAccountant()
    acc.register("columns", inc2.store)
    acc.register("inc", inc2)
    collected = acc.collect()
    resident = acc.resident_bytes(collected)
    backed = sum(
        v
        for parts in collected.values()
        for k, v in parts.items()
        if k.endswith("_snapshot_backed_bytes")
    )
    all_bytes = sum(
        v
        for parts in collected.values()
        for k, v in parts.items()
        if k.endswith("_bytes")
    )
    assert backed > 0
    assert resident + backed == all_bytes


# --------------------------------------------------------------------- #
# conservation across freeze / save / restore / compact
# --------------------------------------------------------------------- #
def _flat_bytes(facts):
    return {
        p: e["flat_bytes"] for p, e in predicate_effectiveness(facts).items()
    }


def test_flat_bytes_conserved_across_roundtrip_and_compact(tmp_path):
    program, dataset, _ = lubm_like(3, 40, 8)
    inc = IncrementalStore(program)
    inc.load(dataset)
    batch = _pick_batch(dataset, 4)
    inc.apply(deletions=batch)
    inc.apply(additions=batch)
    want = _flat_bytes(inc.facts)
    mu_before = predicate_effectiveness(inc.facts)["_total"]["mu_bytes"]

    write_snapshot(
        str(tmp_path / "snap"), inc.facts,
        epoch=inc.epoch, round_tag=inc._round,
        rows=inc.rows.to_dict(), counts=inc.counts,
        explicit=inc.explicit, arities=inc.arities,
    )
    inc2, _ = restore_incremental(program, str(tmp_path / "snap"))
    assert _flat_bytes(inc2.facts) == want

    compact_store(inc)
    _assert_counters_in_sync(inc.store)
    eff = predicate_effectiveness(inc.facts)
    assert _flat_bytes(inc.facts) == want
    # compaction hash-conses: the mu side may only shrink
    assert eff["_total"]["mu_bytes"] <= mu_before


def test_total_row_summarises_cross_predicate_sharing():
    program, dataset, _ = lubm_like(4, 60, 10)
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    eff = predicate_effectiveness(eng.facts)
    total = eff["_total"]
    per_pred_mu = sum(
        e["mu_bytes"] for p, e in eff.items() if p != "_total"
    )
    assert total["flat_bytes"] == sum(
        e["flat_bytes"] for p, e in eff.items() if p != "_total"
    )
    # derived taxonomic predicates share source columns wholesale, so
    # the global deduplicated store is smaller than the per-pred sums
    assert total["mu_bytes"] < per_pred_mu
    assert total["sharing_factor"] > 1.0
    assert total["compression_ratio"] > 1.0


# --------------------------------------------------------------------- #
# property-based conservation (hypothesis, optional)
# --------------------------------------------------------------------- #
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements-dev
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.datalog import Atom, Program, Rule

    PREDS = [("P", 2), ("Q", 2), ("R", 1)]
    VARS = ["x", "y", "z"]

    @hst.composite
    def hyp_programs(draw):
        rules = []
        for _ in range(draw(hst.integers(min_value=1, max_value=3))):
            body = []
            for _ in range(draw(hst.integers(min_value=1, max_value=2))):
                name, arity = draw(hst.sampled_from(PREDS))
                body.append(
                    Atom(
                        name,
                        tuple(
                            draw(hst.sampled_from(VARS)) for _ in range(arity)
                        ),
                    )
                )
            body_vars = [v for a in body for v in a.variables()]
            name, arity = draw(hst.sampled_from(PREDS))
            head = Atom(
                name,
                tuple(draw(hst.sampled_from(body_vars)) for _ in range(arity)),
            )
            rules.append(Rule(tuple(body), head))
        return Program(rules)

    @hst.composite
    def hyp_datasets(draw):
        out = {}
        for name, arity in PREDS:
            n = draw(hst.integers(min_value=0, max_value=8))
            if n == 0:
                continue
            rows = draw(
                hst.lists(
                    hst.tuples(
                        *[hst.integers(min_value=0, max_value=5)] * arity
                    ),
                    min_size=n,
                    max_size=n,
                )
            )
            out[name] = np.unique(np.asarray(rows, dtype=np.int64), axis=0)
        return out

    @settings(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(program=hyp_programs(), dataset=hyp_datasets())
    def test_hypothesis_memory_conserved_roundtrip(
        program, dataset, tmp_path_factory
    ):
        """For random KBs: running counters stay in sync through load /
        churn / snapshot / restore / compact, report part-sums hold, and
        the fact set's flat-equivalent bytes are conserved end to end."""
        if not dataset:
            return
        inc = IncrementalStore(program)
        inc.load(dataset)
        _assert_counters_in_sync(inc.store)
        dels = {p: r[: max(1, r.shape[0] // 2)] for p, r in dataset.items()}
        inc.apply(deletions=dels)
        inc.apply(additions=dels)
        _assert_counters_in_sync(inc.store)
        want = _flat_bytes(inc.facts)

        snap = str(tmp_path_factory.mktemp("memhyp") / "snap")
        write_snapshot(
            snap, inc.facts, epoch=inc.epoch, round_tag=inc._round,
            rows=inc.rows.to_dict(), counts=inc.counts,
            explicit=inc.explicit, arities=inc.arities,
        )
        inc2, _ = restore_incremental(program, snap)
        _assert_counters_in_sync(inc2.store)
        assert _flat_bytes(inc2.facts) == want
        for parts in (inc2.store.memory_report(), inc2.memory_report()):
            assert all(v >= 0 for v in parts.values()), parts

        compact_store(inc2)
        _assert_counters_in_sync(inc2.store)
        assert _flat_bytes(inc2.facts) == want
