"""Incremental maintenance subsystem: differential parity, round-trip
properties, derivation-count invariants, epoch-stamped query caches, and
the satellite engine/plan-cache behaviours that ride with it."""

import numpy as np
import pytest

from repro.core import CMatEngine, flat_seminaive, parse_program
from repro.core.compile import PlanCache, compile_body
from repro.core.generators import chain, lubm_like, paper_example, random_kb
from repro.incremental import IncrementalStore
from repro.query import QueryEngine


def as_sets(facts):
    return {
        p: frozenset(map(tuple, np.asarray(r).tolist()))
        for p, r in facts.items()
        if len(r)
    }


def subtract(dataset, dels):
    out = {}
    for pred, rows in dataset.items():
        rows = np.asarray(rows, dtype=np.int64).reshape(len(rows), -1)
        drop = {
            tuple(r)
            for r in np.asarray(dels.get(pred, np.zeros((0, rows.shape[1]))))
            .astype(np.int64)
            .reshape(-1, rows.shape[1])
            .tolist()
        }
        keep = [r for r in rows.tolist() if tuple(r) not in drop]
        if keep:
            out[pred] = np.asarray(keep, dtype=np.int64)
    return out


def union(dataset, adds):
    out = {p: np.asarray(r, dtype=np.int64) for p, r in dataset.items()}
    for pred, rows in adds.items():
        rows = np.asarray(rows, dtype=np.int64).reshape(len(rows), -1)
        prev = out.get(pred)
        merged = rows if prev is None else np.concatenate([prev, rows])
        out[pred] = np.unique(merged, axis=0)
    return out


def pick_batch(dataset, k, seed=0):
    rng = np.random.default_rng(seed)
    pool = [
        (p, tuple(int(v) for v in row))
        for p, rows in dataset.items()
        for row in np.asarray(rows).reshape(len(rows), -1)
    ]
    rng.shuffle(pool)
    out: dict[str, list] = {}
    for p, row in pool[:k]:
        out.setdefault(p, []).append(row)
    return {p: np.asarray(r, dtype=np.int64) for p, r in out.items()}


KBS = [
    ("paper", lambda: paper_example(4, 3)),
    ("chain", lambda: chain(18)),
    ("lubm", lambda: lubm_like(n_dept=3, n_students=40, n_courses=6, seed=0)),
]


# --------------------------------------------------------------------- #
# differential parity
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name,gen", KBS)
def test_apply_deletions_matches_scratch(name, gen):
    program, dataset, _ = gen()
    inc = IncrementalStore(program)
    inc.load(dataset)
    assert as_sets(inc.to_dict()) == as_sets(flat_seminaive(program, dataset))

    dels = pick_batch(dataset, 5, seed=1)
    st = inc.apply(deletions=dels)
    inc.check_integrity()
    want = as_sets(flat_seminaive(program, subtract(dataset, dels)))
    assert as_sets(inc.to_dict()) == want
    assert st.epoch == 1 and inc.journal[-1]["epoch"] == 1


@pytest.mark.parametrize("name,gen", KBS)
def test_apply_round_trips(name, gen):
    """apply(adds, dels) then apply(dels, adds) restores the original
    materialisation bit for bit (adds fresh, dels ⊆ E, disjoint)."""
    program, dataset, _ = gen()
    inc = IncrementalStore(program)
    inc.load(dataset)
    original = inc.to_dict()

    dels = pick_batch(dataset, 4, seed=2)
    arity_of = {p: np.asarray(r).reshape(len(r), -1).shape[1] for p, r in dataset.items()}
    adds = {
        p: (np.arange(2 * arity_of[p]).reshape(2, arity_of[p]) + 10_000).astype(
            np.int64
        )
        for p in list(dataset)[:2]
    }
    inc.apply(additions=adds, deletions=dels)
    inc.check_integrity()
    want_mid = as_sets(
        flat_seminaive(program, union(subtract(dataset, dels), adds))
    )
    assert as_sets(inc.to_dict()) == want_mid

    inc.apply(additions=dels, deletions=adds)
    inc.check_integrity()
    got = inc.to_dict()
    assert set(got) == set(original)
    for pred in original:
        assert np.array_equal(got[pred], original[pred]), pred
    assert inc.epoch == 2


@pytest.mark.parametrize("name,gen", KBS)
def test_delete_all_equals_empty_kb(name, gen):
    program, dataset, _ = gen()
    inc = IncrementalStore(program)
    inc.load(dataset)
    inc.apply(deletions=dataset)
    inc.check_integrity()
    assert as_sets(inc.to_dict()) == {}
    assert inc.facts.n_facts() == 0
    # and back: inserting everything from empty equals a fresh build
    inc.apply(additions=dataset)
    inc.check_integrity()
    assert as_sets(inc.to_dict()) == as_sets(
        flat_seminaive(program, dataset)
    )


def test_apply_from_never_loaded_store():
    """A store built purely through apply() (no load) equals a fresh
    materialisation — the start-empty serving bootstrap."""
    program, dataset, _ = paper_example(4, 3)
    inc = IncrementalStore(program)
    inc.apply(additions=dataset)
    inc.check_integrity()
    assert as_sets(inc.to_dict()) == as_sets(
        flat_seminaive(program, dataset)
    )


def test_parity_across_engines():
    """Incremental maintenance lands on the same fact set the flat and
    compressed engines compute from scratch on the updated EDB."""
    program, dataset, _ = paper_example(5, 3)
    inc = IncrementalStore(program)
    inc.load(dataset)
    dels = pick_batch(dataset, 3, seed=3)
    inc.apply(deletions=dels)
    updated = subtract(dataset, dels)

    want_flat = as_sets(flat_seminaive(program, updated))
    eng = CMatEngine(program)
    eng.load(updated)
    eng.materialise()
    want_cmat = as_sets(eng.materialisation())

    got = as_sets(inc.to_dict())
    assert got == want_flat == want_cmat


def test_parity_distributed_engine():
    """The distributed engine (1-shard mesh, <=2-atom bodies) agrees with
    the incrementally maintained store on the updated EDB."""
    jax = pytest.importorskip("jax")
    from jax.sharding import Mesh

    from repro.core.distributed import DistributedEngine

    program, dataset, _ = paper_example(4, 3)
    inc = IncrementalStore(program)
    inc.load(dataset)
    dels = pick_batch(dataset, 2, seed=4)
    inc.apply(deletions=dels)

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    eng = DistributedEngine(program, mesh, capacity=1 << 11)
    got_dist = {
        p: rows
        for p, rows in eng.materialise(subtract(dataset, dels)).items()
        if rows.shape[0]
    }
    assert as_sets(got_dist) == as_sets(inc.to_dict())


def test_counting_disabled_matches_counting():
    """Pure-DRed mode (counting=False) and the counting hybrid agree."""
    program, dataset, _ = lubm_like(n_dept=3, n_students=30, n_courses=5, seed=1)
    dels = pick_batch(dataset, 6, seed=5)
    results = []
    for counting in (True, False):
        inc = IncrementalStore(program, counting=counting)
        inc.load(dataset)
        st = inc.apply(deletions=dels)
        results.append(as_sets(inc.to_dict()))
        if counting:
            assert st.counting_strata > 0
        else:
            assert st.counting_strata == 0 and st.dred_strata > 0
    assert results[0] == results[1]


# --------------------------------------------------------------------- #
# property-based (hypothesis)
# --------------------------------------------------------------------- #
def test_random_kbs_differential():
    """Random programs/datasets/batches: apply() == from-scratch, counts
    and row index stay consistent, delete-all drains the store."""
    rng = np.random.default_rng(42)
    for trial in range(25):
        program, dataset = random_kb(
            rng,
            n_constants=int(rng.integers(2, 9)),
            n_facts=int(rng.integers(1, 22)),
            n_rules=int(rng.integers(1, 5)),
        )
        if not len(program.rules):
            continue
        inc = IncrementalStore(program)
        inc.load(dataset)
        dels = {
            p: rows[rng.choice(rows.shape[0], size=int(rng.integers(1, rows.shape[0] + 1)), replace=False)]
            for p, rows in dataset.items()
            if rows.shape[0] and rng.random() < 0.8
        }
        adds = {
            p: rng.integers(20, 24, size=(int(rng.integers(1, 3)), rows.shape[1])).astype(np.int64)
            for p, rows in dataset.items()
            if rng.random() < 0.5
        }
        inc.apply(additions=adds, deletions=dels)
        inc.check_integrity()
        want = as_sets(
            flat_seminaive(program, union(subtract(dataset, dels), adds))
        )
        assert as_sets(inc.to_dict()) == want, f"trial {trial}"


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements-dev
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.datalog import Atom, Program, Rule

    PREDS = [("P", 2), ("Q", 2), ("R", 1)]
    VARS = ["x", "y", "z"]

    @hst.composite
    def hyp_rules(draw):
        body = []
        for _ in range(draw(hst.integers(min_value=1, max_value=3))):
            name, arity = draw(hst.sampled_from(PREDS))
            body.append(
                Atom(name, tuple(draw(hst.sampled_from(VARS)) for _ in range(arity)))
            )
        body_vars = [v for a in body for v in a.variables()]
        name, arity = draw(hst.sampled_from(PREDS))
        head = Atom(
            name, tuple(draw(hst.sampled_from(body_vars)) for _ in range(arity))
        )
        return Rule(tuple(body), head)

    @hst.composite
    def hyp_programs(draw):
        return Program(draw(hst.lists(hyp_rules(), min_size=1, max_size=4)))

    @hst.composite
    def hyp_datasets(draw):
        out = {}
        for name, arity in PREDS:
            n = draw(hst.integers(min_value=0, max_value=10))
            if n == 0:
                continue
            rows = draw(
                hst.lists(
                    hst.tuples(*[hst.integers(min_value=0, max_value=6)] * arity),
                    min_size=n,
                    max_size=n,
                )
            )
            out[name] = np.unique(np.asarray(rows, dtype=np.int64), axis=0)
        return out

    @hst.composite
    def hyp_updates(draw, dataset):
        """(adds, dels): dels ⊆ E, adds fresh (value range disjoint from E
        and from dels), so the round-trip identity holds exactly."""
        dels = {}
        for pred, rows in dataset.items():
            k = draw(hst.integers(min_value=0, max_value=rows.shape[0]))
            if k:
                idx = draw(
                    hst.permutations(list(range(rows.shape[0])))
                )[:k]
                dels[pred] = rows[sorted(idx)]
        adds = {}
        for pred, arity in PREDS:
            n = draw(hst.integers(min_value=0, max_value=3))
            if n == 0:
                continue
            rows = draw(
                hst.lists(
                    hst.tuples(
                        *[hst.integers(min_value=100, max_value=104)] * arity
                    ),
                    min_size=n,
                    max_size=n,
                )
            )
            adds[pred] = np.unique(np.asarray(rows, dtype=np.int64), axis=0)
        return adds, dels

    @settings(
        max_examples=40,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(data=hst.data(), program=hyp_programs(), dataset=hyp_datasets())
    def test_hypothesis_apply_round_trip(data, program, dataset):
        """apply(adds, dels); apply(dels, adds) round-trips bit-identically,
        the intermediate state matches from-scratch materialisation, and
        delete-all equals the empty KB — for random programs/batches."""
        if not dataset:
            return
        adds, dels = data.draw(hyp_updates(dataset))
        inc = IncrementalStore(program)
        inc.load(dataset)
        original = inc.to_dict()

        inc.apply(additions=adds, deletions=dels)
        inc.check_integrity()
        want_mid = as_sets(
            flat_seminaive(program, union(subtract(dataset, dels), adds))
        )
        assert as_sets(inc.to_dict()) == want_mid

        inc.apply(additions=dels, deletions=adds)
        inc.check_integrity()
        got = inc.to_dict()
        assert set(got) == set(original)
        for pred in original:
            assert np.array_equal(got[pred], original[pred]), pred

        inc.apply(deletions=inc.explicit)
        assert as_sets(inc.to_dict()) == {}


# --------------------------------------------------------------------- #
# epoch-stamped query caches (satellite, tested in isolation)
# --------------------------------------------------------------------- #
def test_query_cache_epoch_invalidation():
    program, dataset, dictionary = paper_example(4, 3)
    inc = IncrementalStore(program)
    inc.load(dataset)
    qe = QueryEngine(inc, dictionary)

    res0 = qe.answer("?x, ?y <- S(x, y)")
    assert res0.n_answers > 0
    assert qe.answer("?x, ?y <- S(x, y)").from_cache  # warm hit, same epoch

    # delete every R fact: rule (5) loses all its derivations
    inc.apply(deletions={"R": dataset["R"]})
    # without a bump the stale entry would still be served — that is the
    # bug the version stamp fixes; bump and observe eviction + fresh answers
    qe.bump_epoch(inc)
    res1 = qe.answer("?x, ?y <- S(x, y)")
    assert not res1.from_cache
    assert res1.n_answers == 0
    assert qe.epoch == 1
    assert qe.stale_evictions >= 1
    assert qe.cache_stats()["stale_evictions"] == qe.stale_evictions


def test_query_plan_cache_invalidated_on_epoch():
    """A plan compiled against an *empty* predicate shortcuts to the
    empty plan; after an insertion epoch it must be re-planned, not
    served stale."""
    program, dataset, dictionary = paper_example(4, 3)
    inc = IncrementalStore(program)
    inc.load({"P": dataset["P"], "T": dataset["T"]})  # no R facts at all
    qe = QueryEngine(inc, dictionary)
    assert qe.answer("?x <- R(x)").n_answers == 0
    assert qe.plan("?x <- R(x)").is_empty

    inc.apply(additions={"R": dataset["R"]})
    qe.bump_epoch(inc)
    assert not qe.plan("?x <- R(x)").is_empty
    assert qe.answer("?x <- R(x)").n_answers == dataset["R"].shape[0]


# --------------------------------------------------------------------- #
# plan-cache feedback recalibration (satellite)
# --------------------------------------------------------------------- #
def test_plan_cache_feedback_recalibrates_once_per_bucket():
    program = parse_program("P(x, y), Q(y, z) -> S(x, z)")
    rule = program.rules[0]

    class Stats:
        def n_rows(self, pred):
            return 100

        def arity(self, pred):
            return 2

        def selectivity(self, pred, pos, value):
            return 0.1

    cache = PlanCache()
    build = lambda: compile_body(rule.body, Stats())  # noqa: E731
    plan = cache.get((rule, 0), (7, 7), build)
    assert cache.misses == 1

    # estimate within 4x: no recalibration
    cache.note_actual((rule, 0), plan.first.est_rows, int(plan.first.est_rows * 2))
    assert cache.feedback_replans == 0
    assert cache.get((rule, 0), (7, 7), build) is plan
    assert cache.hits == 1

    # off by >4x: entry dropped, replanned on next get — once per bucket
    cache.note_actual((rule, 0), plan.first.est_rows, int(plan.first.est_rows * 100))
    assert cache.feedback_replans == 1
    assert (rule, 0) in cache.est_log2_ratio
    replanned = cache.get((rule, 0), (7, 7), build)
    assert replanned is not plan
    cache.note_actual((rule, 0), replanned.first.est_rows, 10_000_000)
    assert cache.feedback_replans == 1  # same bucket: no thrash
    # a bucket shift re-arms the feedback
    cache.get((rule, 0), (9, 9), build)
    cache.note_actual((rule, 0), 1.0, 10_000)
    assert cache.feedback_replans == 2


# --------------------------------------------------------------------- #
# snapshot-backed old-partition scans (satellite)
# --------------------------------------------------------------------- #
def test_old_snapshot_scans_preserve_materialisation():
    program = parse_program(
        """
        edge(x, y) -> path(x, y)
        path(x, y), edge(y, z) -> path(x, z)
        path(x, 5), path(5, z) -> path(x, z)
        path(x, x) -> loop(x)
        """
    )
    n = 40
    edge = np.stack([np.arange(n), np.arange(1, n + 1)], axis=1)
    edge = np.concatenate([edge, [[n, 0]]]).astype(np.int64)
    dataset = {"edge": edge}
    want = as_sets(flat_seminaive(program, dataset))

    snap = CMatEngine(program, snapshot_old_scans=True)
    snap.load(dataset)
    snap.materialise()
    assert as_sets(snap.materialisation()) == want
    assert snap.stats.old_snapshot_scans > 0
    assert snap.report()["old_snapshot_scans"] == snap.stats.old_snapshot_scans

    plain = CMatEngine(program, snapshot_old_scans=False)
    plain.load(dataset)
    plain.materialise()
    assert as_sets(plain.materialisation()) == want
    assert plain.stats.old_snapshot_scans == 0


# --------------------------------------------------------------------- #
# journal / stats surface
# --------------------------------------------------------------------- #
def test_journal_records_batches():
    program, dataset, _ = lubm_like(n_dept=2, n_students=20, n_courses=4, seed=2)
    inc = IncrementalStore(program)
    inc.load(dataset)
    dels = pick_batch(dataset, 3, seed=6)
    st1 = inc.apply(deletions=dels)
    st2 = inc.apply(additions=dels)
    assert [j["epoch"] for j in inc.journal] == [1, 2]
    assert inc.journal[0]["del_explicit"] == st1.n_del_explicit > 0
    assert inc.journal[1]["add_explicit"] == st2.n_add_explicit > 0
    assert st1.time_total > 0 and st2.time_total > 0
    assert st1.plan_cache["plans"] > 0
    # freezing seeds snapshots from the maintained index: no unfold cost
    frozen = inc.freeze()
    for pred in inc.rows.predicates():
        assert frozen.has_snapshot(pred)
    assert frozen.snapshot_cells == 0
