"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance, gradient compression, KB linearisation."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import (
    DataConfig,
    SyntheticCorpus,
    TokenStream,
    linearise_materialisation,
)
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compressed_grad_transform,
    init_error_feedback,
    warmup_cosine,
)
from repro.train import (
    ElasticPlan,
    HeartbeatMonitor,
    StragglerMonitor,
    TrainConfig,
    init_train_state,
    latest_step,
    load_checkpoint,
    make_train_step,
    run_with_recovery,
    save_checkpoint,
)


# --------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------- #
class TestAdamW:
    def test_minimises_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip(self):
        grads = {"a": jnp.full((4,), 100.0)}
        clipped, gn = clip_by_global_norm(grads, 1.0)
        assert float(gn) == pytest.approx(200.0)
        norm = float(jnp.linalg.norm(clipped["a"]))
        assert norm == pytest.approx(1.0, rel=1e-5)

    def test_schedule_shape(self):
        s0 = float(warmup_cosine(jnp.int32(0), warmup=10, total=100))
        s10 = float(warmup_cosine(jnp.int32(10), warmup=10, total=100))
        s100 = float(warmup_cosine(jnp.int32(100), warmup=10, total=100))
        assert s0 == 0.0 and s10 == pytest.approx(1.0) and s100 < 0.2


class TestGradCompression:
    def test_roundtrip_with_error_feedback(self):
        params = {"w": jnp.zeros((64,))}
        err = init_error_feedback(params)
        rng = np.random.default_rng(0)
        total_true = np.zeros(64)
        total_applied = np.zeros(64)
        for _ in range(50):
            g = {"w": jnp.asarray(rng.standard_normal(64) * 0.01)}
            total_true += np.asarray(g["w"])
            gq, err = compressed_grad_transform(g, err)
            total_applied += np.asarray(gq["w"])
        # error feedback keeps the cumulative applied gradient unbiased
        np.testing.assert_allclose(total_applied, total_true, atol=2e-4)


# --------------------------------------------------------------------- #
# data
# --------------------------------------------------------------------- #
class TestData:
    def test_synthetic_determinism_and_sharding(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
        c = SyntheticCorpus(cfg)
        a = c.batch(3)["tokens"]
        b = c.batch(3)["tokens"]
        np.testing.assert_array_equal(a, b)  # restart-safe
        h0 = c.batch(3, host_index=0, n_hosts=2)["tokens"]
        h1 = c.batch(3, host_index=1, n_hosts=2)["tokens"]
        assert h0.shape == (4, 16) and h1.shape == (4, 16)
        assert not np.array_equal(h0, h1)

    def test_token_stream_tiling(self):
        cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
        stream = TokenStream(np.arange(40, dtype=np.int32), cfg)
        b0 = stream.batch(0)["tokens"]
        assert b0.shape == (2, 8)
        assert b0.max() < 50

    def test_kb_linearisation(self):
        from repro.core import CMatEngine
        from repro.core.generators import lubm_like

        program, dataset, _ = lubm_like(n_dept=4, n_students=30, n_courses=6)
        eng = CMatEngine(program)
        eng.load(dataset)
        eng.materialise()
        tokens = linearise_materialisation(eng, vocab_size=4096)
        assert tokens.dtype == np.int32
        assert tokens.shape[0] > 0
        assert tokens.min() >= 0 and tokens.max() < 4096


# --------------------------------------------------------------------- #
# checkpointing + fault tolerance
# --------------------------------------------------------------------- #
class TestCheckpoint:
    def test_save_load_roundtrip(self):
        state = {"a": jnp.arange(5), "nested": {"b": jnp.ones((2, 3))}}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 7, state)
            restored, step = load_checkpoint(d, state)
            assert step == 7
            np.testing.assert_array_equal(restored["a"], state["a"])

    def test_double_buffering_gc(self):
        state = {"a": jnp.zeros(3)}
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3, 4):
                save_checkpoint(d, s, state, keep=2)
            steps = sorted(os.listdir(d))
            assert len(steps) == 2
            assert latest_step(d) == 4

    def test_recovery_loop_is_exact(self):
        """Kill the run mid-way; the supervised loop must continue and
        produce the same final state as an uninterrupted run."""
        cfg = get_config("llama3.2-1b", smoke=True)
        tcfg = TrainConfig(total_steps=12, warmup_steps=1)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
        corpus = SyntheticCorpus(dcfg)
        batches = [
            {k: jnp.asarray(v) for k, v in corpus.batch(s).items()}
            for s in range(12)
        ]
        step_fn = jax.jit(make_train_step(cfg, tcfg))

        def fresh_state():
            return init_train_state(jax.random.PRNGKey(0), cfg, tcfg)

        # uninterrupted reference
        ref = fresh_state()
        for b in batches:
            ref, _ = step_fn(ref, b)

        with tempfile.TemporaryDirectory() as d:
            state, last, failures = run_with_recovery(
                step_fn, fresh_state(), batches,
                ckpt_dir=d, ckpt_every=3, fail_at={5, 9},
            )
        assert failures == 2 and last == 12
        ref_leaves = jax.tree_util.tree_leaves(ref["params"])
        got_leaves = jax.tree_util.tree_leaves(state["params"])
        for r, g in zip(ref_leaves, got_leaves):
            np.testing.assert_allclose(
                np.asarray(r, np.float32), np.asarray(g, np.float32),
                rtol=1e-5, atol=1e-6,
            )


class TestFaultTolerance:
    def test_heartbeat(self):
        clock = [0.0]
        mon = HeartbeatMonitor([0, 1, 2], deadline_s=10, clock=lambda: clock[0])
        clock[0] = 5.0
        mon.beat(0)
        mon.beat(1)
        clock[0] = 12.0
        assert mon.failed_hosts() == [2]

    def test_straggler_detection(self):
        mon = StragglerMonitor(threshold=1.5, min_flags=3)
        flagged = []
        for _ in range(8):  # flags accrue per periodic check
            for h in range(4):
                mon.record(h, 2.0 if h == 2 else 1.0)
            flagged = mon.stragglers()
        assert flagged == [2]
        # a recovered host is un-flagged
        for _ in range(8):
            for h in range(4):
                mon.record(h, 1.0)
            flagged = mon.stragglers()
        assert flagged == []

    def test_elastic_plan(self):
        plan = ElasticPlan(total_hosts=64, chips_per_host=4, model_parallel=16)
        data, model = plan.pick(64)
        assert (data, model) == (16, 16)
        data, model = plan.pick(63)  # lost a host -> shrink data axis
        assert (data, model) == (8, 16)
        with pytest.raises(RuntimeError):
            plan.pick(2)
