"""Exact reproduction of the paper's running example (Section 3).

Asserts the *structure* of the compressed materialisation, not just the
fact set: the round at which each meta-fact is derived, the structure
sharing of the cross-join result (one shared e-column), and the O(n)
storage claim for the derived facts.
"""

import numpy as np

from repro.core import CMatEngine
from repro.core.generators import paper_example


def _facts_by_round(eng, pred):
    return sorted((mf.round, mf.length) for mf in eng.facts.all(pred))


class TestPaperRunningExample:
    def setup_method(self):
        self.n, self.m = 4, 3
        program, dataset, self.dictionary = paper_example(self.n, self.m)
        self.eng = CMatEngine(program)
        self.eng.load(dataset)
        self.stats = self.eng.materialise()

    def test_round_count(self):
        # round 1: S(h, j); round 2: P(a_2i, f); round 3: S(a_2i, f);
        # round 4 derives nothing -> fixpoint
        assert self.stats.rounds == 4

    def test_first_round_semi_join(self):
        """Rule (5) derives S(h, j): ONE meta-fact covering n facts."""
        s_round1 = [mf for mf in self.eng.facts.all("S") if mf.round == 1]
        assert len(s_round1) == 1
        assert s_round1[0].length == self.n
        # x-column unfolds to a2.a4...a_2n (the survivors of the semi-join)
        xs = self.eng.store.unfold(s_round1[0].columns[0])
        names = [self.dictionary.term_of(int(v)) for v in xs]
        assert names == [f"a{2*i}" for i in range(1, self.n + 1)]

    def test_second_round_cross_join_sharing(self):
        """Rule (6) derives P(a_2i, f), 1<=i<=n: n meta-facts of length m
        whose e-column is SHARED (paper's structure-sharing cross-join)."""
        p_round2 = [mf for mf in self.eng.facts.all("P") if mf.round == 2]
        assert len(p_round2) == self.n
        assert all(mf.length == self.m for mf in p_round2)
        # the left column is an RLE constant (a_2i repeated m times)
        for mf in p_round2:
            col = self.eng.store.unfold(mf.columns[0])
            assert np.unique(col).shape[0] == 1
        # the e-columns are shared across all n meta-facts
        e_cols = {mf.columns[1] for mf in p_round2}
        assert len(e_cols) == 1, "cross-join must share the group column"

    def test_storage_is_linear_in_n(self):
        """Paper 'Termination': derived storage O(n), not O(n*m)."""
        sizes = []
        for n in (10, 20, 40):
            program, dataset, _ = paper_example(n=n, m=30)
            eng = CMatEngine(program)
            eng.load(dataset)
            eng.materialise()
            rep = eng.report()
            sizes.append(rep["compressed_size"] - rep["flat_size_E"])
        # doubling n should ~double (not ~quadruple) the derived storage
        r1 = sizes[1] / sizes[0]
        r2 = sizes[2] / sizes[1]
        assert r1 < 3.0 and r2 < 3.0, f"superlinear growth: {sizes}"

    def test_flat_storage_is_quadratic_for_reference(self):
        program, dataset, _ = paper_example(n=40, m=30)
        eng = CMatEngine(program)
        eng.load(dataset)
        eng.materialise()
        rep = eng.report()
        flat_derived = rep["flat_size_I"] - rep["flat_size_E"]
        comp_derived = rep["compressed_size"] - rep["flat_size_E"]
        assert flat_derived > 5 * comp_derived
