"""Numerical equivalence of sharded training: the FSDP x TP train step on
a real 2x2 device mesh must produce the same loss trajectory as the
single-device step (same params, same batches).  Subprocess-isolated."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh

from repro.compat import set_mesh

from repro.configs import get_config
from repro.data import DataConfig, SyntheticCorpus
from repro.launch.sharding import batch_shardings, state_shardings
from repro.models.sharding_policy import clear_policy, set_policy_from_mesh
from repro.train import TrainConfig, init_train_state, make_train_step

cfg = get_config("llama3.2-1b", smoke=True)
tcfg = TrainConfig(total_steps=6, warmup_steps=1)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
corpus = SyntheticCorpus(dcfg)
batches = [{k: jnp.asarray(v) for k, v in corpus.batch(s).items()}
           for s in range(4)]

def run(mesh=None):
    if mesh is None:
        clear_policy()
        state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg))
        losses = []
        for b in batches:
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        return losses
    set_policy_from_mesh(mesh)
    with set_mesh(mesh):
        state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        st_sh = state_shardings(state, mesh)
        state = jax.tree_util.tree_map(jax.device_put, state, st_sh)
        step = jax.jit(make_train_step(cfg, tcfg))
        losses = []
        for b in batches:
            b_sh = batch_shardings(b, mesh)
            b = jax.tree_util.tree_map(jax.device_put, b, b_sh)
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        return losses

ref = run()
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
got = run(mesh)
print("single:", [round(l, 4) for l in ref])
print("2x2   :", [round(l, 4) for l in got])
for a, b in zip(ref, got):
    assert abs(a - b) < 0.05, f"trajectory diverged: {ref} vs {got}"
print("SHARDED==SINGLE OK")
"""


def test_sharded_training_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr[-3000:]}"
    assert "SHARDED==SINGLE OK" in out.stdout
