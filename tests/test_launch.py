"""Launch-layer tests: sharding rules, mesh policy, distributed engine."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import flat_seminaive
from repro.core.distributed import DistributedEngine
from repro.core.generators import chain, lubm_like
from repro.launch.sharding import (
    batch_shardings,
    guarded_spec,
    param_shardings,
)
from repro.models.model import abstract_params, input_specs
from repro.configs import SHAPES


def _mesh11():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


class TestGuardedSpec:
    def test_divisible_kept(self):
        mesh = _mesh11()
        spec = guarded_spec(mesh, (16, 32), ("data", "model"))
        assert spec == P("data", "model")

    def test_indivisible_dropped(self):
        # fake a larger mesh shape via a mesh with axis sizes 1 — use the
        # production mesh shape logic instead: axis size 1 divides all
        mesh = _mesh11()
        spec = guarded_spec(mesh, (0, 7), ("data", "model"))
        assert spec == P(None, "model")  # 0-dim dropped, 7 % 1 == 0 kept


class TestParamShardings:
    @pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2-moe-a2.7b",
                                      "falcon-mamba-7b", "deepseek-v3-671b",
                                      "seamless-m4t-large-v2"])
    def test_rules_cover_every_leaf(self, arch):
        cfg = get_config(arch, smoke=True)
        mesh = _mesh11()
        params = abstract_params(cfg)
        shardings = param_shardings(params, mesh)
        n_p = len(jax.tree_util.tree_leaves(params))
        n_s = len(jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding)))
        assert n_p == n_s
        for s in jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding)
        ):
            assert isinstance(s, NamedSharding)

    def test_pure_fsdp_strategy(self):
        cfg = get_config("llama3.2-1b", smoke=True)
        mesh = _mesh11()
        shardings = param_shardings(abstract_params(cfg), mesh,
                                    strategy="pure_fsdp")
        leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
        assert leaves  # all leaves resolved

    def test_batch_shardings(self):
        cfg = get_config("llama3.2-1b")
        mesh = _mesh11()
        batch = input_specs(cfg, SHAPES["train_4k"])
        sh = batch_shardings(batch, mesh)
        assert isinstance(sh["tokens"], NamedSharding)


class TestInputSpecs:
    @pytest.mark.parametrize("shape", list(SHAPES))
    def test_specs_are_abstract(self, shape):
        cfg = get_config("falcon-mamba-7b")  # supports all shapes
        specs = input_specs(cfg, SHAPES[shape])
        for leaf in jax.tree_util.tree_leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_vlm_has_vision_stub(self):
        cfg = get_config("qwen2-vl-72b")
        specs = input_specs(cfg, SHAPES["train_4k"])
        assert "vision_embeds" in specs
        assert specs["vision_embeds"].shape[-1] == cfg.d_model

    def test_encdec_has_audio_stub(self):
        cfg = get_config("seamless-m4t-large-v2")
        specs = input_specs(cfg, SHAPES["train_4k"])
        assert "src_embeds" in specs


class TestDistributedEngine:
    def test_matches_flat_oracle_chain(self):
        program, dataset, _ = chain(10)
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        eng = DistributedEngine(program, mesh, capacity=1 << 10)
        got = eng.materialise(dataset)
        want = flat_seminaive(program, dataset)
        for pred, rows in want.items():
            assert {tuple(r) for r in got[pred]} == {tuple(r) for r in rows}

    def test_pallas_kernel_dedup_path(self):
        """The distributed engine with the Pallas membership kernel as the
        dedup device path must match the flat oracle."""
        program, dataset, _ = chain(8)
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        eng = DistributedEngine(program, mesh, capacity=1 << 9,
                                use_pallas_kernels=True)
        got = eng.materialise(dataset)
        want = flat_seminaive(program, dataset)
        for pred, rows in want.items():
            assert {tuple(r) for r in got[pred]} == {tuple(r) for r in rows}

    def test_matches_flat_oracle_lubm(self):
        program, dataset, _ = lubm_like(n_dept=4, n_students=40, n_courses=8)
        rules = [r for r in program if len(r.body) <= 2]
        program = type(program)(rules)
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        eng = DistributedEngine(program, mesh, capacity=1 << 12)
        got = eng.materialise(dataset)
        want = flat_seminaive(program, dataset)
        for pred, rows in want.items():
            assert {tuple(r) for r in got.get(pred, np.zeros((0, 2)))} == {
                tuple(r) for r in rows
            }
