"""Durable storage subsystem: snapshot round-trips, WAL crash recovery,
checkpoint orchestration, and compaction differentials."""

import json
import os

import numpy as np
import pytest

from repro.core import CMatEngine, flat_seminaive
from repro.core.generators import chain, lubm_like, paper_example, random_kb
from repro.incremental import IncrementalStore
from repro.query import QueryEngine
from repro.storage import (
    CheckpointManager,
    SnapshotError,
    WriteAheadLog,
    load_frozen,
    mu_usage,
    restore_incremental,
    write_snapshot,
)


def as_sets(facts):
    return {
        p: frozenset(map(tuple, np.asarray(r).tolist()))
        for p, r in facts.items()
        if len(r)
    }


def assert_same_store(a: IncrementalStore, b: IncrementalStore):
    """Row-for-row equal materialisations, counts, and explicit sets."""
    da, db = a.to_dict(), b.to_dict()
    assert set(da) == set(db)
    for p in da:
        assert np.array_equal(da[p], db[p]), p
    assert set(a.counts) == set(b.counts)
    for p in a.counts:
        assert np.array_equal(a.counts[p], b.counts[p]), f"counts {p}"
    assert as_sets(a.explicit) == as_sets(b.explicit)
    assert a.epoch == b.epoch


def small_lubm():
    return lubm_like(n_dept=3, n_students=30, n_courses=6, seed=0)


def pick_batch(dataset, k, seed=0):
    rng = np.random.default_rng(seed)
    pool = [
        (p, tuple(int(v) for v in row))
        for p, rows in dataset.items()
        for row in np.asarray(rows).reshape(len(rows), -1)
    ]
    rng.shuffle(pool)
    out: dict[str, list] = {}
    for p, row in pool[:k]:
        out.setdefault(p, []).append(row)
    return {p: np.asarray(r, dtype=np.int64) for p, r in out.items()}


# --------------------------------------------------------------------- #
# snapshot round-trip
# --------------------------------------------------------------------- #
def test_snapshot_round_trip(tmp_path):
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    manifest = write_snapshot(
        str(tmp_path / "snap"), inc.facts,
        epoch=inc.epoch, round_tag=inc._round,
        rows=inc.rows.to_dict(), counts=inc.counts,
        explicit=inc.explicit, arities=inc.arities,
    )
    assert manifest["store"]["n_nodes"] > 0
    inc2, meta = restore_incremental(
        program, str(tmp_path / "snap"), verify=True
    )
    assert_same_store(inc, inc2)
    # the differential gate really ran: counts were compared to a recount
    assert meta.kind == "incremental"


def test_snapshot_preserves_sharing(tmp_path):
    """Splits create shared/concat structure; a round-trip must keep the
    paper's representation size (payload dedup may even shrink it)."""
    program, dataset, _ = paper_example(n=6, m=4)
    inc = IncrementalStore(program)
    inc.load(dataset)
    batch = pick_batch(dataset, 3)
    inc.apply(deletions=batch)  # forces copy-splits -> concats + sharing
    inc.apply(additions=batch)
    size_before = inc.facts.total_repr_size()
    write_snapshot(
        str(tmp_path / "snap"), inc.facts,
        epoch=inc.epoch, round_tag=inc._round,
        rows=inc.rows.to_dict(), counts=inc.counts,
        explicit=inc.explicit, arities=inc.arities,
    )
    inc2, _ = restore_incremental(program, str(tmp_path / "snap"))
    assert inc2.facts.total_repr_size() <= size_before
    assert inc2.facts.n_meta_facts() == inc.facts.n_meta_facts()
    assert_same_store(inc, inc2)


def test_snapshot_rejects_corruption(tmp_path):
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    snap = str(tmp_path / "snap")
    write_snapshot(
        snap, inc.facts, rows=inc.rows.to_dict(),
        counts=inc.counts, explicit=inc.explicit,
    )
    blob = os.path.join(snap, "data.bin")
    with open(blob, "r+b") as fh:
        fh.seek(10)
        byte = fh.read(1)
        fh.seek(10)
        fh.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(SnapshotError):
        restore_incremental(program, snap)
    with pytest.raises(SnapshotError):
        restore_incremental(program, str(tmp_path / "nowhere"))


def test_frozen_snapshot_serves_queries(tmp_path):
    """Static warm start: a frozen-kind snapshot answers queries
    identically to the engine it was written from, without
    re-materialising or re-unfolding."""
    program, dataset, dictionary = small_lubm()
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    frozen = eng.facts.freeze()
    rows = {p: frozen.snapshot(p) for p in frozen.predicates()}
    write_snapshot(
        str(tmp_path / "frozen"), eng.facts, kind="frozen", rows=rows
    )
    restored = load_frozen(str(tmp_path / "frozen"))
    for p in frozen.predicates():
        assert restored.has_snapshot(p)  # seeded, not lazily re-unfolded
    q1 = QueryEngine(frozen, dictionary)
    q2 = QueryEngine(restored, dictionary)
    queries = [
        '?s, ?c <- memberOf(?s, "dept1"), takesCourse(?s, ?c)',
        "?s, ?p, ?c <- advisor(?s, ?p), teacherOf(?p, ?c), takesCourse(?s, ?c)",
        "?x <- Student(?x)",
    ]
    for text in queries:
        assert np.array_equal(q1.answer(text).answers, q2.answer(text).answers)
    assert restored.snapshot_cells == 0


def test_incremental_restore_requires_incremental_kind(tmp_path):
    program, dataset, _ = small_lubm()
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    write_snapshot(str(tmp_path / "frozen"), eng.facts, kind="frozen")
    with pytest.raises(SnapshotError):
        restore_incremental(program, str(tmp_path / "frozen"))


# --------------------------------------------------------------------- #
# WAL + crash recovery
# --------------------------------------------------------------------- #
def test_wal_crash_recovery_parity(tmp_path):
    """Snapshot + WAL replay == the store that crashed == a fresh
    fixpoint over the final explicit set."""
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    ckpt = CheckpointManager(str(tmp_path / "ckpt"))
    ckpt.checkpoint(inc)
    inc.attach_wal(ckpt.wal)
    for i in range(3):
        batch = pick_batch(dataset, 4, seed=i)
        inc.apply(deletions=batch)
        inc.apply(additions=pick_batch(dataset, 2, seed=i))
    # "crash": recover purely from disk
    inc2, rec = ckpt.restore(program, verify=True)
    assert rec.wal_batches == 6
    assert rec.snapshot_epoch == 0 and rec.final_epoch == inc.epoch
    assert_same_store(inc, inc2)
    want = as_sets(
        {p: r for p, r in flat_seminaive(program, inc.explicit).items()}
    )
    assert as_sets(inc2.to_dict()) == want


def test_wal_torn_tail_is_dropped(tmp_path):
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    ckpt = CheckpointManager(str(tmp_path / "ckpt"))
    ckpt.checkpoint(inc)
    inc.attach_wal(ckpt.wal)
    batch = pick_batch(dataset, 3)
    inc.apply(deletions=batch)
    state_after_first = inc.to_dict()
    epoch_after_first = inc.epoch
    # simulate a crash mid-append: a second record only half-written
    with open(ckpt.wal.path, "a") as fh:
        fh.write('{"rec": {"epoch": 99, "adds": {}, "de')
    inc2, rec = ckpt.restore(program)
    assert rec.wal_batches == 1 and rec.wal_dropped == 1
    assert inc2.epoch == epoch_after_first
    got = inc2.to_dict()
    assert set(got) == set(state_after_first)
    for p in got:
        assert np.array_equal(got[p], state_after_first[p])


def test_wal_checksum_guards_bitrot(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    wal.append(1, {"P": np.asarray([[1, 2]])}, None)
    wal.append(2, None, {"P": np.asarray([[1, 2]])})
    lines = open(wal.path).read().splitlines()
    flipped = lines[0].replace('"epoch": 1', '"epoch": 7')
    with open(wal.path, "w") as fh:
        fh.write(flipped + "\n" + lines[1] + "\n")
    # record 0 fails its checksum -> it and everything after are dropped
    assert wal.records() == []
    assert wal.n_dropped == 2


def test_wal_truncate_keeps_newer_records(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    for e in (1, 2, 3):
        wal.append(e, {"P": np.asarray([[e, e]])}, None)
    wal.truncate(keep_after_epoch=2)
    assert [r["epoch"] for r in wal.records()] == [3]
    wal.truncate()
    assert wal.records() == [] and wal.nbytes() == 0


# --------------------------------------------------------------------- #
# checkpoint orchestration
# --------------------------------------------------------------------- #
def test_checkpoint_truncates_wal_and_journal(tmp_path):
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    ckpt = CheckpointManager(str(tmp_path / "ckpt"))
    inc.attach_wal(ckpt.wal)
    batch = pick_batch(dataset, 3)
    st = inc.apply(deletions=batch)
    assert st.journal_bytes > 0
    assert len(ckpt.wal.records()) == 1
    ckpt.checkpoint(inc)
    assert ckpt.wal.records() == []
    assert len(inc.journal) == 0 and inc.journal_bytes() == 0


def test_journal_is_bounded():
    program, dataset, _ = paper_example()
    inc = IncrementalStore(program, journal_max=4)
    inc.load(dataset)
    for _ in range(7):
        inc.apply()  # empty batches still journal + bump the epoch
    assert len(inc.journal) == 4
    assert [j["epoch"] for j in inc.journal] == [4, 5, 6, 7]


def test_checkpoint_prunes_and_tracks_latest(tmp_path):
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    ckpt = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    inc.attach_wal(ckpt.wal)  # batches after the last snapshot replay
    batch = pick_batch(dataset, 2)
    for _ in range(3):
        ckpt.checkpoint(inc)
        inc.apply(deletions=batch)
        inc.apply(additions=batch)
    assert len(ckpt.snapshots()) == 2  # pruned to keep=2
    assert ckpt.latest().endswith(f"snap-{inc.epoch - 2:08d}")
    inc2, rec = ckpt.restore(program, verify=True)
    assert_same_store(inc, inc2)
    manifest = ckpt.latest_manifest()
    assert manifest["epoch"] == inc.epoch - 2
    assert ckpt.disk_nbytes() > 0


def test_restore_then_apply_continues(tmp_path):
    """A restored store is a live store: applying the same further batch
    to the original and the restored copy stays bit-identical."""
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    ckpt = CheckpointManager(str(tmp_path / "ckpt"))
    ckpt.checkpoint(inc)
    inc2, _ = ckpt.restore(program)
    batch = pick_batch(dataset, 5, seed=3)
    inc.apply(deletions=batch)
    inc2.apply(deletions=batch)
    inc.check_integrity()
    inc2.check_integrity()
    assert_same_store(inc, inc2)


def test_label_mismatch_refused(tmp_path):
    """A labelled manager refuses a snapshot written for another KB;
    an unlabelled side leaves the check unbound."""
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    ckpt = CheckpointManager(str(tmp_path / "ckpt"), label="lubm:scale1")
    manifest = ckpt.checkpoint(inc)
    assert manifest["label"] == "lubm:scale1"  # stamped, not shadowed
    inc_ok, _ = ckpt.restore(program)  # matching label round-trips
    assert_same_store(inc, inc_ok)
    wrong = CheckpointManager(str(tmp_path / "ckpt"), label="chain:scale2")
    with pytest.raises(SnapshotError):
        wrong.restore(program)
    unlabelled = CheckpointManager(str(tmp_path / "ckpt"))
    inc2, _ = unlabelled.restore(program)
    assert_same_store(inc, inc2)
    with pytest.raises(SnapshotError):
        load_frozen(ckpt.latest(), expected_label="chain:scale2")


def test_reset_wipes_stale_history(tmp_path):
    """A cold run over a reused directory must not stitch its fresh
    epochs onto a previous run's snapshots and WAL records."""
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    ckpt = CheckpointManager(str(tmp_path / "ckpt"))
    ckpt.checkpoint(inc)
    inc.attach_wal(ckpt.wal)
    inc.apply(deletions=pick_batch(dataset, 3))  # stale WAL record
    # second run, cold start into the same directory
    ckpt2 = CheckpointManager(str(tmp_path / "ckpt"))
    ckpt2.reset()
    assert not ckpt2.has_snapshot()
    assert ckpt2.wal.records() == []
    inc2 = IncrementalStore(program)
    inc2.load(dataset)
    inc2.attach_wal(ckpt2.wal)
    inc2.apply(deletions=pick_batch(dataset, 2, seed=9))
    ckpt2.checkpoint(inc2)
    inc3, rec = ckpt2.restore(program, verify=True)
    assert rec.snapshot_epoch == inc2.epoch  # only run-2 history survives
    assert_same_store(inc2, inc3)


# --------------------------------------------------------------------- #
# GC / compaction epochs
# --------------------------------------------------------------------- #
def _churn(inc, dataset, rounds, batch_size=4):
    for i in range(rounds):
        batch = pick_batch(dataset, batch_size, seed=i)
        inc.apply(deletions=batch)
        inc.apply(additions=batch)


def test_compaction_preserves_answers_and_counts(tmp_path):
    program, dataset, dictionary = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    _churn(inc, dataset, rounds=8)
    before = mu_usage(inc.facts)
    assert before.dead_fraction > 0  # churn strands dead nodes
    qe = QueryEngine(inc, dictionary)
    queries = [
        '?s, ?c <- memberOf(?s, "dept0"), takesCourse(?s, ?c)',
        "?x, ?u <- memberOf(?x, ?d), subOrganizationOf(?d, ?u)",
    ]
    want = [qe.answer(t).answers for t in queries]
    pre = inc.to_dict()

    cs = inc.compact()
    assert cs.nodes_after < cs.nodes_before
    assert cs.bytes_after <= cs.bytes_before
    after = mu_usage(inc.facts)
    assert after.n_dead == 0

    inc.check_integrity()  # row index AND counts survive the swap
    post = inc.to_dict()
    assert set(pre) == set(post)
    for p in pre:
        assert np.array_equal(pre[p], post[p])
    qe.bump_epoch(inc)
    for t, w in zip(queries, want):
        assert np.array_equal(qe.answer(t).answers, w)
    # maintenance still works on the compacted store
    batch = pick_batch(dataset, 3, seed=99)
    inc.apply(deletions=batch)
    inc.check_integrity()


def test_compaction_reshares_across_epochs():
    """Delete/re-insert churn duplicates identical runs in fresh leaves;
    hash-consing merges them again, below the pre-churn node count."""
    program, dataset, _ = chain(30)
    inc = IncrementalStore(program)
    inc.load(dataset)
    _churn(inc, dataset, rounds=6, batch_size=2)
    cs = inc.compact()
    assert cs.reshared_leaves > 0
    assert inc.mu_usage().dead_fraction == 0.0


def test_maybe_compact_threshold():
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    assert inc.maybe_compact(threshold=0.99, min_nodes=1) is None
    assert inc.maybe_compact(threshold=0) is None  # disabled
    _churn(inc, dataset, rounds=6)
    frac = inc.mu_usage().dead_fraction
    assert inc.maybe_compact(threshold=frac + 0.01, min_nodes=1) is None
    cs = inc.maybe_compact(threshold=frac / 2, min_nodes=1)
    assert cs is not None and cs.dead_fraction_before >= frac / 2


def test_snapshot_after_compaction_round_trips(tmp_path):
    program, dataset, _ = small_lubm()
    inc = IncrementalStore(program)
    inc.load(dataset)
    _churn(inc, dataset, rounds=6)
    inc.compact()
    ckpt = CheckpointManager(str(tmp_path / "ckpt"))
    ckpt.checkpoint(inc)
    inc2, _ = ckpt.restore(program, verify=True)
    assert_same_store(inc, inc2)


# --------------------------------------------------------------------- #
# random / property-based round-trips
# --------------------------------------------------------------------- #
def test_random_kbs_snapshot_round_trip(tmp_path):
    rng = np.random.default_rng(7)
    for trial in range(15):
        program, dataset = random_kb(
            rng,
            n_constants=int(rng.integers(2, 8)),
            n_facts=int(rng.integers(1, 20)),
            n_rules=int(rng.integers(1, 4)),
        )
        if not len(program.rules):
            continue
        inc = IncrementalStore(program)
        inc.load(dataset)
        snap = str(tmp_path / f"snap{trial}")
        write_snapshot(
            snap, inc.facts, epoch=inc.epoch, round_tag=inc._round,
            rows=inc.rows.to_dict(), counts=inc.counts,
            explicit=inc.explicit, arities=inc.arities,
        )
        inc2, _ = restore_incremental(program, snap, verify=True)
        assert_same_store(inc, inc2)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is in requirements-dev
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.datalog import Atom, Program, Rule

    PREDS = [("P", 2), ("Q", 2), ("R", 1)]
    VARS = ["x", "y", "z"]

    @hst.composite
    def hyp_rules(draw):
        body = []
        for _ in range(draw(hst.integers(min_value=1, max_value=3))):
            name, arity = draw(hst.sampled_from(PREDS))
            body.append(
                Atom(name, tuple(draw(hst.sampled_from(VARS)) for _ in range(arity)))
            )
        body_vars = [v for a in body for v in a.variables()]
        name, arity = draw(hst.sampled_from(PREDS))
        head = Atom(
            name, tuple(draw(hst.sampled_from(body_vars)) for _ in range(arity))
        )
        return Rule(tuple(body), head)

    @hst.composite
    def hyp_programs(draw):
        return Program(draw(hst.lists(hyp_rules(), min_size=1, max_size=4)))

    @hst.composite
    def hyp_datasets(draw):
        out = {}
        for name, arity in PREDS:
            n = draw(hst.integers(min_value=0, max_value=10))
            if n == 0:
                continue
            rows = draw(
                hst.lists(
                    hst.tuples(*[hst.integers(min_value=0, max_value=6)] * arity),
                    min_size=n,
                    max_size=n,
                )
            )
            out[name] = np.unique(np.asarray(rows, dtype=np.int64), axis=0)
        return out

    @settings(
        max_examples=30,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(program=hyp_programs(), dataset=hyp_datasets())
    def test_hypothesis_snapshot_round_trip(program, dataset, tmp_path_factory):
        """snapshot -> load yields a store with row-for-row equal
        ``mat(Pi, E)``, equal counts, and an equal further-apply future —
        for random programs and datasets."""
        if not dataset:
            return
        inc = IncrementalStore(program)
        inc.load(dataset)
        snap = str(tmp_path_factory.mktemp("hyp") / "snap")
        write_snapshot(
            snap, inc.facts, epoch=inc.epoch, round_tag=inc._round,
            rows=inc.rows.to_dict(), counts=inc.counts,
            explicit=inc.explicit, arities=inc.arities,
        )
        inc2, _ = restore_incremental(program, snap, verify=True)
        assert_same_store(inc, inc2)
        # the restored store has the same future, not just the same rows
        dels = {p: r[: max(1, r.shape[0] // 2)] for p, r in dataset.items()}
        inc.apply(deletions=dels)
        inc2.apply(deletions=dels)
        assert_same_store(inc, inc2)


# --------------------------------------------------------------------- #
# run.py --json schema gate (CI artifact comparability)
# --------------------------------------------------------------------- #
def test_bench_json_schema_check():
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        from benchmarks.run import check_schema
    finally:
        sys.path.pop(0)

    good = {
        "smoke": True,
        "failures": 0,
        "benches": {
            "storage": {
                "status": "ok",
                "seconds": 1.2,
                "rows": [{"kb": "lubm", "t_restore_ms": 3.1, "ok": True}],
            },
            "broken": {"status": "failed", "seconds": 0.1, "error": "boom"},
        },
    }
    assert check_schema(good) == []
    assert check_schema(json.loads(json.dumps(good))) == []

    bad_nested = json.loads(json.dumps(good))
    bad_nested["benches"]["storage"]["rows"][0]["nested"] = {"a": 1}
    assert any("non-scalar" in e for e in check_schema(bad_nested))

    bad_status = json.loads(json.dumps(good))
    bad_status["benches"]["storage"]["status"] = "okay"
    assert any("status" in e for e in check_schema(bad_status))

    bad_top = {"smoke": True, "benches": {}}
    assert check_schema(bad_top)
