"""Per-kernel validation: shape sweeps + hypothesis vs the ref.py oracles.

All kernels run in interpret=True mode (CPU container; TPU is the target).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_array_equal

from repro.kernels import ops, ref

SHAPES = [
    (0, 5),
    (1, 1),
    (7, 3),
    (64, 64),
    (100, 1000),
    (513, 2049),   # non-multiples of the block sizes
    (1024, 17),
    (2000, 0),
]


def _rand_sorted(rng, m, hi=10_000):
    return np.sort(rng.integers(0, hi, size=m).astype(np.int32))


class TestSortedMember:
    @pytest.mark.parametrize("n,m", SHAPES)
    def test_shapes(self, n, m):
        rng = np.random.default_rng(n * 31 + m)
        a = rng.integers(0, 10_000, size=n).astype(np.int32)
        b = _rand_sorted(rng, m)
        got = np.asarray(ops.member(a, b))
        want = np.asarray(ref.sorted_member_ref(a, b))
        assert_array_equal(got, want)

    @pytest.mark.parametrize("block_a,block_b", [(8, 16), (128, 128), (512, 1024)])
    def test_block_sweep(self, block_a, block_b):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 500, size=300).astype(np.int32)
        b = _rand_sorted(rng, 450, hi=500)
        got = np.asarray(ops.member(a, b, block_a=block_a, block_b=block_b))
        want = np.asarray(ref.sorted_member_ref(a, b))
        assert_array_equal(got, want)

    def test_anti_join(self):
        a = np.asarray([1, 2, 3, 4, 5], dtype=np.int32)
        b = np.asarray([2, 4], dtype=np.int32)
        got = np.asarray(ops.anti_join_mask(a, b))
        assert_array_equal(got, [True, False, True, False, True])

    @settings(max_examples=40, deadline=None)
    @given(
        a=st.lists(st.integers(0, 1000), max_size=200),
        b=st.lists(st.integers(0, 1000), max_size=200),
    )
    def test_property(self, a, b):
        a = np.asarray(a, dtype=np.int32)
        b = np.sort(np.asarray(b, dtype=np.int32))
        got = np.asarray(ops.member(a, b, block_a=64, block_b=64))
        want = np.isin(a, b)
        assert_array_equal(got, want)


class TestRleExpand:
    @pytest.mark.parametrize(
        "runs",
        [
            [(5, 1)],
            [(3, 4), (7, 2), (9, 10)],
            [(1, 1000)],
            [(i, 1) for i in range(100)],
            [(i, (i % 7) + 1) for i in range(300)],
        ],
    )
    def test_shapes(self, runs):
        vals = np.asarray([v for v, _ in runs], dtype=np.int32)
        cnts = np.asarray([c for _, c in runs], dtype=np.int32)
        total = int(cnts.sum())
        got = np.asarray(ops.expand_rle(vals, cnts, total))
        want = np.asarray(ref.rle_expand_ref(vals, cnts, total))
        assert_array_equal(got, want)

    @pytest.mark.parametrize("block_out", [16, 128, 1024])
    def test_block_sweep(self, block_out):
        rng = np.random.default_rng(1)
        vals = rng.integers(0, 100, size=50).astype(np.int32)
        cnts = rng.integers(1, 9, size=50).astype(np.int32)
        total = int(cnts.sum())
        got = np.asarray(ops.expand_rle(vals, cnts, total, block_out=block_out))
        want = np.asarray(ref.rle_expand_ref(vals, cnts, total))
        assert_array_equal(got, want)

    @settings(max_examples=40, deadline=None)
    @given(
        runs=st.lists(
            st.tuples(st.integers(0, 100), st.integers(1, 20)),
            min_size=1,
            max_size=60,
        )
    )
    def test_property(self, runs):
        vals = np.asarray([v for v, _ in runs], dtype=np.int32)
        cnts = np.asarray([c for _, c in runs], dtype=np.int32)
        total = int(cnts.sum())
        got = np.asarray(ops.expand_rle(vals, cnts, total, block_out=64))
        assert_array_equal(got, np.repeat(vals, cnts))


class TestJoinBounds:
    @pytest.mark.parametrize("n,m", SHAPES)
    def test_shapes(self, n, m):
        rng = np.random.default_rng(n * 7 + m)
        l = rng.integers(0, 300, size=n).astype(np.int32)
        r = _rand_sorted(rng, m, hi=300)
        lo_g, hi_g = ops.group_spans(l, r)
        lo_w, hi_w = ref.join_bounds_ref(l, r)
        assert_array_equal(np.asarray(lo_g), np.asarray(lo_w))
        assert_array_equal(np.asarray(hi_g), np.asarray(hi_w))

    @pytest.mark.parametrize("block_l,block_r", [(8, 8), (64, 256), (512, 1024)])
    def test_block_sweep(self, block_l, block_r):
        rng = np.random.default_rng(3)
        l = rng.integers(0, 100, size=333).astype(np.int32)
        r = _rand_sorted(rng, 777, hi=100)
        lo_g, hi_g = ops.group_spans(l, r, block_l=block_l, block_r=block_r)
        lo_w, hi_w = ref.join_bounds_ref(l, r)
        assert_array_equal(np.asarray(lo_g), np.asarray(lo_w))
        assert_array_equal(np.asarray(hi_g), np.asarray(hi_w))

    def test_prune_fastpath_correct(self):
        """Left tile far above right blocks exercises the += BLOCK path."""
        l = np.full(64, 1_000_000, dtype=np.int32)
        r = np.arange(4096, dtype=np.int32)
        lo_g, hi_g = ops.group_spans(l, r, block_l=64, block_r=256)
        assert_array_equal(np.asarray(lo_g), np.full(64, 4096, dtype=np.int32))
        assert_array_equal(np.asarray(hi_g), np.full(64, 4096, dtype=np.int32))

    @settings(max_examples=40, deadline=None)
    @given(
        l=st.lists(st.integers(0, 500), max_size=150),
        r=st.lists(st.integers(0, 500), max_size=150),
    )
    def test_property(self, l, r):
        l = np.asarray(l, dtype=np.int32)
        r = np.sort(np.asarray(r, dtype=np.int32))
        lo_g, hi_g = ops.group_spans(l, r, block_l=32, block_r=32)
        lo_w = np.searchsorted(r, l, side="left")
        hi_w = np.searchsorted(r, l, side="right")
        assert_array_equal(np.asarray(lo_g), lo_w.astype(np.int32))
        assert_array_equal(np.asarray(hi_g), hi_w.astype(np.int32))
