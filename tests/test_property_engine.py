"""Property-based tests: the compressed engine computes exactly
``mat(Pi, E)`` for random programs and datasets (vs the flat oracle)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import CMatEngine, flat_seminaive
from repro.core.datalog import Atom, Program, Rule

PREDS = [("P", 2), ("Q", 2), ("R", 1), ("S", 1)]
VARS = ["x", "y", "z"]


@st.composite
def atoms(draw, preds=PREDS):
    name, arity = draw(st.sampled_from(preds))
    terms = tuple(draw(st.sampled_from(VARS)) for _ in range(arity))
    return Atom(name, terms)


@st.composite
def rules(draw):
    body = tuple(draw(st.lists(atoms(), min_size=1, max_size=3)))
    body_vars = [v for a in body for v in a.variables()]
    name, arity = draw(st.sampled_from(PREDS))
    head_terms = tuple(draw(st.sampled_from(body_vars)) for _ in range(arity))
    return Rule(body, Atom(name, head_terms))


@st.composite
def programs(draw):
    return Program(draw(st.lists(rules(), min_size=1, max_size=4)))


@st.composite
def datasets(draw):
    n_const = draw(st.integers(min_value=1, max_value=8))
    out = {}
    for name, arity in PREDS:
        n = draw(st.integers(min_value=0, max_value=12))
        if n == 0:
            continue
        rows = draw(
            st.lists(
                st.tuples(
                    *[st.integers(min_value=0, max_value=n_const - 1)] * arity
                ),
                min_size=n,
                max_size=n,
            )
        )
        out[name] = np.unique(np.asarray(rows, dtype=np.int64), axis=0)
    return out


def _as_sets(facts):
    return {
        p: frozenset(map(tuple, rows.tolist()))
        for p, rows in facts.items()
        if rows.shape[0]
    }


@settings(
    max_examples=120,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(program=programs(), dataset=datasets())
def test_cmat_equals_flat_oracle(program, dataset):
    """The sound default (copy-mode splits) matches the flat oracle on
    arbitrary programs, including repeated variables and projections."""
    if not dataset:
        return
    expected = _as_sets(flat_seminaive(program, dataset))
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    actual = _as_sets(eng.materialisation())
    assert actual == expected


def test_inplace_mode_known_hazard_documented():
    """The paper's in-place redefinition (Alg. 4 line 51) is unsound when a
    derived meta-fact shares a column with a source meta-fact whose other
    columns are not co-split.  Minimal counterexample found by hypothesis:
    ``Q(x,x) -> P(x,x)`` with E = {P(0,0), Q(0,0), Q(1,1)}: the dedup split
    of the head column permutes Q's first column but not its second.

    This test pins the *documented* behaviour: copy-mode is correct here;
    if in-place mode ever becomes correct too, the guard can be revisited.
    """
    program = Program(
        [Rule((Atom("Q", ("x", "x")),), Atom("P", ("x", "x")))]
    )
    dataset = {
        "P": np.asarray([[0, 0]], dtype=np.int64),
        "Q": np.asarray([[0, 0], [1, 1]], dtype=np.int64),
    }
    expected = _as_sets(flat_seminaive(program, dataset))
    eng = CMatEngine(program, inplace_splits=False)
    eng.load(dataset)
    eng.materialise()
    assert _as_sets(eng.materialisation()) == expected


@settings(max_examples=60, deadline=None)
@given(program=programs(), dataset=datasets())
def test_representation_size_consistency(program, dataset):
    """||<M, mu>|| must account for every represented fact, and unfolding
    must be duplicate-free after materialisation's dedup."""
    if not dataset:
        return
    eng = CMatEngine(program)
    eng.load(dataset)
    eng.materialise()
    for pred in list(eng.facts.predicates()):
        rows = eng.facts.unfold_pred(pred)
        uniq = np.unique(rows, axis=0)
        assert uniq.shape[0] == rows.shape[0], f"{pred} has duplicate facts"
    assert eng.facts.total_repr_size() > 0
