"""Engine-level parity for the PR 7 fused fast path.

Both fused round tails (FlatEngine's host analogue and CMatEngine's
flat-tail xjoin emission) must produce materialisations bit-identical
to their per-step references on every generator workload — including
the cross-product-heavy ones where the fused path is slower but must
still be correct — plus the ``unique_rows`` / positional-merge helpers
they are built from."""

import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.core import CMatEngine, FlatEngine
from repro.core.generators import (
    bipartite,
    chain,
    lubm_like,
    paper_example,
    star,
)
from repro.core.util import (
    factorize_rows,
    merge_sorted_rows_np,
    merge_sorted_unique_np,
    unique_rows,
)

WORKLOADS = [
    ("paper", lambda: paper_example(n=30, m=20)),
    ("chain", lambda: chain(n=60)),
    ("lubm", lambda: lubm_like(n_dept=4, n_students=60, n_courses=10)),
    ("star", lambda: star(n_spokes=80, n_hubs=3)),
    ("bipartite", lambda: bipartite(n_left=30, n_right=30)),
]


def _flat_mat(program, dataset, fused):
    eng = FlatEngine(program, fused=fused)
    eng.load(dataset)
    return eng.materialise()


def _cmat_mat(program, dataset, **kw):
    eng = CMatEngine(program, **kw)
    eng.load(dataset)
    eng.materialise()
    return {p: np.unique(r, axis=0) for p, r in eng.materialisation().items()}


@pytest.mark.parametrize("name,gen", WORKLOADS)
def test_flat_fused_round_tail_bit_identical(name, gen):
    program, dataset, _ = gen()
    per_step = _flat_mat(program, dataset, fused=False)
    fused = _flat_mat(program, dataset, fused=True)
    assert set(per_step) == set(fused)
    for pred in per_step:
        assert_array_equal(per_step[pred], fused[pred])


@pytest.mark.parametrize("name,gen", WORKLOADS)
def test_cmat_fused_parity(name, gen):
    program, dataset, _ = gen()
    base = _cmat_mat(program, dataset)
    fused = _cmat_mat(program, dataset, fused=True)
    flat = _flat_mat(program, dataset, fused=True)
    assert set(base) == set(fused) == set(flat)
    for pred in base:
        assert_array_equal(base[pred], fused[pred])
        assert_array_equal(base[pred], np.asarray(flat[pred]))


def test_cmat_fused_wide_join_falls_back():
    """fused_max_pairs=0 forces every final xjoin over the cap, so the
    structure-shared fallback carries the whole round — results must
    not change."""
    program, dataset, _ = chain(n=40)
    base = _cmat_mat(program, dataset)
    capped = _cmat_mat(program, dataset, fused=True, fused_max_pairs=0)
    for pred in base:
        assert_array_equal(base[pred], capped[pred])


def test_cmat_fused_counts_fused_rounds():
    from repro.obs import get_registry

    reg = get_registry()
    reg.reset("cmat.")
    program, dataset, _ = chain(n=20)
    _cmat_mat(program, dataset, fused=True)
    assert reg.snapshot("cmat.").get("cmat.fused_rounds", 0) > 0


class TestUniqueRows:
    @pytest.mark.parametrize("k", [1, 2, 3])
    def test_matches_np_unique_axis0(self, k):
        rng = np.random.default_rng(k)
        rows = rng.integers(0, 50, size=(200, k)).astype(np.int64)
        u, inv = unique_rows(rows, return_inverse=True)
        ru, rinv = np.unique(rows, axis=0, return_inverse=True)
        assert_array_equal(u, ru)
        assert_array_equal(inv, rinv.reshape(-1))
        assert_array_equal(unique_rows(rows), ru)

    def test_wide_values_fall_back(self):
        rows = np.array([[2**40, 1], [0, 2], [2**40, 1]], dtype=np.int64)
        assert_array_equal(unique_rows(rows), np.unique(rows, axis=0))

    def test_empty(self):
        rows = np.zeros((0, 2), dtype=np.int64)
        assert unique_rows(rows).shape == (0, 2)


class TestPositionalMerge:
    def test_merge_sorted_unique_np(self):
        rng = np.random.default_rng(0)
        old = np.unique(rng.integers(0, 1000, size=80))
        fresh = np.setdiff1d(np.unique(rng.integers(0, 1000, size=40)), old)
        out = merge_sorted_unique_np(old, fresh)
        assert_array_equal(out, np.union1d(old, fresh))

    def test_merge_sorted_rows_np(self):
        rng = np.random.default_rng(1)
        old = unique_rows(rng.integers(0, 60, size=(50, 2)).astype(np.int64))
        cand = unique_rows(rng.integers(0, 60, size=(30, 2)).astype(np.int64))
        codes_cand, codes_old = factorize_rows(cand, old)
        keep = ~np.isin(codes_cand, codes_old)
        out = merge_sorted_rows_np(old, cand[keep], codes_old, codes_cand[keep])
        expect = np.unique(np.concatenate([old, cand]), axis=0)
        assert_array_equal(out, expect)
