"""Optimizer substrate: AdamW (ZeRO-sharded), LR schedules, gradient
compression with error feedback."""

from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from .compress import (
    compress_grads,
    compressed_grad_transform,
    decompress_grads,
    init_error_feedback,
)
from .schedule import constant, warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "compress_grads",
    "compressed_grad_transform",
    "decompress_grads",
    "init_error_feedback",
    "constant",
    "warmup_cosine",
]
