"""Gradient compression: int8 quantised data-parallel all-reduce with
error feedback.

At 1000+ node scale the DP gradient all-reduce is the dominant inter-pod
traffic.  We quantise each gradient leaf to int8 with a per-leaf scale
before the reduction and keep the quantisation residual in an error-
feedback buffer (added back into the next step's gradient), which keeps
SGD/Adam convergence unaffected in expectation.

Under pjit the quantised tensors are what crosses the DCI links; the
4x byte reduction shows up directly in the collective roofline term.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_feedback", "compress_grads", "decompress_grads",
           "compressed_grad_transform"]


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
    )


def _quantise(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, error_buf):
    """Returns (quantised pytree, scales pytree, new error buffer)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantise(g32)
        recon = q.astype(jnp.float32) * scale
        return q, scale, g32 - recon

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    errs = treedef.unflatten([o[2] for o in out])
    return qs, scales, errs


def decompress_grads(qs, scales):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, qs, scales
    )


def compressed_grad_transform(grads, error_buf):
    """Round-trip compress/decompress (the collective itself is inserted by
    the partitioner between the two halves).  Returns (grads', new_error)."""
    qs, scales, errs = compress_grads(grads, error_buf)
    return decompress_grads(qs, scales), errs
