"""AdamW with fully-sharded (ZeRO) optimizer states.

No optax in this environment — implemented from scratch.  States are
plain pytrees mirroring the parameters; under pjit they inherit the
parameters' fully-sharded layout (ZeRO-1/3 equivalent: every chip holds
1/N of params, moments, and master copies).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        update = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"mu": new_mu, "nu": new_nu, "step": step},
        {"grad_norm": gnorm},
    )
