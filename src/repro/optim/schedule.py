"""Learning-rate schedules (warmup + cosine / linear / constant)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 1000, total: int = 100_000,
                  min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return warm * cos


def constant(step):
    return jnp.ones_like(step, dtype=jnp.float32)
