"""Incremental maintenance over the compressed store.

The fourth engine subsystem: keeps ``mat(Pi, E)`` up to date in place
under explicit insert/delete batches instead of re-running the fixpoint
from scratch.  Recursive strata run Delete/Rederive with a
backward/forward rederivation check (:mod:`repro.incremental.dred`);
non-recursive strata maintain exact derivation counts
(:mod:`repro.incremental.store`).  Everything compiles through the
shared body compiler and operates on meta-facts — a meta-fact covering
many triples is probed, split, or restored once.
"""

from .index import RowIndex
from .store import IncrementalStats, IncrementalStore

__all__ = ["IncrementalStore", "IncrementalStats", "RowIndex"]
