"""Source-mapped rule-body evaluation for incremental maintenance.

Every phase of Delete/Rederive and of counting maintenance is "evaluate
a rule body with one atom pinned to a delta" — exactly the semi-naive
shape the shared body compiler (:mod:`repro.core.compile`) already
plans.  The only difference between phases is *which* meta-fact lists
the plan's ``old`` / ``delta`` / ``all`` source labels resolve to:

=============================  =============  =============  ==========
phase                          ``old``        ``all``        ``delta``
=============================  =============  =============  ==========
overdelete                     pre-deletion   pre-deletion   ΔO
counting, deletion sweep       post-deletion  pre-deletion   Δdeleted
counting, insertion sweep      post-insert    pre-insert     Δinserted
rederive forward / insertion   current        current        Δrestored
=============================  =============  =============  ==========

(The counting rows implement the telescoping identity
``old^n − new^n = Σ_i new^{<i} × Δ_i × old^{>i}`` — the compiler tags
sources by *original body position*, so the mapping stays exact under
plan reordering.)

This module owns the pieces the phases share: the evaluator driving
``match``/``sjoin``/``xjoin`` over a source mapping, head projection
with or without derivation multiplicity, row↔meta-fact conversion, the
backward-bounding head filter, and :class:`PhaseStats` — planner
statistics that never shortcut a plan to empty (per-atom emptiness is a
property of the *partition* an atom reads, decided at evaluation time).
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from ..core.columns import ColumnStore
from ..core.compile import PlanCache, compile_body, stats_bucket
from ..core.compress import compress_rows
from ..core.datalog import Atom, Rule
from ..core.joins import SubstSet, match, sjoin, xjoin
from ..core.metafacts import FactStore, MetaFact

__all__ = [
    "PhaseStats",
    "Sources",
    "evaluate_rule",
    "project_head",
    "rows_to_metafacts",
    "head_binding_filter",
]

#: a source mapping: (predicate, src-label) -> meta-fact list
Sources = Callable[[str, str], list]


class PhaseStats:
    """Planner statistics for incremental phases.

    Cardinalities come from the live store but are clamped to ``>= 1``
    and arities come from the program/dataset schema: a maintenance plan
    must never compile to the empty plan just because the *current*
    store partition is empty — the phase may be reading a pre-update
    view that is not.  Real emptiness is detected per atom when the
    actual partition is matched.
    """

    def __init__(self, facts: FactStore, arities: dict[str, int]):
        self.facts = facts
        self.arities = arities
        self._n_rows: dict[str, int] = {}
        self._runs: dict[tuple[str, int], int] = {}

    def n_rows(self, pred: str) -> int:
        cached = self._n_rows.get(pred)
        if cached is None:
            cached = max(sum(mf.length for mf in self.facts.all(pred)), 1)
            self._n_rows[pred] = cached
        return cached

    def arity(self, pred: str) -> int:
        known = self.arities.get(pred)
        if known is not None:
            return known
        mfs = self.facts.all(pred)
        return mfs[0].arity if mfs else 0

    def selectivity(self, pred: str, pos: int, value: int) -> float:
        key = (pred, pos)
        runs = self._runs.get(key)
        if runs is None:
            store = self.facts.store
            runs = max(
                sum(
                    store.n_runs(mf.columns[pos])
                    for mf in self.facts.all(pred)
                    if pos < mf.arity
                ),
                1,
            )
            self._runs[key] = runs
        return 1.0 / runs

    def refresh(self) -> None:
        self._n_rows.clear()
        self._runs.clear()


# --------------------------------------------------------------------- #
def rows_to_metafacts(
    pred: str, rows: np.ndarray, store: ColumnStore, round_tag: int = 0
) -> list[MetaFact]:
    """Compress flat rows into meta-facts (Algorithm 2 segmentation)."""
    return [
        MetaFact(pred, cols, length, round_tag)
        for cols, length in compress_rows(rows, store)
    ]


def head_binding_filter(
    head: Atom, rows: np.ndarray, store: ColumnStore
) -> SubstSet | None:
    """A :class:`SubstSet` binding the head's variables to the given head
    tuples — the *backward* bound of the Backward/Forward rederivation
    check: any body substitution rederiving one of ``rows`` must agree
    with some row on every shared variable, so atom scans are semi-joined
    against this set before any join work happens."""
    vars_ = head.variables()
    if not vars_ or rows.shape[0] == 0:
        return None
    first_pos = {v: head.terms.index(v) for v in vars_}
    mask = np.ones(rows.shape[0], dtype=bool)
    for pos, t in enumerate(head.terms):
        if isinstance(t, int):
            mask &= rows[:, pos] == t
        elif pos != first_pos[t]:
            mask &= rows[:, pos] == rows[:, first_pos[t]]
    sel = rows[mask][:, [first_pos[v] for v in vars_]]
    if sel.shape[0] == 0:
        return SubstSet(vars_)
    sel = np.unique(sel, axis=0)
    return SubstSet(vars_, compress_rows(sel, store))


# --------------------------------------------------------------------- #
def evaluate_rule(
    rule: Rule,
    pivot: int | None,
    sources: Sources,
    store: ColumnStore,
    stats: PhaseStats,
    plan_cache: PlanCache,
    *,
    match_cache: dict | None = None,
    head_filter: SubstSet | None = None,
) -> SubstSet | None:
    """Evaluate one (rule, pivot) body over a phase's source mapping.

    Returns the body-substitution :class:`SubstSet` (``None`` when any
    partition comes up empty).  ``head_filter`` bounds every atom scan
    by the deleted-head bindings (backward rederivation); it is
    rule-specific, so the shared ``match_cache`` is bypassed then.
    """
    plan = plan_cache.get(
        (rule, pivot),
        stats_bucket(stats, rule.body),
        lambda: compile_body(rule.body, stats, pivot=pivot),
    )
    if plan.is_empty:  # unreachable under PhaseStats; kept for safety
        return None

    filter_vars = set(head_filter.vars) if head_filter is not None else set()

    def scan(step) -> SubstSet:
        key = (step.atom, step.source)
        if head_filter is None and match_cache is not None:
            hit = match_cache.get(key)
            if hit is not None:
                return hit
        out = match(
            step.atom, sources(step.atom.predicate, step.source), store, False
        )
        if head_filter is not None and not out.is_empty():
            shared = tuple(v for v in out.vars if v in filter_vars)
            if shared:
                out = sjoin(head_filter, out, shared, store, False)
        if head_filter is None and match_cache is not None:
            match_cache[key] = out
        return out

    L = scan(plan.first)
    if L.is_empty():
        return None
    if head_filter is None:
        # feedback only for unfiltered scans: a head-filtered first scan
        # is deliberately tiny and says nothing about the estimate
        plan_cache.note_actual(
            (rule, pivot), plan.first.est_rows, L.n_substitutions()
        )
    for step in plan.joins:
        R = scan(step.scan)
        if R.is_empty():
            return None
        if step.kind == "sjoin":
            if step.filter_left:
                L = sjoin(R, L, step.key_vars, store, False)
            else:
                L = sjoin(L, R, step.key_vars, store, False)
        else:
            L = xjoin(L, R, step.key_vars, store)
        if L.is_empty():
            return None
    return L


def project_head(
    head: Atom,
    L: SubstSet,
    store: ColumnStore,
    *,
    multiplicity: bool = False,
) -> tuple[np.ndarray, np.ndarray | None]:
    """Project body substitutions onto the head.

    Returns ``(rows, counts)``: unique head tuples and — with
    ``multiplicity=True`` — how many distinct body substitutions derive
    each (the per-rule derivation count; the pipeline is duplicate-free
    because the store is, so ``unique(..., return_counts)`` is exact).
    """
    var_idx = {v: L.vars.index(v) for v in head.variables()}
    n = L.n_substitutions()
    cols = []
    for t in head.terms:
        if isinstance(t, int):
            cols.append(np.full(n, t, dtype=np.int64))
        else:
            cols.append(
                np.concatenate(
                    [store.unfold(ids[var_idx[t]]) for ids, _ in L.items]
                )
            )
    rows = np.stack(cols, axis=1)
    if multiplicity:
        uniq, counts = np.unique(rows, axis=0, return_counts=True)
        return uniq, counts.astype(np.int64)
    return np.unique(rows, axis=0), None
