"""Delete/Rederive (DRed) over meta-facts, per recursive stratum.

Incremental deletion for a recursive stratum runs the classic three
phases, but set-at-a-time over the compressed representation:

* **overdelete** — propagate the deleted delta through the stratum's
  rules (pivot = the delta, other atoms read the *pre-deletion* view),
  collecting every materialised fact whose derivation may have passed
  through a deleted fact.  Plans come from the shared body compiler;
  a meta-fact covering many facts is probed/split once per phase, not
  per expanded triple.
* **delete** — physically remove the overdeleted rows: each meta-fact is
  masked with one vectorised membership test; untouched meta-facts keep
  sharing their columns, partially-hit ones are split copy-mode
  (the frozen-store contract: no node is ever redefined in place).
* **rederive (Backward/Forward)** — restore overdeleted facts that are
  still explicit, then run a *backward-bounded* probe per rule: every
  atom scan is semi-joined against the missing head bindings
  (:func:`~repro.incremental.eval.head_binding_filter`) before any join
  work, so the check explores only derivations that could end in a
  deleted fact.  Newly restored facts then propagate *forward*
  semi-naively (pivot = restorations) until the missing set stops
  shrinking.

All evaluation intermediates live in a :meth:`ColumnStore.mark` /
``release`` scratch region; only the split survivors and restored
meta-facts persist.
"""

from __future__ import annotations

import time

import numpy as np

from ..core.compile import SRC_DELTA
from ..core.util import multicol_member
from ..obs import span
from .eval import (
    evaluate_rule,
    head_binding_filter,
    project_head,
    rows_to_metafacts,
)
from .index import merge_rows, setdiff_rows

__all__ = ["dred_stratum", "explicit_restores"]


def explicit_restores(
    missing: dict[str, np.ndarray], explicit: dict[str, np.ndarray]
) -> dict[str, np.ndarray]:
    """Overdeleted rows that are still explicit facts — they come back
    without any derivability probe (the first rederivation step, shared
    by the host DRed and the distributed delta exchange)."""
    out: dict[str, np.ndarray] = {}
    for pred, miss in missing.items():
        present = explicit.get(pred)
        if present is None or present.shape[0] == 0 or miss.shape[0] == 0:
            continue
        back = miss[multicol_member(miss, present)]
        if back.shape[0]:
            out[pred] = back
    return out


def dred_stratum(inc, stratum, seeds, head_dels, st) -> dict[str, np.ndarray]:
    """Maintain one recursive stratum under deletion.

    ``seeds`` are the net-removed rows of lower-strata/EDB predicates;
    ``head_dels`` the explicit deletions of this stratum's head
    predicates.  Returns the net-removed rows per head predicate (the
    deltas later strata see).  ``inc`` is the :class:`IncrementalStore`.
    """
    store, facts = inc.store, inc.facts
    with span("dred.overdelete") as sp:
        over = _overdelete(inc, stratum, seeds, head_dels, st)
        sp.set(n_overdeleted=sum(int(r.shape[0]) for r in over.values()))
    if not over:
        return {}
    for pred, rows in over.items():
        inc.record_provenance("overdelete", pred, n_new=rows.shape[0])

    t0 = time.perf_counter()
    with span("dred.delete"):
        missing: dict[str, np.ndarray] = {}
        for pred, rows in over.items():
            inc.delete_rows(pred, rows)
            missing[pred] = rows
    st.time_delete += time.perf_counter() - t0

    t0 = time.perf_counter()
    with span("dred.rederive") as rede:
        # --- rederive: explicit survivors come back without a probe --- #
        delta_mfs: dict[str, list] = {}
        for pred, back in explicit_restores(missing, inc.explicit).items():
            delta_mfs[pred] = inc.add_rows(pred, back)
            missing[pred] = setdiff_rows(missing[pred], back)
            st.n_rederived += int(back.shape[0])
            inc.record_provenance(
                "survive_explicit", pred,
                n_new=back.shape[0], out_mfs=delta_mfs[pred],
            )

        def current(pred: str, src: str = "") -> list:
            return facts.all(pred)

        # --- backward pass: bounded one-step rederivability check ----- #
        for rule in stratum:
            if not rule.body:
                continue
            pred = rule.head.predicate
            miss = missing.get(pred)
            if miss is None or miss.shape[0] == 0:
                continue
            mark = store.mark()
            hf = head_binding_filter(rule.head, miss, store)
            L = evaluate_rule(
                rule, None, current, store, inc.stats_view, inc.plan_cache,
                head_filter=hf,
            )
            st.n_rule_applications += 1
            if L is None:
                store.release(mark)
                continue
            rows, _ = project_head(rule.head, L, store)
            store.release(mark)
            back = rows[multicol_member(rows, miss)]
            if back.shape[0]:
                mfs = inc.add_rows(pred, back)
                delta_mfs.setdefault(pred, []).extend(mfs)
                missing[pred] = setdiff_rows(miss, back)
                st.n_rederived += int(back.shape[0])
                inc.record_provenance(
                    "survive_backward", pred,
                    rule_id=inc._rule_ids.get(rule, -1),
                    n_emitted=rows.shape[0], n_new=back.shape[0],
                    out_mfs=mfs,
                )

        # --- forward pass: restorations propagate semi-naively -------- #
        while delta_mfs:
            def sources(pred: str, src: str) -> list:
                if src == SRC_DELTA:
                    return delta_mfs.get(pred, [])
                return facts.all(pred)

            mark = store.mark()
            derived: dict[str, list[np.ndarray]] = {}
            for rule in stratum:
                pred = rule.head.predicate
                miss = missing.get(pred)
                if miss is None or miss.shape[0] == 0:
                    continue
                hf = head_binding_filter(rule.head, miss, store)
                for i, atom in enumerate(rule.body):
                    if atom.predicate not in delta_mfs:
                        continue
                    L = evaluate_rule(
                        rule, i, sources, store, inc.stats_view,
                        inc.plan_cache, head_filter=hf,
                    )
                    st.n_rule_applications += 1
                    if L is None:
                        continue
                    rows, _ = project_head(rule.head, L, store)
                    derived.setdefault(pred, []).append(rows)
            store.release(mark)

            new_delta: dict[str, list] = {}
            for pred, blocks in derived.items():
                cand = np.unique(np.concatenate(blocks), axis=0)
                back = cand[multicol_member(cand, missing[pred])]
                if back.shape[0]:
                    new_delta[pred] = inc.add_rows(pred, back)
                    missing[pred] = setdiff_rows(missing[pred], back)
                    st.n_rederived += int(back.shape[0])
                    inc.record_provenance(
                        "rederive", pred,
                        n_emitted=cand.shape[0], n_new=back.shape[0],
                        out_mfs=new_delta[pred],
                    )
            delta_mfs = new_delta
        rede.set(
            n_missing=sum(int(m.shape[0]) for m in missing.values())
        )
    st.time_rederive += time.perf_counter() - t0

    net = {p: m for p, m in missing.items() if m.shape[0]}
    st.n_deleted += sum(int(m.shape[0]) for m in net.values())
    return net


def _overdelete(inc, stratum, seeds, head_dels, st) -> dict[str, np.ndarray]:
    """Propagate deletions through the stratum over the pre-deletion
    view; returns the overdeleted rows per head predicate."""
    t0 = time.perf_counter()
    store = inc.store
    over: dict[str, np.ndarray] = {}
    delta: dict[str, np.ndarray] = {
        p: r for p, r in seeds.items() if r.shape[0]
    }
    for pred, rows in head_dels.items():
        rows = rows[inc.rows.member_mask(pred, rows)]
        if rows.shape[0]:
            over[pred] = rows
            delta[pred] = merge_rows(delta.get(pred), rows)

    def pre_view(pred: str) -> list:
        return inc.pre_mfs.get(pred, [])

    while delta:
        mark = store.mark()
        delta_mfs = {
            p: rows_to_metafacts(p, r, store) for p, r in delta.items()
        }

        def sources(pred: str, src: str) -> list:
            if src == SRC_DELTA:
                return delta_mfs.get(pred, [])
            return pre_view(pred)

        match_cache: dict = {}
        derived: dict[str, list[np.ndarray]] = {}
        for rule in stratum:
            if not rule.body:
                continue
            for i, atom in enumerate(rule.body):
                if atom.predicate not in delta_mfs:
                    continue
                L = evaluate_rule(
                    rule, i, sources, store, inc.stats_view, inc.plan_cache,
                    match_cache=match_cache,
                )
                st.n_rule_applications += 1
                if L is None:
                    continue
                rows, _ = project_head(rule.head, L, store)
                derived.setdefault(rule.head.predicate, []).append(rows)
        store.release(mark)

        new_delta: dict[str, np.ndarray] = {}
        for pred, blocks in derived.items():
            cand = np.unique(np.concatenate(blocks), axis=0)
            # only materialised facts can be overdeleted, each only once
            cand = cand[inc.rows.member_mask(pred, cand)]
            prev = over.get(pred)
            if prev is not None and prev.shape[0]:
                cand = setdiff_rows(cand, prev)
            if cand.shape[0]:
                over[pred] = merge_rows(prev, cand)
                new_delta[pred] = cand
        delta = new_delta

    st.n_overdeleted += sum(int(r.shape[0]) for r in over.values())
    st.time_overdelete += time.perf_counter() - t0
    return over
