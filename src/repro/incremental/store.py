"""IncrementalStore: a live, updatable compressed materialisation.

The paper materialises once; a serving system takes inserts and deletes
continuously.  ``IncrementalStore`` wraps the compressed store built by
:class:`~repro.core.engine.CMatEngine` and maintains ``mat(Pi, E)`` in
place under explicit-fact update batches::

    inc = IncrementalStore(program)
    inc.load(dataset)                       # initial fixpoint (CMatEngine)
    stats = inc.apply(additions, deletions) # incremental maintenance
    frozen = inc.freeze()                   # epoch snapshot for queries

``apply`` runs a **deletion sweep** then an **insertion sweep**, each
stratum-by-stratum in the SCC topological order
(:mod:`repro.core.program_graph`), so every stratum sees final deltas
from the strata below it.  Per stratum the cheapest sound algorithm is
chosen:

* **non-recursive strata** (one fixpoint round; most of an RDFS/OWL RL
  taxonomy) maintain exact per-fact **derivation counts**: the
  telescoping identity ``old^n − new^n = Σ_i new^{<i} Δ_i old^{>i}``
  counts every lost/gained rule instantiation exactly once, counts are
  scatter-updated in one pass, and facts whose count reaches zero (and
  are not explicit) are deleted — no overdeletion, no rederivation.
* **recursive strata** fall back to Delete/Rederive with the
  backward/forward rederivation check (:mod:`repro.incremental.dred`).

Derivation counts are flat int64 columns aligned with the maintained
:class:`~repro.incremental.index.RowIndex` rows; all phase evaluation
runs inside :meth:`ColumnStore.mark`/``release`` scratch regions, so the
mu-store grows only by what the update actually changes (split
survivors + newly derived meta-facts), never by probe intermediates.

Every batch appends to :attr:`journal` (bounded; the durable history is
the optional write-ahead log, :meth:`attach_wal`) and bumps
:attr:`epoch` — the serving layer version-stamps its query caches with
the epoch and invalidates on change (``launch/serve_datalog.py
--live``).  The :mod:`repro.storage` layer adds snapshots, recovery,
and GC/compaction epochs on top (:meth:`maybe_compact`).
"""

from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..core.compile import SRC_DELTA, SRC_OLD, PlanCache
from ..core.datalog import Program
from ..core.engine import CMatEngine, MaterialisationStats
from ..core.frozen import FrozenFacts
from ..core.metafacts import MetaFact
from ..core.program_graph import is_recursive, stratify, stratum_predicates
from ..core.util import multicol_member, unique_rows
from ..obs import publish_incremental, span
from ..obs.memory import register_reporter, split_owned_backed
from .dred import dred_stratum
from .eval import (
    PhaseStats,
    evaluate_rule,
    project_head,
    rows_to_metafacts,
)
from .index import RowIndex, merge_rows

__all__ = [
    "IncrementalStore",
    "IncrementalStats",
    "normalise_batch",
    "effective_updates",
]


@dataclass
class IncrementalStats(MaterialisationStats):
    """Per-``apply`` maintenance statistics (extends the engine stats)."""

    epoch: int = 0
    n_del_explicit: int = 0  # explicit facts removed from E
    n_add_explicit: int = 0  # explicit facts added to E
    n_overdeleted: int = 0   # facts entering the DRed overdeletion set
    n_rederived: int = 0     # overdeleted facts restored
    n_deleted: int = 0       # net facts removed from the materialisation
    n_inserted: int = 0      # net facts added to the materialisation
    n_count_updates: int = 0  # derivation-count entries scatter-updated
    counting_strata: int = 0  # strata maintained by exact count deltas
    dred_strata: int = 0      # strata maintained by Delete/Rederive
    time_overdelete: float = 0.0
    time_delete: float = 0.0
    time_rederive: float = 0.0
    time_counting: float = 0.0
    time_insert: float = 0.0
    journal_bytes: int = 0    # resident bytes of the (capped) journal


def normalise_batch(batch) -> dict[str, np.ndarray]:
    """Canonical update batch: sorted-unique ``(n, arity)`` int64 rows per
    predicate, empty predicates dropped (shared with the distributed
    engine's ``apply``)."""
    out: dict[str, np.ndarray] = {}
    for pred, rows in (batch or {}).items():
        rows = np.asarray(rows, dtype=np.int64)
        if rows.ndim == 1:
            rows = rows.reshape(-1, 1)
        if rows.shape[0]:
            out[pred] = unique_rows(rows)
    return out


_normalise = normalise_batch  # backwards-compatible internal alias


def effective_updates(
    explicit: dict[str, np.ndarray],
    adds: dict[str, np.ndarray],
    dels: dict[str, np.ndarray],
) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
    """Clamp a normalised batch against the explicit set and update it in
    place (``E := (E \\ dels) ∪ adds``).

    Returns ``(eff_adds, eff_dels)``: deletions of non-explicit facts and
    additions of already-explicit facts are dropped, so batches are
    idempotent.  This is the update contract every maintenance engine
    shares (host :class:`IncrementalStore` and the distributed engine).
    """
    eff_dels: dict[str, np.ndarray] = {}
    for pred, rows in dels.items():
        present = explicit.get(pred)
        if present is None or present.shape[0] == 0:
            continue
        rows = rows[multicol_member(rows, present)]
        if rows.shape[0]:
            eff_dels[pred] = rows
            explicit[pred] = present[~multicol_member(present, rows)]
    eff_adds: dict[str, np.ndarray] = {}
    for pred, rows in adds.items():
        present = explicit.get(pred)
        if present is not None and present.shape[0]:
            rows = rows[~multicol_member(rows, present)]
        if rows.shape[0]:
            eff_adds[pred] = rows
            explicit[pred] = merge_rows(present, rows)
    return eff_adds, eff_dels


class IncrementalStore:
    """Journalled insert/delete maintenance over the compressed store."""

    def __init__(
        self,
        program: Program,
        *,
        counting: bool = True,
        plan_cache: PlanCache | None = None,
        journal_max: int = 1024,
    ):
        self.program = program
        self.strata = stratify(program)
        self.engine = CMatEngine(program)
        self.facts = self.engine.facts
        self.store = self.engine.store
        self.rows = RowIndex()
        self.explicit: dict[str, np.ndarray] = {}
        self.counting = counting
        #: derivation-count columns, aligned with ``rows`` (heads of
        #: non-recursive strata only; count = #one-step derivations
        #: from the current materialisation + 1 if explicit)
        self.counts: dict[str, np.ndarray] = {}
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.epoch = 0
        #: bounded per-batch maintenance record (the durable history is
        #: the WAL, not this; see :meth:`attach_wal`)
        self.journal: deque[dict] = deque(maxlen=max(journal_max, 1))
        self._journal_sizes: deque[int] = deque(maxlen=max(journal_max, 1))
        self._journal_nbytes = 0
        #: optional write-ahead log: batches are logged *before* the
        #: store mutates, so snapshot + replay reproduces this store
        self.wal = None
        #: (n_nodes, MuUsage) of the last GC probe (see maybe_compact)
        self._gc_usage: tuple[int, object] | None = None
        self._round = 0
        self._head_preds = {r.head.predicate for r in program}
        self._counting_preds: set[str] = set()
        if counting:
            for stratum in self.strata:
                if not is_recursive(stratum):
                    self._counting_preds.update(
                        r.head.predicate for r in stratum
                    )
            # aligned-from-empty so apply() works on a never-loaded store
            self.counts = {
                p: np.zeros(0, dtype=np.int64) for p in self._counting_preds
            }
        self.arities: dict[str, int] = {}
        for rule in program:
            for atom in (rule.head, *rule.body):
                self.arities.setdefault(atom.predicate, atom.arity)
        self.stats_view = PhaseStats(self.facts, self.arities)
        #: publish-after-apply handoff: callbacks ``cb(store, stats)``
        #: invoked at the end of every ``apply`` (after the epoch bump
        #: and journal append).  The serving tier subscribes here so a
        #: new MVCC epoch is published no matter which code path applied
        #: the batch.
        self.publish_hooks: list = []
        # per-apply pre-update meta-fact snapshots (read by the phases)
        self.pre_mfs: dict[str, list] = {}
        # provenance (obs.provenance — distinct from the maintenance
        # journal above): bound per-apply when recording is on
        self._pjournal = None
        self._cur_stratum = -1
        self._rule_ids: dict = {}
        for k, rule in enumerate(program):
            self._rule_ids.setdefault(rule, k)
        # obs.memory: the store reports its side structures only — the
        # ColumnStore registers itself, so its node bytes are never
        # counted twice
        register_reporter("inc", self)

    # ------------------------------------------------------------------ #
    # initial build
    # ------------------------------------------------------------------ #
    def load(self, dataset: dict[str, np.ndarray]) -> MaterialisationStats:
        """Compress + materialise the initial KB and build the row index
        and derivation-count columns."""
        dataset = _normalise(dataset)
        for pred, rows in dataset.items():
            self.explicit[pred] = rows
            self.arities.setdefault(pred, int(rows.shape[1]))
        self.engine.load(dataset)
        stats = self.engine.materialise()
        self._round = stats.rounds + 1
        for pred, rows in self.facts.to_dict().items():
            self.rows.seed(pred, rows)
        if self.counting:
            self._build_counts()
        return stats

    def _build_counts(self) -> None:
        """Support counts for heads of non-recursive strata: one bounded
        naive evaluation per rule over the final materialisation."""
        computed = self.recompute_counts()
        self.counts = computed

    def recompute_counts(self) -> dict[str, np.ndarray]:
        """Derivation counts from scratch (also the test oracle for the
        maintained ones)."""
        self.stats_view.refresh()
        counts = {
            p: np.zeros(self.rows.n_rows(p), dtype=np.int64)
            for p in self._counting_preds
        }

        def current(pred: str, src: str) -> list:
            return self.facts.all(pred)

        for stratum in self.strata:
            if is_recursive(stratum) or not self.counting:
                continue
            for rule in stratum:
                if not rule.body:
                    continue
                mark = self.store.mark()
                L = evaluate_rule(
                    rule, None, current, self.store, self.stats_view,
                    self.plan_cache,
                )
                if L is None:
                    self.store.release(mark)
                    continue
                rows, cnts = project_head(
                    rule.head, L, self.store, multiplicity=True
                )
                self.store.release(mark)
                pred = rule.head.predicate
                np.add.at(counts[pred], self.rows.positions(pred, rows), cnts)
        for pred in self._counting_preds:
            explicit = self.explicit.get(pred)
            if explicit is not None and explicit.shape[0]:
                present = explicit[self.rows.member_mask(pred, explicit)]
                if present.shape[0]:
                    counts[pred][self.rows.positions(pred, present)] += 1
        return counts

    # ------------------------------------------------------------------ #
    # store mutation primitives (shared by all phases)
    # ------------------------------------------------------------------ #
    def delete_rows(self, pred: str, rows: np.ndarray) -> None:
        """Remove flat rows from the compressed store: one vectorised
        membership pass over the whole predicate (unfolds come from the
        cache), then per-meta-fact mask slices; disjoint meta-facts stay
        shared, partially-hit ones split copy-mode (one split per
        distinct column, not per expanded triple)."""
        mfs = self.facts.all(pred)
        if mfs:
            arity = mfs[0].arity
            all_rows = np.stack(
                [
                    np.concatenate(
                        [self.store.unfold(mf.columns[j]) for mf in mfs]
                    )
                    for j in range(arity)
                ],
                axis=1,
            )
            keep_all = ~multicol_member(all_rows, rows)
            new_list = []
            off = 0
            for mf in mfs:
                keep = keep_all[off : off + mf.length]
                off += mf.length
                if keep.all():
                    new_list.append(mf)
                elif keep.any():
                    split_of = {
                        c: self.store.split(c, keep, inplace=False)
                        for c in dict.fromkeys(mf.columns)
                    }
                    new_list.append(
                        MetaFact(
                            pred,
                            tuple(split_of[c] for c in mf.columns),
                            int(keep.sum()),
                            mf.round,
                        )
                    )
            self.facts.replace(pred, new_list)
        keep_mask = self.rows.remove(pred, rows)
        if pred in self.counts:
            self.counts[pred] = self.counts[pred][keep_mask]

    def add_rows(
        self,
        pred: str,
        rows: np.ndarray,
        counts: np.ndarray | None = None,
    ) -> list[MetaFact]:
        """Compress fresh rows into meta-facts, append them, and keep the
        row index (and count column, if any) aligned."""
        self._round += 1
        mfs = rows_to_metafacts(pred, rows, self.store, self._round)
        for mf in mfs:
            self.facts.add(mf)
        perm = self.rows.add(pred, rows)
        if pred in self.counts:
            new_counts = (
                counts
                if counts is not None
                else np.ones(rows.shape[0], dtype=np.int64)
            )
            self.counts[pred] = np.concatenate(
                [self.counts[pred], new_counts]
            )[perm]
        return mfs

    # ------------------------------------------------------------------ #
    # the update entry point
    # ------------------------------------------------------------------ #
    def apply(
        self,
        additions: dict[str, np.ndarray] | None = None,
        deletions: dict[str, np.ndarray] | None = None,
    ) -> IncrementalStats:
        """Maintain ``mat(Pi, E)`` for ``E' = (E \\ deletions) ∪
        additions``; returns per-batch maintenance statistics.

        Deletions of non-explicit facts and additions of already-explicit
        facts are ignored (idempotent batches)."""
        t_start = time.perf_counter()
        st = IncrementalStats()
        from ..obs.provenance import get_journal

        pj = get_journal()
        self._pjournal = pj if pj.enabled else None
        if self._pjournal is not None:
            self._pjournal.begin_epoch(self.epoch + 1)
            self._pjournal.attach_program(self.program)
        adds = normalise_batch(additions)
        dels = normalise_batch(deletions)
        if self.wal is not None:
            # write-ahead: the record is durable before any mutation, so
            # a crash mid-apply recovers to the post-batch state
            self.wal.append(self.epoch + 1, adds, dels)

        with span(
            "inc.apply",
            epoch=self.epoch + 1,
            n_additions=sum(int(r.shape[0]) for r in adds.values()),
            n_deletions=sum(int(r.shape[0]) for r in dels.values()),
        ):
            # effective explicit deletions (E := E \ D), swept before the
            # additions clamp so a fact in both batches deletes then
            # re-adds
            _, eff_dels = effective_updates(self.explicit, {}, dels)
            st.n_del_explicit += sum(
                int(r.shape[0]) for r in eff_dels.values()
            )
            if eff_dels:
                self.stats_view.refresh()
                with span("inc.deletion_sweep"):
                    self._deletion_sweep(eff_dels, st)

            # effective explicit additions (E := E ∪ A)
            for pred, rows in adds.items():
                self.arities.setdefault(pred, int(rows.shape[1]))
            eff_adds, _ = effective_updates(self.explicit, adds, {})
            st.n_add_explicit += sum(
                int(r.shape[0]) for r in eff_adds.values()
            )
            if eff_adds:
                self.stats_view.refresh()
                with span("inc.insertion_sweep"):
                    self._insertion_sweep(eff_adds, st)

        self.epoch += 1
        st.epoch = self.epoch
        st.n_strata = len(self.strata)
        st.n_meta_facts = self.facts.n_meta_facts()
        st.n_facts = self.facts.n_facts()
        st.plan_cache = self.plan_cache.counters()
        st.time_total = time.perf_counter() - t_start
        self._journal_append(
            {
                "epoch": self.epoch,
                "del_explicit": st.n_del_explicit,
                "add_explicit": st.n_add_explicit,
                "overdeleted": st.n_overdeleted,
                "rederived": st.n_rederived,
                "deleted": st.n_deleted,
                "inserted": st.n_inserted,
                "counting_strata": st.counting_strata,
                "dred_strata": st.dred_strata,
                "time_s": st.time_total,
            }
        )
        st.journal_bytes = self.journal_bytes()
        publish_incremental(st)
        if self._pjournal is not None:
            self._pjournal.publish()
        for cb in self.publish_hooks:
            cb(self, st)
        return st

    def subscribe_publish(self, cb) -> None:
        """Register a publish-after-apply callback ``cb(store, stats)``."""
        self.publish_hooks.append(cb)

    def unsubscribe_publish(self, cb) -> None:
        if cb in self.publish_hooks:
            self.publish_hooks.remove(cb)

    def record_provenance(
        self,
        kind: str,
        pred: str,
        *,
        n_emitted: int = 0,
        n_new: int = 0,
        rule_id: int = -1,
        out_mfs=(),
        time_ns: int = 0,
    ) -> None:
        """Journal one maintenance-phase step (no-op unless recording is
        on).  The DRed phases call this to answer *why a fact survived*:
        ``survive_explicit`` / ``survive_backward`` / ``rederive``
        records carry the restoring rule and the restored meta-facts."""
        j = self._pjournal
        if j is None:
            return
        from ..obs.provenance import DerivationRecord

        j.record(DerivationRecord(
            kind=kind,
            engine="inc",
            stratum=self._cur_stratum,
            round=self._round,
            rule_id=rule_id,
            pivot=-1,
            pred=pred,
            n_emitted=int(n_emitted),
            n_new=int(n_new),
            out_mf_ids=tuple(mf.mf_id for mf in list(out_mfs)[:16]),
            epoch=j.epoch,
            time_ns=time_ns,
        ))

    # ------------------------------------------------------------------ #
    # deletion sweep
    # ------------------------------------------------------------------ #
    def _deletion_sweep(self, dels: dict[str, np.ndarray], st) -> None:
        # pre-deletion view: list snapshots are stable because deletion
        # splits copy (the original meta-facts keep their columns)
        self.pre_mfs = {
            p: list(self.facts.all(p)) for p in list(self.facts.predicates())
        }
        removed: dict[str, np.ndarray] = {}
        t0 = time.perf_counter()
        for pred, rows in dels.items():
            if pred in self._head_preds:
                continue  # handled by the predicate's stratum
            rows = rows[self.rows.member_mask(pred, rows)]
            if rows.shape[0]:
                self.delete_rows(pred, rows)
                removed[pred] = rows
                st.n_deleted += int(rows.shape[0])
                self.record_provenance(
                    "delete_explicit", pred, n_new=rows.shape[0]
                )
        st.time_delete += time.perf_counter() - t0

        for s_idx, stratum in enumerate(self.strata):
            stratum_heads, body_preds = stratum_predicates(stratum)
            seeds = {
                p: removed[p] for p in body_preds if p in removed
            }
            head_dels = {
                p: dels[p] for p in stratum_heads if p in dels
            }
            if not seeds and not head_dels:
                continue
            self._cur_stratum = s_idx
            self.stats_view.refresh()
            if self.counting and not is_recursive(stratum):
                with span("inc.counting_delete", rules=len(stratum)):
                    net = self._counting_delete(
                        stratum, seeds, head_dels, st
                    )
                st.counting_strata += 1
            else:
                with span("inc.dred_stratum", rules=len(stratum)):
                    net = dred_stratum(self, stratum, seeds, head_dels, st)
                st.dred_strata += 1
            for pred, rows in net.items():
                removed[pred] = merge_rows(removed.get(pred), rows)

    def _delta_derivation_counts(self, stratum, seeds, st):
        """Per-head-predicate ``(rows, counts)`` blocks for the rule
        instantiations a delta gains or loses, via the telescoping
        identity: pivot → the delta, atoms before the pivot → the
        *post-update* view, atoms after → the *pre-update* snapshot —
        each changed instantiation is counted exactly once (shared by
        the deletion and insertion counting sweeps)."""
        acc: dict[str, list[tuple[np.ndarray, np.ndarray]]] = {}
        if not seeds:
            return acc
        mark = self.store.mark()
        delta_mfs = {
            p: rows_to_metafacts(p, r, self.store) for p, r in seeds.items()
        }

        def sources(pred: str, src: str) -> list:
            if src == SRC_DELTA:
                return delta_mfs.get(pred, [])
            if src == SRC_OLD:  # atoms before the pivot: new view
                return self.facts.all(pred)
            return self.pre_mfs.get(pred, [])  # after: old view

        match_cache: dict = {}
        for rule in stratum:
            if not rule.body:
                continue
            for i, atom in enumerate(rule.body):
                if atom.predicate not in delta_mfs:
                    continue
                L = evaluate_rule(
                    rule, i, sources, self.store, self.stats_view,
                    self.plan_cache, match_cache=match_cache,
                )
                st.n_rule_applications += 1
                if L is None:
                    continue
                rows, cnts = project_head(
                    rule.head, L, self.store, multiplicity=True
                )
                acc.setdefault(rule.head.predicate, []).append((rows, cnts))
        self.store.release(mark)
        return acc

    def _counting_delete(self, stratum, seeds, head_dels, st):
        """Exact count-decrement maintenance for a non-recursive stratum:
        decrement by the lost derivations, delete facts reaching zero."""
        t0 = time.perf_counter()
        acc = self._delta_derivation_counts(stratum, seeds, st)
        for pred, rows in head_dels.items():
            rows = rows[self.rows.member_mask(pred, rows)]
            if rows.shape[0]:  # the fact loses its explicit support
                acc.setdefault(pred, []).append(
                    (rows, np.ones(rows.shape[0], dtype=np.int64))
                )

        net: dict[str, np.ndarray] = {}
        for pred, blocks in acc.items():
            all_rows = np.concatenate([r for r, _ in blocks])
            all_cnts = np.concatenate([c for _, c in blocks])
            uniq, inv = unique_rows(all_rows, return_inverse=True)
            lost = np.bincount(inv, weights=all_cnts).astype(np.int64)
            pos = self.rows.positions(pred, uniq)
            np.subtract.at(self.counts[pred], pos, lost)
            st.n_count_updates += int(uniq.shape[0])
            dead = uniq[self.counts[pred][pos] <= 0]
            if dead.shape[0]:
                self.delete_rows(pred, dead)
                net[pred] = dead
                st.n_deleted += int(dead.shape[0])
            self.record_provenance(
                "count_delete", pred,
                n_emitted=uniq.shape[0], n_new=dead.shape[0],
            )
        st.time_counting += time.perf_counter() - t0
        return net

    # ------------------------------------------------------------------ #
    # insertion sweep
    # ------------------------------------------------------------------ #
    def _insertion_sweep(self, adds: dict[str, np.ndarray], st) -> None:
        t_sweep = time.perf_counter()
        self.pre_mfs = {
            p: list(self.facts.all(p)) for p in list(self.facts.predicates())
        }
        added_mfs: dict[str, list] = {}
        added: dict[str, np.ndarray] = {}

        def note_added(pred, rows, mfs):
            added[pred] = merge_rows(added.get(pred), rows)
            added_mfs.setdefault(pred, []).extend(mfs)
            st.n_inserted += int(rows.shape[0])

        for pred, rows in adds.items():
            if pred in self._head_preds:
                continue  # handled by the predicate's stratum
            mfs = self.add_rows(pred, rows)
            note_added(pred, rows, mfs)
            self.record_provenance(
                "insert_explicit", pred, n_new=rows.shape[0], out_mfs=mfs
            )

        for s_idx, stratum in enumerate(self.strata):
            stratum_heads, body_preds = stratum_predicates(stratum)
            seeds = {
                p: added_mfs[p] for p in body_preds if p in added_mfs
            }
            seed_rows = {p: added[p] for p in body_preds if p in added}
            head_adds = {
                p: adds[p] for p in stratum_heads if p in adds
            }
            if not seeds and not head_adds:
                continue
            self._cur_stratum = s_idx
            self.stats_view.refresh()
            if self.counting and not is_recursive(stratum):
                with span("inc.counting_insert", rules=len(stratum)):
                    self._counting_insert(
                        stratum, seed_rows, head_adds, st, note_added
                    )
                st.counting_strata += 1
            else:
                with span("inc.seminaive_insert", rules=len(stratum)):
                    self._seminaive_insert(
                        stratum, seeds, head_adds, st, note_added
                    )
                st.dred_strata += 1
        st.time_insert += time.perf_counter() - t_sweep

    def _counting_insert(self, stratum, seeds, head_adds, st, note_added):
        """Count-increment maintenance (mirror of :meth:`_counting_delete`
        with the roles of old/new swapped); facts whose count becomes
        positive enter the materialisation."""
        t0 = time.perf_counter()
        acc = self._delta_derivation_counts(stratum, seeds, st)
        for pred, rows in head_adds.items():
            acc.setdefault(pred, []).append(
                (rows, np.ones(rows.shape[0], dtype=np.int64))
            )

        for pred, blocks in acc.items():
            all_rows = np.concatenate([r for r, _ in blocks])
            all_cnts = np.concatenate([c for _, c in blocks])
            uniq, inv = unique_rows(all_rows, return_inverse=True)
            gained = np.bincount(inv, weights=all_cnts).astype(np.int64)
            present = self.rows.member_mask(pred, uniq)
            if present.any():
                pos = self.rows.positions(pred, uniq[present])
                np.add.at(self.counts[pred], pos, gained[present])
            st.n_count_updates += int(uniq.shape[0])
            fresh = uniq[~present]
            if fresh.shape[0]:
                mfs = self.add_rows(pred, fresh, counts=gained[~present])
                note_added(pred, fresh, mfs)
                self.record_provenance(
                    "insert", pred,
                    n_emitted=uniq.shape[0], n_new=fresh.shape[0],
                    out_mfs=mfs,
                )
        st.time_counting += time.perf_counter() - t0

    def _seminaive_insert(self, stratum, seeds, head_adds, st, note_added):
        """Standard semi-naive insertion for a recursive stratum: the
        added meta-facts are the delta; candidates are deduplicated
        against the row index."""
        delta_mfs: dict[str, list] = {p: list(m) for p, m in seeds.items()}
        for pred, rows in head_adds.items():
            fresh = rows[~self.rows.member_mask(pred, rows)]
            if fresh.shape[0]:
                mfs = self.add_rows(pred, fresh)
                delta_mfs.setdefault(pred, []).extend(mfs)
                note_added(pred, fresh, mfs)

        while delta_mfs:
            delta_ids = {
                id(mf) for lst in delta_mfs.values() for mf in lst
            }
            cur_delta = delta_mfs

            def sources(pred: str, src: str) -> list:
                if src == SRC_DELTA:
                    return cur_delta.get(pred, [])
                if src == SRC_OLD:
                    return [
                        mf
                        for mf in self.facts.all(pred)
                        if id(mf) not in delta_ids
                    ]
                return self.facts.all(pred)

            mark = self.store.mark()
            match_cache: dict = {}
            derived: dict[str, list[np.ndarray]] = {}
            for rule in stratum:
                if not rule.body:
                    continue
                for i, atom in enumerate(rule.body):
                    if atom.predicate not in delta_mfs:
                        continue
                    L = evaluate_rule(
                        rule, i, sources, self.store, self.stats_view,
                        self.plan_cache, match_cache=match_cache,
                    )
                    st.n_rule_applications += 1
                    if L is None:
                        continue
                    rows, _ = project_head(rule.head, L, self.store)
                    derived.setdefault(rule.head.predicate, []).append(rows)
                    self.record_provenance(
                        "apply", rule.head.predicate,
                        rule_id=self._rule_ids.get(rule, -1),
                        n_emitted=rows.shape[0],
                    )
            self.store.release(mark)

            new_delta: dict[str, list] = {}
            for pred, blocks in derived.items():
                cand = unique_rows(np.concatenate(blocks))
                fresh = cand[~self.rows.member_mask(pred, cand)]
                if fresh.shape[0]:
                    mfs = self.add_rows(pred, fresh)
                    new_delta[pred] = mfs
                    note_added(pred, fresh, mfs)
                    self.record_provenance(
                        "insert", pred,
                        n_emitted=cand.shape[0], n_new=fresh.shape[0],
                        out_mfs=mfs,
                    )
            delta_mfs = new_delta

    # ------------------------------------------------------------------ #
    # durability hooks (repro.storage)
    # ------------------------------------------------------------------ #
    def attach_wal(self, wal) -> None:
        """Log every subsequent ``apply`` batch to ``wal`` before the
        store mutates (recovery = snapshot + replay; DESIGN.md
        §Storage).  Attach only *after* any replay, or the replay would
        re-log itself."""
        self.wal = wal

    def _journal_append(self, entry: dict) -> None:
        """Bounded append with a running byte count (re-serialising the
        whole journal per batch would tax the apply hot path)."""
        nbytes = len(json.dumps(entry))
        if (
            self.journal.maxlen is not None
            and len(self.journal) == self.journal.maxlen
        ):
            self._journal_nbytes -= self._journal_sizes[0]
        self.journal.append(entry)
        self._journal_sizes.append(nbytes)
        self._journal_nbytes += nbytes

    def truncate_journal(self) -> None:
        """Drop the in-memory journal — called once a checkpoint makes
        its entries redundant (the WAL keeps the durable history)."""
        self.journal.clear()
        self._journal_sizes.clear()
        self._journal_nbytes = 0

    def journal_bytes(self) -> int:
        """Resident bytes of the journal (JSON size of the scalar
        records, maintained incrementally; cap is ``journal_max``)."""
        return self._journal_nbytes

    def memory_report(self) -> dict[str, int]:
        """obs.memory reporter: maintained row index, derivation-count
        columns, explicit facts, and the bounded journal.  Mu-DAG node
        bytes are *not* here — the ``ColumnStore`` self-reports them."""
        idx = self.rows.memory_report()
        expl_owned, expl_backed = split_owned_backed(self.explicit.values())
        return {
            "index_bytes": idx["rows_bytes"],
            "index_snapshot_backed_bytes": idx["rows_snapshot_backed_bytes"],
            "counts_bytes": sum(int(a.nbytes) for a in self.counts.values()),
            "explicit_bytes": expl_owned,
            "explicit_snapshot_backed_bytes": expl_backed,
            "journal_bytes": self._journal_nbytes,
        }

    def mu_usage(self):
        """Dead-node accounting over the mu-store (deletion splits
        strand unreachable nodes; see :meth:`maybe_compact`)."""
        from ..storage.compact import mu_usage

        return mu_usage(self.facts)

    def compact(self):
        """Rebuild the reachable mu-DAG (hash-consing identical runs)
        and swap it in; answers and row indexes are unchanged."""
        from ..storage.compact import compact_store

        self._gc_usage = None
        return compact_store(self)

    def maybe_compact(
        self,
        threshold: float = 0.5,
        min_nodes: int = 256,
        growth: float = 1.1,
    ):
        """Run a compaction epoch when the dead-node fraction crosses
        ``threshold`` (and the store is big enough to matter).  Returns
        the :class:`CompactionStats` or ``None``.

        Cheap to call per batch: the O(store) reachability probe only
        reruns once the node count has grown by ``growth`` since the
        last below-threshold probe.  The count only grows between
        compactions, so this is a sound staleness signal up to one
        corner: dropping a whole meta-fact strands nodes without adding
        any, which the *next* growth-triggered probe accounts for — a
        GC trigger may lag, never fire spuriously."""
        if threshold <= 0:
            return None
        n = self.store.n_nodes()
        if n < min_nodes:
            return None
        if self._gc_usage is not None and self._gc_usage[0] == n:
            usage = self._gc_usage[1]
        elif self._gc_usage is not None and n < growth * self._gc_usage[0]:
            return None  # barely grew since the last clean probe
        else:
            usage = self.mu_usage()
            self._gc_usage = (n, usage)
        if usage.dead_fraction < threshold:
            return None
        return self.compact()

    # ------------------------------------------------------------------ #
    # read side
    # ------------------------------------------------------------------ #
    def freeze(self, *, pin_meta: bool = False) -> FrozenFacts:
        """Epoch snapshot for query answering — the maintained row index
        seeds the sorted snapshots, so freezing is O(1) per epoch.

        ``pin_meta=True`` additionally captures the per-predicate
        meta-fact lists, making the snapshot immune to later ``apply``
        batches (the MVCC epoch contract; compaction still invalidates
        pinned node ids, so the serving tier defers it while pinned)."""
        return FrozenFacts(
            self.facts, seed_rows=self.rows.to_dict(), pin_meta=pin_meta
        )

    def to_dict(self) -> dict[str, np.ndarray]:
        """Flat per-predicate materialisation (sorted unique rows)."""
        return self.rows.to_dict()

    def explain_fact(self, pred: str, terms, decode=None) -> dict | None:
        """Verified proof tree for a maintained fact (obs.provenance) —
        works on a freshly-loaded, updated, or restored store: rounds
        persist through snapshots, and the journal is only a search
        accelerator."""
        from ..obs.provenance import Explainer, get_journal

        ex = Explainer.from_fact_store(
            self.program, self.facts, self.explicit,
            journal=get_journal(), decode=decode,
        )
        return ex.explain(pred, terms)

    def check_integrity(self) -> None:
        """Test/debug invariants: the row index matches the unfolded
        store, and maintained counts match a from-scratch recount."""
        unfolded = self.facts.to_dict()
        index = self.to_dict()
        preds = {p for p, r in unfolded.items() if r.shape[0]} | set(index)
        for pred in preds:
            a = unfolded.get(pred)
            b = index.get(pred)
            a = a if a is not None else np.zeros((0, 1), dtype=np.int64)
            b = b if b is not None else np.zeros((0, 1), dtype=np.int64)
            if a.shape != b.shape or not np.array_equal(a, b):
                raise AssertionError(f"row index diverged for {pred!r}")
        if self.counting:
            expect = self.recompute_counts()
            for pred, want in expect.items():
                got = self.counts.get(
                    pred, np.zeros(0, dtype=np.int64)
                )
                if not np.array_equal(got, want):
                    raise AssertionError(
                        f"derivation counts diverged for {pred!r}: "
                        f"{got.tolist()} != {want.tolist()}"
                    )
