"""Maintained flat row index over the materialisation.

The incremental store keeps, per predicate, the **sorted unique flat
rows** of the current materialisation.  This is the same O(|I|)
speed-for-memory trade the engine's ``DedupIndex`` makes, promoted to a
first-class structure because every maintenance phase needs it:

* membership probes (is an overdelete candidate actually materialised?
  is a derived candidate fresh?) are one vectorised ``multicol_member``,
* derivation-count columns align positionally with the rows, so count
  scatter-updates are ``np.add.at`` over looked-up positions,
* :meth:`rows` seeds :class:`~repro.core.frozen.FrozenFacts` snapshots
  at freeze time, making per-epoch freezes O(1) instead of re-unfolding
  the store.

Mutations return the alignment information (sort permutation on insert,
keep mask on remove) so callers can permute/mask parallel columns.
"""

from __future__ import annotations

import numpy as np

from ..core.util import factorize_rows, multicol_member, unique_rows
from ..obs.memory import split_owned_backed

__all__ = ["RowIndex", "merge_rows", "setdiff_rows"]

_EMPTY = np.zeros((0, 1), dtype=np.int64)


def merge_rows(a: np.ndarray | None, b: np.ndarray) -> np.ndarray:
    """Sorted-unique union of two row sets (``a`` may be absent)."""
    if a is None or a.shape[0] == 0:
        return b
    return unique_rows(np.concatenate([a, b]))


def setdiff_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Rows of ``a`` not occurring in ``b``."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return a
    return a[~multicol_member(a, b)]


def _lexsort_rows(rows: np.ndarray) -> np.ndarray:
    """Permutation sorting rows lexicographically (first column primary —
    the ``np.unique(axis=0)`` order)."""
    keys = tuple(rows[:, j] for j in reversed(range(rows.shape[1])))
    return np.lexsort(keys)


class RowIndex:
    """Per-predicate sorted unique ``(n, arity)`` row arrays."""

    def __init__(self) -> None:
        self._rows: dict[str, np.ndarray] = {}

    def seed(self, pred: str, rows: np.ndarray) -> None:
        self._rows[pred] = unique_rows(
            np.asarray(rows, dtype=np.int64)
        )

    def seed_sorted(self, pred: str, rows: np.ndarray) -> None:
        """Adopt rows that are *already* sorted-unique — the snapshot
        restore path, where the rows were written from :meth:`to_dict`
        and re-sorting would only burn the warm-start budget."""
        self._rows[pred] = np.asarray(rows, dtype=np.int64)

    def predicates(self):
        return self._rows.keys()

    def rows(self, pred: str) -> np.ndarray:
        return self._rows.get(pred, _EMPTY)

    def n_rows(self, pred: str) -> int:
        return int(self.rows(pred).shape[0])

    def member_mask(self, pred: str, q: np.ndarray) -> np.ndarray:
        """Which rows of ``q`` are present."""
        return multicol_member(q, self.rows(pred))

    def positions(self, pred: str, q: np.ndarray) -> np.ndarray:
        """Index of each row of ``q`` in the stored array.  Every row of
        ``q`` must be present (probe with :meth:`member_mask` first)."""
        rows = self.rows(pred)
        codes_r, codes_q = factorize_rows(rows, q)
        order = np.argsort(codes_r)  # stored rows are unique -> injective
        pos = order[
            np.searchsorted(codes_r[order], codes_q)
        ]
        return pos

    def add(self, pred: str, q: np.ndarray) -> np.ndarray:
        """Insert rows (must be unique and absent).  Returns the sort
        permutation of ``concat(old_rows, q)`` so aligned columns can be
        permuted identically."""
        q = np.asarray(q, dtype=np.int64)
        old = self._rows.get(pred)
        merged = q if old is None or old.shape[0] == 0 else np.concatenate(
            [old, q]
        )
        perm = _lexsort_rows(merged)
        self._rows[pred] = merged[perm]
        return perm

    def remove(self, pred: str, q: np.ndarray) -> np.ndarray:
        """Remove rows.  Returns the keep mask over the *previous* stored
        array so aligned columns can be masked identically."""
        rows = self.rows(pred)
        keep = ~multicol_member(rows, q)
        self._rows[pred] = rows[keep]
        return keep

    def to_dict(self) -> dict[str, np.ndarray]:
        return {
            p: r.copy() for p, r in self._rows.items() if r.shape[0]
        }

    # ------------------------------------------------------------------ #
    def nbytes(self) -> int:
        return sum(int(r.nbytes) for r in self._rows.values())

    def memory_report(self) -> dict[str, int]:
        """obs.memory reporter: owned rows vs rows adopted as snapshot
        views (:meth:`seed_sorted` on a restore blob), counted once."""
        owned, backed = split_owned_backed(self._rows.values())
        return {
            "rows_bytes": owned,
            "rows_snapshot_backed_bytes": backed,
            "n_predicates": len(self._rows),
        }
