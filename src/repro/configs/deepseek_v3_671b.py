"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048 vocab=129280,
MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]"""

from .base import MLAConfig, ModelConfig, MoEConfig, register, smoke_of

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18_432,  # dense-prefix FFN width (paper: 18432 for first 3 layers)
    vocab_size=129_280,
    rope_theta=10_000.0,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_expert_ff=2048,
        n_shared=1,
        d_shared_ff=2048,
        first_k_dense=3,
    ),
    mtp_depth=1,
)

register(
    CONFIG,
    smoke_of(
        CONFIG,
        n_heads=4,
        n_kv_heads=4,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=64, n_shared=1,
                      d_shared_ff=64, first_k_dense=1),
        n_layers=3,
        mtp_depth=1,
    ),
)
