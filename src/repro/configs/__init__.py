"""Architecture registry: one module per assigned architecture."""

import importlib

_ARCH_MODULES = [
    "qwen3_0_6b",
    "granite_20b",
    "deepseek_7b",
    "llama3_2_1b",
    "qwen2_moe_a2_7b",
    "deepseek_v3_671b",
    "falcon_mamba_7b",
    "zamba2_1_2b",
    "seamless_m4t_large_v2",
    "qwen2_vl_72b",
]

_loaded = False


def _load_all():
    global _loaded
    if _loaded:
        return
    _loaded = True
    for mod in _ARCH_MODULES:
        importlib.import_module(f".{mod}", __name__)


from .base import (  # noqa: E402
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    get_config,
    list_configs,
)

__all__ = [
    "SHAPES",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeConfig",
    "get_config",
    "list_configs",
]
