"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576
vocab=49152 — llama-arch, code.  [arXiv:2405.04324; hf]"""

from .base import ModelConfig, register, smoke_of

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,  # multi-query attention
    d_ff=24_576,
    vocab_size=49_152,
    rope_theta=10_000.0,
)

register(CONFIG, smoke_of(CONFIG, n_kv_heads=1))
