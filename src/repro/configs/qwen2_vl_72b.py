"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution; the vision frontend is a stub
(input_specs supplies precomputed patch embeddings).
[arXiv:2409.12191; hf]"""

from .base import ModelConfig, register, smoke_of

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # (t, h, w) pairs; sum = d_head/2 = 64
    frontend="vision",
)

register(CONFIG, smoke_of(CONFIG, mrope_sections=(2, 3, 3)))
