"""Model configuration schema + registry for the assigned architectures."""

from __future__ import annotations

from dataclasses import dataclass, field, replace

__all__ = [
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ModelConfig",
    "ShapeConfig",
    "register",
    "get_config",
    "list_configs",
    "SHAPES",
]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0
    d_shared_ff: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0  # leading dense layers (DeepSeek-V3 style)
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    variant: str = "mamba1"  # mamba1 | mamba2
    n_ssm_heads: int = 0     # mamba2 (SSD) heads; 0 = derive from expand*d/64
    chunk: int = 128         # chunked-scan block length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] | None = None  # (t, h, w) M-RoPE
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int = 0  # hybrid: one shared attention block every k layers
    n_encoder_layers: int = 0  # encdec only
    mtp_depth: int = 0  # DeepSeek-V3 multi-token-prediction heads
    frontend: str | None = None  # 'audio' | 'vision' stub frontends
    attn_chunk: int = 1024  # chunked-attention query block
    sub_quadratic: bool = False  # may run long_500k
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.head_dim
        embed = v * d * (1 if self.tie_embeddings else 2)
        total = embed
        enc_layers = self.n_encoder_layers
        dec_layers = L

        def attn_params():
            if self.mla is not None:
                m = self.mla
                qk = m.qk_nope_dim + m.qk_rope_dim
                return (
                    d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank
                    * self.n_heads
                    * (m.qk_nope_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d
                )
            return (
                d * self.n_heads * hd
                + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d
            )

        def mlp_params(ff):
            return 3 * d * ff

        def ssm_params():
            s = self.ssm
            d_in = s.expand * d
            return 2 * d * d_in + d_in * (2 * s.state_dim + s.conv_dim + 2) + d_in * d

        for _ in range(enc_layers):
            total += attn_params() + mlp_params(f) + 2 * d
        for i in range(dec_layers):
            if self.family in ("ssm",):
                total += ssm_params() + 2 * d
            elif self.family == "hybrid":
                total += ssm_params() + 2 * d
            elif self.moe is not None and i >= self.moe.first_k_dense:
                m = self.moe
                total += attn_params() + 2 * d
                total += m.n_experts * mlp_params(m.d_expert_ff)  # routed
                total += m.n_shared * mlp_params(m.d_shared_ff or m.d_expert_ff)
                total += d * m.n_experts  # router
            else:
                total += attn_params() + mlp_params(f) + 2 * d
            if self.family == "encdec":
                total += attn_params()  # cross-attention
        if self.family == "hybrid" and self.attn_every:
            total += attn_params()  # one shared block
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        routed_all = (
            (self.n_layers - m.first_k_dense) * m.n_experts * 3 * self.d_model * m.d_expert_ff
        )
        routed_active = (
            (self.n_layers - m.first_k_dense) * m.top_k * 3 * self.d_model * m.d_expert_ff
        )
        return int(full - routed_all + routed_active)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}
_SMOKE: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    from . import _load_all  # noqa: F401  (populates the registry)

    _load_all()
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


def list_configs() -> list[str]:
    from . import _load_all

    _load_all()
    return sorted(_REGISTRY)


def smoke_of(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Derive a reduced smoke-test config of the same family."""
    defaults = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        d_head=16,
        attn_chunk=32,
    )
    defaults.update(overrides)
    if cfg.n_encoder_layers:
        defaults.setdefault("n_encoder_layers", 2)
    return replace(cfg, name=cfg.name + "-smoke", **defaults)
