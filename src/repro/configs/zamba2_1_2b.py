"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks.
[arXiv:2411.15242; hf]"""

from .base import ModelConfig, SSMConfig, register, smoke_of

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    d_head=64,
    ssm=SSMConfig(state_dim=64, conv_dim=4, expand=2, variant="mamba2",
                  n_ssm_heads=64, chunk=128),
    attn_every=6,  # one shared attention block every 6 mamba layers
    sub_quadratic=True,
)

register(
    CONFIG,
    smoke_of(
        CONFIG,
        n_layers=4,
        attn_every=2,
        n_kv_heads=4,
        ssm=SSMConfig(state_dim=8, conv_dim=4, expand=2, variant="mamba2",
                      n_ssm_heads=4, chunk=16),
    ),
)
