"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — mamba1 arch.  [arXiv:2410.05355; unverified]"""

from .base import ModelConfig, SSMConfig, register, smoke_of

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # attention-free; kept for schema uniformity
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    d_head=64,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2, variant="mamba1",
                  chunk=128),
    sub_quadratic=True,
)

register(
    CONFIG,
    smoke_of(
        CONFIG,
        d_ff=0,
        ssm=SSMConfig(state_dim=4, conv_dim=4, expand=2, variant="mamba1",
                      chunk=16),
    ),
)
