"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 — 4 shared + 60 routed top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from .base import ModelConfig, MoEConfig, register, smoke_of
from dataclasses import replace

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        d_expert_ff=1408,
        n_shared=4,
        d_shared_ff=1408,
    ),
)

register(
    CONFIG,
    smoke_of(
        CONFIG,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=64, n_shared=2,
                      d_shared_ff=64),
    ),
)
