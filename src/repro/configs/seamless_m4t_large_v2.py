"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — enc-dec, multimodal backbone; the audio frontend
is a stub (input_specs supplies precomputed frame embeddings).
[arXiv:2308.11596; hf]"""

from .base import ModelConfig, register, smoke_of

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,    # encoder layers over frame embeddings
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    rope_theta=10_000.0,
    frontend="audio",
)

register(CONFIG, smoke_of(CONFIG, n_encoder_layers=2))
