"""Shared model layers: norms, RoPE / M-RoPE, SwiGLU, embeddings.

Pure-function style: parameters are nested dicts of jax arrays, every
layer is ``apply(params, x, ...)``.  Parameters are stored f32 and cast to
the compute dtype (bf16) inside the blocks (mixed-precision discipline).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


# --------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------- #
def dense_init(key, shape, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * scale).astype(PARAM_DTYPE)


def embed_init(key, shape):
    return (jax.random.normal(key, shape) * 0.02).astype(PARAM_DTYPE)


# --------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------- #
def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), dtype=PARAM_DTYPE)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


def l2norm(x, eps: float = 1e-6):
    """Head-dim L2 norm used by qk_norm variants without learned scale."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype)


# --------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., s, h, d_head); positions: broadcastable to (..., s)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,s,1,d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, sections, theta: float = 10_000.0):
    """Multimodal RoPE (Qwen2-VL): the head dim is split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (b, s, heads, d); positions_3d: (b, 3, s); sections: per-axis
    *pair* counts summing to d/2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, "M-RoPE sections must sum to d_head/2"
    freqs = rope_frequencies(d, theta)  # (d/2,)
    # build per-pair position ids by section
    sec_ids = jnp.concatenate(
        [jnp.full((n,), i, dtype=jnp.int32) for i, n in enumerate(sections)]
    )  # (d/2,)
    # positions_3d: (b, 3, s) -> per pair (b, s, d/2)
    pos = jnp.take(positions_3d, sec_ids, axis=1)  # (b, d/2, s)
    pos = jnp.swapaxes(pos, 1, 2)  # (b, s, d/2)
    angles = pos[..., None, :].astype(jnp.float32) * freqs  # (b, s, 1, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# SwiGLU MLP
# --------------------------------------------------------------------- #
def mlp_init(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f)),
        "w_up": dense_init(k2, (d, f)),
        "w_down": dense_init(k3, (f, d)),
    }


def mlp_apply(params, x):
    dtype = x.dtype
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dtype))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dtype))


# --------------------------------------------------------------------- #
# embeddings / unembedding
# --------------------------------------------------------------------- #
def embedding_init(key, vocab: int, d: int, tied: bool):
    k1, k2 = jax.random.split(key)
    params = {"embed": embed_init(k1, (vocab, d))}
    if not tied:
        params["unembed"] = dense_init(k2, (d, vocab))
    return params


def embed_tokens(params, tokens):
    return params["embed"][tokens].astype(COMPUTE_DTYPE)


def unembed(params, x):
    if "unembed" in params:
        w = params["unembed"].astype(x.dtype)
        return jnp.einsum("...d,dv->...v", x, w)
    w = params["embed"].astype(x.dtype)
    return jnp.einsum("...d,vd->...v", x, w)
