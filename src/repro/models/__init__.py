"""LM substrate: layers, attention (GQA/MLA), MoE, SSM, composition."""

from . import attention, layers, mla, model, moe, ssm, transformer

__all__ = ["attention", "layers", "mla", "model", "moe", "ssm", "transformer"]
