"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD).

Both use *chunked* scans: the sequence is split into blocks; within a
block the recurrence is computed in parallel (associative scan for
Mamba-1, matmul form for Mamba-2/SSD — the MXU-friendly formulation), and
a lightweight ``lax.scan`` carries the state across blocks.  Decode is the
O(1)-state single-step recurrence — this is what makes the SSM archs the
designated ``long_500k`` runners.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


# ===================================================================== #
# Mamba-1
# ===================================================================== #
def mamba1_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in)),
        "conv_w": dense_init(ks[1], (s.conv_dim, d_in), scale=s.conv_dim**-0.5),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * s.state_dim)),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), scale=dt_rank**-0.5),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(
            jnp.broadcast_to(
                jnp.arange(1, s.state_dim + 1, dtype=jnp.float32), (d_in, s.state_dim)
            )
        ),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (b, l, d_in), w (k, d_in)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _mamba1_gates(params, x, cfg):
    """Common projections; returns (a, bx, C, z, x_conv) all (b,l,...)."""
    s = cfg.ssm
    dtype = x.dtype
    d_in = params["conv_b"].shape[0]
    dt_rank = params["dt_proj"].shape[0]
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(dtype))
    xi, z = xz[..., :d_in], xz[..., d_in:]
    xc = jax.nn.silu(
        _causal_conv(xi, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype))
        .astype(jnp.float32)
    )
    proj = jnp.einsum(
        "bld,de->ble", xc.astype(dtype), params["x_proj"].astype(dtype)
    ).astype(jnp.float32)
    dt, B, C = (
        proj[..., :dt_rank],
        proj[..., dt_rank : dt_rank + s.state_dim],
        proj[..., dt_rank + s.state_dim :],
    )
    delta = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt, params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"]
    )  # (b, l, d_in)
    A = -jnp.exp(params["A_log"])  # (d_in, n)
    a = jnp.exp(delta[..., None] * A[None, None])  # (b, l, d_in, n)
    bx = (delta * xc)[..., None] * B[:, :, None, :]  # (b, l, d_in, n)
    return a, bx, C, z, xc


def mamba1_apply(params, x, cfg):
    """Training/prefill forward. x: (b, l, d)."""
    s = cfg.ssm
    dtype = x.dtype
    a, bx, C, z, xc = _mamba1_gates(params, x, cfg)
    b_, l, d_in, n = a.shape
    chunk = min(s.chunk, l)
    n_chunks = max(l // chunk, 1)
    chunk = l // n_chunks

    a_c = jnp.moveaxis(a.reshape(b_, n_chunks, chunk, d_in, n), 1, 0)
    bx_c = jnp.moveaxis(bx.reshape(b_, n_chunks, chunk, d_in, n), 1, 0)

    def assoc(left, right):
        al, bl = left
        ar, br = right
        return al * ar, br + ar * bl

    def one_chunk(h, inputs):
        ac, bc = inputs  # (b, chunk, d_in, n)
        pa, pb = jax.lax.associative_scan(assoc, (ac, bc), axis=1)
        hs = pb + pa * h[:, None]  # (b, chunk, d_in, n)
        return hs[:, -1], hs

    h0 = jnp.zeros((b_, d_in, n), dtype=a.dtype)
    _, hs = jax.lax.scan(one_chunk, h0, (a_c, bx_c))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b_, l, d_in, n)
    y = jnp.einsum("bldn,bln->bld", hs, C) + params["D"] * xc
    y = y.astype(dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    return jnp.einsum("bld,de->ble", y, params["out_proj"].astype(dtype))


def mamba1_decode(params, x, cfg, conv_state, ssm_state):
    """Single-token decode. x: (b, 1, d); conv_state: (b, k-1, d_in);
    ssm_state: (b, d_in, n)."""
    s = cfg.ssm
    dtype = x.dtype
    d_in = params["conv_b"].shape[0]
    dt_rank = params["dt_proj"].shape[0]
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(dtype))
    xi, z = xz[..., :d_in], xz[..., d_in:]
    window = jnp.concatenate([conv_state.astype(dtype), xi], axis=1)  # (b,k,d_in)
    conv_state_new = window[:, 1:]
    w = params["conv_w"].astype(dtype)
    xc = jnp.einsum("bkd,kd->bd", window, w) + params["conv_b"].astype(dtype)
    xc = jax.nn.silu(xc.astype(jnp.float32))  # (b, d_in)
    # match the train path's precision: x_proj runs in compute dtype
    proj = (xc.astype(dtype) @ params["x_proj"].astype(dtype)).astype(
        jnp.float32
    )
    dt, B, C = (
        proj[..., :dt_rank],
        proj[..., dt_rank : dt_rank + s.state_dim],
        proj[..., dt_rank + s.state_dim :],
    )
    delta = jax.nn.softplus(dt @ params["dt_proj"].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(delta[..., None] * A[None])  # (b, d_in, n)
    h = a * ssm_state + (delta * xc)[..., None] * B[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C) + params["D"] * xc
    y = y.astype(dtype) * jax.nn.silu(z[:, 0].astype(jnp.float32)).astype(dtype)
    out = jnp.einsum("bd,de->be", y, params["out_proj"].astype(dtype))
    return out[:, None, :], conv_state_new, h


# ===================================================================== #
# Mamba-2 (SSD)
# ===================================================================== #
def mamba2_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = s.n_ssm_heads or max(d_in // 64, 1)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * s.state_dim + nh)),
        "conv_w": dense_init(
            ks[1], (s.conv_dim, d_in + 2 * s.state_dim), scale=s.conv_dim**-0.5
        ),
        "conv_b": jnp.zeros((d_in + 2 * s.state_dim,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_in, d)),
    }


def _mamba2_gates(params, x, cfg):
    s = cfg.ssm
    dtype = x.dtype
    d = x.shape[-1]
    d_in = s.expand * d
    nh = params["A_log"].shape[0]
    hd = d_in // nh
    proj = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(dtype))
    z = proj[..., :d_in]
    xBC = proj[..., d_in : 2 * d_in + 2 * s.state_dim]
    dt_raw = proj[..., 2 * d_in + 2 * s.state_dim :]  # (b, l, nh)
    xBC = jax.nn.silu(
        _causal_conv(xBC, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype))
        .astype(jnp.float32)
    ).astype(dtype)
    xi = xBC[..., :d_in]
    B = xBC[..., d_in : d_in + s.state_dim].astype(jnp.float32)
    C = xBC[..., d_in + s.state_dim :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,l,nh)
    A = -jnp.exp(params["A_log"])  # (nh,)
    xh = xi.reshape(*xi.shape[:-1], nh, hd)
    return xh, B, C, dt, A, z


def mamba2_apply(params, x, cfg):
    """SSD chunked forward (matmul formulation). x: (b, l, d)."""
    s = cfg.ssm
    dtype = x.dtype
    xh, B, C, dt, A, z = _mamba2_gates(params, x, cfg)
    b_, l, nh, hd = xh.shape
    n = s.state_dim
    chunk = min(s.chunk, l)
    n_chunks = max(l // chunk, 1)
    chunk = l // n_chunks

    # reshape into chunks
    def to_chunks(t):
        return jnp.moveaxis(
            t.reshape(b_, n_chunks, chunk, *t.shape[2:]), 1, 0
        )

    xh_c, B_c, C_c, dt_c = map(to_chunks, (xh, B, C, dt))
    loga = dt * A[None, None]  # (b, l, nh)
    loga_c = to_chunks(loga)

    def one_chunk(h, inputs):
        xc, Bc, Cc, dtc, lac = inputs
        # cumulative decay within chunk: (b, chunk, nh)
        cum = jnp.cumsum(lac, axis=1)
        # intra-chunk (attention-like) term
        # decay(t, s) = exp(cum_t - cum_s) for s <= t
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (b, t, s, nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("btn,bsn->bts", Cc, Bc)  # (b, t, s)
        w = cb[..., None] * decay * dtc[:, None]  # (b, t, s, nh)
        y_intra = jnp.einsum("btsh,bshd->bthd", w, xc.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum(
            "btn,bhnd,bth->bthd",
            Cc,
            h,
            jnp.exp(cum),
        )
        # new carried state
        rem = cum[:, -1:, :] - cum  # decay from position to chunk end
        state_in = jnp.einsum(
            "bsn,bshd,bsh->bhnd",
            Bc,
            xc.astype(jnp.float32),
            jnp.exp(rem) * dtc,
        )
        h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] + state_in
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b_, nh, n, hd), dtype=jnp.float32)
    _, ys = jax.lax.scan(
        one_chunk, h0, (xh_c, B_c, C_c, dt_c, loga_c)
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b_, l, nh, hd)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b_, l, nh * hd).astype(dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    return jnp.einsum("bld,de->ble", y, params["out_proj"].astype(dtype))


def mamba2_decode(params, x, cfg, conv_state, ssm_state):
    """Single-token SSD decode. conv_state: (b, k-1, d_conv_in);
    ssm_state: (b, nh, n, hd)."""
    s = cfg.ssm
    dtype = x.dtype
    d = x.shape[-1]
    d_in = s.expand * d
    nh = params["A_log"].shape[0]
    hd = d_in // nh
    proj = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(dtype))
    z = proj[..., :d_in][:, 0]
    xBC = proj[..., d_in : 2 * d_in + 2 * s.state_dim]
    dt_raw = proj[:, 0, 2 * d_in + 2 * s.state_dim :]
    window = jnp.concatenate([conv_state.astype(dtype), xBC], axis=1)
    conv_state_new = window[:, 1:]
    w = params["conv_w"].astype(dtype)
    xBC = jnp.einsum("bkd,kd->bd", window, w) + params["conv_b"].astype(dtype)
    xBC = jax.nn.silu(xBC.astype(jnp.float32))
    xi = xBC[..., :d_in]
    B = xBC[..., d_in : d_in + s.state_dim]
    C = xBC[..., d_in + s.state_dim :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,nh)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A[None])  # (b, nh)
    xh = xi.reshape(-1, nh, hd)
    h = (
        ssm_state * a[:, :, None, None]
        + jnp.einsum("bn,bhd,bh->bhnd", B, xh, dt)
    )
    y = jnp.einsum("bn,bhnd->bhd", C, h) + params["D"][None, :, None] * xh
    y = y.reshape(-1, d_in).astype(dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    out = jnp.einsum("bd,de->be", y, params["out_proj"].astype(dtype))
    return out[:, None, :], conv_state_new, h
