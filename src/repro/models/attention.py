"""GQA attention: chunked-causal training path + KV-cache decode path.

Training attention is *query-chunked*: scores are materialised only for
one query block at a time ((b, h, q_chunk, S) instead of (b, h, S, S)),
which bounds activation memory at long sequence lengths without a custom
kernel; XLA pipelines the chunk loop.  Heads shard over the ``model`` mesh
axis, batch over ``data`` — see ``repro.launch.sharding``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE, apply_mrope, apply_rope, dense_init, l2norm

NEG_INF = -1e30


def attention_init(key, cfg):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": dense_init(k1, (d, h, hd)),
        "wk": dense_init(k2, (d, kv, hd)),
        "wv": dense_init(k3, (d, kv, hd)),
        "wo": dense_init(k4, (h, hd, d)),
    }
    if cfg.qk_norm:
        params["q_scale"] = jnp.ones((hd,), dtype=jnp.float32)
        params["k_scale"] = jnp.ones((hd,), dtype=jnp.float32)
    return params


def _project_qkv(params, x, cfg, positions, mrope_positions=None):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.qk_norm:
        q = l2norm(q) * params["q_scale"].astype(dtype)
        k = l2norm(k) * params["k_scale"].astype(dtype)
    if cfg.mrope_sections is not None and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, chunk: int, q_offset: int = 0):
    """Query-chunked attention.

    q: (b, s_q, h, hd); k, v: (b, s_kv, n_kv, hd).  GQA is expressed by
    reshaping q to (b, s, n_kv, group, hd) so the einsum never tiles KV.
    """
    b, s_q, h, hd = q.shape
    n_kv = k.shape[2]
    group = h // n_kv
    scale = hd**-0.5
    q = q.reshape(b, s_q, n_kv, group, hd) * scale

    n_chunks = max(s_q // chunk, 1)
    chunk = s_q // n_chunks
    q_chunks = q.reshape(b, n_chunks, chunk, n_kv, group, hd)
    q_chunks = jnp.moveaxis(q_chunks, 1, 0)  # (n_chunks, b, chunk, kv, g, hd)

    kv_pos = jnp.arange(k.shape[1])

    def one_chunk(carry, qc_and_idx):
        qc, idx = qc_and_idx
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qc, k).astype(jnp.float32)
        if causal:
            q_pos = q_offset + idx * chunk + jnp.arange(chunk)
            mask = kv_pos[None, :] <= q_pos[:, None]  # (chunk, s_kv)
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        return carry, out

    _, outs = jax.lax.scan(
        one_chunk, None, (q_chunks, jnp.arange(n_chunks))
    )
    outs = jnp.moveaxis(outs, 0, 1)  # (b, n_chunks, chunk, kv, g, hd)
    return outs.reshape(b, s_q, h, hd)


def attention_apply(
    params,
    x,
    cfg,
    positions,
    *,
    causal: bool = True,
    mrope_positions=None,
):
    """Full-sequence (training / prefill) attention."""
    q, k, v = _project_qkv(params, x, cfg, positions, mrope_positions)
    out = chunked_attention(
        q, k, v, causal=causal, chunk=min(cfg.attn_chunk, x.shape[1])
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def attention_decode(
    params,
    x,
    cfg,
    cache_k,
    cache_v,
    cache_len,
    *,
    mrope_positions=None,
):
    """Single-token decode against a KV cache.

    x: (b, 1, d); cache_k/v: (b, S, n_kv, hd); cache_len: scalar int32 —
    the number of valid cache entries (new token is written at that slot).
    """
    dtype = x.dtype
    positions = jnp.full((x.shape[0], 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions, mrope_positions)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), cache_len, axis=1
    )
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), cache_len, axis=1
    )
    b, _, h, hd = q.shape
    n_kv = cache_k.shape[2]
    group = h // n_kv
    qg = q.reshape(b, 1, n_kv, group, hd) * hd**-0.5
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, cache_k.astype(dtype)
    ).astype(jnp.float32)
    valid = jnp.arange(cache_k.shape[1])[None, :] <= cache_len
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cache_v.astype(dtype))
    out = out.reshape(b, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, cache_k, cache_v
