"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and KV are projected through low-rank latents; only the compressed
KV latent (kv_lora_rank) plus the shared RoPE key (qk_rope_dim) are cached
at decode time.  The decode path uses the *absorbed* formulation: W_UK is
folded into the query and W_UV into the output so scores and values are
computed directly against the cached latent — the latency win that makes
MLA serve-efficient.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import NEG_INF
from .layers import apply_rope, dense_init, rmsnorm, rmsnorm_init


def mla_init(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": rmsnorm_init(m.q_lora_rank),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h, qk)),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim)),
        "kv_norm": rmsnorm_init(m.kv_lora_rank),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, h, m.qk_nope_dim)),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, h, m.v_head_dim)),
        "wo": dense_init(ks[5], (h, m.v_head_dim, d)),
    }


def _project_latents(params, x, cfg, positions):
    """Shared Q/KV latent computation; returns per-head q and the caches."""
    m = cfg.mla
    dtype = x.dtype
    cq = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(dtype))
    cq = rmsnorm(params["q_norm"], cq)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"].astype(dtype))
    q_nope, q_rope = (
        q[..., : m.qk_nope_dim],
        q[..., m.qk_nope_dim :],
    )
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(dtype))
    c_kv = rmsnorm(params["kv_norm"], ckv_full[..., : m.kv_lora_rank])
    k_rope = ckv_full[..., m.kv_lora_rank :][:, :, None, :]  # (b,s,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(params, x, cfg, positions, *, causal: bool = True):
    """Training / prefill path: materialise per-head K/V and attend."""
    m = cfg.mla
    dtype = x.dtype
    q_nope, q_rope, c_kv, k_rope = _project_latents(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"].astype(dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"].astype(dtype))

    b, s, h, _ = q_nope.shape
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    chunk = min(cfg.attn_chunk, s)
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks

    kv_pos = jnp.arange(s)

    def one_chunk(_, qs):
        qn, qr, idx = qs
        scores = (
            jnp.einsum("bqhk,bshk->bhqs", qn, k_nope)
            + jnp.einsum("bqhk,bsk->bhqs", qr, k_rope)
        ).astype(jnp.float32) * scale
        if causal:
            q_pos = idx * chunk + jnp.arange(chunk)
            mask = kv_pos[None, :] <= q_pos[:, None]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
        return None, jnp.einsum("bhqs,bshk->bqhk", probs, v)

    qn_c = jnp.moveaxis(q_nope.reshape(b, n_chunks, chunk, h, -1), 1, 0)
    qr_c = jnp.moveaxis(q_rope.reshape(b, n_chunks, chunk, h, -1), 1, 0)
    _, outs = jax.lax.scan(one_chunk, None, (qn_c, qr_c, jnp.arange(n_chunks)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, m.v_head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def mla_decode(params, x, cfg, cache_ckv, cache_krope, cache_len):
    """Absorbed single-token decode.

    cache_ckv: (b, S, kv_lora_rank); cache_krope: (b, S, qk_rope_dim).
    Scores:  q_nope W_UK^T . c_kv  +  q_rope . k_rope
    Output:  (probs . c_kv) W_UV   -> heads -> W_O
    """
    m = cfg.mla
    dtype = x.dtype
    positions = jnp.full((x.shape[0], 1), cache_len, dtype=jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _project_latents(
        params, x, cfg, positions
    )
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv_new.astype(cache_ckv.dtype), cache_len, axis=1
    )
    cache_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, k_rope_new.astype(cache_krope.dtype), cache_len, axis=1
    )
    # absorb W_UK into q: (b,1,h,nope) x (r,h,nope) -> (b,1,h,r)
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["wk_b"].astype(dtype))
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_lat, cache_ckv.astype(dtype))
        + jnp.einsum("bqhk,bsk->bhqs", q_rope, cache_krope.astype(dtype))
    ).astype(jnp.float32) * scale
    valid = jnp.arange(cache_ckv.shape[1])[None, :] <= cache_len
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, cache_ckv.astype(dtype))
    out = jnp.einsum("bqhr,rhk->bqhk", out_lat, params["wv_b"].astype(dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, cache_ckv, cache_krope
