"""Public model facade: build / init / apply for any registered arch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import transformer

__all__ = ["init_params", "abstract_params", "input_specs", "Model"]


def init_params(key, cfg: ModelConfig):
    return transformer.init_params(key, cfg)


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: transformer.init_params(k, cfg), key)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, per_host: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train/prefill: full-sequence batch.  decode: one new token plus the
    KV/SSM cache of ``seq_len`` (built via ``init_cache`` eval_shape).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            # stub vision frontend: precomputed patch embeddings (1/4 of
            # the span is vision, matching dynamic-resolution image packing)
            n_vis = max(s // 4, 16)
            batch["tokens"] = jax.ShapeDtypeStruct((b, s - n_vis), i32)
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, n_vis, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "encdec":
            # stub audio frontend: precomputed frame embeddings, 2x the
            # target length (speech-to-text ratio)
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, min(2 * s, 8192), cfg.d_model), jnp.bfloat16
            )
        return batch
    # decode: one token + cache of seq_len
    cache = jax.eval_shape(lambda: transformer.init_cache(cfg, b, s))
    batch = {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "cache": cache,
        "cache_len": jax.ShapeDtypeStruct((), i32),
    }
    if cfg.family == "encdec":
        batch["memory"] = jax.ShapeDtypeStruct(
            (b, 1024, cfg.d_model), jnp.bfloat16
        )
    return batch


class Model:
    """Thin OO wrapper used by examples and the serving loop."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return init_params(key, self.cfg)

    def loss(self, params, batch):
        return transformer.forward_train(params, self.cfg, batch)

    def logits(self, params, batch):
        return transformer.forward_logits(params, self.cfg, batch)

    def init_cache(self, batch: int, max_len: int):
        return transformer.init_cache(self.cfg, batch, max_len)

    def decode_step(self, params, token, cache, cache_len, memory=None):
        return transformer.decode_step(
            params, self.cfg, token, cache, cache_len, memory=memory
        )
