"""Model composition: stage-structured transformer / SSM / hybrid LMs.

A model is a sequence of homogeneous *stages*; each stage is a stack of
identical layers whose parameters are stacked on a leading axis and
executed with ``jax.lax.scan`` (small HLO, fast compiles at 61+ layers)
with per-layer ``jax.checkpoint`` (remat).  Stage kinds:

  attn_mlp   dense transformer block (GQA + SwiGLU)
  attn_moe   GQA + shared/routed MoE
  mla_mlp    multi-head latent attention + SwiGLU (DeepSeek dense prefix)
  mla_moe    MLA + MoE (DeepSeek-V3)
  mamba1     Mamba-1 selective-scan block
  mamba2     Mamba-2 (SSD) block; hybrid models inject a *shared*
             attention block every ``cfg.attn_every`` layers (Zamba2)
  xattn_mlp  decoder block with cross-attention (encoder-decoder)

Entry points: ``init_params``, ``forward_train`` (loss), ``forward_logits``
(prefill), ``init_cache`` + ``decode_step`` (serving).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention, mla, moe, ssm
from .layers import (
    COMPUTE_DTYPE,
    embed_tokens,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)
from .sharding_policy import constrain

# --------------------------------------------------------------------- #
# stage plan
# --------------------------------------------------------------------- #
def stage_plan(cfg) -> list[tuple[str, int]]:
    if cfg.family in ("dense", "vlm"):
        return [("attn_mlp", cfg.n_layers)]
    if cfg.family == "moe":
        if cfg.mla is not None:
            plan = []
            if cfg.moe.first_k_dense:
                plan.append(("mla_mlp", cfg.moe.first_k_dense))
            plan.append(("mla_moe", cfg.n_layers - cfg.moe.first_k_dense))
            return plan
        plan = []
        if cfg.moe.first_k_dense:
            plan.append(("attn_mlp", cfg.moe.first_k_dense))
        plan.append(("attn_moe", cfg.n_layers - cfg.moe.first_k_dense))
        return plan
    if cfg.family == "ssm":
        kind = "mamba2" if cfg.ssm.variant == "mamba2" else "mamba1"
        return [(kind, cfg.n_layers)]
    if cfg.family == "hybrid":
        return [("mamba2" if cfg.ssm.variant == "mamba2" else "mamba1", cfg.n_layers)]
    if cfg.family == "encdec":
        return [("xattn_mlp", cfg.n_layers)]
    raise ValueError(f"unknown family {cfg.family}")


# --------------------------------------------------------------------- #
# per-layer init (vmapped into stacks)
# --------------------------------------------------------------------- #
def _layer_init(kind: str, key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "attn_mlp":
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attention.attention_init(k1, cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff),
        }
    if kind == "attn_moe":
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attention.attention_init(k1, cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "moe": moe.moe_init(k2, cfg),
        }
    if kind == "mla_mlp":
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": mla.mla_init(k1, cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff),
        }
    if kind == "mla_moe":
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": mla.mla_init(k1, cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "moe": moe.moe_init(k2, cfg),
        }
    if kind == "mamba1":
        return {"norm1": rmsnorm_init(cfg.d_model), "mixer": ssm.mamba1_init(k1, cfg)}
    if kind == "mamba2":
        return {"norm1": rmsnorm_init(cfg.d_model), "mixer": ssm.mamba2_init(k1, cfg)}
    if kind == "xattn_mlp":
        return {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attention.attention_init(k1, cfg),
            "norm_x": rmsnorm_init(cfg.d_model),
            "xattn": attention.attention_init(k3, cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff),
        }
    raise ValueError(kind)


def init_params(key, cfg):
    keys = jax.random.split(key, 8)
    params = {"embedding": embedding_init(keys[0], cfg.vocab_size, cfg.d_model,
                                          cfg.tie_embeddings)}
    stages = []
    for si, (kind, n) in enumerate(stage_plan(cfg)):
        layer_keys = jax.random.split(jax.random.fold_in(keys[1], si), n)
        stacked = jax.vmap(lambda k: _layer_init(kind, k, cfg))(layer_keys)
        stages.append({"kind_params": stacked})
    params["stages"] = stages
    params["final_norm"] = rmsnorm_init(cfg.d_model)
    if cfg.family == "hybrid" and cfg.attn_every:
        params["shared_attn"] = {
            "norm": rmsnorm_init(cfg.d_model),
            "attn": attention.attention_init(keys[2], cfg),
        }
    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[3], cfg.n_encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _layer_init("attn_mlp", k, cfg))(enc_keys),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
    if cfg.mtp_depth:
        params["mtp"] = _layer_init("attn_mlp", keys[4], cfg)
        params["mtp_norm"] = rmsnorm_init(cfg.d_model)
    return params


# --------------------------------------------------------------------- #
# forward layers
# --------------------------------------------------------------------- #
def _apply_layer(kind, lp, x, cfg, positions, *, causal=True, memory=None,
                 mrope_positions=None):
    """One layer forward; returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_mlp", "attn_moe"):
        h = rmsnorm(lp["norm1"], x)
        x = x + attention.attention_apply(
            lp["attn"], h, cfg, positions, causal=causal,
            mrope_positions=mrope_positions,
        )
        h = rmsnorm(lp["norm2"], x)
        if kind == "attn_mlp":
            x = x + mlp_apply(lp["mlp"], h)
        else:
            y, aux = moe.moe_apply(lp["moe"], h, cfg)
            x = x + y
    elif kind in ("mla_mlp", "mla_moe"):
        h = rmsnorm(lp["norm1"], x)
        x = x + mla.mla_apply(lp["attn"], h, cfg, positions, causal=causal)
        h = rmsnorm(lp["norm2"], x)
        if kind == "mla_mlp":
            x = x + mlp_apply(lp["mlp"], h)
        else:
            y, aux = moe.moe_apply(lp["moe"], h, cfg)
            x = x + y
    elif kind == "mamba1":
        x = x + ssm.mamba1_apply(lp["mixer"], rmsnorm(lp["norm1"], x), cfg)
    elif kind == "mamba2":
        x = x + ssm.mamba2_apply(lp["mixer"], rmsnorm(lp["norm1"], x), cfg)
    elif kind == "xattn_mlp":
        h = rmsnorm(lp["norm1"], x)
        x = x + attention.attention_apply(lp["attn"], h, cfg, positions, causal=True)
        h = rmsnorm(lp["norm_x"], x)
        x = x + _cross_attention(lp["xattn"], h, memory, cfg)
        h = rmsnorm(lp["norm2"], x)
        x = x + mlp_apply(lp["mlp"], h)
    else:
        raise ValueError(kind)
    return x, aux


def _cross_attention(params, x, memory, cfg):
    """Decoder->encoder cross attention (no RoPE on memory keys)."""
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"].astype(dtype))
    out = attention.chunked_attention(
        q, k, v, causal=False, chunk=min(cfg.attn_chunk, x.shape[1])
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def _shared_attn_maybe(params, x, cfg, positions, layer_idx):
    """Zamba2-style shared attention block every ``attn_every`` layers."""
    if "shared_attn" not in params or not cfg.attn_every:
        return x
    sa = params["shared_attn"]

    def apply_it(x):
        h = rmsnorm(sa["norm"], x)
        return x + attention.attention_apply(sa["attn"], h, cfg, positions,
                                             causal=True)

    return jax.lax.cond(
        (layer_idx + 1) % cfg.attn_every == 0, apply_it, lambda x: x, x
    )


#: per-layer remat policy: 'full' recomputes everything in the backward
#: pass (min memory); 'dots' saves matmul outputs (less recompute, more
#: memory) — see EXPERIMENTS.md §Perf for the measured trade-off.
REMAT_POLICY = "full"


def set_remat_policy(name: str) -> None:
    global REMAT_POLICY
    assert name in ("full", "dots")
    REMAT_POLICY = name


def _run_stage(stage_params, kind, x, cfg, positions, params, *,
               causal=True, memory=None, mrope_positions=None,
               layer_offset=0):
    """Scan a layer stack with remat; returns (x, aux_sum)."""

    def body(carry, inputs):
        x, aux = carry
        # pin the residual stream: (b@dp, s[, @model if SP], d)
        lp, idx = inputs
        x = constrain(x, ("batch", "seq", None))
        x, a = _apply_layer(
            kind, lp, x, cfg, positions, causal=causal, memory=memory,
            mrope_positions=mrope_positions,
        )
        if cfg.family == "hybrid":
            x = _shared_attn_maybe(params, x, cfg, positions, idx)
        return (x, aux + a), None

    if REMAT_POLICY == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    else:
        body = jax.checkpoint(body)
    n_layers = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
    idxs = layer_offset + jnp.arange(n_layers)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stage_params, idxs)
    )
    return x, aux


# --------------------------------------------------------------------- #
# top-level forwards
# --------------------------------------------------------------------- #
def _cast_stage_params(stage_params):
    """Cast matrix weights to the compute dtype *before* the layer scan so
    the FSDP all-gather moves bf16, not f32 (halves the gather bytes —
    EXPERIMENTS.md §Perf 'cast-before-gather').  Vectors (norm scales,
    biases) stay f32: they are replicated, never gathered."""
    return jax.tree_util.tree_map(
        lambda a: a.astype(COMPUTE_DTYPE)
        if (a.ndim >= 3 and a.dtype == jnp.float32) else a,
        stage_params,
    )


def _backbone(params, cfg, x, positions, *, causal=True, memory=None,
              mrope_positions=None):
    aux_total = jnp.zeros((), jnp.float32)
    offset = 0
    for (kind, n), stage in zip(stage_plan(cfg), params["stages"]):
        x, aux = _run_stage(
            _cast_stage_params(stage["kind_params"]), kind, x, cfg,
            positions, params,
            causal=causal, memory=memory, mrope_positions=mrope_positions,
            layer_offset=offset,
        )
        aux_total = aux_total + aux
        offset += n
    return rmsnorm(params["final_norm"], x), aux_total


def _encode(params, cfg, src_embeds):
    """Encoder stack over precomputed frontend embeddings (audio stub)."""
    positions = jnp.arange(src_embeds.shape[1])[None, :]
    x = src_embeds.astype(COMPUTE_DTYPE)
    x, _ = _run_stage(
        params["encoder"]["layers"], "attn_mlp", x, cfg, positions, params,
        causal=False,
    )
    return rmsnorm(params["encoder"]["final_norm"], x)


def _make_mrope_positions(cfg, batch, n_vis, n_text):
    """Synthesized 3D (t, h, w) M-RoPE ids: vision patches on a grid, text
    linear after the vision span (stub frontend discipline)."""
    side = max(int(n_vis**0.5), 1)
    t = jnp.concatenate([jnp.zeros((n_vis,), jnp.int32),
                         jnp.arange(n_text, dtype=jnp.int32) + side])
    hh = jnp.concatenate([(jnp.arange(n_vis, dtype=jnp.int32) // side),
                          jnp.arange(n_text, dtype=jnp.int32) + side])
    ww = jnp.concatenate([(jnp.arange(n_vis, dtype=jnp.int32) % side),
                          jnp.arange(n_text, dtype=jnp.int32) + side])
    pos = jnp.stack([t, hh, ww])  # (3, s)
    return jnp.broadcast_to(pos[None], (batch, 3, n_vis + n_text))


def forward_hidden(params, cfg, batch):
    """Full-sequence forward -> final hidden states (pre-unembed)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = constrain(embed_tokens(params["embedding"], tokens),
                  ("batch", None, None))
    mrope_positions = None
    memory = None
    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(COMPUTE_DTYPE)
        x = jnp.concatenate([vis, x], axis=1)
        mrope_positions = _make_mrope_positions(
            cfg, b, vis.shape[1], tokens.shape[1]
        )
    if cfg.family == "encdec":
        memory = _encode(params, cfg, batch["src_embeds"])
    positions = jnp.arange(x.shape[1])[None, :]
    h, aux = _backbone(
        params, cfg, x, positions, memory=memory,
        mrope_positions=mrope_positions,
    )
    return h, aux


def forward_logits(params, cfg, batch):
    """Full-sequence forward -> logits (prefill / eval path)."""
    h, aux = forward_hidden(params, cfg, batch)
    logits = constrain(
        unembed(params["embedding"], h), ("batch", None, "model")
    )
    return logits, aux


def _xent(logits, targets):
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean(), jnp.square(logz).mean()


def forward_train(params, cfg, batch):
    """Next-token loss (+ router aux + MTP head if configured)."""
    tokens = batch["tokens"]
    h, aux = forward_hidden(params, cfg, batch)
    h = h[:, -tokens.shape[1] :]  # score only the text span (vlm prefix)
    logits = constrain(
        unembed(params["embedding"], h), ("batch", None, "model")
    )
    xent, z2 = _xent(logits[:, :-1], tokens[:, 1:])
    zloss = 1e-4 * z2
    loss = xent + zloss + aux
    metrics = {"xent": xent, "aux": aux, "zloss": zloss}
    if cfg.mtp_depth and "mtp" in params:
        # DeepSeek-V3-style multi-token prediction: one extra dense block
        # over the trunk hiddens predicts token t+2 with the shared head.
        positions = jnp.arange(h.shape[1])[None, :]
        h2, _ = _apply_layer("attn_mlp", params["mtp"], h, cfg, positions)
        h2 = rmsnorm(params["mtp_norm"], h2)
        mtp_logits = unembed(params["embedding"], h2)
        mtp_xent, _ = _xent(mtp_logits[:, :-2], tokens[:, 2:])
        loss = loss + 0.3 * mtp_xent
        metrics["mtp_xent"] = mtp_xent
    return loss, metrics


# --------------------------------------------------------------------- #
# serving: cache init + decode step
# --------------------------------------------------------------------- #
def init_cache(cfg, batch: int, max_len: int):
    """Per-stage stacked caches (dtype bf16, layer-major)."""
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    caches = []
    for kind, n in stage_plan(cfg):
        if kind in ("attn_mlp", "attn_moe", "xattn_mlp"):
            caches.append({
                "k": jnp.zeros((n, batch, max_len, kv, hd), COMPUTE_DTYPE),
                "v": jnp.zeros((n, batch, max_len, kv, hd), COMPUTE_DTYPE),
            })
        elif kind in ("mla_mlp", "mla_moe"):
            m = cfg.mla
            caches.append({
                "ckv": jnp.zeros((n, batch, max_len, m.kv_lora_rank), COMPUTE_DTYPE),
                "krope": jnp.zeros((n, batch, max_len, m.qk_rope_dim), COMPUTE_DTYPE),
            })
        elif kind in ("mamba1", "mamba2"):
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            conv_ch = d_in if kind == "mamba1" else d_in + 2 * s.state_dim
            entry = {
                "conv": jnp.zeros((n, batch, s.conv_dim - 1, conv_ch), COMPUTE_DTYPE),
            }
            if kind == "mamba1":
                entry["ssm"] = jnp.zeros((n, batch, d_in, s.state_dim), jnp.float32)
            else:
                nh = s.n_ssm_heads or max(d_in // 64, 1)
                entry["ssm"] = jnp.zeros(
                    (n, batch, nh, s.state_dim, d_in // nh), jnp.float32
                )
            caches.append(entry)
        else:
            raise ValueError(kind)
    shared = None
    if cfg.family == "hybrid" and cfg.attn_every:
        n_shared = cfg.n_layers // cfg.attn_every
        shared = {
            "k": jnp.zeros((n_shared, batch, max_len, kv, hd), COMPUTE_DTYPE),
            "v": jnp.zeros((n_shared, batch, max_len, kv, hd), COMPUTE_DTYPE),
        }
    return {"stages": caches, "shared_attn": shared}


def decode_step(params, cfg, token, cache, cache_len, *, memory=None):
    """One serving step: token (b, 1) int32 -> (logits, new cache).

    ``cache_len`` is the current number of valid positions (scalar int32).
    """
    x = embed_tokens(params["embedding"], token)
    new_stage_caches = []
    shared_cache = cache.get("shared_attn")
    shared_idx = 0

    for (kind, n), stage, sc in zip(
        stage_plan(cfg), params["stages"], cache["stages"]
    ):
        if kind in ("attn_mlp", "attn_moe", "mla_mlp", "mla_moe", "xattn_mlp"):
            def body(carry, inputs):
                x, = carry
                lp, c = inputs
                h = rmsnorm(lp["norm1"], x)
                if kind in ("mla_mlp", "mla_moe"):
                    y, ckv, krope = mla.mla_decode(
                        lp["attn"], h, cfg, c["ckv"], c["krope"], cache_len
                    )
                    new_c = {"ckv": ckv, "krope": krope}
                else:
                    y, ck, cv = attention.attention_decode(
                        lp["attn"], h, cfg, c["k"], c["v"], cache_len
                    )
                    new_c = {"k": ck, "v": cv}
                x = x + y
                if kind == "xattn_mlp":
                    h = rmsnorm(lp["norm_x"], x)
                    x = x + _cross_attention(lp["xattn"], h, memory, cfg)
                h = rmsnorm(lp["norm2"], x)
                if kind in ("attn_mlp", "mla_mlp", "xattn_mlp"):
                    x = x + mlp_apply(lp["mlp"], h)
                else:
                    y, _ = moe.moe_apply(lp["moe"], h, cfg)
                    x = x + y
                return (x,), new_c

            (x,), new_c = jax.lax.scan(body, (x,), (stage["kind_params"], sc))
            new_stage_caches.append(new_c)
        elif kind in ("mamba1", "mamba2"):
            decode_fn = ssm.mamba1_decode if kind == "mamba1" else ssm.mamba2_decode

            def body(carry, inputs):
                (x,) = carry
                lp, c = inputs
                h = rmsnorm(lp["norm1"], x)
                y, conv, st = decode_fn(lp["mixer"], h, cfg, c["conv"], c["ssm"])
                x = x + y
                return (x,), {"conv": conv, "ssm": st}

            every = cfg.attn_every if (cfg.family == "hybrid" and cfg.attn_every) else n
            seg_bounds = list(range(0, n, every)) + [n]
            new_c_parts = []
            for lo, hi in zip(seg_bounds[:-1], seg_bounds[1:]):
                seg_params = jax.tree_util.tree_map(
                    lambda a: a[lo:hi], stage["kind_params"]
                )
                seg_cache = jax.tree_util.tree_map(lambda a: a[lo:hi], sc)
                (x,), seg_new = jax.lax.scan(body, (x,), (seg_params, seg_cache))
                new_c_parts.append(seg_new)
                # shared attention block after each full segment (Zamba2)
                if (
                    cfg.family == "hybrid"
                    and cfg.attn_every
                    and shared_cache is not None
                    and hi - lo == every
                    and shared_idx < shared_cache["k"].shape[0]
                ):
                    sa = params["shared_attn"]
                    h = rmsnorm(sa["norm"], x)
                    y, ck, cv = attention.attention_decode(
                        sa["attn"], h, cfg,
                        shared_cache["k"][shared_idx],
                        shared_cache["v"][shared_idx],
                        cache_len,
                    )
                    x = x + y
                    shared_cache = {
                        "k": shared_cache["k"].at[shared_idx].set(ck),
                        "v": shared_cache["v"].at[shared_idx].set(cv),
                    }
                    shared_idx += 1
            new_c = jax.tree_util.tree_map(
                lambda *parts: jnp.concatenate(parts, axis=0), *new_c_parts
            )
            new_stage_caches.append(new_c)
        else:
            raise ValueError(kind)

    h = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embedding"], h)
    return logits, {"stages": new_stage_caches, "shared_attn": shared_cache}
