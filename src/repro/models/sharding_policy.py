"""Activation-sharding policy.

GSPMD propagates input shardings, but propagation alone can settle in
pathological layouts (e.g. feature-sharded activations with a replicated
batch).  Production frameworks pin the layout at a few anchor points with
``with_sharding_constraint``; models call :func:`constrain` with logical
axis names and the launcher installs the physical mapping:

    batch  -> ('pod', 'data')     model -> 'model'      None -> replicated

When no policy is installed (CPU unit tests), ``constrain`` is a no-op.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

_POLICY: dict | None = None


def set_policy_from_mesh(mesh: Mesh, *, sequence_parallel: bool = False,
                         strategy: str = "fsdp_tp") -> None:
    if strategy == "pure_fsdp":
        axes = tuple(mesh.axis_names)
        batch = axes if len(axes) > 1 else (axes[0] if axes else None)
        set_policy(batch, None, dict(zip(mesh.axis_names, mesh.devices.shape)))
        return
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    model = "model" if "model" in mesh.axis_names else None
    set_policy(batch, model, dict(zip(mesh.axis_names, mesh.devices.shape)),
               sequence_parallel=sequence_parallel)


def set_policy(batch_axes, model_axis, axis_sizes: dict, *,
               sequence_parallel: bool = False) -> None:
    global _POLICY
    _POLICY = {
        "batch": batch_axes,
        "model": model_axis,
        # 'seq' maps the logical sequence dim of the residual stream onto
        # the model axis (Megatron sequence parallelism): the per-layer TP
        # output all-reduce becomes all-gather + reduce-scatter and every
        # elementwise/norm op runs on 1/TP of the tokens.
        "seq": model_axis if sequence_parallel else None,
        "sizes": dict(axis_sizes),
    }


def clear_policy() -> None:
    global _POLICY
    _POLICY = None


def _axis_size(axis, sizes) -> int:
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        n *= sizes.get(a, 1)
    return n


def constrain(x, dims: tuple):
    """dims entries: 'batch' | 'model' | None per array dimension."""
    if _POLICY is None:
        return x
    sizes = _POLICY["sizes"]
    spec = []
    for d, size in zip(dims, x.shape):
        axis = _POLICY.get(d) if d else None
        if axis is None:
            spec.append(None)
            continue
        # divisibility guard: replicate when the dim does not divide
        spec.append(axis if size % _axis_size(axis, sizes) == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
