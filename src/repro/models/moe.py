"""Mixture-of-Experts FFN: shared experts + routed top-k experts.

Dispatch is sort-based with a static per-expert capacity (TPU-friendly: no
dynamic shapes): tokens are ranked within their chosen expert, tokens past
capacity are dropped (standard GShard/Switch discipline), and expert FFNs
run as one batched einsum over the expert dimension, which shards over the
``model`` mesh axis (expert parallelism).  Router uses softmax-then-top-k
with an auxiliary load-balancing loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .layers import dense_init
from . import sharding_policy
from .sharding_policy import constrain


def moe_init(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    params = {
        "router": dense_init(ks[0], (d, m.n_experts), scale=d**-0.5),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_expert_ff)),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_expert_ff)),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_expert_ff, d)),
    }
    if m.n_shared:
        f_sh = (m.d_shared_ff or m.d_expert_ff) * m.n_shared
        params["shared"] = {
            "w_gate": dense_init(ks[4], (d, f_sh)),
            "w_up": dense_init(ks[5], (d, f_sh)),
            "w_down": dense_init(jax.random.fold_in(key, 7), (f_sh, d)),
        }
    return params


def _capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, cap + (-cap % 8))


def moe_apply(params, x, cfg):
    """x: (b, s, d) -> (y, aux_loss).

    Two implementations:

    * **EP shard_map path** (production): activations are replicated over
      the ``model`` axis by the surrounding TP layout, so each model-shard
      routes the *local* token block to its **own** expert slice and the
      only collective is one ``psum`` over ``model`` for the combine.
      This removes the cross-shard dispatch gather that GSPMD otherwise
      lowers into dot-shaped data movement (observed 50x FLOP blow-up —
      see EXPERIMENTS.md §Perf iteration 1).
    * **gather fallback** (no mesh policy / tiny batches): sort-based
      capacity dispatch in plain jnp.
    """
    policy = sharding_policy._POLICY
    if policy is not None and policy.get("model"):
        dp = policy.get("batch")
        dp_size = 1
        if dp:
            for a in (dp if isinstance(dp, tuple) else (dp,)):
                dp_size *= policy["sizes"].get(a, 1)
        if dp_size > 1 and x.shape[0] % dp_size == 0:
            return _moe_ep_shardmap(params, x, cfg, policy)
    return _moe_gather(params, x, cfg)


def _moe_gather(params, x, cfg):
    m = cfg.moe
    b, s, d = x.shape
    dtype = x.dtype
    n_tokens = b * s
    xt = x.reshape(n_tokens, d)

    logits = jnp.einsum("td,de->te", xt, params["router"].astype(dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)
    one_hot = jax.nn.one_hot(expert_ids[:, 0], m.n_experts, dtype=jnp.float32)
    ce = one_hot.mean(axis=0)
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight

    # ---- sort-based dispatch with static capacity ---- #
    cap = _capacity(n_tokens, cfg)
    flat_expert = expert_ids.reshape(-1)  # (T*k,)
    flat_token = jnp.repeat(jnp.arange(n_tokens), m.top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    se, stok, sgate = flat_expert[order], flat_token[order], flat_gate[order]
    # rank of each entry within its expert
    pos = jnp.arange(se.shape[0]) - jnp.searchsorted(se, se, side="left")
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, m.n_experts * cap)  # overflow row

    # token index per (expert, capacity) slot; padded slots -> row n_tokens
    slot_token = jnp.full((m.n_experts * cap + 1,), n_tokens, dtype=jnp.int32)
    slot_token = slot_token.at[slot].set(
        jnp.where(keep, stok, n_tokens).astype(jnp.int32)
    )[: m.n_experts * cap]
    slot_gate = jnp.zeros((m.n_experts * cap + 1,), dtype=jnp.float32)
    slot_gate = slot_gate.at[slot].set(jnp.where(keep, sgate, 0.0))[
        : m.n_experts * cap
    ]

    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), dtype=dtype)])
    # (E@model, cap, d): the gather across data-sharded tokens is the
    # dispatch all-to-all; experts live on the model axis (EP)
    xe = constrain(
        x_pad[slot_token].reshape(m.n_experts, cap, d), ("model", None, None)
    )

    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dtype))

    # combine: scatter-add expert outputs back to tokens, gate-weighted
    ye_flat = ye.reshape(m.n_experts * cap, d) * slot_gate[:, None].astype(dtype)
    y = jnp.zeros((n_tokens + 1, d), dtype=dtype)
    y = y.at[slot_token].add(ye_flat)[:n_tokens]

    if m.n_shared:
        y = y + _shared_experts(params, xt, dtype)

    return y.reshape(b, s, d), aux


def _shared_experts(params, xt, dtype):
    sh = params["shared"]
    g = jnp.einsum("td,df->tf", xt, sh["w_gate"].astype(dtype))
    u = jnp.einsum("td,df->tf", xt, sh["w_up"].astype(dtype))
    hh = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("tf,fd->td", hh, sh["w_down"].astype(dtype))


# --------------------------------------------------------------------- #
# expert-parallel shard_map path
# --------------------------------------------------------------------- #
def _moe_ep_shardmap(params, x, cfg, policy):
    """EP dispatch with shard-local routing (see moe_apply docstring).

    Experts are padded up to a multiple of the model-axis size; every
    model-shard owns a contiguous slice and processes only tokens routed
    to that slice.  Because each token's top-k experts spread over shards,
    the per-shard partial outputs are summed with one ``psum('model')`` —
    the single collective of the whole MoE block.
    """
    m = cfg.moe
    b, s, d = x.shape
    dtype = x.dtype
    dp = policy.get("batch")
    model_axis = policy["model"]
    nm = policy["sizes"].get(model_axis, 1)
    e_pad = -(-m.n_experts // nm) * nm
    e_loc = e_pad // nm

    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    x_spec = P(dp, None, None)
    router_spec = P(None, None)
    expert_spec = P(model_axis, None, None)
    out_spec = P(dp, None, None)
    aux_spec = P()

    w_gate, w_up, w_down = params["w_gate"], params["w_up"], params["w_down"]
    if e_pad != m.n_experts:
        pad = [(0, e_pad - m.n_experts), (0, 0), (0, 0)]
        w_gate, w_up, w_down = (jnp.pad(w, pad) for w in (w_gate, w_up, w_down))

    def block(xb, router, wg, wu, wd):
        # xb: (b_loc, s, d) — replicated over `model`
        b_loc = xb.shape[0]
        t_loc = b_loc * s
        xt = xb.reshape(t_loc, d)
        logits = jnp.einsum("td,de->te", xt, router.astype(xt.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(axis=-1, keepdims=True), 1e-9
        )
        # aux loss (identical on every model shard)
        me = probs.mean(axis=0)
        ce = jax.nn.one_hot(expert_ids[:, 0], m.n_experts,
                            dtype=jnp.float32).mean(axis=0)
        aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
        # aux is computed from the model-replicated x, so it is provably
        # invariant over `model`; pmean over the data axes replicates it
        # fully (required by out_specs P())
        aux = jax.lax.pmean(aux, dp_axes)

        # shard-local expert slice
        shard = jax.lax.axis_index(model_axis)
        e_lo = shard * e_loc
        flat_expert = expert_ids.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(t_loc), m.top_k)
        flat_gate = gate_vals.reshape(-1)
        mine = (flat_expert >= e_lo) & (flat_expert < e_lo + e_loc)
        local_e = jnp.where(mine, flat_expert - e_lo, e_loc)

        cap = max(8, int(t_loc * m.top_k * m.capacity_factor / m.n_experts))
        cap += -cap % 8
        order = jnp.argsort(local_e, stable=True)
        se, stok, sgate = local_e[order], flat_token[order], flat_gate[order]
        pos = jnp.arange(se.shape[0]) - jnp.searchsorted(se, se, side="left")
        keep = (pos < cap) & (se < e_loc)
        slot = jnp.where(keep, se * cap + pos, e_loc * cap)

        slot_token = jnp.full((e_loc * cap + 1,), t_loc, dtype=jnp.int32)
        slot_token = slot_token.at[slot].set(
            jnp.where(keep, stok, t_loc).astype(jnp.int32)
        )[: e_loc * cap]
        slot_gate = jnp.zeros((e_loc * cap + 1,), dtype=jnp.float32)
        slot_gate = slot_gate.at[slot].set(
            jnp.where(keep, sgate, 0.0)
        )[: e_loc * cap]

        x_pad = jnp.concatenate([xt, jnp.zeros((1, d), dtype=xt.dtype)])
        xe = x_pad[slot_token].reshape(e_loc, cap, d)  # local gather
        g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xt.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xt.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
        ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(xt.dtype))
        ye_flat = ye.reshape(e_loc * cap, d) * slot_gate[:, None].astype(xt.dtype)
        y = jnp.zeros((t_loc + 1, d), dtype=xt.dtype)
        y = y.at[slot_token].add(ye_flat)[:t_loc]
        # combine across expert shards — the one collective
        y = jax.lax.psum(y, model_axis)
        return y.reshape(b_loc, s, d), aux

    mapped = shard_map(
        block,
        in_specs=(x_spec, router_spec, expert_spec, expert_spec, expert_spec),
        out_specs=(out_spec, aux_spec),
    )
    y, aux = mapped(x, params["router"], w_gate, w_up, w_down)

    if m.n_shared:
        xt = x.reshape(b * s, d)
        y = y + _shared_experts(params, xt, dtype).reshape(b, s, d)
    return y, aux
