"""HLO-text cost model with loop-trip-count multipliers.

``compiled.cost_analysis()`` counts every ``while`` body exactly once, so
a scan-over-layers model under-reports FLOPs by ~n_layers and collective
bytes by every loop factor.  This parser rebuilds per-device totals from
the post-SPMD-partitioner HLO text:

* the module is segmented into computations,
* ``while`` ops give (caller, body, cond) edges; trip counts are read from
  the loop-bound constant in the condition computation,
* every computation's multiplier = product of enclosing trip counts
  (propagated over the call graph, including fusion/call edges),
* FLOPs are counted from ``dot`` / ``convolution`` result+contraction
  shapes; collective bytes from the result shapes of all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute ops;
  HBM traffic is approximated as bytes written (every op result) plus
  parameter reads, post-fusion.

All numbers are per-device (the partitioned module is per-device).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)"
    r"\[([\d,]*)\]"
)
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_COLLECTIVE_KIND = re.compile(
    r"\b(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\b"
)
_DOT_RE = re.compile(r"=\s*[\w\[\],{}\s]*?\bdot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _shape_elems(m) -> int:
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
    return n


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_written: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    per_collective_ops: int = 0
    trip_counts: dict = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _split_computations(hlo: str) -> tuple[dict[str, list[str]], str]:
    """Segment HLO text into computations; returns (bodies, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if current is None:
            # computation headers end with '{' and contain '->'; names may
            # be followed by a parameter list with nested parentheses.
            if stripped.endswith("{") and "->" in stripped:
                head = stripped
                is_entry = head.startswith("ENTRY")
                if is_entry:
                    head = head[len("ENTRY"):].strip()
                name = head.lstrip("%").split("(")[0].split(" ")[0].strip()
                current = name
                comps[current] = []
                if is_entry:
                    entry = name
            continue
        if stripped == "}":
            current = None
            continue
        comps[current].append(stripped)
    return comps, entry


_DOT_OPERAND_RE = re.compile(r"dot\(\s*%?([\w\.\-]+)")


def _dot_flops(line: str, symbols: dict[str, list[int]]) -> float:
    """2 * |output| * |contracting| from the result shape + dnums.

    HLO format: ``%name = f32[m,n]{...} dot(%a, %b), lhs_contracting_...``
    — operands are names; their shapes come from the computation-local
    symbol table (every op/parameter line defines ``%name = shape ...``).
    """
    rhs = line.split("=", 1)[1] if "=" in line else line
    first = _SHAPE_RE.search(rhs)
    if first is None:
        return 0.0
    out_elems = _shape_elems(first)
    cm = _CONTRACT_RE.search(line)
    om = _DOT_OPERAND_RE.search(rhs)
    lhs_dims = symbols.get(om.group(1)) if om else None
    if cm is None or not lhs_dims:
        return 2.0 * out_elems  # fallback: at least count outputs
    contract = 1
    for idx in cm.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=")


def _build_symbols(lines: list[str]) -> dict[str, list[int]]:
    """name -> result dims for every definition in a computation."""
    out: dict[str, list[int]] = {}
    for line in lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rhs = line.split("=", 1)[1]
        sm = _SHAPE_RE.search(rhs)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d] if sm.group(2) else []
            out[dm.group(1)] = dims
    return out


def _line_result_bytes(line: str) -> int:
    """Bytes of the op's result: the first shape literal right of ``=``
    (tuple results sum every element shape of the tuple literal)."""
    rhs = line.split("=", 1)[1] if "=" in line else line
    op_split = rhs.find("(")
    head = rhs[:op_split] if op_split > 0 else rhs
    total = _shapes_bytes(head)
    if total == 0:  # shape may sit inside a tuple literal before the op
        m = _SHAPE_RE.search(rhs)
        if m:
            total = _shape_elems(m) * _DTYPE_BYTES[m.group(1)]
    return total


def analyze_hlo(hlo: str) -> HloCost:
    comps, entry = _split_computations(hlo)

    # ---- call graph + trip counts ---- #
    # while edges (trip-weighted) vs plain call/fusion edges (weight 1):
    # FLOPs propagate through both (dots often live inside wrapped
    # fusions); bytes only through while edges — fusion internals are
    # register traffic, not HBM writes, and the fusion *result* is already
    # counted at the caller line.
    while_edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    call_edges: dict[str, list[str]] = {c: [] for c in comps}
    trip_of_body: dict[str, float] = {}
    for cname, lines in comps.items():
        for line in lines:
            bm, cm = _BODY_RE.search(line), _COND_RE.search(line)
            if bm and cm:
                cond, body = cm.group(1), bm.group(1)
                trip = _trip_count(comps.get(cond, []))
                trip_of_body[body] = trip
                while_edges[cname].append((body, trip))
                while_edges[cname].append((cond, trip))
                continue
            for callee in _CALL_RE.findall(line):
                if callee in comps:
                    call_edges[cname].append(callee)

    if entry is None:
        entry = _find_entry(comps, while_edges, call_edges)

    flop_mult: dict[str, float] = {}
    byte_mult: dict[str, float] = {}

    def visit(name: str, m: float, depth=0):
        if depth > 64 or name not in comps:
            return
        flop_mult[name] = flop_mult.get(name, 0.0) + m
        for callee, k in while_edges.get(name, []):
            visit(callee, m * k, depth + 1)
        for callee in call_edges.get(name, []):
            visit(callee, m, depth + 1)

    def visit_bytes(name: str, m: float, depth=0):
        if depth > 64 or name not in comps:
            return
        byte_mult[name] = byte_mult.get(name, 0.0) + m
        for callee, k in while_edges.get(name, []):
            visit_bytes(callee, m * k, depth + 1)

    visit(entry, 1.0)
    visit_bytes(entry, 1.0)

    # ---- accumulate ---- #
    cost = HloCost(trip_counts=trip_of_body)
    for cname, lines in comps.items():
        fm = flop_mult.get(cname, 0.0)
        bm_ = byte_mult.get(cname, 0.0)
        if fm <= 0 and bm_ <= 0:
            continue
        symbols = _build_symbols(lines)
        for line in lines:
            if fm > 0 and (" dot(" in line or "convolution(" in line):
                cost.flops += fm * _dot_flops(line, symbols)
            if bm_ <= 0:
                continue
            km = _COLLECTIVE_KIND.search(line)
            if km and "=" in line:
                kind = km.group(1).replace("-start", "")
                cost.collective_bytes[kind] = (
                    cost.collective_bytes.get(kind, 0.0)
                    + bm_ * _line_result_bytes(line)
                )
                cost.per_collective_ops += 1
            if "=" in line and "parameter(" not in line and \
                    "get-tuple-element" not in line:
                cost.bytes_written += bm_ * _line_result_bytes(line)
    return cost


def _trip_count(cond_lines: list[str]) -> float:
    """Loop bound from the condition computation: the largest integer
    constant compared against the induction variable."""
    best = 1.0
    for line in cond_lines:
        if "constant(" in line:
            for c in _CONST_RE.findall(line):
                best = max(best, float(c))
    return best


def _find_entry(comps: dict, while_edges: dict, call_edges: dict) -> str:
    called = set()
    for edges in while_edges.values():
        called.update(c for c, _ in edges)
    for edges in call_edges.values():
        called.update(edges)
    for c in comps:
        if c not in called:
            return c
    return next(iter(comps))
