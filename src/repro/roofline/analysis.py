"""Three-term roofline model over dry-run artifacts.

Hardware model (TPU v5e target):
    peak_flops = 197e12  bf16 FLOP/s per chip
    hbm_bw     = 819e9   B/s per chip
    link_bw    = 50e9    B/s per ICI link

Terms (seconds, per step, per device — the dry-run artifacts are already
per-device):

    compute    = HLO_FLOPs / peak_flops
    memory     = HLO_bytes / hbm_bw
    collective = collective_bytes / link_bw

``collective_bytes`` counts each collective's *result* bytes once (ring
all-reduce moves ~2x that on the wire; the constant factor does not change
which term dominates, and is noted in EXPERIMENTS.md).

MODEL_FLOPS (the "useful" floor) is ``6 * N * D`` for training (N = total
params for dense, active params for MoE; D = tokens per step) and
``2 * N * batch`` for a decode step.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

from ..configs import SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

__all__ = ["RooflineRow", "roofline_row", "load_dryrun", "full_table",
           "format_table"]


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_dev: float
    hlo_flops_per_dev: float
    temp_bytes: float

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return (
            self.model_flops_per_dev / self.hlo_flops_per_dev
            if self.hlo_flops_per_dev
            else 0.0
        )

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput vs peak, given the *dominant* term paces
        the step: (MODEL_FLOPS/peak) / max(term)."""
        dom = max(self.compute_s, self.memory_s, self.collective_s)
        if dom <= 0:
            return 0.0
        return (self.model_flops_per_dev / PEAK_FLOPS) / dom


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / n_devices
    # decode / prefill-step: forward only
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / n_devices
    return 2.0 * n * shape.global_batch / n_devices


def roofline_row(rec: dict) -> RooflineRow | None:
    if rec.get("status") != "OK":
        return None
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["n_devices"])
    return RooflineRow(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        compute_s=rec["flops_per_device"] / PEAK_FLOPS,
        memory_s=rec["hbm_bytes_per_device"] / HBM_BW,
        collective_s=rec["collective_total_per_device"] / LINK_BW,
        model_flops_per_dev=mf,
        hlo_flops_per_dev=rec["flops_per_device"],
        temp_bytes=rec["memory"]["temp_bytes"] or 0,
    )


def load_dryrun(directory: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for fname in sorted(os.listdir(directory)):
        if fname.endswith(".json"):
            with open(os.path.join(directory, fname)) as f:
                recs.append(json.load(f))
    return recs


def full_table(directory: str = "experiments/dryrun", mesh: str = "single"):
    rows = []
    for rec in load_dryrun(directory):
        if rec.get("mesh") != mesh:
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def format_table(rows: list[RooflineRow]) -> str:
    hdr = (
        f"{'arch':<22}{'shape':<13}{'compute_s':>11}{'memory_s':>11}"
        f"{'coll_s':>10}{'bottleneck':>12}{'useful':>8}{'roofl%':>8}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<22}{r.shape:<13}{r.compute_s:>11.4f}"
            f"{r.memory_s:>11.4f}{r.collective_s:>10.4f}"
            f"{r.bottleneck:>12}{r.useful_ratio:>8.2f}"
            f"{100*r.roofline_fraction:>7.1f}%"
        )
    return "\n".join(lines)
