"""Roofline analysis: HLO cost parsing + three-term roofline model."""

from .hlo_cost import HloCost, analyze_hlo

__all__ = ["HloCost", "analyze_hlo"]
