"""Block-pruned membership kernel — the semi-join / dedup hot spot.

TPU adaptation of the paper's priority-queue merge (semi-)join and merge
anti-join (Algorithms 3 and 6).  A serial two-pointer merge is O(n+m) but
has loop-carried dependencies that do not vectorise.  On TPU we instead
evaluate membership as a *block-pruned brute-force compare*:

* grid = (tiles of ``a``) x (blocks of ``b``),
* each step compares an ``a``-tile against a ``b``-block with one
  broadcast equality (VPU-friendly, no data-dependent control flow),
* because ``b`` is sorted, a block whose [min, max] range does not
  overlap the tile's range is skipped with ``pl.when`` — for sorted
  inputs at most O(1) of the ``m/BLOCK_B`` blocks per tile survive the
  prune, so useful work is O(n * overlap) rather than O(n * m).

Used for: dedup anti-join (``~member``), semi-join filters, and the
distributed engine's ``dedup_against``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import resolve_interpret

DEFAULT_BLOCK_A = 512
DEFAULT_BLOCK_B = 1024
_SENTINEL = jnp.iinfo(jnp.int32).max  # caller guarantees ids < sentinel


def _member_kernel(a_ref, b_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    # prune: sorted b => this block covers [bmin, bmax]; skip if disjoint
    # from the tile's value range.
    bmin, bmax = b[0], b[-1]
    amin, amax = jnp.min(a), jnp.max(a)

    @pl.when(jnp.logical_and(amax >= bmin, amin <= bmax))
    def _compare():
        hit = (a[:, None] == b[None, :]).any(axis=1)
        o_ref[...] = jnp.logical_or(o_ref[...], hit)


def sorted_member(
    a: jax.Array,
    b_sorted: jax.Array,
    *,
    block_a: int = DEFAULT_BLOCK_A,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
) -> jax.Array:
    """``out[i] = a[i] in b_sorted``; ``b_sorted`` ascending int32.

    ``interpret=None`` resolves per backend/env (see
    :mod:`repro.kernels.backend`) — outside the jit, so the trace cache
    keys on the concrete bool and an env flip takes effect immediately.
    """
    return _sorted_member_jit(
        a,
        b_sorted,
        block_a=block_a,
        block_b=block_b,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(
    jax.jit, static_argnames=("block_a", "block_b", "interpret")
)
def _sorted_member_jit(
    a: jax.Array,
    b_sorted: jax.Array,
    *,
    block_a: int,
    block_b: int,
    interpret: bool,
) -> jax.Array:
    n, m = a.shape[0], b_sorted.shape[0]
    if n == 0:
        return jnp.zeros((0,), dtype=bool)
    if m == 0:
        return jnp.zeros((n,), dtype=bool)
    n_pad = -n % block_a
    m_pad = -m % block_b
    a_p = jnp.pad(a.astype(jnp.int32), (0, n_pad), constant_values=_SENTINEL)
    b_p = jnp.pad(
        b_sorted.astype(jnp.int32), (0, m_pad), constant_values=_SENTINEL
    )
    grid = (a_p.shape[0] // block_a, b_p.shape[0] // block_b)
    out = pl.pallas_call(
        _member_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_a,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_a,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((a_p.shape[0],), jnp.bool_),
        interpret=interpret,
    )(a_p, b_p)
    return out[:n]
