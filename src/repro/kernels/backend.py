"""Backend detection for the Pallas kernels: resolving ``interpret=None``.

Every kernel wrapper takes ``interpret: bool | None = None``.  ``None``
means "interpret exactly when the jax backend is CPU": on a CPU-only
container the kernel bodies execute in Python for validation, while the
same call sites compile the real Mosaic kernel as soon as a TPU/GPU
backend is present — no code change needed to switch.

The environment variable :data:`ENV_VAR` (``REPRO_PALLAS_INTERPRET``)
overrides the detection in both directions: ``1/true/yes`` forces
interpret mode (debugging a miscompile on hardware), ``0/false/no``
forces compilation (exercising the Mosaic lowering under interpret-
capable CI).  Resolution happens *outside* the jit'd kernels, so their
caches are keyed on the resolved concrete bool.
"""

from __future__ import annotations

import os

__all__ = ["ENV_VAR", "backend_name", "default_interpret", "resolve_interpret"]

#: env override: truthy -> always interpret, falsy -> never interpret
ENV_VAR = "REPRO_PALLAS_INTERPRET"

_TRUTHY = {"1", "true", "yes", "on"}
_FALSY = {"0", "false", "no", "off"}


def backend_name() -> str:
    """The active jax backend ("cpu", "tpu", "gpu").  Imported lazily so
    numpy-only consumers of :mod:`repro.kernels` never pay the jax
    import just to ask."""
    import jax

    return jax.default_backend()


def default_interpret() -> bool:
    """True when kernels should run in interpret mode by default.

    Order: :data:`ENV_VAR` if set (anything unrecognised raises — a typo
    silently flipping the execution path is the worst failure mode),
    else backend detection (CPU -> interpret).
    """
    env = os.environ.get(ENV_VAR)
    if env is not None:
        val = env.strip().lower()
        if val in _TRUTHY:
            return True
        if val in _FALSY:
            return False
        raise ValueError(
            f"{ENV_VAR}={env!r}: expected one of "
            f"{sorted(_TRUTHY | _FALSY)}"
        )
    return backend_name() == "cpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a wrapper's ``interpret`` argument to a concrete bool."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)
