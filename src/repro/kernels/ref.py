"""Pure-jnp oracles for every kernel (the correctness references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sorted_member_ref(a: jax.Array, b_sorted: jax.Array) -> jax.Array:
    """Membership of a[i] in sorted b — searchsorted reference."""
    if b_sorted.shape[0] == 0:
        return jnp.zeros(a.shape, dtype=bool)
    idx = jnp.clip(jnp.searchsorted(b_sorted, a), 0, b_sorted.shape[0] - 1)
    return b_sorted[idx] == a


def rle_expand_ref(run_values, run_counts, total: int):
    """np.repeat reference (host; dynamic output size)."""
    out = np.repeat(np.asarray(run_values), np.asarray(run_counts))
    assert out.shape[0] == total
    return jnp.asarray(out, dtype=jnp.int32)


def join_bounds_ref(l_keys: jax.Array, r_sorted: jax.Array):
    lo = jnp.searchsorted(r_sorted, l_keys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(r_sorted, l_keys, side="right").astype(jnp.int32)
    return lo, hi
