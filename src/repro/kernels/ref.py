"""Pure-jnp oracles for every kernel (the correctness references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sorted_member_ref(a: jax.Array, b_sorted: jax.Array) -> jax.Array:
    """Membership of a[i] in sorted b — searchsorted reference."""
    if b_sorted.shape[0] == 0:
        return jnp.zeros(a.shape, dtype=bool)
    idx = jnp.clip(jnp.searchsorted(b_sorted, a), 0, b_sorted.shape[0] - 1)
    return b_sorted[idx] == a


def rle_expand_ref(run_values, run_counts, total: int):
    """np.repeat reference (host; dynamic output size)."""
    out = np.repeat(np.asarray(run_values), np.asarray(run_counts))
    assert out.shape[0] == total
    return jnp.asarray(out, dtype=jnp.int32)


def join_bounds_ref(l_keys: jax.Array, r_sorted: jax.Array):
    lo = jnp.searchsorted(r_sorted, l_keys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(r_sorted, l_keys, side="right").astype(jnp.int32)
    return lo, hi


_BIG = np.iinfo(np.int32).max


def fused_join_dedup_ref(
    l_keys, l_payload, r_keys_sorted, r_payload, *, capacity: int
):
    """Host reference for the fused join→dedup kernel.

    Mirrors the kernel exactly — including the truncation contract: pairs
    are enumerated in left-major order and only the first ``capacity``
    survive before dedup, so a truncated kernel call and this reference
    stay bit-identical.  Returns ``(out, count, total)`` as numpy.
    """
    l_keys = np.asarray(l_keys, dtype=np.int64)
    l_payload = np.asarray(l_payload, dtype=np.int64)
    r_keys = np.asarray(r_keys_sorted, dtype=np.int64)
    r_payload = np.asarray(r_payload, dtype=np.int64)
    out = np.full(capacity, _BIG, dtype=np.int32)
    if l_keys.shape[0] == 0 or r_keys.shape[0] == 0 or capacity == 0:
        return out, 0, 0
    lo = np.searchsorted(r_keys, l_keys, side="left")
    hi = np.searchsorted(r_keys, l_keys, side="right")
    pairs = []
    for i in range(l_keys.shape[0]):
        for j in range(int(lo[i]), int(hi[i])):
            pairs.append((int(l_payload[i]) << 16) | (int(r_payload[j]) & 0xFFFF))
    total = len(pairs)
    uniq = np.unique(np.asarray(pairs[:capacity], dtype=np.int32))
    out[: uniq.shape[0]] = uniq
    return out, int(uniq.shape[0]), total


def merge_sorted_unique_ref(buf, fresh):
    """Host reference for the in-place sorted-unique merge.

    ``buf`` is sorted unique padded with int32-max; ``fresh`` likewise.
    Returns ``(merged, count, n_new)`` with ``merged`` the same length
    as ``buf``.
    """
    buf = np.asarray(buf, dtype=np.int32)
    fresh = np.asarray(fresh, dtype=np.int32)
    cap = buf.shape[0]
    old = buf[buf != _BIG]
    merged = np.unique(np.concatenate([old, fresh[fresh != _BIG]]))
    out = np.full(cap, _BIG, dtype=np.int32)
    out[: min(cap, merged.shape[0])] = merged[:cap]
    return out, int(merged.shape[0]), int(merged.shape[0] - old.shape[0])
