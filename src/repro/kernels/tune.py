"""Block-size autotuner for the Pallas kernels.

The blocked kernels (``sorted_member``, ``join_bounds``, ``rle_expand``)
take ``block_*`` sizes that trade VMEM residency against grid overhead;
the right choice depends on the backend and the operand size.  This
module picks them per ``(kernel, dtype, size-bucket)`` from a one-shot
timing sweep:

* **buckets** — operand sizes are bucketed to the next power of two
  (floor 256), so one sweep covers every size in the bucket and the
  disk cache stays small,
* **sweep** — each candidate block assignment is timed best-of-3 on
  synthetic sorted operands of the bucket size (``block_until_ready``
  so device time is measured, not dispatch), and the fastest wins,
* **cache** — winners persist to a JSON file (:func:`cache_path`;
  override with ``REPRO_TUNE_CACHE``) keyed by
  ``kernel|dtype|bucket|backend``.

Invalidation rules: the file carries ``{"version", "jax"}`` — a version
bump or a jax upgrade discards the whole cache (kernel lowerings
change); the backend lives in every entry key, so a cache written on
CPU never serves a TPU process.  Corrupt or unreadable files are
treated as empty, never an error.

In interpret mode the sweep is skipped entirely and the hand-tuned
defaults are returned: timing the Python emulation would tune for the
emulator, not the hardware.  Traffic is surfaced through the
``kernels.`` metrics scope — ``kernels.tune.cache_hits`` /
``kernels.tune.sweeps`` / ``kernels.tune.defaults``.
"""

from __future__ import annotations

import json
import os
import time

from ..obs import get_registry, span
from .backend import backend_name, resolve_interpret

__all__ = [
    "CACHE_VERSION",
    "DEFAULTS",
    "cache_path",
    "clear_cache",
    "get_blocks",
    "size_bucket",
]

CACHE_VERSION = 1

#: hand-tuned fallbacks (v5e-sized VMEM tiles) — returned without a
#: sweep in interpret mode and for kernels with no registered runner
DEFAULTS: dict[str, dict[str, int]] = {
    "sorted_member": {"block_a": 512, "block_b": 1024},
    "join_bounds": {"block_l": 512, "block_r": 1024},
    "rle_expand": {"block_out": 1024},
}

#: candidate assignments swept per kernel (defaults always included)
CANDIDATES: dict[str, list[dict[str, int]]] = {
    "sorted_member": [
        {"block_a": a, "block_b": b}
        for a in (256, 512, 1024)
        for b in (512, 1024, 2048)
    ],
    "join_bounds": [
        {"block_l": a, "block_r": b}
        for a in (256, 512, 1024)
        for b in (512, 1024, 2048)
    ],
    "rle_expand": [{"block_out": b} for b in (512, 1024, 2048, 4096)],
}

_cache: dict[str, dict[str, int]] | None = None  # in-process mirror


def cache_path() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "pallas_tune.json"
    )


def size_bucket(n: int) -> int:
    """Power-of-two bucket (floor 256) a size-``n`` operand tunes in."""
    n = max(int(n), 1)
    return max(256, 1 << (n - 1).bit_length())


def _load_cache() -> dict[str, dict[str, int]]:
    global _cache
    if _cache is not None:
        return _cache
    _cache = {}
    try:
        with open(cache_path()) as fh:
            raw = json.load(fh)
        import jax

        if (
            isinstance(raw, dict)
            and raw.get("version") == CACHE_VERSION
            and raw.get("jax") == jax.__version__
        ):
            _cache = {
                k: v for k, v in raw.get("entries", {}).items()
                if isinstance(v, dict)
            }
    except (OSError, ValueError):
        pass  # missing/corrupt cache is just a cold cache
    return _cache


def _save_cache() -> None:
    import jax

    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as fh:
            json.dump(
                {
                    "version": CACHE_VERSION,
                    "jax": jax.__version__,
                    "entries": _cache or {},
                },
                fh,
                indent=2,
                sort_keys=True,
            )
    except OSError:
        pass  # read-only FS: tuning still works, it just re-sweeps


def clear_cache() -> None:
    """Drop the in-process mirror and the disk file (tests)."""
    global _cache
    _cache = None
    try:
        os.unlink(cache_path())
    except OSError:
        pass


# ------------------------------------------------------------------ #
# sweep runners: synthetic operands of the bucket size per kernel
# ------------------------------------------------------------------ #
def _runner(kernel: str, bucket: int, blocks: dict[str, int], interpret: bool):
    import jax.numpy as jnp

    if kernel == "sorted_member":
        from .sorted_member import sorted_member

        a = jnp.arange(bucket, dtype=jnp.int32) * 3
        b = jnp.arange(bucket, dtype=jnp.int32) * 2
        out = sorted_member(a, b, interpret=interpret, **blocks)
    elif kernel == "join_bounds":
        from .join_bounds import join_bounds

        a = jnp.arange(bucket, dtype=jnp.int32) * 3
        b = jnp.arange(bucket, dtype=jnp.int32) * 2
        out = join_bounds(a, b, interpret=interpret, **blocks)[0]
    elif kernel == "rle_expand":
        from .rle_expand import rle_expand

        runs = max(bucket // 8, 1)
        vals = jnp.arange(runs, dtype=jnp.int32)
        counts = jnp.full((runs,), 8, dtype=jnp.int32)
        out = rle_expand(
            vals, counts, total=runs * 8, interpret=interpret, **blocks
        )
    else:
        raise KeyError(kernel)
    out.block_until_ready()


def _sweep(kernel: str, bucket: int, interpret: bool) -> dict[str, int]:
    with span(
        "kernels.tune.sweep",
        kernel=kernel,
        bucket=bucket,
        candidates=len(CANDIDATES[kernel]),
    ) as sp:
        best_blocks, best_t = DEFAULTS[kernel], float("inf")
        for blocks in CANDIDATES[kernel]:
            try:
                _runner(kernel, bucket, blocks, interpret)  # compile + warm
                t = float("inf")
                for _ in range(3):
                    t0 = time.perf_counter()
                    _runner(kernel, bucket, blocks, interpret)
                    t = min(t, time.perf_counter() - t0)
            except Exception:  # noqa: BLE001 — an invalid tiling just loses
                continue
            if t < best_t:
                best_blocks, best_t = blocks, t
        sp.set(best=str(dict(best_blocks)), best_s=best_t)
    return dict(best_blocks)


def get_blocks(
    kernel: str,
    dtype: str = "int32",
    n: int = 0,
    *,
    interpret: bool | None = None,
) -> dict[str, int]:
    """Best-known ``block_*`` kwargs for ``kernel`` on a size-``n``
    operand — cached sweep result, or the hand-tuned defaults when
    interpreting (sweeping the emulator tunes the emulator)."""
    reg = get_registry()
    interp = resolve_interpret(interpret)
    defaults = DEFAULTS.get(kernel)
    if defaults is None:
        raise KeyError(f"no tuning table for kernel {kernel!r}")
    if interp:
        reg.counter("kernels.tune.defaults").inc()
        return dict(defaults)
    bucket = size_bucket(n)
    key = f"{kernel}|{dtype}|{bucket}|{backend_name()}"
    cache = _load_cache()
    hit = cache.get(key)
    if hit is not None:
        reg.counter("kernels.tune.cache_hits").inc()
        return dict(hit)
    blocks = _sweep(kernel, bucket, interp)
    cache[key] = blocks
    _save_cache()
    reg.counter("kernels.tune.sweeps").inc()
    return dict(blocks)
