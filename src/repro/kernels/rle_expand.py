"""RLE expansion kernel — unfolding leaf meta-constants.

The paper stores leaf meta-constants run-length encoded (``d * n``); every
join/dedup unfolds them.  A serial decoder is memory-bound and sequential;
on TPU we decode positionally: output element ``i`` belongs to the first
run whose cumulative end exceeds ``i``, i.e. ``run(i) = #{k : ends[k] <= i}``
— a broadcast compare-and-sum per output tile, followed by a gather of the
run values (on TPU the gather can be expressed as a one-hot matmul to run
on the MXU; ``jnp.take`` lowers to the native gather here).

The run table (ends + values) is replicated into VMEM for every output
tile: with the default 16 MiB VMEM budget that caps the table at ~1M runs
per call; ``repro.kernels.ops.rle_expand`` chunks larger tables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import resolve_interpret

DEFAULT_BLOCK_OUT = 1024
_END_SENTINEL = jnp.iinfo(jnp.int32).max


def _rle_kernel(ends_ref, vals_ref, o_ref, *, block_out: int):
    i = pl.program_id(0)
    idx = i * block_out + jax.lax.iota(jnp.int32, block_out)
    ends = ends_ref[...]
    vals = vals_ref[...]
    # run index of each output position: number of run-ends <= idx
    run = jnp.sum(
        (ends[None, :] <= idx[:, None]).astype(jnp.int32), axis=1
    )
    run = jnp.minimum(run, vals.shape[0] - 1)
    o_ref[...] = jnp.take(vals, run)


def rle_expand(
    run_values: jax.Array,
    run_counts: jax.Array,
    *,
    total: int,
    block_out: int = DEFAULT_BLOCK_OUT,
    interpret: bool | None = None,
) -> jax.Array:
    """Expand RLE runs into ``total`` output elements.

    ``total`` must equal ``run_counts.sum()`` (static, host-known — meta-
    constant lengths are part of the representation).
    ``interpret=None`` resolves per backend/env outside the jit.
    """
    return _rle_expand_jit(
        run_values,
        run_counts,
        total=total,
        block_out=block_out,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(
    jax.jit, static_argnames=("total", "block_out", "interpret")
)
def _rle_expand_jit(
    run_values: jax.Array,
    run_counts: jax.Array,
    *,
    total: int,
    block_out: int,
    interpret: bool,
) -> jax.Array:
    r = run_values.shape[0]
    if total == 0 or r == 0:
        return jnp.zeros((0,), dtype=jnp.int32)
    ends = jnp.cumsum(run_counts.astype(jnp.int32))
    n_pad = -total % block_out
    out_len = total + n_pad
    ends_p = ends  # replicated whole per tile
    vals_p = run_values.astype(jnp.int32)
    grid = (out_len // block_out,)
    out = pl.pallas_call(
        functools.partial(_rle_kernel, block_out=block_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r,), lambda i: (0,)),
            pl.BlockSpec((r,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_out,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((out_len,), jnp.int32),
        interpret=interpret,
    )(ends_p, vals_p)
    return out[:total]
