"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; the kernel
bodies execute in Python for validation).  On TPU pass
``interpret=False`` — BlockSpecs are already VMEM-tiled for v5e.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .join_bounds import join_bounds as _join_bounds
from .rle_expand import rle_expand as _rle_expand
from .sorted_member import sorted_member as _sorted_member

__all__ = ["member", "anti_join_mask", "expand_rle", "group_spans"]


def member(a, b_sorted, *, interpret: bool = True, **blocks) -> jax.Array:
    """``out[i] = a[i] in b_sorted`` (semi-join filter)."""
    return _sorted_member(
        jnp.asarray(a), jnp.asarray(b_sorted), interpret=interpret, **blocks
    )


def anti_join_mask(new, old_sorted, *, interpret: bool = True, **blocks):
    """Mask of ``new`` elements NOT in ``old_sorted`` (the dedup test of
    Algorithm 6)."""
    return ~member(new, old_sorted, interpret=interpret, **blocks)


def expand_rle(run_values, run_counts, total: int, *, interpret: bool = True,
               **blocks):
    """Unfold an RLE leaf meta-constant into ``total`` constants."""
    return _rle_expand(
        jnp.asarray(run_values),
        jnp.asarray(run_counts),
        total=int(total),
        interpret=interpret,
        **blocks,
    )


def group_spans(l_keys, r_sorted, *, interpret: bool = True, **blocks):
    """Per-left-key [lo, hi) spans in the sorted right keys — the
    cross-join group locator of Algorithm 5."""
    return _join_bounds(
        jnp.asarray(l_keys), jnp.asarray(r_sorted), interpret=interpret, **blocks
    )
