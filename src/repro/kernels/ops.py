"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to ``None`` — "interpret exactly when the jax
backend is CPU", overridable with ``REPRO_PALLAS_INTERPRET`` (see
:mod:`repro.kernels.backend`) — so the same call sites compile the real
Mosaic kernels on TPU/GPU.  When no explicit ``block_*`` sizes are
passed the blocked kernels take them from the autotuner
(:mod:`repro.kernels.tune`): hand-tuned defaults in interpret mode,
cached sweep winners on hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..obs import get_registry
from .backend import resolve_interpret
from .fused import fused_join_dedup as _fused_join_dedup
from .fused import merge_sorted_unique as _merge_sorted_unique
from .join_bounds import join_bounds as _join_bounds
from .rle_expand import rle_expand as _rle_expand
from .sorted_member import sorted_member as _sorted_member
from .tune import get_blocks

__all__ = [
    "member",
    "anti_join_mask",
    "expand_rle",
    "group_spans",
    "join_dedup",
    "launch_count",
    "merge_unique",
    "meter",
    "meter_reset",
]

# kernel-launch metering lives in the metrics registry under the
# ``kernels.`` scope (``kernels.<op>.calls`` / ``kernels.<op>.elements``,
# plus the cross-op ``kernels.kernel_launches`` total that the bench
# gate watches) — cheap host-side counters so benchmarks and the serving
# driver can report how much work the device path absorbed, resettable
# per scope without clobbering anyone else's metrics.  Counts *eager*
# launches only: inside a jit trace the Python side effect would fire
# once per trace, not per execution, so traced calls are excluded rather
# than silently underreported.
_SCOPE = "kernels."


def _metered(op: str, n, operand=None, launches: int = 1) -> None:
    if isinstance(operand, jax.core.Tracer):
        return
    reg = get_registry()
    reg.counter(f"{_SCOPE}{op}.calls").inc()
    reg.counter(f"{_SCOPE}{op}.elements").inc(int(n))
    reg.counter(f"{_SCOPE}kernel_launches").inc(launches)


def meter() -> dict[str, dict[str, int]]:
    """Snapshot of per-op kernel traffic since the last reset (the
    legacy ``{op: {"calls", "elements"}}`` shape, reassembled from the
    registry's ``kernels.`` scope)."""
    out: dict[str, dict[str, int]] = {}
    for name, val in get_registry().snapshot(_SCOPE).items():
        rest = name[len(_SCOPE):]
        if "." not in rest:
            continue  # scope-level totals (kernel_launches) and gauges
        op, field = rest.rsplit(".", 1)
        if field not in ("calls", "elements"):
            continue
        out.setdefault(op, {"calls": 0, "elements": 0})[field] = int(val)
    # registry reset zeroes in place; drop untouched ops so the dict
    # looks exactly like the legacy meter after meter_reset()
    return {op: m for op, m in out.items() if m["calls"]}


def launch_count() -> int:
    """Total eager kernel launches since the last ``kernels.`` reset."""
    snap = get_registry().snapshot(_SCOPE)
    return int(snap.get(f"{_SCOPE}kernel_launches", 0))


def meter_reset() -> None:
    """Zero the ``kernels.`` registry scope only (other scopes keep
    accumulating — per-scope reset is the whole point)."""
    get_registry().reset(_SCOPE)


def _blocks_for(kernel: str, n: int, interpret, blocks: dict) -> dict:
    """Caller-supplied ``block_*`` win; otherwise ask the autotuner."""
    if blocks:
        return blocks
    return get_blocks(kernel, "int32", n, interpret=interpret)


def member(a, b_sorted, *, interpret: bool | None = None, **blocks) -> jax.Array:
    """``out[i] = a[i] in b_sorted`` (semi-join filter)."""
    a = jnp.asarray(a)
    _metered("member", a.size, a)
    interpret = resolve_interpret(interpret)
    blocks = _blocks_for("sorted_member", a.size, interpret, blocks)
    return _sorted_member(a, jnp.asarray(b_sorted), interpret=interpret, **blocks)


def anti_join_mask(new, old_sorted, *, interpret: bool | None = None, **blocks):
    """Mask of ``new`` elements NOT in ``old_sorted`` (the dedup test of
    Algorithm 6)."""
    return ~member(new, old_sorted, interpret=interpret, **blocks)


def expand_rle(run_values, run_counts, total: int, *,
               interpret: bool | None = None, **blocks):
    """Unfold an RLE leaf meta-constant into ``total`` constants."""
    _metered("expand_rle", int(total), run_values)
    interpret = resolve_interpret(interpret)
    blocks = _blocks_for("rle_expand", int(total), interpret, blocks)
    return _rle_expand(
        jnp.asarray(run_values),
        jnp.asarray(run_counts),
        total=int(total),
        interpret=interpret,
        **blocks,
    )


def group_spans(l_keys, r_sorted, *, interpret: bool | None = None, **blocks):
    """Per-left-key [lo, hi) spans in the sorted right keys — the
    cross-join group locator of Algorithm 5."""
    l_keys = jnp.asarray(l_keys)
    _metered("group_spans", l_keys.size, l_keys)
    interpret = resolve_interpret(interpret)
    blocks = _blocks_for("join_bounds", l_keys.size, interpret, blocks)
    return _join_bounds(
        l_keys, jnp.asarray(r_sorted), interpret=interpret, **blocks
    )


def join_dedup(l_keys, l_payload, r_keys_sorted, r_payload, *,
               capacity: int, interpret: bool | None = None):
    """Fused span-probe → gather → sort → dedup, **one** launch (vs the
    unfused ``group_spans`` + gather + sort + ``member`` chain).  See
    :func:`repro.kernels.fused.fused_join_dedup` for the contract."""
    l_keys = jnp.asarray(l_keys)
    _metered("join_dedup", l_keys.size, l_keys)
    return _fused_join_dedup(
        l_keys,
        jnp.asarray(l_payload),
        jnp.asarray(r_keys_sorted),
        jnp.asarray(r_payload),
        capacity=capacity,
        interpret=resolve_interpret(interpret),
    )


def merge_unique(buf, fresh, *, interpret: bool | None = None):
    """Fused in-place sorted-unique merge, one launch (vs the unfused
    anti-join + concatenate + re-sort chain).  Buffer-donating rounds
    should go through :class:`repro.kernels.buffers.FactBuffers`."""
    fresh = jnp.asarray(fresh)
    _metered("merge_unique", fresh.size, fresh)
    return _merge_sorted_unique(
        jnp.asarray(buf), fresh, interpret=resolve_interpret(interpret)
    )
