"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; the kernel
bodies execute in Python for validation).  On TPU pass
``interpret=False`` — BlockSpecs are already VMEM-tiled for v5e.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..obs import get_registry
from .join_bounds import join_bounds as _join_bounds
from .rle_expand import rle_expand as _rle_expand
from .sorted_member import sorted_member as _sorted_member

__all__ = [
    "member",
    "anti_join_mask",
    "expand_rle",
    "group_spans",
    "meter",
    "meter_reset",
]

# kernel-launch metering lives in the metrics registry under the
# ``kernels.`` scope (``kernels.<op>.calls`` / ``kernels.<op>.elements``)
# — cheap host-side counters so benchmarks and the serving driver can
# report how much work the device path absorbed, resettable per scope
# without clobbering anyone else's metrics.  Counts *eager* launches
# only: inside a jit trace the Python side effect would fire once per
# trace, not per execution, so traced calls are excluded rather than
# silently underreported.
_SCOPE = "kernels."


def _metered(op: str, n, operand=None) -> None:
    if isinstance(operand, jax.core.Tracer):
        return
    reg = get_registry()
    reg.counter(f"{_SCOPE}{op}.calls").inc()
    reg.counter(f"{_SCOPE}{op}.elements").inc(int(n))


def meter() -> dict[str, dict[str, int]]:
    """Snapshot of per-op kernel traffic since the last reset (the
    legacy ``{op: {"calls", "elements"}}`` shape, reassembled from the
    registry's ``kernels.`` scope)."""
    out: dict[str, dict[str, int]] = {}
    for name, val in get_registry().snapshot(_SCOPE).items():
        op, field = name[len(_SCOPE):].rsplit(".", 1)
        out.setdefault(op, {"calls": 0, "elements": 0})[field] = int(val)
    # registry reset zeroes in place; drop untouched ops so the dict
    # looks exactly like the legacy meter after meter_reset()
    return {op: m for op, m in out.items() if m["calls"]}


def meter_reset() -> None:
    """Zero the ``kernels.`` registry scope only (other scopes keep
    accumulating — per-scope reset is the whole point)."""
    get_registry().reset(_SCOPE)


def member(a, b_sorted, *, interpret: bool = True, **blocks) -> jax.Array:
    """``out[i] = a[i] in b_sorted`` (semi-join filter)."""
    a = jnp.asarray(a)
    _metered("member", a.size, a)
    return _sorted_member(a, jnp.asarray(b_sorted), interpret=interpret, **blocks)


def anti_join_mask(new, old_sorted, *, interpret: bool = True, **blocks):
    """Mask of ``new`` elements NOT in ``old_sorted`` (the dedup test of
    Algorithm 6)."""
    return ~member(new, old_sorted, interpret=interpret, **blocks)


def expand_rle(run_values, run_counts, total: int, *, interpret: bool = True,
               **blocks):
    """Unfold an RLE leaf meta-constant into ``total`` constants."""
    _metered("expand_rle", int(total), run_values)
    return _rle_expand(
        jnp.asarray(run_values),
        jnp.asarray(run_counts),
        total=int(total),
        interpret=interpret,
        **blocks,
    )


def group_spans(l_keys, r_sorted, *, interpret: bool = True, **blocks):
    """Per-left-key [lo, hi) spans in the sorted right keys — the
    cross-join group locator of Algorithm 5."""
    l_keys = jnp.asarray(l_keys)
    _metered("group_spans", l_keys.size, l_keys)
    return _join_bounds(
        l_keys, jnp.asarray(r_sorted), interpret=interpret, **blocks
    )
