"""Per-predicate fact buffers with watermarks — the donation layer.

The distributed engine keeps each shard's facts as ``(rows, count,
delta_lo)``: a padded buffer plus watermarks.  This module generalises
that shape for every engine:

* **host mode** (default) — facts are sorted-unique packed **int64**
  codes in exact-size numpy arrays.  :meth:`FactBuffers.fresh_mask` is
  API-compatible with ``core.dedup.DedupIndex`` (so ``CMatEngine`` can
  take either), but survivors are folded in with the positional
  ``merge_sorted_unique_np`` instead of a full re-sort per round.
* **device mode** (``device=True``) — facts are sorted-unique packed
  **int32** codes (the 16-bit-halves pack of
  ``core.distributed.pack_pairs``) in ``BIG``-padded device buffers of
  power-of-two capacity, with a host-tracked ``count`` watermark.
  Each round's fresh codes are folded in by the ``merge_sorted_unique``
  Pallas kernel with the buffer **donated**
  (``jax.jit(..., donate_argnums=(0,))`` + ``input_output_aliases``),
  so XLA rewrites the merge into the existing allocation: a
  steady-state round allocates **nothing**.

Watermark invariants (device mode):

1. ``buf[:count]`` is strictly increasing (sorted unique); every slot
   at or beyond ``count`` holds ``BIG``.
2. ``count <= capacity`` and ``capacity`` is a multiple of 128.
3. Regrow happens *before* the donating merge — donation invalidates
   the input buffer, so an overflowing merge could not be retried.
   :meth:`merge` therefore regrows whenever ``count + len(fresh)``
   might exceed capacity, making kernel-side overflow unreachable.

Traffic is metered in the ``kernels.`` scope:
``kernels.buffers.allocations`` (buffer (re)allocations — flat in
steady state, the donation test's assertion), ``.regrows``,
``.merges``, and ``.rows_merged``.
"""

from __future__ import annotations

import numpy as np

from ..core.util import (
    first_occurrence_mask,
    merge_sorted_unique_np,
    sorted_member,
)
from ..obs import get_registry
from ..obs.memory import register_reporter

__all__ = ["FactBuffers", "BIG_NP"]

_SCOPE = "kernels.buffers."

#: numpy view of the device pad sentinel (int32 max)
BIG_NP = np.int32(np.iinfo(np.int32).max)

_MIN_CAPACITY = 128


def _round_capacity(n: int) -> int:
    """Next power of two >= n (floor 128) — doubling keeps the number of
    regrows logarithmic and jit retraces bounded."""
    n = max(int(n), _MIN_CAPACITY)
    return 1 << (n - 1).bit_length()


class FactBuffers:
    """Sorted per-predicate fact code buffers (host or device resident)."""

    def __init__(
        self,
        *,
        device: bool = False,
        interpret: bool | None = None,
        donate: bool | None = None,
        initial_capacity: int = 1024,
    ):
        self.device = bool(device)
        self._initial_capacity = _round_capacity(initial_capacity)
        self._reg = get_registry()
        # per-instance regrow history + peak-occupancy watermark
        # (obs.memory: capacity vs occupancy is the padding waste the
        # power-of-two policy trades for bounded retraces)
        self.regrows = 0
        self._peak_occupied_bytes = 0
        register_reporter("buffers", self)
        if self.device:
            from .backend import backend_name, resolve_interpret

            self.interpret = resolve_interpret(interpret)
            # donation is a no-op (with a warning) on CPU; default it to
            # the backends that honour it, overridable for tests
            self.donate = (
                backend_name() != "cpu" if donate is None else bool(donate)
            )
            self._buf: dict[str, object] = {}  # pred -> jax.Array
            self._count: dict[str, int] = {}
        else:
            self._codes: dict[str, np.ndarray] = {}

    # ------------------------------------------------------------------ #
    # byte accounting (obs.memory reporter protocol)
    # ------------------------------------------------------------------ #
    def occupied_bytes(self) -> int:
        """Bytes of live codes (device: below the watermark)."""
        if self.device:
            return 4 * sum(self._count.values())
        return sum(int(c.nbytes) for c in self._codes.values())

    def capacity_bytes(self) -> int:
        """Bytes allocated (device: BIG-padded power-of-two buffers;
        host: exact-size arrays, so capacity == occupancy)."""
        if self.device:
            return sum(int(b.nbytes) for b in self._buf.values())
        return self.occupied_bytes()

    def _note_occupancy(self) -> None:
        occ = self.occupied_bytes()
        if occ > self._peak_occupied_bytes:
            self._peak_occupied_bytes = occ

    def memory_report(self) -> dict[str, int]:
        """Disjoint parts — ``occupied + padding == capacity`` — plus
        the peak-occupancy watermark and regrow history as auxiliaries
        (non-``_bytes`` keys stay out of the resident roll-up)."""
        occ = self.occupied_bytes()
        cap = self.capacity_bytes()
        self._note_occupancy()
        return {
            "occupied_bytes": occ,
            "padding_bytes": cap - occ,
            "peak_occupied": self._peak_occupied_bytes,
            "regrows": self.regrows,
            "n_predicates": len(self._buf if self.device else self._codes),
        }

    # ------------------------------------------------------------------ #
    # host mode: DedupIndex-compatible surface over int64 packed codes
    # ------------------------------------------------------------------ #
    @staticmethod
    def pack(rows: np.ndarray) -> np.ndarray | None:
        """Row pack (same contract as ``DedupIndex.pack``): arity-1 is
        the id, arity-2 is ``(a << 32) | b``; wider rows return None and
        the caller falls back to joint factorisation."""
        if rows.shape[1] == 1:
            return rows[:, 0].astype(np.int64)
        if rows.shape[1] == 2:
            return (rows[:, 0].astype(np.int64) << 32) | rows[:, 1].astype(
                np.int64
            )
        return None

    def seed(self, pred: str, rows: np.ndarray) -> None:
        """Fold already-known facts in without producing a mask."""
        packed = self.pack(rows)
        if packed is None:
            return
        existing = self._codes.get(pred)
        merged = packed if existing is None else np.concatenate(
            [existing, packed]
        )
        self._codes[pred] = np.unique(merged)
        self._note_occupancy()

    def fresh_mask(self, pred: str, rows: np.ndarray) -> np.ndarray | None:
        """Keep-mask over ``rows``: not already buffered AND first
        occurrence in the block; survivors are merged in.  None when the
        arity is unpackable (caller falls back to factorisation)."""
        packed = self.pack(rows)
        if packed is None:
            return None
        index = self._codes.get(pred)
        if index is None or index.shape[0] == 0:
            not_in = np.ones(rows.shape[0], dtype=bool)
        else:
            not_in = sorted_member(packed, index)
            np.logical_not(not_in, out=not_in)
        keep = not_in & first_occurrence_mask(packed)
        survivors = packed[keep]
        if survivors.shape[0]:
            survivors = np.sort(survivors)
            self._codes[pred] = (
                survivors
                if index is None
                else merge_sorted_unique_np(index, survivors)
            )
            self._note_occupancy()
        return keep

    def codes(self, pred: str) -> np.ndarray:
        if self.device:
            buf = self._buf.get(pred)
            if buf is None:
                return np.zeros(0, dtype=np.int32)
            return np.asarray(buf)[: self._count[pred]]
        return self._codes.get(pred, np.zeros(0, dtype=np.int64))

    def count(self, pred: str) -> int:
        if self.device:
            return self._count.get(pred, 0)
        codes = self._codes.get(pred)
        return 0 if codes is None else int(codes.shape[0])

    def predicates(self) -> list[str]:
        return sorted(self._buf if self.device else self._codes)

    # ------------------------------------------------------------------ #
    # device mode: BIG-padded int32 buffers + donated Pallas merge
    # ------------------------------------------------------------------ #
    def capacity(self, pred: str) -> int:
        buf = self._buf.get(pred)
        return 0 if buf is None else int(buf.shape[0])

    def _alloc(self, pred: str, capacity: int):
        import jax.numpy as jnp

        cap = _round_capacity(capacity)
        old = self._buf.get(pred)
        buf = jnp.full((cap,), BIG_NP, dtype=jnp.int32)
        if old is not None:
            buf = buf.at[: old.shape[0]].set(old)
            self.regrows += 1
            self._reg.counter(f"{_SCOPE}regrows").inc()
        self._buf[pred] = buf
        self._count.setdefault(pred, 0)
        self._reg.counter(f"{_SCOPE}allocations").inc()
        return buf

    def ensure(self, pred: str, min_capacity: int | None = None):
        """Device buffer for ``pred``, (re)allocated to hold at least
        ``min_capacity`` codes (invariant 3: grow before merging)."""
        if not self.device:
            raise RuntimeError("ensure() is device-mode only")
        need = self._initial_capacity if min_capacity is None else min_capacity
        buf = self._buf.get(pred)
        if buf is None or buf.shape[0] < need:
            buf = self._alloc(pred, need)
        return buf

    def merge(self, pred: str, fresh) -> int:
        """Merge a round's fresh sorted-unique code block (BIG-padded or
        exact, e.g. a ``fused_join_dedup`` output) into ``pred``'s
        buffer via the donated in-place kernel.  Returns the number of
        genuinely new codes."""
        if not self.device:
            raise RuntimeError("merge() is device-mode only")
        import jax.numpy as jnp

        from .fused import merge_sorted_unique, merge_sorted_unique_donating

        fresh = jnp.asarray(fresh, dtype=jnp.int32)
        count = self._count.get(pred, 0)
        buf = self.ensure(pred, count + int(fresh.shape[0]))
        if self.donate:
            merged, cnt, n_new = merge_sorted_unique_donating(
                buf, fresh, interpret=self.interpret
            )
        else:
            merged, cnt, n_new = merge_sorted_unique(
                buf, fresh, interpret=self.interpret
            )
        # the donated handle is dead from here on — overwrite it
        self._buf[pred] = merged
        new_count = int(cnt[0])
        assert new_count <= merged.shape[0], "merge overflowed capacity"
        self._count[pred] = new_count
        self._note_occupancy()
        self._reg.counter(f"{_SCOPE}merges").inc()
        self._reg.counter(f"{_SCOPE}rows_merged").inc(int(fresh.shape[0]))
        self._reg.counter("kernels.kernel_launches").inc()
        return int(n_new[0])
