"""Join-bounds kernel — the cross-join (Algorithm 5) group locator.

For every left key the cross-join needs the span ``[lo, hi)`` of matching
rows in the key-sorted right side:

    lo[i] = #{k : r[k] <  l[i]}        hi[i] = #{k : r[k] <= l[i]}

A serial merge computes these with two pointers; on TPU we accumulate the
counts blockwise over the sorted right side, with a three-way prune per
(left-tile x right-block):

* ``rmax <  lmin``  -> the whole block is below the tile: add BLOCK to
  both counters without comparing,
* ``rmin >  lmax``  -> the whole block is above: skip entirely,
* otherwise        -> one broadcast compare (VPU).

For sorted inputs only O(1) blocks per tile take the compare path, so the
work is O(n + m) with machine-width parallelism — this is the paper's
merge retimed for a vector unit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import resolve_interpret

DEFAULT_BLOCK_L = 512
DEFAULT_BLOCK_R = 1024
_SENTINEL = jnp.iinfo(jnp.int32).max


def _bounds_kernel(l_ref, r_ref, lo_ref, hi_ref, *, block_r: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)

    l = l_ref[...]
    r = r_ref[...]
    rmin, rmax = r[0], r[-1]
    lmin, lmax = jnp.min(l), jnp.max(l)

    @pl.when(rmax < lmin)
    def _all_below():
        lo_ref[...] += block_r
        hi_ref[...] += block_r

    @pl.when(jnp.logical_and(rmax >= lmin, rmin <= lmax))
    def _compare():
        lo_ref[...] += jnp.sum(
            (r[None, :] < l[:, None]).astype(jnp.int32), axis=1
        )
        hi_ref[...] += jnp.sum(
            (r[None, :] <= l[:, None]).astype(jnp.int32), axis=1
        )


def join_bounds(
    l_keys: jax.Array,
    r_sorted: jax.Array,
    *,
    block_l: int = DEFAULT_BLOCK_L,
    block_r: int = DEFAULT_BLOCK_R,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Return (lo, hi) spans of each left key in the sorted right keys.

    ``interpret=None`` resolves per backend/env outside the jit."""
    return _join_bounds_jit(
        l_keys,
        r_sorted,
        block_l=block_l,
        block_r=block_r,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(
    jax.jit, static_argnames=("block_l", "block_r", "interpret")
)
def _join_bounds_jit(
    l_keys: jax.Array,
    r_sorted: jax.Array,
    *,
    block_l: int,
    block_r: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    n, m = l_keys.shape[0], r_sorted.shape[0]
    if n == 0:
        z = jnp.zeros((0,), dtype=jnp.int32)
        return z, z
    if m == 0:
        z = jnp.zeros((n,), dtype=jnp.int32)
        return z, z
    n_pad = -n % block_l
    m_pad = -m % block_r
    l_p = jnp.pad(l_keys.astype(jnp.int32), (0, n_pad), constant_values=_SENTINEL)
    r_p = jnp.pad(
        r_sorted.astype(jnp.int32), (0, m_pad), constant_values=_SENTINEL
    )
    grid = (l_p.shape[0] // block_l, r_p.shape[0] // block_r)
    lo, hi = pl.pallas_call(
        functools.partial(_bounds_kernel, block_r=block_r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_l,), lambda i, j: (i,)),
            pl.BlockSpec((block_r,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_l,), lambda i, j: (i,)),
            pl.BlockSpec((block_l,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l_p.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((l_p.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(l_p, r_p)
    return lo[:n], hi[:n]
