"""Pallas TPU kernels for CompMat's hot spots (semi-join membership,
RLE unfolding, cross-join span location) with pure-jnp oracles.

Public surface: :mod:`ops` (jit'd kernel wrappers), :mod:`ref` (oracles),
and :func:`in_set` (the numpy/Pallas membership dispatch used by the
query executor).  The jax-backed submodules load lazily (PEP 562) so
numpy-only consumers — the host query executor, the serving driver —
never pay the jax import; the kernel functions themselves live in their
submodules (``kernels.sorted_member.sorted_member`` etc.) and are
re-exported through :mod:`ops`.
"""

import importlib

from .lookup import in_set

__all__ = ["in_set", "ops", "ref"]

_LAZY_MODULES = (
    "backend",
    "buffers",
    "fused",
    "join_bounds",
    "lookup",
    "ops",
    "ref",
    "rle_expand",
    "sorted_member",
    "tune",
)


def __getattr__(name):
    if name in _LAZY_MODULES:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | set(_LAZY_MODULES))
