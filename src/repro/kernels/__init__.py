"""Pallas TPU kernels for CompMat's hot spots (semi-join membership,
RLE unfolding, cross-join span location) with pure-jnp oracles."""

from . import ops, ref
from .join_bounds import join_bounds
from .rle_expand import rle_expand
from .sorted_member import sorted_member

__all__ = ["join_bounds", "ops", "ref", "rle_expand", "sorted_member"]
