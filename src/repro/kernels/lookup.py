"""Constant-bound lookup filter for the query executor.

Query plans filter candidate rows by constant equality / set membership.
On the host that is a ``searchsorted`` membership test; on TPU the same
test is the block-pruned :mod:`sorted_member` Pallas kernel (serial
binary search does not vectorise, brute-force compare with sorted-block
pruning does — see that module's header).  ``in_set`` dispatches between
the two so the executor has a single entry point.
"""

from __future__ import annotations

import numpy as np

__all__ = ["in_set"]


def in_set(
    values: np.ndarray,
    constants: np.ndarray,
    *,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> np.ndarray:
    """Boolean mask ``values[i] in constants``.

    ``use_pallas=True`` routes through the ``sorted_member`` Pallas
    kernel; ``interpret=None`` resolves per backend/env (see
    :mod:`repro.kernels.backend`).  The numpy path is the default for
    the host-only serving driver.
    """
    values = np.asarray(values, dtype=np.int64)
    constants = np.asarray(constants, dtype=np.int64)
    if values.shape[0] == 0 or constants.shape[0] == 0:
        return np.zeros(values.shape[0], dtype=bool)
    sorted_constants = np.sort(constants)
    if use_pallas:
        from .ops import _metered
        from .sorted_member import sorted_member as _pallas_member

        _metered("in_set", values.size)
        return np.asarray(
            _pallas_member(values, sorted_constants, interpret=interpret)
        )
    from ..core.util import sorted_member as _np_member

    return _np_member(values, sorted_constants)
