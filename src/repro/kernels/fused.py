"""Fused fixpoint kernels: join→dedup and sorted-buffer merge, one launch each.

The per-round hot path of every engine is the same chain: locate join
spans (``join_bounds``), enumerate the matching pairs (gather), pack
them, sort, and drop duplicates — historically four separate launches
with a host round-trip for the ``np.unique`` in the middle.  These two
kernels fuse the chain so a round's derivation traffic never leaves the
device:

* :func:`fused_join_dedup` — span probe → pair enumeration → 16-bit
  pack → sort → adjacent-unique mask → compaction, in **one**
  ``pallas_call``.  Output is the sorted-unique packed pair set, padded
  to a static ``capacity`` with :data:`BIG`; the true pair total is
  returned so the caller can regrow and retry when ``capacity`` was too
  small (the same doubling contract as the distributed exchange).
* :func:`merge_sorted_unique` — merge a round's fresh sorted-unique
  codes into the per-predicate sorted buffer **in place**
  (``input_output_aliases`` + a donating jit variant), so steady-state
  rounds reuse the same device allocation (see :mod:`.buffers`).

Value contract (identical to ``core.distributed.pack_pairs``): all ids
are non-negative int32; packed pairs are ``(hi << 16) | (lo & 0xffff)``
with the high half below ``2**15``, so every packed code is in
``[0, 2**31)`` and :data:`BIG` (int32 max) is a safe pad sentinel.

Both kernels are single-program launches holding their operands in VMEM
(the pair-enumeration broadcast is O(capacity x n_left)); callers cap
per-call sizes at a few thousand rows and chunk above that — one launch
per chunk still beats the four-launch chain per chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import resolve_interpret

__all__ = [
    "BIG",
    "fused_join_dedup",
    "merge_sorted_unique",
    "merge_sorted_unique_donating",
]

#: pad sentinel: larger than any packed code, so sorting moves padding
#: to the tail and adjacent-unique masks never count it
BIG = jnp.iinfo(jnp.int32).max

_LANE = 128  # pad operands to lane multiples so TPU layouts stay happy


def _pad_to(x: jax.Array, n: int) -> jax.Array:
    return jnp.pad(x.astype(jnp.int32), (0, n - x.shape[0]), constant_values=BIG)


def _round_up(n: int, mult: int = _LANE) -> int:
    return max(mult, -(-n // mult) * mult)


# --------------------------------------------------------------------- #
# fused join → dedup
# --------------------------------------------------------------------- #
def _fused_join_dedup_kernel(
    l_ref, lp_ref, r_ref, rp_ref, o_ref, cnt_ref, tot_ref, *, capacity: int
):
    l = l_ref[...]
    lp = lp_ref[...]
    r = r_ref[...]
    rp = rp_ref[...]
    cap = o_ref.shape[0]  # lane-padded >= capacity

    # --- span probe (join_bounds, inlined): r is sorted, so the span of
    # l[i] is [#(r < l[i]), #(r <= l[i])).  BIG pads in r sort above every
    # real key; BIG pads in l are masked out below.
    lo = jnp.sum((r[None, :] < l[:, None]).astype(jnp.int32), axis=1)
    hi = jnp.sum((r[None, :] <= l[:, None]).astype(jnp.int32), axis=1)
    valid_l = l != BIG
    cnt = jnp.where(valid_l, hi - lo, 0)

    # --- pair enumeration: pair t belongs to the left row whose
    # exclusive offset is the largest one <= t (broadcast count instead
    # of searchsorted — Mosaic-safe, and zero-count rows resolve to the
    # last index of their offset tie-run, which is the producing row).
    offs = jnp.cumsum(cnt) - cnt
    total = jnp.sum(cnt)
    t = jax.lax.broadcasted_iota(jnp.int32, (cap, 1), 0)[:, 0]
    li = jnp.sum((offs[None, :] <= t[:, None]).astype(jnp.int32), axis=1) - 1
    li = jnp.clip(li, 0, l.shape[0] - 1)
    rj = jnp.clip(lo[li] + (t - offs[li]), 0, r.shape[0] - 1)
    # truncate at the *caller-visible* capacity, not the lane-padded
    # buffer size, so the numpy reference can mirror the contract
    valid = (t < total) & (t < capacity)

    # --- pack → sort → adjacent-unique → compact.  The second sort is
    # the scatter-free compaction trick: masked-out slots become BIG and
    # sort to the tail, leaving the unique codes sorted at the front.
    packed = jnp.where(valid, (lp[li] << 16) | (rp[rj] & 0xFFFF), BIG)
    s = jnp.sort(packed)
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s[:-1]])
    uniq = (s != BIG) & (s != prev)
    o_ref[...] = jnp.sort(jnp.where(uniq, s, BIG))
    cnt_ref[0] = jnp.sum(uniq.astype(jnp.int32))
    tot_ref[0] = total


def fused_join_dedup(
    l_keys: jax.Array,
    l_payload: jax.Array,
    r_keys_sorted: jax.Array,
    r_payload: jax.Array,
    *,
    capacity: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Join ``l`` against sorted ``r`` on key and emit the deduplicated
    packed pairs ``(l_payload << 16) | r_payload`` — one kernel launch.

    Returns ``(out, count, total)``: ``out`` is ``(capacity,)`` int32,
    sorted unique, padded with :data:`BIG`; ``count`` the number of
    unique pairs kept; ``total`` the pre-dedup pair count.  When
    ``total > capacity`` the enumeration was truncated — regrow
    ``capacity`` to ``>= total`` and call again (results for the
    truncated call cover exactly the first ``capacity`` pairs in
    left-major order, which the numpy reference mirrors).
    ``interpret=None`` resolves per backend/env outside the jit.
    """
    return _fused_join_dedup_jit(
        l_keys,
        l_payload,
        r_keys_sorted,
        r_payload,
        capacity=capacity,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def _fused_join_dedup_jit(
    l_keys: jax.Array,
    l_payload: jax.Array,
    r_keys_sorted: jax.Array,
    r_payload: jax.Array,
    *,
    capacity: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    n, m = l_keys.shape[0], r_keys_sorted.shape[0]
    one = jax.ShapeDtypeStruct((1,), jnp.int32)
    if n == 0 or m == 0 or capacity == 0:
        return (
            jnp.full((capacity,), BIG, jnp.int32),
            jnp.zeros((1,), jnp.int32),
            jnp.zeros((1,), jnp.int32),
        )
    n_p, m_p, cap_p = _round_up(n), _round_up(m), _round_up(capacity)
    out, cnt, tot = pl.pallas_call(
        functools.partial(_fused_join_dedup_kernel, capacity=capacity),
        out_shape=[
            jax.ShapeDtypeStruct((cap_p,), jnp.int32),
            one,
            one,
        ],
        interpret=interpret,
    )(
        _pad_to(l_keys, n_p),
        _pad_to(l_payload, n_p),
        _pad_to(r_keys_sorted, m_p),
        _pad_to(r_payload, m_p),
    )
    return out[:capacity], cnt, tot


# --------------------------------------------------------------------- #
# in-place sorted-unique merge
# --------------------------------------------------------------------- #
def _merge_kernel(buf_ref, fresh_ref, o_ref, cnt_ref, new_ref):
    b = buf_ref[...]
    f = fresh_ref[...]
    cap = o_ref.shape[0]
    s = jnp.sort(jnp.concatenate([b, f]))
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), s[:-1]])
    uniq = (s != BIG) & (s != prev)
    o_ref[...] = jnp.sort(jnp.where(uniq, s, BIG))[:cap]
    n_after = jnp.sum(uniq.astype(jnp.int32))
    cnt_ref[0] = n_after
    new_ref[0] = n_after - jnp.sum((b != BIG).astype(jnp.int32))


def _merge_impl(
    buf: jax.Array, fresh: jax.Array, *, interpret: bool
) -> tuple[jax.Array, jax.Array, jax.Array]:
    cap = buf.shape[0]
    f_p = _round_up(fresh.shape[0]) if fresh.shape[0] else _LANE
    one = jax.ShapeDtypeStruct((1,), jnp.int32)
    return pl.pallas_call(
        _merge_kernel,
        out_shape=[jax.ShapeDtypeStruct((cap,), jnp.int32), one, one],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(buf, _pad_to(fresh, f_p))


def _check_merge_args(buf: jax.Array) -> None:
    if buf.shape[0] % _LANE:
        raise ValueError(
            f"merge buffer capacity must be a multiple of {_LANE}, "
            f"got {buf.shape[0]} (FactBuffers rounds for you)"
        )


def merge_sorted_unique(
    buf: jax.Array, fresh: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Merge sorted-unique ``fresh`` codes into the sorted-unique,
    BIG-padded ``buf`` — one launch, output aliased onto ``buf``.

    Returns ``(merged, count, n_new)``.  Precondition (checked by
    :mod:`.buffers`, not here): ``capacity >= count_before + #fresh``,
    so the merge can never overflow — regrow happens *before* the
    donating call, never after, because donation invalidates ``buf``.
    ``interpret=None`` resolves per backend/env outside the jit.
    """
    _check_merge_args(buf)
    return _merge_jit(buf, fresh, interpret=resolve_interpret(interpret))


_merge_jit = jax.jit(_merge_impl, static_argnames=("interpret",))


#: same kernel with the buffer argument donated: XLA reuses ``buf``'s
#: allocation for ``merged``, so a steady-state round allocates nothing.
#: After the call ``buf`` is dead — callers must overwrite their handle.
merge_sorted_unique_donating = jax.jit(
    _merge_impl, static_argnames=("interpret",), donate_argnums=(0,)
)
