"""Conjunctive (BGP-style) query AST + text parser.

A query is a projection list over a conjunction of body atoms, written
with the same atom syntax as :mod:`repro.core.datalog` rules::

    ?s, ?c <- memberOf(?s, "dept3"), takesCourse(?s, ?c)

The head may equivalently be written atom-style (``Q(?s, ?c) <- ...``);
an empty head (``<- body``) is a boolean/ASK query.  Constants are
interned into the supplied :class:`~repro.core.terms.Dictionary`, exactly
as in rule parsing — note the atom grammar's convention: lowercase
multi-character bare tokens are *variables*, so constants must be
quoted (``"dept3"``), capitalised, or prefixed (``ex:dept3``).
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.datalog import Atom, _parse_atom, _split_atoms
from ..core.terms import Dictionary

__all__ = ["Query", "parse_query"]


@dataclass(frozen=True)
class Query:
    """``projection <- body`` with every projected variable bound in the body."""

    projection: tuple[str, ...]
    body: tuple[Atom, ...]

    def __post_init__(self):
        body_vars = {v for a in self.body for v in a.variables()}
        for v in self.projection:
            if v not in body_vars:
                raise ValueError(f"projected variable {v!r} unbound in body")
        if not self.body:
            raise ValueError("query needs at least one body atom")

    def variables(self) -> tuple[str, ...]:
        seen: list[str] = []
        for a in self.body:
            for v in a.variables():
                if v not in seen:
                    seen.append(v)
        return tuple(seen)

    @property
    def is_ask(self) -> bool:
        return not self.projection

    def __str__(self) -> str:
        """Round-trippable text form with constants as numeric id
        literals (``parse_query(str(q)) == q``); use :meth:`to_text` for
        the term-name rendering."""
        head = ", ".join(f"?{v}" for v in self.projection)
        return head + " <- " + ", ".join(_atom_str(a, None) for a in self.body)

    def to_text(self, dictionary: Dictionary) -> str:
        """Parseable text form, constants quoted back through the
        dictionary (``parse_query(q.to_text(d), d) == q``)."""
        head = ", ".join(f"?{v}" for v in self.projection)
        return head + " <- " + ", ".join(
            _atom_str(a, dictionary) for a in self.body
        )


def _atom_str(atom: Atom, dictionary: Dictionary | None) -> str:
    terms = []
    for t in atom.terms:
        if isinstance(t, int):
            # negative ids are unknown-constant sentinels with no term
            # name; render as id literals (still round-trippable)
            if dictionary is not None and t >= 0:
                terms.append(f'"{dictionary.term_of(t)}"')
            else:
                terms.append(str(t))
        else:
            terms.append(f"?{t}")
    return f"{atom.predicate}({', '.join(terms)})"


def parse_query(text: str, dictionary: Dictionary | None = None) -> Query:
    """Parse ``?x, ?y <- P(?x, ?y), R(?x)`` (or ``Q(?x, ?y) <- ...``)."""
    if "<-" not in text:
        raise ValueError(f"query missing '<-': {text!r}")
    head_text, body_text = text.split("<-", 1)
    body = tuple(
        _parse_atom(a, dictionary) for a in _split_atoms(body_text) if a.strip()
    )
    head_text = head_text.strip()
    if not head_text:
        projection: tuple[str, ...] = ()
    elif "(" in head_text:
        head = _parse_atom(head_text, dictionary)
        if any(not isinstance(t, str) for t in head.terms):
            raise ValueError(f"projection must be variables only: {head_text!r}")
        projection = tuple(head.terms)
    else:
        projection = tuple(
            tok.strip().lstrip("?") for tok in head_text.split(",") if tok.strip()
        )
    return Query(projection, body)
