"""Query planner: the shared body compiler applied to BGP queries.

A query body is a conjunction of atoms — the same planning problem as a
rule body under semi-naive evaluation, so since the one-body-compiler
refactor all of the actual logic (cardinality estimation, greedy
connected-selectivity ordering, join-kind/direction selection, the
``Plan``/``ScanStep``/``JoinStep`` types) lives in
:mod:`repro.core.compile` and is shared with all three materialisation
engines.  This module is the request-path entry point: it feeds the
compiler :class:`~repro.core.frozen.FrozenFacts` statistics (exact
constant frequencies once a snapshot exists, RLE-run estimates
otherwise) and attaches the query so plans ``explain()`` with their
projection.

Plans carry only estimates; the executor (``exec.py``) records actuals.
"""

from __future__ import annotations

from ..core.compile import (
    SCAN_INDEX,
    SCAN_SHARE,
    JoinStep,
    Plan,
    ScanStep,
    compile_body,
    estimate_rows,
)
from ..core.frozen import FrozenFacts
from .ast import Query

__all__ = [
    "ScanStep",
    "JoinStep",
    "Plan",
    "plan_query",
    "estimate_rows",
    "SCAN_SHARE",
    "SCAN_INDEX",
]


def plan_query(query: Query, frozen: FrozenFacts) -> Plan:
    """Greedy selectivity-ordered plan (constants bound first)."""
    return compile_body(
        query.body, frozen, projection=query.projection, query=query
    )
