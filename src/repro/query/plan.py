"""Query planner: selectivity-ordered atom schedule + join-kind choice.

The planner turns a :class:`~repro.query.ast.Query` into an inspectable
:class:`Plan` — a scan step followed by join steps — using only cheap
statistics from :class:`~repro.core.frozen.FrozenFacts`:

* per-atom cardinality estimates: represented fact count, scaled by the
  estimated selectivity of each constant (exact frequency once a
  snapshot exists, 1/RLE-run-count otherwise) and a fixed discount per
  repeated variable,
* greedy ordering: the most selective atom first (constants bound
  first), then repeatedly the most selective atom *connected* to the
  bound variables; disconnected atoms (cartesian) are deferred,
* join kind per step, mirroring the materialisation engine's dispatch:
  a semi-join when one side's variables cover the other's, the
  structure-sharing ``xjoin`` otherwise.

Plans carry only estimates; the executor (``exec.py``) records actuals.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..core.datalog import Atom
from ..core.frozen import FrozenFacts
from .ast import Query, _atom_str

__all__ = ["ScanStep", "JoinStep", "Plan", "plan_query"]

#: selectivity discount for a repeated variable inside one atom
_REPEAT_DISCOUNT = 0.1

# scan modes ------------------------------------------------------------- #
#: share meta-fact columns wholesale (pure-variable atom, zero unfolding)
SCAN_SHARE = "share"
#: binary-search the frozen snapshot on the most selective constant
SCAN_INDEX = "index"


@dataclass(frozen=True)
class ScanStep:
    atom: Atom
    mode: str  # SCAN_SHARE | SCAN_INDEX
    est_rows: float

    def __str__(self) -> str:
        return (
            f"scan[{self.mode}] {_atom_str(self.atom, None)} "
            f"(~{self.est_rows:.0f} rows)"
        )


@dataclass(frozen=True)
class JoinStep:
    scan: ScanStep
    kind: str  # "sjoin" | "xjoin"
    key_vars: tuple[str, ...]
    #: semi-join direction: True = the new atom filters the pipeline,
    #: False = the pipeline filters the new atom
    filter_left: bool = False

    def __str__(self) -> str:
        key = ", ".join(self.key_vars) if self.key_vars else "(cartesian)"
        direction = ""
        if self.kind == "sjoin":
            direction = " filter=atom" if self.filter_left else " filter=pipeline"
        return f"{self.kind} on [{key}]{direction} <- {self.scan}"


@dataclass
class Plan:
    query: Query
    first: ScanStep | None  # None => provably empty (unknown predicate)
    joins: list[JoinStep] = field(default_factory=list)

    @property
    def is_empty(self) -> bool:
        return self.first is None

    def atom_order(self) -> list[Atom]:
        if self.first is None:
            return []
        return [self.first.atom] + [j.scan.atom for j in self.joins]

    def explain(self) -> str:
        lines = [f"plan for: {self.query}"]
        if self.first is None:
            lines.append("  <empty: body atom over an unknown predicate>")
            return "\n".join(lines)
        lines.append(f"  1. {self.first}")
        for i, j in enumerate(self.joins, start=2):
            lines.append(f"  {i}. {j}")
        lines.append(f"  {len(self.joins) + 2}. project [" +
                     ", ".join(self.query.projection) + "]")
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.explain()


def estimate_rows(frozen: FrozenFacts, atom: Atom) -> float:
    """Estimated matching rows for one atom (0 if the predicate is absent
    or its stored arity disagrees with the atom's)."""
    n = frozen.n_rows(atom.predicate)
    if n == 0 or frozen.arity(atom.predicate) != atom.arity:
        return 0.0
    est = float(n)
    vars_seen: set[str] = set()
    for pos, t in enumerate(atom.terms):
        if isinstance(t, int):
            est *= frozen.selectivity(atom.predicate, pos, t)
        elif t in vars_seen:
            est *= _REPEAT_DISCOUNT
        else:
            vars_seen.add(t)
    return est


def _scan_step(frozen: FrozenFacts, atom: Atom, est: float) -> ScanStep:
    constrained = any(isinstance(t, int) for t in atom.terms) or len(
        set(atom.variables())
    ) != len(atom.terms)
    mode = SCAN_INDEX if constrained else SCAN_SHARE
    return ScanStep(atom, mode, est)


def plan_query(query: Query, frozen: FrozenFacts) -> Plan:
    """Greedy selectivity-ordered plan (constants bound first)."""
    remaining = list(enumerate(query.body))
    estimates = {i: estimate_rows(frozen, a) for i, a in remaining}
    if any(frozen.arity(a.predicate) != a.arity or not frozen.meta_facts(a.predicate)
           for _, a in remaining):
        return Plan(query, None)

    # first atom: constant-bound atoms outrank pure-variable ones (an
    # indexed scan touches only matching rows whatever the predicate
    # size), then most selective first (ties by body position)
    def _anchor_key(ia):
        i, a = ia
        has_const = any(isinstance(t, int) for t in a.terms)
        return (0 if has_const else 1, estimates[i], i)

    remaining.sort(key=_anchor_key)
    first_idx, first_atom = remaining.pop(0)
    plan = Plan(query, _scan_step(frozen, first_atom, estimates[first_idx]))
    bound: set[str] = set(first_atom.variables())

    while remaining:
        connected = [
            (i, a) for i, a in remaining if bound & set(a.variables())
        ]
        pool = connected if connected else remaining
        pool.sort(key=lambda ia: (estimates[ia[0]], ia[0]))
        idx, atom = pool[0]
        remaining.remove((idx, atom))

        atom_vars = set(atom.variables())
        shared = tuple(v for v in atom.variables() if v in bound)
        if bound <= atom_vars:
            # the pipeline's vars are all in the new atom: pipeline
            # filters the atom's substitutions (semi-join keeps the atom side)
            kind, filter_left = "sjoin", False
        elif atom_vars <= bound:
            # the new atom only restricts existing bindings
            kind, filter_left = "sjoin", True
        else:
            kind, filter_left = "xjoin", False
        plan.joins.append(
            JoinStep(
                _scan_step(frozen, atom, estimates[idx]),
                kind,
                shared,
                filter_left,
            )
        )
        bound |= atom_vars
    return plan
