"""Compressed query answering: BGP queries served directly over meta-facts.

The missing request path of the paper's pipeline: materialisation is a
preprocessing step; this package answers conjunctive (BGP-style) queries
*on the compressed ``<M, mu>`` representation* without unfolding the
store (see DESIGN.md §Query):

* :mod:`ast` — query AST + text parser (rule-atom syntax),
* :mod:`plan` — selectivity-ordered plans over frozen-store statistics,
* :mod:`exec` — plan execution with the engine's ``match``/``sjoin``/
  ``xjoin`` primitives plus indexed constant lookups,
* :mod:`engine` — :class:`QueryEngine`, the cached serving facade,
* :mod:`ref` — the flat-join correctness oracle.
"""

from .ast import Query, parse_query
from .batch import BatchStats, answer_group, plan_signature
from .engine import QueryEngine, QueryResult
from .exec import ExecStats, execute
from .plan import JoinStep, Plan, ScanStep, plan_query
from .ref import answer_flat

__all__ = [
    "BatchStats",
    "ExecStats",
    "JoinStep",
    "Plan",
    "Query",
    "QueryEngine",
    "QueryResult",
    "ScanStep",
    "answer_flat",
    "answer_group",
    "execute",
    "parse_query",
    "plan_query",
    "plan_signature",
]
