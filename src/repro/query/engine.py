"""QueryEngine: the request path over a materialised compressed KB.

Materialise once (``CMatEngine``), freeze, then answer a stream of
conjunctive queries::

    qe = QueryEngine(eng, dictionary)
    res = qe.answer("?s, ?c <- memberOf(?s, \"dept3\"), takesCourse(?s, ?c)")
    res.answers            # (n, 2) int64, sorted unique
    print(res.plan)        # inspectable plan
    res.stats.unfold_fractions()

Serving behaviour:

* **plan cache** (LRU): a query shape is planned once,
* **result cache** (LRU): repeated queries are answered by lookup,
* scratch reclamation: every miss evaluates in a released scratch region
  of the column store, so memory stays flat across millions of requests,
* **epoch stamping**: plan and result entries are stamped with the KB
  epoch they were computed at; :meth:`QueryEngine.bump_epoch` (called by
  the live-update serving loop after every applied batch) makes stale
  entries miss and evict lazily, so a mutated store can never serve
  pre-update answers — and a pre-update *plan*, whose emptiness shortcut
  and scan modes were derived from stale statistics, is re-planned too.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from ..core.engine import CMatEngine
from ..core.frozen import FrozenFacts
from ..core.metafacts import FactStore
from ..core.terms import Dictionary
from ..obs import span
from .ast import Query, parse_query
from .exec import ExecStats, execute
from .plan import Plan, plan_query

__all__ = ["QueryEngine", "QueryResult"]

#: sentinel for constants absent from the dictionary: no stored fact can
#: contain it (term ids are dense and non-negative), so any atom naming
#: it provably matches nothing
_UNKNOWN_CONSTANT = -1


class _LookupOnlyDict:
    """Read-only dictionary view for query parsing: unseen constants map
    to :data:`_UNKNOWN_CONSTANT` instead of being interned, so a stream
    of queries over unknown terms cannot grow the shared dictionary.
    (Two distinct unknown constants collide on the sentinel, but every
    query naming one has a provably empty answer set, so the collision
    is observationally harmless — including as a cache key.)"""

    def __init__(self, base: Dictionary):
        self._base = base

    def intern(self, term: str) -> int:
        if term in self._base:
            return self._base.id_of(term)
        return _UNKNOWN_CONSTANT


@dataclass
class QueryResult:
    query: Query
    answers: np.ndarray  # (n, len(projection)) int64, sorted unique
    plan: Plan
    stats: ExecStats
    from_cache: bool = False

    @property
    def n_answers(self) -> int:
        return int(self.answers.shape[0])

    @property
    def ask(self) -> bool:
        """Truth value for ASK queries (any query: 'has answers')."""
        return self.answers.shape[0] > 0


class QueryEngine:
    """Answers BGP queries directly over the frozen ``<M, mu>`` store."""

    def __init__(
        self,
        source: CMatEngine | FactStore | FrozenFacts,
        dictionary: Dictionary | None = None,
        *,
        plan_cache_size: int = 256,
        result_cache_size: int = 1024,
        use_pallas: bool = False,
        interpret: bool | None = None,
    ):
        self.frozen = self._resolve_frozen(source)
        self.dictionary = dictionary
        # 'is not None': an empty Dictionary is falsy but still a dictionary
        self._parse_dict = (
            _LookupOnlyDict(dictionary) if dictionary is not None else None
        )
        self.use_pallas = use_pallas
        self.interpret = interpret
        self._plan_cache: OrderedDict[Query, Plan] = OrderedDict()
        self._result_cache: OrderedDict[Query, QueryResult] = OrderedDict()
        self._text_cache: OrderedDict[str, Query] = OrderedDict()
        self._plan_cache_size = plan_cache_size
        self._result_cache_size = result_cache_size
        self.plan_hits = self.plan_misses = 0
        self.result_hits = self.result_misses = 0
        #: KB version: entries cached at an older epoch are stale
        self.epoch = 0
        self.stale_evictions = 0

    # ------------------------------------------------------------------ #
    @staticmethod
    def _resolve_frozen(source) -> FrozenFacts:
        if isinstance(source, FrozenFacts):
            return source
        if isinstance(source, CMatEngine):
            return source.facts.freeze()
        if isinstance(source, FactStore):
            return source.freeze()
        if hasattr(source, "freeze"):  # e.g. incremental.IncrementalStore
            return source.freeze()
        raise TypeError(f"cannot build QueryEngine from {type(source)!r}")

    def bump_epoch(self, source) -> None:
        """Switch to a new KB snapshot after an applied update batch.

        Every plan/result entry cached before this call is version-
        stamped with the previous epoch and will miss (and be evicted)
        on its next lookup."""
        self.frozen = self._resolve_frozen(source)
        self.epoch += 1

    # ------------------------------------------------------------------ #
    @staticmethod
    def _lru_get(cache: OrderedDict, key):
        hit = cache.get(key)
        if hit is not None:
            cache.move_to_end(key)
        return hit

    @staticmethod
    def _lru_put(cache: OrderedDict, key, value, capacity: int) -> None:
        cache[key] = value
        if len(cache) > capacity:
            cache.popitem(last=False)

    def _stamped_get(self, cache: OrderedDict, key):
        """Epoch-checked LRU lookup: entries stamped with an older epoch
        are evicted and reported as misses."""
        hit = cache.get(key)
        if hit is None:
            return None
        entry_epoch, value = hit
        if entry_epoch != self.epoch:
            del cache[key]
            self.stale_evictions += 1
            return None
        cache.move_to_end(key)
        return value

    def _stamped_put(self, cache: OrderedDict, key, value, capacity: int) -> None:
        cache[key] = (self.epoch, value)
        if len(cache) > capacity:
            cache.popitem(last=False)

    def parse(self, text: str) -> Query:
        """Parse query text (LRU-cached, so repeated requests skip the
        regex work; never interns new terms into the dictionary)."""
        query = self._lru_get(self._text_cache, text)
        if query is None:
            query = parse_query(text, self._parse_dict)
            # must not be smaller than the result cache it gates, or hot
            # result hits beyond its capacity re-parse on every request
            self._lru_put(
                self._text_cache,
                text,
                query,
                max(self._plan_cache_size, self._result_cache_size, 1),
            )
        return query

    def plan(self, query: Query | str) -> Plan:
        if isinstance(query, str):
            query = self.parse(query)
        plan = self._stamped_get(self._plan_cache, query)
        if plan is not None:
            self.plan_hits += 1
            return plan
        self.plan_misses += 1
        plan = plan_query(query, self.frozen)
        self._stamped_put(self._plan_cache, query, plan, self._plan_cache_size)
        return plan

    def explain(self, query: Query | str) -> str:
        return self.plan(query).explain()

    def answer(self, query: Query | str) -> QueryResult:
        with span("query.answer") as sp:
            if isinstance(query, str):
                query = self.parse(query)
            if self._result_cache_size > 0:
                hit = self._stamped_get(self._result_cache, query)
                if hit is not None:
                    self.result_hits += 1
                    sp.set(cached=True, n_answers=int(hit.answers.shape[0]))
                    return QueryResult(
                        query, hit.answers, hit.plan, hit.stats,
                        from_cache=True,
                    )
            self.result_misses += 1
            plan = self.plan(query)
            answers, stats = execute(
                plan,
                self.frozen,
                use_pallas=self.use_pallas,
                interpret=self.interpret,
            )
            # cached answers are shared across hits: freeze them so a
            # caller mutating in place cannot poison later responses
            answers.setflags(write=False)
            result = QueryResult(query, answers, plan, stats)
            if self._result_cache_size > 0:
                self._stamped_put(
                    self._result_cache, query, result,
                    self._result_cache_size,
                )
            sp.set(cached=False, n_answers=int(answers.shape[0]))
            return result

    # ------------------------------------------------------------------ #
    # micro-batch admission (serving tier; see query.batch)
    # ------------------------------------------------------------------ #
    def cached(self, query: Query | str) -> QueryResult | None:
        """Result-cache peek (epoch-checked, counts as a hit when it
        lands; no evaluation on miss — the batch executor uses this to
        skip already-answered members of a signature group)."""
        if isinstance(query, str):
            query = self.parse(query)
        if self._result_cache_size <= 0:
            return None
        hit = self._stamped_get(self._result_cache, query)
        if hit is None:
            return None
        self.result_hits += 1
        return QueryResult(
            query, hit.answers, hit.plan, hit.stats, from_cache=True
        )

    def seed_result(self, result: QueryResult) -> None:
        """Install an externally computed result (e.g. a split of a
        generalised batched answer) into the result cache, stamped with
        the current epoch."""
        if self._result_cache_size > 0:
            self._stamped_put(
                self._result_cache, result.query, result,
                self._result_cache_size,
            )

    def answer_batch(self, queries, *, min_group: int = 2):
        """Answer a micro-batch with shared-plan grouping: queries with
        the same constant-abstracted signature and one constant slot run
        as a single generalised scan/join.  Returns ``(results,
        BatchStats)`` with ``results`` aligned to the input order."""
        from .batch import answer_group

        parsed = [
            self.parse(q) if isinstance(q, str) else q for q in queries
        ]
        by_query, stats = answer_group(self, parsed, min_group=min_group)
        return [by_query[q] for q in parsed], stats

    # ------------------------------------------------------------------ #
    def decode(self, answers: np.ndarray) -> list[tuple[str, ...]]:
        """Render answer rows back to term strings via the dictionary."""
        if self.dictionary is None:
            raise ValueError("no dictionary attached")
        return [
            tuple(self.dictionary.term_of(int(v)) for v in row) for row in answers
        ]

    def cache_stats(self) -> dict:
        return {
            "plan_hits": self.plan_hits,
            "plan_misses": self.plan_misses,
            "result_hits": self.result_hits,
            "result_misses": self.result_misses,
            "stale_evictions": self.stale_evictions,
        }
