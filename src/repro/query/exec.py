"""Plan executor: evaluates BGP plans directly over the compressed store.

The pipeline state is a :class:`~repro.core.joins.SubstSet` — the same
meta-substitution working set the materialisation engine uses — driven by
the existing ``match`` / ``sjoin`` / ``xjoin`` primitives.  Everything a
query allocates (split survivors, cross-join groups) lands in a scratch
region of the column store and is released when the answers have been
extracted, so the frozen store does not grow across a query stream.

Instrumentation (the acceptance evidence for compressed answering):
:class:`ExecStats` records, per predicate, how many *flat rows* the query
materialised whole (`rows_scanned`, from indexed scans) and how many
column cells it fed flat into joins (`join_cells`: key columns for a
semi-join, every atom column for a cross-join), both against the
predicate's distinct stored size (`pred_rows` / `pred_cells`).  A
selective multi-join query answers with ``rows_scanned`` empty and only
key columns of its large predicates in ``join_cells`` — the store is
never fully row-unfolded.  (Re-expressing partial semi-join survivors
copies whole touched columns inside ``ColumnStore.split``; that cost is
bounded by the column count, served from the unfold cache across
queries, and does not materialise rows.)

Constant-bound scans take the indexed fast path: a binary search on the
frozen snapshot's per-column sort order touches only matching rows;
residual constants filter through :func:`repro.kernels.in_set` — numpy
by default, the ``sorted_member`` Pallas kernel when ``use_pallas=True``
(jax is only imported on that path; the kernels package loads its
jax-backed submodules lazily).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core.compress import compress_rows
from ..core.datalog import Atom
from ..core.frozen import FrozenFacts
from ..core.joins import SubstSet, _unfold_cols, match, sjoin, xjoin
from ..core.util import unique_rows
from ..kernels.lookup import in_set
from .ast import Query
from .plan import SCAN_INDEX, Plan, ScanStep

__all__ = ["ExecStats", "execute"]


@dataclass
class ExecStats:
    """Per-query evaluation actuals."""

    #: whole flat rows materialised per predicate (indexed scans)
    rows_scanned: dict[str, int] = field(default_factory=dict)
    #: atom column cells fed flat into joins, per predicate (key columns
    #: for sjoin, all columns for xjoin; includes unfold-cache hits)
    join_cells: dict[str, int] = field(default_factory=dict)
    #: distinct stored fact count of every predicate the query touched
    #: (falls back to the with-multiplicity count until a snapshot exists)
    pred_rows: dict[str, int] = field(default_factory=dict)
    #: pred_rows * arity — cell-count denominator for join_cells
    pred_cells: dict[str, int] = field(default_factory=dict)
    #: pipeline-side cells fed flat into joins (intermediate results,
    #: not attributable to a single stored predicate)
    pipeline_cells: int = 0
    cells_unfolded: int = 0  # fresh store.unfold cells during evaluation
    cells_cached: int = 0  # unfold cells served from the unfold cache
    n_answers: int = 0
    time_s: float = 0.0

    def unfold_fractions(self) -> dict[str, float]:
        """rows_scanned / pred_rows per predicate (0 when never scanned flat)."""
        return {
            p: self.rows_scanned.get(p, 0) / n if n else 0.0
            for p, n in self.pred_rows.items()
        }

    def join_cell_fractions(self) -> dict[str, float]:
        """join_cells / pred_cells per predicate."""
        return {
            p: self.join_cells.get(p, 0) / n if n else 0.0
            for p, n in self.pred_cells.items()
        }

    def fully_unfolded(self) -> list[str]:
        """Predicates fully materialised flat: every stored row scanned
        whole, or every cell fed into a join."""
        out = []
        for p, n in self.pred_rows.items():
            if not n:
                continue
            if self.rows_scanned.get(p, 0) >= n or (
                self.pred_cells.get(p, 0)
                and self.join_cells.get(p, 0) >= self.pred_cells[p]
            ):
                out.append(p)
        return out


class _CountingStore:
    """ColumnStore proxy that meters ``unfold`` traffic for ExecStats."""

    def __init__(self, store, stats: ExecStats):
        self._store = store
        self._stats = stats

    def unfold(self, cid: int) -> np.ndarray:
        cached = cid in self._store._unfold_cache
        out = self._store.unfold(cid)
        if cached:
            self._stats.cells_cached += int(out.size)
        else:
            self._stats.cells_unfolded += int(out.size)
        return out

    def __getattr__(self, name):
        return getattr(self._store, name)


# --------------------------------------------------------------------- #
def execute(
    plan: Plan,
    frozen: FrozenFacts,
    *,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> tuple[np.ndarray, ExecStats]:
    """Evaluate a plan; returns ``(answers, stats)``.

    ``answers`` is a sorted, duplicate-free ``(n, len(projection))`` int64
    array; for ASK queries the shape is ``(1, 0)`` (true) or ``(0, 0)``.
    ``interpret=None`` resolves per backend/env when the Pallas path is
    used (see :mod:`repro.kernels.backend`).
    """
    stats = ExecStats()
    t0 = time.perf_counter()
    if plan.is_empty:
        stats.time_s = time.perf_counter() - t0
        return _empty_answers(plan.query), stats

    store = frozen.store
    mark = store.mark()
    counting = _CountingStore(store, stats)
    try:
        L = _scan(plan.first, frozen, counting, stats, use_pallas, interpret)
        for step in plan.joins:
            if L.is_empty():
                break
            R = _scan(step.scan, frozen, counting, stats, use_pallas, interpret)
            _meter_join(stats, step, L, R)
            if step.kind == "sjoin":
                if step.filter_left:
                    L = sjoin(R, L, step.key_vars, counting)
                else:
                    L = sjoin(L, R, step.key_vars, counting)
            else:
                L = xjoin(L, R, step.key_vars, counting)
        answers = _project(plan.query, L, counting)
        stats.n_answers = int(answers.shape[0])
        stats.time_s = time.perf_counter() - t0
        return answers, stats
    finally:
        store.release(mark)


# --------------------------------------------------------------------- #
def _meter_join(stats: ExecStats, step, L: SubstSet, R: SubstSet) -> None:
    """Account the flat cells the join will materialise from each side:
    key columns for a semi-join, every column for a cross-join."""
    n_cols_r = len(R.vars) if step.kind == "xjoin" else len(step.key_vars)
    n_cols_l = len(L.vars) if step.kind == "xjoin" else len(step.key_vars)
    pred = step.scan.atom.predicate
    stats.join_cells[pred] = (
        stats.join_cells.get(pred, 0) + R.n_substitutions() * n_cols_r
    )
    stats.pipeline_cells += L.n_substitutions() * n_cols_l


def _scan(
    step: ScanStep,
    frozen: FrozenFacts,
    counting: _CountingStore,
    stats: ExecStats,
    use_pallas: bool,
    interpret: bool | None,
) -> SubstSet:
    atom = step.atom
    pred = atom.predicate
    if step.mode != SCAN_INDEX:
        # pure-variable atom: share the meta-fact columns wholesale —
        # match() emits (cols, length) pairs without unfolding anything.
        out = match(atom, frozen.meta_facts(pred), counting, inplace_splits=False)
        _record_pred_size(stats, frozen, pred)
        return out

    rows = _indexed_rows(frozen, atom, use_pallas, interpret, stats)
    _record_pred_size(stats, frozen, pred)
    vars_ = atom.variables()
    if not vars_:
        items = [((), int(rows.shape[0]))] if rows.shape[0] else []
        return SubstSet((), items)
    first_pos = {v: atom.terms.index(v) for v in vars_}
    cols = rows[:, [first_pos[v] for v in vars_]]
    if cols.shape[0] == 0:
        return SubstSet(vars_)
    return SubstSet(vars_, compress_rows(cols, counting))


def _record_pred_size(stats: ExecStats, frozen: FrozenFacts, pred: str) -> None:
    """Denominators for the unfolding evidence: the *distinct* stored row
    count once a snapshot exists (duplicates across meta-facts would
    otherwise understate unfolding fractions), the represented count
    before — computing it must never force an unfold."""
    if frozen.has_snapshot(pred):
        n = int(frozen.snapshot(pred).shape[0])
    else:
        n = frozen.n_rows(pred)
    stats.pred_rows[pred] = n
    stats.pred_cells[pred] = n * frozen.arity(pred)


def _indexed_rows(
    frozen: FrozenFacts,
    atom: Atom,
    use_pallas: bool,
    interpret: bool | None,
    stats: ExecStats,
) -> np.ndarray:
    """Flat snapshot rows matching an atom's constants / repeated vars,
    touching only the candidate range of the most selective constant."""
    pred = atom.predicate
    const_pos = [(pos, t) for pos, t in enumerate(atom.terms) if isinstance(t, int)]
    if const_pos:
        best_pos, best_val = min(
            const_pos, key=lambda pt: frozen.count_eq(pred, pt[0], pt[1])
        )
        rows = frozen.eq_slice(pred, best_pos, best_val)
    else:
        best_pos = -1
        rows = frozen.snapshot(pred)
    stats.rows_scanned[pred] = stats.rows_scanned.get(pred, 0) + int(rows.shape[0])

    mask = np.ones(rows.shape[0], dtype=bool)
    for pos, value in const_pos:
        if pos == best_pos:
            continue
        mask &= in_set(
            rows[:, pos],
            np.asarray([value], dtype=np.int64),
            use_pallas=use_pallas,
            interpret=interpret,
        )
    vars_ = atom.variables()
    first_pos = {v: atom.terms.index(v) for v in vars_}
    for pos, t in enumerate(atom.terms):
        if isinstance(t, str) and pos != first_pos[t]:
            mask &= rows[:, pos] == rows[:, first_pos[t]]
    return rows if mask.all() else rows[mask]


def _project(query: Query, L: SubstSet | None, counting: _CountingStore) -> np.ndarray:
    if L is None or L.is_empty():
        return _empty_answers(query)
    if query.is_ask:
        return np.zeros((1, 0), dtype=np.int64)
    idx = [L.vars.index(v) for v in query.projection]
    rows = _unfold_cols(counting, L.items, idx)
    return unique_rows(rows)


def _empty_answers(query: Query) -> np.ndarray:
    return np.zeros((0, len(query.projection)), dtype=np.int64)
