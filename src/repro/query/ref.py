"""Flat reference evaluator: answers a query by joining unfolded arrays.

The correctness oracle for the compressed executor (differential tests)
and the "answer on the flat store" baseline of ``bench_query.py``.  It
reuses the flat engine's match/join primitives over plain per-predicate
``(n, arity)`` arrays — i.e. it requires the fully unfolded
materialisation the compressed path avoids.
"""

from __future__ import annotations

import numpy as np

from ..core.flat import _join, _match_flat
from .ast import Query

__all__ = ["answer_flat"]


def answer_flat(query: Query, facts: dict[str, np.ndarray]) -> np.ndarray:
    """Sorted unique answers of ``query`` over flat fact arrays."""
    L = None
    for atom in query.body:
        rows = facts.get(atom.predicate)
        if rows is None or rows.shape[0] == 0:
            return _empty(query)
        T = _match_flat(atom, rows)
        if T is None:
            return _empty(query)
        if not T.vars:
            continue  # all-constant atom: satisfied, adds no bindings
        L = T if L is None else _join(L, T)
        if L.rows.shape[0] == 0:
            return _empty(query)
    if query.is_ask:
        return np.zeros((1, 0), dtype=np.int64)
    idx = [L.vars.index(v) for v in query.projection]
    return np.unique(L.rows[:, idx], axis=0)


def _empty(query: Query) -> np.ndarray:
    return np.zeros((0, len(query.projection)), dtype=np.int64)
