"""Shared-plan micro-batch execution for the serving tier.

Concurrent query streams are heavily templated: the same BGP shape with
different constants (``memberOf(?s, "dept0")`` vs ``"dept3"``).  The
planner already dedups *plans* by query shape; this module goes one step
further and dedups the *scan/join work* across a micro-batch:

1. :func:`plan_signature` abstracts every constant occurrence in a query
   to a reserved slot variable (``__b0``, ``__b1``, ...) — queries with
   the same signature share a plan shape and differ only in constants.
2. A signature group with exactly one constant slot is executed as one
   **generalised query**: the slot variable is appended to the
   projection and the group runs as a single batched scan/join through
   the engine (hitting its epoch-stamped caches).
3. The generalised answer set is split back per constant with one
   stable argsort + vectorised binary searches — exact equivalence with
   per-query execution (filtering ``slot == c`` then dropping the slot
   column preserves sort order and uniqueness).

Groups that do not batch (no constants, several slots, fewer than
``min_group`` distinct constants) fall back to per-query ``answer()``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.datalog import Atom
from .ast import Query

__all__ = ["BatchStats", "abstract_query", "answer_group", "plan_signature"]

#: reserved variable-name prefix for constant slots; queries whose own
#: variables collide with it are served per-query (never batched)
SLOT_PREFIX = "__b"


@dataclass
class BatchStats:
    """What one micro-batch execution did (feeds ``serve.batch.*``)."""

    n_queries: int = 0       # distinct queries answered
    n_groups: int = 0        # signature groups executed generalised
    n_grouped: int = 0       # queries answered via a generalised plan
    n_single: int = 0        # queries answered individually
    n_cached: int = 0        # queries answered from the result cache


def abstract_query(query: Query):
    """``(signature, constants)``: the query with every constant occurrence
    replaced by a slot variable, plus the constants in slot order.
    Returns ``(None, ())`` when the query cannot be abstracted (a user
    variable collides with the reserved slot prefix)."""
    consts: list[int] = []
    new_body: list[Atom] = []
    for atom in query.body:
        terms: list = []
        for t in atom.terms:
            if isinstance(t, int):
                terms.append(f"{SLOT_PREFIX}{len(consts)}")
                consts.append(int(t))
            else:
                if t.startswith(SLOT_PREFIX):
                    return None, ()
                terms.append(t)
        new_body.append(Atom(atom.predicate, tuple(terms)))
    return Query(query.projection, tuple(new_body)), tuple(consts)


def plan_signature(query: Query) -> Query | None:
    """Hashable shared-plan key: the constant-abstracted query shape."""
    sig, _ = abstract_query(query)
    return sig


def _split_generalised(gen_answers: np.ndarray, wanted: list[int], ask: bool):
    """Per-constant answer arrays from one generalised answer set.

    ``gen_answers`` is sorted unique over ``projection + (slot,)``; for
    each wanted constant the rows with ``slot == c`` are gathered (one
    shared stable argsort, then two binary searches per constant) and the
    slot column dropped — the result is sorted unique over the original
    projection."""
    slot = gen_answers[:, -1]
    order = np.argsort(slot, kind="stable")
    svals = slot[order]
    values = np.asarray(wanted, dtype=np.int64)
    los = np.searchsorted(svals, values, side="left")
    his = np.searchsorted(svals, values, side="right")
    out = []
    for lo, hi in zip(los, his):
        if ask:
            n = 1 if hi > lo else 0
            out.append(np.zeros((n, 0), dtype=np.int64))
        else:
            # stable sort keeps equal-slot rows in their original
            # (lexicographic) order, so the projected rows stay sorted
            # and unique
            out.append(gen_answers[order[lo:hi], :-1])
    return out


def answer_group(engine, queries, *, min_group: int = 2):
    """Answer a micro-batch of (pre-parsed) queries through ``engine``.

    Returns ``(results, stats)`` where ``results`` maps each distinct
    query to its :class:`~repro.query.engine.QueryResult` and ``stats``
    is a :class:`BatchStats`.  Exact-duplicate queries in the batch are
    answered once; single-slot signature groups with at least
    ``min_group`` distinct constants run as one generalised query."""
    from .engine import QueryResult

    stats = BatchStats()
    distinct = list(dict.fromkeys(queries))
    stats.n_queries = len(distinct)

    groups: dict[Query, list[tuple[Query, int]]] = {}
    singles: list[Query] = []
    out: dict[Query, QueryResult] = {}
    for q in distinct:
        sig, consts = abstract_query(q)
        if sig is None or len(consts) != 1:
            singles.append(q)
            continue
        groups.setdefault(sig, []).append((q, consts[0]))

    for sig, members in groups.items():
        pending = []
        for q, c in members:
            hit = engine.cached(q)
            if hit is not None:
                out[q] = hit
                stats.n_cached += 1
            else:
                pending.append((q, c))
        if not pending:
            continue
        if len({c for _, c in pending}) < min_group:
            singles.extend(q for q, _ in pending)
            continue
        gen = Query(sig.projection + (f"{SLOT_PREFIX}0",), sig.body)
        res = engine.answer(gen)
        stats.n_groups += 1
        stats.n_grouped += len(pending)
        per_const = _split_generalised(
            res.answers, [c for _, c in pending],
            ask=not sig.projection,
        )
        for (q, _), answers in zip(pending, per_const):
            answers.setflags(write=False)
            result = QueryResult(q, answers, res.plan, res.stats,
                                 from_cache=res.from_cache)
            engine.seed_result(result)
            out[q] = result

    for q in singles:
        res = engine.answer(q)
        if res.from_cache:
            stats.n_cached += 1
        else:
            stats.n_single += 1
        out[q] = res
    return out, stats
