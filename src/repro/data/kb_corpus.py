"""KB-to-token linearisation: where the paper's engine feeds LM training.

The materialised knowledge base (computed by the CompMat engine — the
paper's contribution) is linearised into token sequences for KB-grounded
language-model training:

    <S> subject predicate object <E> <S> ...

Token ids are offset so constants, predicates, and specials occupy
disjoint id ranges inside the model's vocabulary.  The compressed
representation pays off operationally: the linearisation iterates
*meta-facts* and emits RLE runs without unfolding duplicated columns.
"""

from __future__ import annotations

import numpy as np

from ..core.engine import CMatEngine

__all__ = ["KBTokenizer", "linearise_materialisation"]

TOK_BOS = 0
TOK_EOS = 1
TOK_SEP = 2
N_SPECIALS = 3


class KBTokenizer:
    """Maps predicates/constants into a model vocabulary."""

    def __init__(self, n_constants: int, predicates: list[str], vocab_size: int):
        self.pred_of = {p: N_SPECIALS + i for i, p in enumerate(sorted(predicates))}
        self.const_base = N_SPECIALS + len(self.pred_of)
        self.vocab_size = vocab_size
        if self.const_base + n_constants > vocab_size:
            # fold constants into the available range (hash-bucketing):
            # standard trick for entity vocabularies larger than the LM's
            self.n_buckets = vocab_size - self.const_base
        else:
            self.n_buckets = n_constants

    def constant(self, cid: int) -> int:
        return self.const_base + (int(cid) % max(self.n_buckets, 1))

    def predicate(self, pred: str) -> int:
        return self.pred_of[pred]


def linearise_materialisation(
    engine: CMatEngine, vocab_size: int, max_facts: int | None = None
) -> np.ndarray:
    """Emit a token stream from a materialised CMat engine."""
    preds = sorted(engine.facts.predicates())
    n_constants = max(
        (int(engine.store.unfold(c).max()) + 1
         for lst in (engine.facts.all(p) for p in preds)
         for mf in lst
         for c in mf.columns
         if engine.store.length(c)),
        default=0,
    )
    tok = KBTokenizer(n_constants, preds, vocab_size)
    out: list[np.ndarray] = []
    emitted = 0
    for pred in preds:
        pid = tok.predicate(pred)
        for mf in engine.facts.all(pred):
            cols = [engine.store.unfold(c) for c in mf.columns]
            n = mf.length
            if max_facts is not None and emitted + n > max_facts:
                n = max_facts - emitted
                if n <= 0:
                    break
            arity = len(cols)
            # layout per fact: BOS pred c1 [c2] EOS
            width = 3 + arity
            block = np.empty((n, width), dtype=np.int32)
            block[:, 0] = TOK_BOS
            block[:, 1] = pid
            for j, col in enumerate(cols):
                vals = (tok.const_base
                        + (col[:n] % max(tok.n_buckets, 1))).astype(np.int32)
                block[:, 2 + j] = vals
            block[:, -1] = TOK_EOS
            out.append(block.reshape(-1))
            emitted += n
    if not out:
        return np.zeros((0,), dtype=np.int32)
    return np.concatenate(out)
