"""Deterministic, shardable token pipeline.

Production framing: each host process draws only its slice of the global
batch (``host_slice``), derived from (step, host_index) — restart-safe
(the stream is a pure function of the step, so checkpoint/restart never
replays or skips data) and elastic-safe (re-slicing by the new host count
is a pure re-index).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["DataConfig", "SyntheticCorpus", "TokenStream"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticCorpus:
    """Zipf-distributed synthetic tokens (stable across restarts)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._cdf = np.cumsum(probs / probs.sum())

    def batch(self, step: int, host_index: int = 0, n_hosts: int = 1):
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        per_host = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_index])
        )
        u = rng.random((per_host, cfg.seq_len))
        tokens = np.searchsorted(self._cdf, u).astype(np.int32)
        return {"tokens": np.clip(tokens, 0, cfg.vocab_size - 1)}


class TokenStream:
    """Chunk a fixed token array into training batches (KB corpus path)."""

    def __init__(self, tokens: np.ndarray, cfg: DataConfig):
        self.tokens = np.asarray(tokens, dtype=np.int32)
        self.cfg = cfg
        n = cfg.seq_len * cfg.global_batch
        if self.tokens.shape[0] < n:
            reps = -(-n // self.tokens.shape[0])
            self.tokens = np.tile(self.tokens, reps)
        self.n_batches = self.tokens.shape[0] // n

    def batch(self, step: int, host_index: int = 0, n_hosts: int = 1):
        cfg = self.cfg
        per_host = cfg.global_batch // n_hosts
        n = cfg.seq_len * cfg.global_batch
        base = (step % max(self.n_batches, 1)) * n
        start = base + host_index * per_host * cfg.seq_len
        chunk = self.tokens[start : start + per_host * cfg.seq_len]
        return {"tokens": chunk.reshape(per_host, cfg.seq_len)}
