"""Data substrate: deterministic token pipeline + KB linearisation."""

from .kb_corpus import KBTokenizer, linearise_materialisation
from .pipeline import DataConfig, SyntheticCorpus, TokenStream

__all__ = [
    "DataConfig",
    "KBTokenizer",
    "SyntheticCorpus",
    "TokenStream",
    "linearise_materialisation",
]
