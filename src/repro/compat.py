"""Feature-detected shims over the moving parts of the jax API.

The distributed engine, the EP MoE path, and the launch drivers target
the modern top-level API (``jax.shard_map`` with ``check_vma``,
``jax.set_mesh``).  Older jax releases (this container ships 0.4.x) only
have ``jax.experimental.shard_map.shard_map`` (``check_rep``, mandatory
``mesh``) and no ambient-mesh setter — but ``jax.sharding.Mesh`` is a
context manager that installs the physical mesh for the thread, which is
exactly what ``set_mesh`` is used for here.

Routing every call through this module keeps one code path working on
both API generations, so environment skew cannot mask real regressions.
"""

from __future__ import annotations

import contextlib

import jax

__all__ = ["set_mesh", "shard_map", "HAS_NATIVE_SHARD_MAP", "HAS_NATIVE_SET_MESH"]

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
HAS_NATIVE_SET_MESH = hasattr(jax, "set_mesh")


def set_mesh(mesh) -> contextlib.AbstractContextManager:
    """``with set_mesh(mesh):`` — ambient mesh for the enclosed block.

    Uses ``jax.set_mesh`` when present; otherwise ``Mesh`` itself (a
    context manager on every 0.4.x release) installs the physical mesh.
    """
    if HAS_NATIVE_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh


def _ambient_mesh():
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        raise ValueError(
            "shard_map called without a mesh and no ambient mesh is set; "
            "wrap the call in `with repro.compat.set_mesh(mesh):`"
        )
    return mesh


def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` across jax generations.

    * new jax: forwards verbatim (``mesh=None`` resolves to the ambient
      mesh inside jax; ``check_vma`` passed through when given);
    * old jax: ``jax.experimental.shard_map.shard_map`` with ``check_vma``
      translated to its predecessor ``check_rep`` and ``mesh=None``
      resolved from the thread's ambient mesh at wrap time.
    """
    if HAS_NATIVE_SHARD_MAP:
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is None:
        mesh = _ambient_mesh()
    if check_vma is not None:
        kwargs["check_rep"] = bool(check_vma)
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
