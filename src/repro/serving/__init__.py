"""Epoch-based MVCC serving tier (DESIGN.md §Serving).

Readers pin immutable epoch snapshots through a refcounted registry, a
single writer thread applies update batches and publishes new epochs,
and queries are admitted in vectorised micro-batches executed with
shared-plan grouping.  The load driver lives in
``benchmarks/bench_serving.py``; the CLI entry point is
``repro.launch.serve_datalog --mvcc``.
"""

from .admission import AdmissionQueue, Request
from .epochs import EpochEntry, EpochLease, EpochRegistry
from .tier import ServeResponse, ServingLease, ServingTier

__all__ = [
    "AdmissionQueue",
    "EpochEntry",
    "EpochLease",
    "EpochRegistry",
    "Request",
    "ServeResponse",
    "ServingLease",
    "ServingTier",
]
