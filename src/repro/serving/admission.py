"""Micro-batch admission: requests queue, the executor drains batches.

Client threads never touch the store — :meth:`AdmissionQueue.submit`
enqueues a :class:`Request` and blocks on its event; the single batch
executor drains up to ``max_batch`` requests at a time and answers the
whole batch against one pinned epoch (see ``tier.py``).  Micro-batching
is what buys concurrency-8 its throughput: one lock acquisition, one
epoch pin, and one shared-plan group execution amortise over the whole
batch, and exact-duplicate queries (Zipf streams repeat themselves) are
answered once per batch.
"""

from __future__ import annotations

import threading
import time
from collections import deque

__all__ = ["AdmissionQueue", "Request"]


class Request:
    """One admitted query: text + completion event + result slots."""

    __slots__ = (
        "text", "t_submit", "admit_version", "event",
        "response", "error",
    )

    def __init__(self, text: str, admit_version: int):
        self.text = text
        self.t_submit = time.perf_counter()
        #: registry version current at admission — a response computed
        #: at an older version is a stale read (must never happen)
        self.admit_version = admit_version
        self.event = threading.Event()
        self.response = None
        self.error: BaseException | None = None

    def resolve(self, response) -> None:
        self.response = response
        self.event.set()

    def fail(self, error: BaseException) -> None:
        self.error = error
        self.event.set()

    def wait(self, timeout: float | None = None):
        if not self.event.wait(timeout):
            raise TimeoutError(f"query not answered in {timeout}s")
        if self.error is not None:
            raise self.error
        return self.response


class AdmissionQueue:
    """Unbounded FIFO with condition-variable batch draining."""

    def __init__(self):
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._items: deque[Request] = deque()
        self._closed = False
        self.max_depth = 0

    def submit(self, req: Request) -> None:
        with self._not_empty:
            if self._closed:
                raise RuntimeError("admission queue closed")
            self._items.append(req)
            self.max_depth = max(self.max_depth, len(self._items))
            self._not_empty.notify()

    def drain(self, max_batch: int, timeout: float = 0.05) -> list[Request]:
        """Up to ``max_batch`` queued requests; blocks until at least one
        arrives, the timeout elapses (empty list), or the queue closes."""
        with self._not_empty:
            if not self._items and not self._closed:
                self._not_empty.wait(timeout)
            batch = []
            while self._items and len(batch) < max_batch:
                batch.append(self._items.popleft())
            return batch

    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    def close(self) -> None:
        """Reject new submissions and wake the executor."""
        with self._not_empty:
            self._closed = True
            self._not_empty.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed
