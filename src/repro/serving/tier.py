"""ServingTier: epoch-based MVCC serving over an IncrementalStore.

Thread roles (DESIGN.md §Serving):

* **clients** call :meth:`ServingTier.answer` — enqueue a request into
  the admission queue and wait on its event.  They never touch the
  column store.
* the **batch executor** (one thread) drains vectorised micro-batches,
  pins the current epoch, and answers the whole batch against that one
  pinned snapshot through the epoch's
  :class:`~repro.query.QueryEngine` with shared-plan grouping
  (:mod:`repro.query.batch`).
* the **writer** (one thread) applies :meth:`IncrementalStore.apply`
  batches; the store's publish-after-apply hook freezes a pinned
  snapshot and publishes a new epoch entry; checkpoints go through the
  existing ``LATEST`` pointer (:class:`CheckpointManager`).

All store access (scratch ``mark``/``release`` regions, appends,
compaction) is serialised by one re-entrant store mutex; epoch pins are
refcounts in the :class:`~repro.serving.epochs.EpochRegistry` and cost
O(1).  Readers holding a lease never block the writer — old epochs are
retired only when their last lease is released.  Compaction swaps the
mu-node table (pinned meta-facts would hold dangling node ids), so it
is **deferred while any epoch is pinned** and the post-compaction state
is republished under a fresh registry version.

Without :meth:`start` the tier runs degenerate-synchronously (submit →
execute inline on the calling thread) — same code path, deterministic,
which is what the hypothesis interleaving tests drive.
"""

from __future__ import annotations

import queue as _queue
import threading
import time
from dataclasses import dataclass

import numpy as np

from ..obs import get_registry, span
from ..obs.memory import register_reporter
from ..query import QueryEngine
from .admission import AdmissionQueue, Request
from .epochs import EpochLease, EpochRegistry

__all__ = ["ServeResponse", "ServingLease", "ServingTier"]


@dataclass
class ServeResponse:
    """What a client gets back: answers + the epoch that served them."""

    answers: np.ndarray
    version: int        # registry version pinned during execution
    epoch: int          # store epoch of that version
    from_cache: bool
    stale: bool         # version < version current at admission (never)

    @property
    def n_answers(self) -> int:
        return int(self.answers.shape[0])


class ServingLease:
    """A reader's pinned epoch: answer any number of queries against one
    immutable snapshot while the writer keeps publishing new epochs."""

    def __init__(self, tier: ServingTier, lease: EpochLease):
        self._tier = tier
        self._lease = lease

    @property
    def version(self) -> int:
        return self._lease.version

    @property
    def epoch(self) -> int:
        return self._lease.epoch

    @property
    def engine(self):
        return self._lease.engine

    def answer(self, text: str):
        """Answer against the pinned snapshot (store access serialised
        with the writer)."""
        with self._tier._store_lock:
            return self._lease.engine.answer(text)

    def release(self) -> None:
        self._lease.release()

    def __enter__(self) -> ServingLease:
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class ServingTier:
    """Concurrent MVCC serving facade over one IncrementalStore."""

    def __init__(
        self,
        inc,
        dictionary=None,
        *,
        max_batch: int = 64,
        min_group: int = 2,
        plan_cache_size: int = 256,
        result_cache_size: int = 1024,
        use_pallas: bool = False,
        checkpoint=None,
        checkpoint_every: int = 0,
        compact_threshold: float = 0.0,
        drain_timeout: float = 0.02,
    ):
        self.inc = inc
        self.dictionary = dictionary
        self.max_batch = max(int(max_batch), 1)
        self.min_group = max(int(min_group), 2)
        self.plan_cache_size = plan_cache_size
        self.result_cache_size = result_cache_size
        self.use_pallas = use_pallas
        self.checkpoint = checkpoint
        self.checkpoint_every = checkpoint_every
        self.compact_threshold = compact_threshold
        self.drain_timeout = drain_timeout

        #: one mutex serialises every store touch: query scratch regions,
        #: apply mutations, compaction, and epoch pins (pinning under the
        #: lock closes the pin-vs-compaction race)
        self._store_lock = threading.RLock()
        self.registry = EpochRegistry(on_retire=self._on_retire)
        self.queue = AdmissionQueue()
        self._writer_q: _queue.Queue = _queue.Queue()
        self._executor: threading.Thread | None = None
        self._writer: threading.Thread | None = None
        self._started = False

        # plain counters (reported via obs.publish_serving and the
        # driver's ``serving`` block; registry metrics mirror them live)
        self.n_queries = 0
        self.n_batches = 0
        self.n_batched_queries = 0   # answered via a generalised group
        self.n_single_queries = 0
        self.n_cache_hits = 0
        self.n_dedup_hits = 0        # exact duplicates folded per batch
        self.n_groups = 0
        self.stale_reads = 0
        self.n_applies = 0
        self.n_checkpoints = 0
        self.compactions = 0
        self.compactions_deferred = 0
        self.batch_sizes_sum = 0
        self.max_batch_seen = 0
        self.lag_max = 0

        if checkpoint is not None:
            checkpoint.attach_epoch_source(self.registry.pinned_epochs)
        # epochs stay in sync with *any* apply path, not only tier.apply
        self._publish_cb = self._on_store_publish
        inc.subscribe_publish(self._publish_cb)
        register_reporter("serving", self)
        with self._store_lock:
            self._publish()

    # ------------------------------------------------------------------ #
    # epoch publication
    # ------------------------------------------------------------------ #
    def _on_store_publish(self, store, stats) -> None:
        # runs inside IncrementalStore.apply; the writer (or apply_sync)
        # already holds the store mutex — re-entrant, so direct
        # single-threaded inc.apply() use works too
        with self._store_lock:
            self._publish()

    def _publish(self) -> None:
        with span("serve.publish", epoch=self.inc.epoch):
            frozen = self.inc.freeze(pin_meta=True)
            engine = QueryEngine(
                frozen,
                self.dictionary,
                plan_cache_size=self.plan_cache_size,
                result_cache_size=self.result_cache_size,
                use_pallas=self.use_pallas,
            )
            self.registry.publish(self.inc.epoch, frozen, engine)
        reg = get_registry()
        reg.counter("serve.epoch.published").inc()
        reg.gauge("serve.epoch.current").set(self.inc.epoch)
        reg.gauge("serve.epoch.live").set(self.registry.n_live())

    def _on_retire(self, entry) -> None:
        reg = get_registry()
        reg.counter("serve.epoch.retired").inc()
        reg.gauge("serve.epoch.live").set(self.registry.n_live())

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        self._executor = threading.Thread(
            target=self._executor_loop, name="serving-executor", daemon=True
        )
        self._writer = threading.Thread(
            target=self._writer_loop, name="serving-writer", daemon=True
        )
        self._executor.start()
        self._writer.start()

    def stop(self) -> None:
        """Drain outstanding work and join both threads (idempotent)."""
        if not self._started:
            return
        self.queue.close()
        self._executor.join()
        self._writer_q.put(None)
        self._writer.join()
        self._executor = self._writer = None
        self._started = False

    def close(self) -> None:
        """Stop threads and detach from the store's publish hook."""
        self.stop()
        self.inc.unsubscribe_publish(self._publish_cb)

    # ------------------------------------------------------------------ #
    # read path
    # ------------------------------------------------------------------ #
    def submit(self, text: str) -> Request:
        req = Request(text, self.registry.version)
        if self._started:
            self.queue.submit(req)
            get_registry().gauge("serve.queue.depth").set(self.queue.depth())
        else:
            self._execute_batch([req])
        return req

    def answer(self, text: str, timeout: float | None = 60.0) -> ServeResponse:
        return self.submit(text).wait(timeout)

    def pin(self) -> ServingLease:
        """Pin the current epoch for repeatable reads (O(1); only an
        in-flight writer apply can delay it, never other readers)."""
        with self._store_lock:
            lease = self.registry.pin()
        get_registry().gauge("serve.epoch.pinned").set(
            self.registry.n_pinned()
        )
        return ServingLease(self, lease)

    # ------------------------------------------------------------------ #
    # write path
    # ------------------------------------------------------------------ #
    def apply(self, additions=None, deletions=None) -> Request:
        """Hand an update batch to the writer; returns a ticket whose
        ``wait()`` yields the IncrementalStats.  Synchronous (inline)
        when the tier is not started."""
        ticket = Request("<apply>", self.registry.version)
        if self._started:
            self._writer_q.put((additions, deletions, ticket))
        else:
            try:
                ticket.resolve(self._apply_impl(additions, deletions))
            except BaseException as e:  # noqa: BLE001 — ticket carries it
                ticket.fail(e)
        return ticket

    def apply_sync(self, additions=None, deletions=None):
        return self.apply(additions, deletions).wait(timeout=600.0)

    def _apply_impl(self, additions, deletions):
        with span("serve.writer.apply", epoch=self.inc.epoch + 1):
            with self._store_lock:
                st = self.inc.apply(additions=additions, deletions=deletions)
                self.n_applies += 1
                if self.compact_threshold > 0:
                    if self.registry.n_pinned() == 0:
                        cs = self.inc.maybe_compact(self.compact_threshold)
                        if cs is not None:
                            self.compactions += 1
                            # pinned meta-fact lists of the pre-compaction
                            # view hold dead node ids: republish the same
                            # store epoch under a fresh registry version
                            self._publish()
                    else:
                        self.compactions_deferred += 1
                        get_registry().counter(
                            "serve.compactions_deferred"
                        ).inc()
                if (
                    self.checkpoint is not None
                    and self.checkpoint_every > 0
                    and self.n_applies % self.checkpoint_every == 0
                ):
                    self.checkpoint.checkpoint(self.inc)
                    self.n_checkpoints += 1
        return st

    def _writer_loop(self) -> None:
        while True:
            item = self._writer_q.get()
            if item is None:
                return
            additions, deletions, ticket = item
            try:
                ticket.resolve(self._apply_impl(additions, deletions))
            except BaseException as e:  # noqa: BLE001 — ticket carries it
                ticket.fail(e)

    # ------------------------------------------------------------------ #
    # executor
    # ------------------------------------------------------------------ #
    def _executor_loop(self) -> None:
        while True:
            batch = self.queue.drain(self.max_batch, self.drain_timeout)
            if not batch:
                if self.queue.closed:
                    return
                continue
            self._execute_batch(batch)

    def _execute_batch(self, batch: list[Request]) -> None:
        reg = get_registry()
        try:
            with span("serve.batch", size=len(batch)):
                with self._store_lock:
                    with self.registry.pin() as lease:
                        # parse per-request so one malformed query fails
                        # alone instead of poisoning its co-batch
                        good, parsed = [], []
                        for req in batch:
                            try:
                                parsed.append(lease.engine.parse(req.text))
                                good.append(req)
                            except Exception as e:  # noqa: BLE001
                                req.fail(e)
                        batch = good
                        results, bstats = lease.engine.answer_batch(
                            parsed, min_group=self.min_group,
                        ) if batch else ([], None)
        except BaseException as e:  # noqa: BLE001 — fail the whole batch
            for req in batch:
                req.fail(e)
            return
        if not batch:
            return

        now = time.perf_counter()
        self.n_batches += 1
        self.batch_sizes_sum += len(batch)
        self.max_batch_seen = max(self.max_batch_seen, len(batch))
        self.n_queries += len(batch)
        self.n_groups += bstats.n_groups
        self.n_batched_queries += bstats.n_grouped
        self.n_single_queries += bstats.n_single
        self.n_cache_hits += bstats.n_cached
        self.n_dedup_hits += len(batch) - bstats.n_queries
        reg.counter("serve.queries").inc(len(batch))
        reg.counter("serve.batch.count").inc()
        reg.histogram("serve.batch.size").observe(len(batch))
        reg.counter("serve.batch.grouped").inc(bstats.n_grouped)
        reg.counter("serve.batch.single").inc(bstats.n_single)
        reg.counter("serve.batch.cached").inc(bstats.n_cached)
        reg.counter("serve.batch.dedup_hits").inc(
            len(batch) - bstats.n_queries
        )
        adm = reg.histogram("serve.admission_s")
        cur_version = self.registry.version
        lag = cur_version - lease.version
        self.lag_max = max(self.lag_max, lag)
        reg.histogram("serve.epoch.lag").observe(lag)
        for req, res in zip(batch, results):
            stale = lease.version < req.admit_version
            if stale:
                self.stale_reads += 1
                reg.counter("serve.stale_reads").inc()
            adm.observe(now - req.t_submit)
            req.resolve(ServeResponse(
                answers=res.answers,
                version=lease.version,
                epoch=lease.epoch,
                from_cache=res.from_cache,
                stale=stale,
            ))

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    def reset_counters(self) -> None:
        """Zero the measurement-window counters (warmup discard); epoch
        bookkeeping and the registry's live metrics are untouched."""
        self.n_queries = self.n_batches = 0
        self.n_batched_queries = self.n_single_queries = 0
        self.n_cache_hits = self.n_dedup_hits = self.n_groups = 0
        self.stale_reads = 0
        self.batch_sizes_sum = self.max_batch_seen = 0
        self.lag_max = 0
        self.queue.max_depth = 0

    def stats(self) -> dict:
        epochs = self.registry.stats()
        return {
            "queries": self.n_queries,
            "batches": self.n_batches,
            "mean_batch": self.batch_sizes_sum / max(self.n_batches, 1),
            "max_batch": self.max_batch_seen,
            "grouped_queries": self.n_batched_queries,
            "single_queries": self.n_single_queries,
            "cache_hits": self.n_cache_hits,
            "dedup_hits": self.n_dedup_hits,
            "groups": self.n_groups,
            "stale_reads": self.stale_reads,
            "applies": self.n_applies,
            "checkpoints": self.n_checkpoints,
            "compactions": self.compactions,
            "compactions_deferred": self.compactions_deferred,
            "max_queue_depth": self.queue.max_depth,
            "epoch_lag_max": self.lag_max,
            "epochs_published": epochs["published"],
            "epochs_retired": epochs["retired"],
            "epochs_live": epochs["live"],
            "epochs_pinned": epochs["pinned"],
            "epoch": epochs["epoch"],
        }

    def memory_report(self) -> dict[str, int]:
        """obs.memory reporter.  **No ``*_bytes`` parts on purpose**:
        every live epoch's FrozenFacts self-reports its snapshot bytes
        under ``mem.frozen.*`` (N retained epochs genuinely cost N
        snapshots), and the store/index bytes belong to ``mem.inc.*`` /
        the ColumnStore — double-counting them here would inflate
        ``mem.resident_bytes`` (see DESIGN.md §Serving)."""
        s = self.registry.stats()
        return {
            "n_live_epochs": s["live"],
            "n_pinned_leases": s["pinned"],
            "n_queued_requests": self.queue.depth(),
        }
