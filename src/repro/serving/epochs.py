"""Refcounted epoch registry: the MVCC core of the serving tier.

One *epoch entry* is an immutable read view of the KB at a published
store epoch: a pinned :class:`~repro.core.frozen.FrozenFacts` snapshot
plus the :class:`~repro.query.QueryEngine` serving it (with its own
epoch-stamped plan/result caches).  The registry holds every entry that
is either *current* or still pinned by a reader:

* :meth:`publish` installs a new current entry; the previous one is
  retired immediately if unpinned, otherwise it survives until its last
  lease is released,
* :meth:`pin` hands out an :class:`EpochLease` on the current entry —
  an O(1) refcount bump under a mutex, never blocking on readers or the
  writer's apply work,
* retirement runs the ``on_retire`` callback (the tier counts it and
  drops the snapshot, letting GC reclaim the epoch's arrays).

Registry *versions* increase by one per publish and are decoupled from
store epochs: a compaction republishes the same store epoch under a new
version because the old entry's pinned meta-facts hold pre-compaction
node ids.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

__all__ = ["EpochEntry", "EpochLease", "EpochRegistry"]


@dataclass
class EpochEntry:
    """One published read view (identity: registry ``version``)."""

    version: int
    epoch: int            # IncrementalStore.epoch at publish time
    frozen: object        # pinned FrozenFacts snapshot
    engine: object        # QueryEngine over ``frozen``
    refs: int = 0
    retired: bool = False
    payload: dict = field(default_factory=dict)


class EpochLease:
    """Context-managed pin on one epoch entry (release-once)."""

    def __init__(self, registry: EpochRegistry, entry: EpochEntry):
        self._registry = registry
        self._entry = entry
        self._released = False

    @property
    def version(self) -> int:
        return self._entry.version

    @property
    def epoch(self) -> int:
        return self._entry.epoch

    @property
    def frozen(self):
        return self._entry.frozen

    @property
    def engine(self):
        return self._entry.engine

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._registry._unpin(self._entry)

    def __enter__(self) -> EpochLease:
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class EpochRegistry:
    """Never-blocking refcounted registry of live epoch entries."""

    def __init__(self, on_retire=None):
        self._lock = threading.Lock()
        self._entries: dict[int, EpochEntry] = {}
        self._current: EpochEntry | None = None
        self._next_version = 0
        self._on_retire = on_retire
        self.published = 0
        self.retired = 0

    # ------------------------------------------------------------------ #
    @property
    def version(self) -> int:
        """Version of the current entry (-1 before the first publish)."""
        with self._lock:
            return self._current.version if self._current else -1

    @property
    def current(self) -> EpochEntry | None:
        with self._lock:
            return self._current

    def publish(self, epoch: int, frozen, engine, **payload) -> EpochEntry:
        """Install a new current read view; retire the previous one if
        (and only if) no lease still pins it."""
        to_retire = None
        with self._lock:
            entry = EpochEntry(
                version=self._next_version,
                epoch=epoch,
                frozen=frozen,
                engine=engine,
                payload=dict(payload),
            )
            self._next_version += 1
            self._entries[entry.version] = entry
            prev, self._current = self._current, entry
            self.published += 1
            if prev is not None and prev.refs == 0:
                to_retire = self._retire_locked(prev)
        self._run_retire(to_retire)
        return entry

    def pin(self) -> EpochLease:
        """Lease the current entry (O(1); raises before first publish)."""
        with self._lock:
            if self._current is None:
                raise RuntimeError("no epoch published yet")
            self._current.refs += 1
            return EpochLease(self, self._current)

    def _unpin(self, entry: EpochEntry) -> None:
        to_retire = None
        with self._lock:
            entry.refs -= 1
            if (
                entry.refs == 0
                and entry is not self._current
                and not entry.retired
            ):
                to_retire = self._retire_locked(entry)
        self._run_retire(to_retire)

    def _retire_locked(self, entry: EpochEntry) -> EpochEntry:
        entry.retired = True
        del self._entries[entry.version]
        self.retired += 1
        return entry

    def _run_retire(self, entry: EpochEntry | None) -> None:
        # run callbacks outside the lock: they may take other locks
        if entry is not None and self._on_retire is not None:
            self._on_retire(entry)

    # ------------------------------------------------------------------ #
    def n_live(self) -> int:
        with self._lock:
            return len(self._entries)

    def n_pinned(self) -> int:
        """Total outstanding leases across all live entries."""
        with self._lock:
            return sum(e.refs for e in self._entries.values())

    def pinned_epochs(self) -> set[int]:
        """Store epochs still pinned by at least one lease (the storage
        layer keeps their snapshots/WAL suffix alive; see
        ``CheckpointManager.attach_epoch_source``)."""
        with self._lock:
            return {e.epoch for e in self._entries.values() if e.refs > 0}

    def live_versions(self) -> list[int]:
        with self._lock:
            return sorted(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {
                "published": self.published,
                "retired": self.retired,
                "live": len(self._entries),
                "pinned": sum(e.refs for e in self._entries.values()),
                "version": self._current.version if self._current else -1,
                "epoch": self._current.epoch if self._current else -1,
            }
