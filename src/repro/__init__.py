"""CompMat-JAX: Datalog reasoning over compressed RDF knowledge bases
(Hu, Urbani, Motik, Horrocks — CIKM 2019) as a production JAX framework.

Subpackages: ``core`` (the paper's engine), ``query`` (BGP answering
over the frozen store), ``incremental`` (DRed/counting maintenance
under live updates), ``kernels`` (Pallas hot spots),
``models``/``configs`` (the 10 assigned architectures),
``data``/``optim``/``train`` (training substrate), ``launch`` (meshes,
sharding, dry-run, drivers), ``roofline`` (HLO cost analysis).
"""

__version__ = "1.0.0"
