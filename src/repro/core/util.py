"""Vectorised multi-column set operations (host / numpy path).

These replace the paper's priority-queue merge loops with data-parallel
sorted-array primitives — the same adaptation the Pallas kernels make on
TPU (see ``repro.kernels``).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "factorize_rows",
    "multicol_member",
    "first_occurrence_mask",
    "sorted_member",
]


def sorted_member(a: np.ndarray, b_sorted: np.ndarray) -> np.ndarray:
    """Membership of each element of ``a`` in the sorted 1-D array ``b``."""
    if b_sorted.shape[0] == 0:
        return np.zeros(a.shape[0], dtype=bool)
    idx = np.searchsorted(b_sorted, a)
    idx = np.minimum(idx, b_sorted.shape[0] - 1)
    return b_sorted[idx] == a


def factorize_rows(*row_sets: np.ndarray) -> list[np.ndarray]:
    """Jointly factorize several ``(n_i, k)`` row sets into int codes
    such that two rows (from any set) get equal codes iff they are equal
    (codes are order-consistent with row order, not necessarily dense).

    Pairs of dictionary-range ids (the dominant RDF case after vertical
    partitioning) take a packing fast path — ``(a << 32) | b`` preserves
    equality and lexicographic order and skips the O(n log n)
    ``np.unique(axis=0)`` void-view sort entirely."""
    k = row_sets[0].shape[1] if row_sets[0].ndim == 2 else 1
    splits = np.cumsum([r.shape[0] for r in row_sets])[:-1]
    stacked = np.concatenate([np.atleast_2d(r.reshape(r.shape[0], -1)) for r in row_sets])
    if stacked.shape[0] == 0:
        return [np.zeros(r.shape[0], dtype=np.int64) for r in row_sets]
    if k == 0:
        codes = np.zeros(stacked.shape[0], dtype=np.int64)
    elif k == 1:
        codes = stacked[:, 0]
    elif k == 2 and stacked.min() >= 0 and stacked.max() < 2**31:
        codes = (stacked[:, 0] << 32) | stacked[:, 1]
    else:
        _, codes = np.unique(stacked, axis=0, return_inverse=True)
    codes = codes.astype(np.int64)
    return list(np.split(codes, splits))


def multicol_member(a_rows: np.ndarray, b_rows: np.ndarray) -> np.ndarray:
    """Boolean mask: which rows of ``a_rows`` occur in ``b_rows``."""
    n = a_rows.shape[0]
    if n == 0:
        return np.zeros(0, dtype=bool)
    if b_rows.shape[0] == 0:
        return np.zeros(n, dtype=bool)
    if a_rows.ndim == 2 and a_rows.shape[1] == 1:
        a_rows, b_rows = a_rows[:, 0], b_rows[:, 0]
    if a_rows.ndim == 1:
        return sorted_member(a_rows, np.sort(b_rows))
    codes_a, codes_b = factorize_rows(a_rows, b_rows)
    return sorted_member(codes_a, np.sort(codes_b))


def first_occurrence_mask(codes: np.ndarray) -> np.ndarray:
    """Mask of positions that are the first occurrence of their value."""
    n = codes.shape[0]
    if n == 0:
        return np.zeros(0, dtype=bool)
    order = np.argsort(codes, kind="stable")
    sorted_codes = codes[order]
    is_first_sorted = np.empty(n, dtype=bool)
    is_first_sorted[0] = True
    is_first_sorted[1:] = sorted_codes[1:] != sorted_codes[:-1]
    mask = np.zeros(n, dtype=bool)
    mask[order] = is_first_sorted
    return mask
