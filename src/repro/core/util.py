"""Vectorised multi-column set operations (host / numpy path).

These replace the paper's priority-queue merge loops with data-parallel
sorted-array primitives — the same adaptation the Pallas kernels make on
TPU (see ``repro.kernels``).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "factorize_rows",
    "multicol_member",
    "first_occurrence_mask",
    "merge_sorted_rows_np",
    "merge_sorted_unique_np",
    "sorted_member",
    "unique_rows",
]


def sorted_member(a: np.ndarray, b_sorted: np.ndarray) -> np.ndarray:
    """Membership of each element of ``a`` in the sorted 1-D array ``b``."""
    if b_sorted.shape[0] == 0:
        return np.zeros(a.shape[0], dtype=bool)
    idx = np.searchsorted(b_sorted, a)
    idx = np.minimum(idx, b_sorted.shape[0] - 1)
    return b_sorted[idx] == a


def factorize_rows(*row_sets: np.ndarray) -> list[np.ndarray]:
    """Jointly factorize several ``(n_i, k)`` row sets into int codes
    such that two rows (from any set) get equal codes iff they are equal
    (codes are order-consistent with row order, not necessarily dense).

    Pairs of dictionary-range ids (the dominant RDF case after vertical
    partitioning) take a packing fast path — ``(a << 32) | b`` preserves
    equality and lexicographic order and skips the O(n log n)
    ``np.unique(axis=0)`` void-view sort entirely."""
    k = row_sets[0].shape[1] if row_sets[0].ndim == 2 else 1
    splits = np.cumsum([r.shape[0] for r in row_sets])[:-1]
    stacked = np.concatenate([np.atleast_2d(r.reshape(r.shape[0], -1)) for r in row_sets])
    if stacked.shape[0] == 0:
        return [np.zeros(r.shape[0], dtype=np.int64) for r in row_sets]
    if k == 0:
        codes = np.zeros(stacked.shape[0], dtype=np.int64)
    elif k == 1:
        codes = stacked[:, 0]
    elif k == 2 and stacked.min() >= 0 and stacked.max() < 2**31:
        codes = (stacked[:, 0] << 32) | stacked[:, 1]
    else:
        _, codes = np.unique(stacked, axis=0, return_inverse=True)
    codes = codes.astype(np.int64)
    return list(np.split(codes, splits))


def multicol_member(a_rows: np.ndarray, b_rows: np.ndarray) -> np.ndarray:
    """Boolean mask: which rows of ``a_rows`` occur in ``b_rows``."""
    n = a_rows.shape[0]
    if n == 0:
        return np.zeros(0, dtype=bool)
    if b_rows.shape[0] == 0:
        return np.zeros(n, dtype=bool)
    if a_rows.ndim == 2 and a_rows.shape[1] == 1:
        a_rows, b_rows = a_rows[:, 0], b_rows[:, 0]
    if a_rows.ndim == 1:
        return sorted_member(a_rows, np.sort(b_rows))
    codes_a, codes_b = factorize_rows(a_rows, b_rows)
    return sorted_member(codes_a, np.sort(codes_b))


def unique_rows(rows: np.ndarray, return_inverse: bool = False):
    """Lexicographically sorted unique rows of an ``(n, k)`` block.

    Drop-in for ``np.unique(rows, axis=0)`` with the packed-int64 fast
    path of :func:`factorize_rows` for k <= 2: packing ``(a << 32) | b``
    preserves lexicographic order for dictionary-range ids, so the
    axis-unique void-view sort (~2x slower, measured in PR 3) is only
    needed for wider rows or out-of-range values.
    """
    rows = np.asarray(rows)
    n, k = rows.shape
    if k == 1:
        u, inv = np.unique(rows[:, 0], return_inverse=True)
        out = u.reshape(-1, 1).astype(rows.dtype, copy=False)
        return (out, inv) if return_inverse else out
    if k == 2 and n and rows.min() >= 0 and rows.max() < 2**31:
        codes = (rows[:, 0].astype(np.int64) << 32) | rows[:, 1].astype(np.int64)
        u, inv = np.unique(codes, return_inverse=True)
        out = np.stack([u >> 32, u & 0xFFFFFFFF], axis=1).astype(
            rows.dtype, copy=False
        )
        return (out, inv) if return_inverse else out
    if return_inverse:
        out, inv = np.unique(rows, axis=0, return_inverse=True)
        return out, inv.reshape(-1)
    return np.unique(rows, axis=0)


def merge_sorted_unique_np(old: np.ndarray, fresh: np.ndarray) -> np.ndarray:
    """Positional merge of sorted-unique ``fresh`` values into the
    sorted-unique array ``old`` — ``fresh`` must be disjoint from
    ``old`` (anti-joined first).  O(m log n + n) instead of the
    re-sort-everything O((n+m) log(n+m)) the per-round ``np.unique``
    pays; this is the host analogue of the ``merge_sorted_unique``
    Pallas kernel."""
    if fresh.shape[0] == 0:
        return old
    if old.shape[0] == 0:
        return fresh
    dest = np.searchsorted(old, fresh) + np.arange(fresh.shape[0])
    out = np.empty(old.shape[0] + fresh.shape[0], dtype=old.dtype)
    taken = np.zeros(out.shape[0], dtype=bool)
    taken[dest] = True
    out[dest] = fresh
    out[~taken] = old
    return out


def merge_sorted_rows_np(
    old: np.ndarray,
    fresh: np.ndarray,
    codes_old: np.ndarray,
    codes_fresh: np.ndarray,
) -> np.ndarray:
    """Row-block analogue of :func:`merge_sorted_unique_np`: positionally
    merge lex-sorted-unique, disjoint ``fresh`` rows into lex-sorted-
    unique ``old`` rows.  ``codes_*`` are jointly order-consistent row
    codes (one :func:`factorize_rows` call) used for the placement
    search, so no column is re-sorted."""
    if fresh.shape[0] == 0:
        return old
    if old.shape[0] == 0:
        return fresh
    dest = np.searchsorted(codes_old, codes_fresh) + np.arange(fresh.shape[0])
    out = np.empty((old.shape[0] + fresh.shape[0], old.shape[1]), dtype=old.dtype)
    taken = np.zeros(out.shape[0], dtype=bool)
    taken[dest] = True
    out[dest] = fresh
    out[~taken] = old
    return out


def first_occurrence_mask(codes: np.ndarray) -> np.ndarray:
    """Mask of positions that are the first occurrence of their value."""
    n = codes.shape[0]
    if n == 0:
        return np.zeros(0, dtype=bool)
    order = np.argsort(codes, kind="stable")
    sorted_codes = codes[order]
    is_first_sorted = np.empty(n, dtype=bool)
    is_first_sorted[0] = True
    is_first_sorted[1:] = sorted_codes[1:] != sorted_codes[:-1]
    mask = np.zeros(n, dtype=bool)
    mask[order] = is_first_sorted
    return mask
