"""Vectorised meta-substitution joins: match / sjoin / xjoin (Alg. 3-5).

A :class:`SubstSet` is the engine's working set ``L`` from Algorithm 1: a
variable order plus a list of meta-substitutions, each a tuple of column
ids (one per variable, equal unfolding length).

TPU/vector adaptation (see DESIGN.md §3): the paper enumerates
substitutions through priority queues; we instead

* *materialise only the join-key columns* (unfold + cache),
* evaluate semi-joins as sorted-membership tests (``searchsorted``),
* evaluate cross-joins by grouping the right side on the key with one
  ``compress`` per group, sharing each group's meta-constants across all
  matching left rows (identical output representation to Algorithm 5).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .columns import ColumnStore
from .compress import compress_grouped, sort_for_compression
from .metafacts import MetaFact
from .util import factorize_rows, multicol_member

__all__ = ["SubstSet", "match", "sjoin", "xjoin"]


@dataclass
class SubstSet:
    """A set of meta-substitutions over a fixed variable order."""

    vars: tuple[str, ...]
    items: list[tuple[tuple[int, ...], int]] = field(default_factory=list)
    # items: (column ids aligned with ``vars``, unfolding length)

    def is_empty(self) -> bool:
        return not self.items

    def n_substitutions(self) -> int:
        return sum(length for _, length in self.items)


def _unfold_cols(store: ColumnStore, items, var_idx: list[int]) -> np.ndarray:
    """Unfold selected columns of every item into one ``(n, k)`` array."""
    if not items:
        return np.zeros((0, len(var_idx)), dtype=np.int64)
    cols = []
    for j in var_idx:
        cols.append(np.concatenate([store.unfold(cols_ids[j]) for cols_ids, _ in items]))
    if not var_idx:
        n = sum(length for _, length in items)
        return np.zeros((n, 0), dtype=np.int64)
    return np.stack(cols, axis=1)


def _filter_items(
    store: ColumnStore,
    subst: SubstSet,
    mask: np.ndarray,
    inplace_splits: bool = False,
) -> SubstSet:
    """Keep only the positions of ``mask`` in each item, via the paper's
    shuffle: untouched items are shared as-is; touched items have every
    column split (Algorithm 4)."""
    out = SubstSet(subst.vars)
    off = 0
    for cols_ids, length in subst.items:
        sub = mask[off : off + length]
        off += length
        if sub.all():
            out.items.append((cols_ids, length))
        elif sub.any():
            split_of = {
                c: store.split(c, sub, inplace=inplace_splits)
                for c in dict.fromkeys(cols_ids)
            }
            new_cols = tuple(split_of[c] for c in cols_ids)
            out.items.append((new_cols, int(sub.sum())))
    return out


# --------------------------------------------------------------------- #
# match (Appendix A.1, last paragraph)
# --------------------------------------------------------------------- #
def match(
    atom,
    facts: list[MetaFact],
    store: ColumnStore,
    inplace_splits: bool = False,
) -> SubstSet:
    """All meta-substitutions matching ``atom`` against a meta-fact list.

    Handles constants in the atom and repeated variables by masking +
    shuffle, exactly as the paper's ``match``/``shuffle`` combination.
    """
    vars_ = atom.variables()
    var_first_pos = {v: atom.terms.index(v) for v in vars_}
    needs_mask = any(isinstance(t, int) for t in atom.terms) or len(vars_) != len(
        atom.terms
    )
    out = SubstSet(vars_)
    for mf in facts:
        if len(mf.columns) != len(atom.terms):
            continue
        if not needs_mask:
            cols = tuple(mf.columns[var_first_pos[v]] for v in vars_)
            out.items.append((cols, mf.length))
            continue
        mask = np.ones(mf.length, dtype=bool)
        for pos, t in enumerate(atom.terms):
            if isinstance(t, int):  # constant
                mask &= store.unfold(mf.columns[pos]) == t
            elif pos != var_first_pos[t]:  # repeated variable
                mask &= store.unfold(mf.columns[pos]) == store.unfold(
                    mf.columns[var_first_pos[t]]
                )
        if not mask.any():
            continue
        cols = tuple(mf.columns[var_first_pos[v]] for v in vars_)
        if mask.all():
            out.items.append((cols, mf.length))
        else:
            if inplace_splits:
                # In-place redefinition is only sound if *every* column of
                # the source meta-fact is co-split with the same mask
                # (positional alignment) — including duplicate-variable
                # positions the result does not use.
                split_of = {}
                for c in dict.fromkeys(mf.columns):
                    split_of[c] = store.split(c, mask, inplace=True)
                new_cols = tuple(split_of[mf.columns[var_first_pos[v]]] for v in vars_)
            else:
                new_cols = tuple(store.split(c, mask, inplace=False) for c in cols)
            out.items.append((new_cols, int(mask.sum())))
    return out


# --------------------------------------------------------------------- #
# semi-join (Algorithm 3)
# --------------------------------------------------------------------- #
def sjoin(
    filter_set: SubstSet,
    data_set: SubstSet,
    key_vars: tuple[str, ...],
    store: ColumnStore,
    inplace_splits: bool = False,
) -> SubstSet:
    """Filter ``data_set`` to the substitutions whose key tuple occurs in
    ``filter_set`` (vars(filter) ⊇ key_vars, vars(data) ⊇ key_vars).

    The paper's queue-merge becomes one sorted-membership test; survivors
    are re-expressed with structure sharing through ``shuffle``.
    """
    if data_set.is_empty() or filter_set.is_empty():
        return SubstSet(data_set.vars)
    f_idx = [filter_set.vars.index(v) for v in key_vars]
    d_idx = [data_set.vars.index(v) for v in key_vars]
    filter_keys = _unfold_cols(store, filter_set.items, f_idx)
    data_keys = _unfold_cols(store, data_set.items, d_idx)
    mask = multicol_member(data_keys, filter_keys)
    if not mask.any():
        return SubstSet(data_set.vars)
    return _filter_items(store, data_set, mask, inplace_splits)


# --------------------------------------------------------------------- #
# cross-join (Algorithm 5)
# --------------------------------------------------------------------- #
def xjoin(
    left: SubstSet,
    right: SubstSet,
    key_vars: tuple[str, ...],
    store: ColumnStore,
) -> SubstSet:
    """General equi-join with structure-shared output.

    For every join-key group, the right side's non-key columns are
    compressed **once**; every matching left row then emits meta-
    substitutions that reference the group's meta-constants, with the left
    values as O(1) RLE-constant columns (paper Alg. 5 lines 63-72).
    Output storage is O(|L| + |R|) instead of O(|L| x |R|).

    ``key_vars`` may be empty, in which case this is a Cartesian product
    with a single group.
    """
    out_vars = tuple(left.vars) + tuple(v for v in right.vars if v not in left.vars)
    out = SubstSet(out_vars)
    if left.is_empty() or right.is_empty():
        return out

    l_key_idx = [left.vars.index(v) for v in key_vars]
    r_key_idx = [right.vars.index(v) for v in key_vars]
    r_rest_vars = [v for v in right.vars if v not in key_vars and v not in left.vars]
    r_rest_idx = [right.vars.index(v) for v in r_rest_vars]

    l_keys = _unfold_cols(store, left.items, l_key_idx)
    r_keys = _unfold_cols(store, right.items, r_key_idx)
    l_all = _unfold_cols(store, left.items, list(range(len(left.vars))))
    r_rest = _unfold_cols(store, right.items, r_rest_idx)

    codes_l, codes_r = factorize_rows(l_keys, r_keys)

    # sort right by (key code, rest columns) so each group is
    # compression-ready; sort left by key code
    if r_rest.shape[1] > 0:
        # One global permutation: key primary, rest columns secondary with
        # fewest-distinct-first inside the group (compression-friendly).
        n_distinct = [np.unique(r_rest[:, j]).shape[0] for j in range(r_rest.shape[1])]
        col_order = np.argsort(n_distinct, kind="stable")
        keys = tuple(r_rest[:, j] for j in reversed(col_order)) + (codes_r,)
        r_perm = np.lexsort(keys)
    else:
        r_perm = np.argsort(codes_r, kind="stable")
    codes_r_s = codes_r[r_perm]
    r_rest_s = r_rest[r_perm]
    l_perm = np.argsort(codes_l, kind="stable")
    codes_l_s = codes_l[l_perm]
    l_all_s = l_all[l_perm]

    # group boundaries on the right
    uniq_r, r_starts = np.unique(codes_r_s, return_index=True)
    r_ends = np.append(r_starts[1:], codes_r_s.shape[0])
    # which right-groups have any left match, and the left span per group
    l_lo = np.searchsorted(codes_l_s, uniq_r, side="left")
    l_hi = np.searchsorted(codes_l_s, uniq_r, side="right")
    has_match = l_hi > l_lo
    if not has_match.any():
        return out

    m_starts = r_starts[has_match]
    m_ends = r_ends[has_match]
    m_l_lo = l_lo[has_match]
    m_l_hi = l_hi[has_match]

    if r_rest_s.shape[1] > 0:
        # The paper's T is a *set* (Alg. 5 line 65): drop duplicate rest-rows
        # within each group before compressing.  Rows are sorted within
        # groups, so duplicates are consecutive.
        n_r = codes_r_s.shape[0]
        dup = np.zeros(n_r, dtype=bool)
        if n_r > 1:
            dup[1:] = (r_rest_s[1:] == r_rest_s[:-1]).all(axis=1) & (
                codes_r_s[1:] == codes_r_s[:-1]
            )
        if dup.any():
            keep_rows = ~dup
            # remap group boundaries to the deduplicated index space
            pos = np.cumsum(keep_rows) - 1  # new index of each kept row
            m_starts = pos[m_starts]
            m_ends = np.searchsorted(np.flatnonzero(keep_rows), m_ends)
            r_rest_s = r_rest_s[keep_rows]
        groups = compress_grouped(m_starts, m_ends, r_rest_s, store)
    else:
        groups = [[((), 1)] for _ in range(len(m_starts))]

    n_left_vars = len(left.vars)
    for g, (llo, lhi) in enumerate(zip(m_l_lo, m_l_hi)):
        pieces = groups[g]
        for li in range(int(llo), int(lhi)):
            lrow = l_all_s[li]
            for piece_cols, plen in pieces:
                cols = tuple(
                    store.new_constant(int(lrow[j]), plen) for j in range(n_left_vars)
                ) + tuple(piece_cols)
                out.items.append((cols, plen))
    return out
