"""Datalog rules, programs, parsing, and RDF vertical partitioning.

Vertical partitioning (Section 2): a triple ``<s, rdf:type, C>`` becomes a
unary fact ``C(s)``; any other triple ``<s, P, o>`` becomes ``P(s, o)``.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from .terms import RDF_TYPE, Dictionary

__all__ = ["Atom", "Rule", "Program", "parse_program", "vertical_partition"]


@dataclass(frozen=True)
class Atom:
    """``P(t1, ..., tn)``; terms are variable names (str) or constant ids (int)."""

    predicate: str
    terms: tuple

    @property
    def arity(self) -> int:
        return len(self.terms)

    def variables(self) -> tuple[str, ...]:
        # unique, in order of first occurrence
        seen: list[str] = []
        for t in self.terms:
            if isinstance(t, str) and t not in seen:
                seen.append(t)
        return tuple(seen)

    def __str__(self) -> str:
        return f"{self.predicate}({', '.join(map(str, self.terms))})"


@dataclass(frozen=True)
class Rule:
    """``B1 ∧ ... ∧ Bn -> H`` with every head variable bound in the body."""

    body: tuple[Atom, ...]
    head: Atom

    def __post_init__(self):
        body_vars = {v for b in self.body for v in b.variables()}
        for v in self.head.variables():
            if v not in body_vars:
                raise ValueError(f"unsafe rule: head variable {v!r} unbound")

    def __str__(self) -> str:
        return " , ".join(map(str, self.body)) + " -> " + str(self.head)


@dataclass
class Program:
    rules: list[Rule] = field(default_factory=list)

    def __iter__(self):
        return iter(self.rules)

    def __len__(self) -> int:
        return len(self.rules)

    def predicates(self) -> set[str]:
        preds = set()
        for r in self.rules:
            preds.add(r.head.predicate)
            for b in r.body:
                preds.add(b.predicate)
        return preds


_ATOM_RE = re.compile(r"\s*([A-Za-z_][\w:.\-]*)\s*\(([^)]*)\)\s*")


def _parse_atom(text: str, dictionary: Dictionary | None) -> Atom:
    m = _ATOM_RE.fullmatch(text)
    if m is None:
        raise ValueError(f"cannot parse atom: {text!r}")
    pred = m.group(1)
    terms: list = []
    for raw in m.group(2).split(","):
        raw = raw.strip()
        if not raw:
            continue
        if raw[0] == "?" or (raw[0].islower() and raw.isidentifier() and len(raw) <= 3):
            # variables: ?x style, or short lowercase identifiers (x, y, zz)
            terms.append(raw.lstrip("?"))
        elif raw.startswith('"') or raw[0] == "<" or raw[0].isupper() or ":" in raw:
            if dictionary is None:
                raise ValueError(f"constant {raw!r} needs a dictionary")
            terms.append(dictionary.intern(raw.strip('"<>')))
        elif raw.lstrip("-").isdigit():
            # numeric literal: a raw constant id (negative ids occur only
            # as unknown-constant sentinels; they match no stored fact)
            terms.append(int(raw))
        elif raw.isidentifier():
            terms.append(raw)  # treat as variable
        else:
            raise ValueError(f"cannot interpret term {raw!r} in {text!r}")
    return Atom(pred, tuple(terms))


def parse_program(text: str, dictionary: Dictionary | None = None) -> Program:
    """Parse rules of the form ``P(x,y), R(x) -> S(x,y)`` (one per line).

    ``#``-prefixed lines are comments.  Constants (capitalised / quoted /
    prefixed tokens) are interned into ``dictionary``.
    """
    rules = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if "->" not in line:
            raise ValueError(f"rule missing '->': {line!r}")
        body_text, head_text = line.split("->")
        body = tuple(
            _parse_atom(a, dictionary) for a in _split_atoms(body_text) if a.strip()
        )
        head = _parse_atom(head_text, dictionary)
        rules.append(Rule(body, head))
    return Program(rules)


def _split_atoms(text: str) -> list[str]:
    """Split a conjunction on commas that are outside parentheses."""
    parts, depth, cur = [], 0, []
    for ch in text:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def vertical_partition(
    triples, dictionary: Dictionary
) -> dict[str, np.ndarray]:
    """Convert ``(s, p, o)`` string triples into per-predicate fact arrays.

    Returns ``{predicate: (n, arity) int64 array}`` with arity 1 for
    ``rdf:type`` triples (predicate = class name) and arity 2 otherwise.
    """
    unary: dict[str, list[int]] = {}
    binary: dict[str, list[tuple[int, int]]] = {}
    for s, p, o in triples:
        if p == RDF_TYPE:
            unary.setdefault(o, []).append(dictionary.intern(s))
        else:
            binary.setdefault(p, []).append(
                (dictionary.intern(s), dictionary.intern(o))
            )
    out: dict[str, np.ndarray] = {}
    for pred, subs in unary.items():
        out[pred] = np.asarray(subs, dtype=np.int64).reshape(-1, 1)
    for pred, pairs in binary.items():
        out[pred] = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    return out
