"""Algorithm 1: the CompMat semi-naive materialisation engine.

The fixpoint loop runs on the host (round count is data dependent and
small, as in the paper); per-round bulk work (compression, joins, dedup)
is vectorised column arithmetic — the numpy host path here, with the same
primitives available as Pallas TPU kernels (``repro.kernels``) and as a
``shard_map`` distributed engine (``repro.core.distributed``).

Rule bodies are conjunctive queries: each (rule, delta-pivot) pair is
compiled through the shared body compiler (:mod:`repro.core.compile`) —
the delta atom anchors the plan, remaining atoms are ordered by connected
selectivity, and the sjoin/xjoin kind choice is plan metadata rather than
an engine-loop dispatch.  Plans are cached per (rule, pivot) and
re-planned only when a body predicate's cardinality bucket shifts.  The
fixpoint itself runs stratum-by-stratum over the SCC condensation of the
predicate dependency graph (:mod:`repro.core.program_graph`), and within
a round, (rule, pivot) pairs whose pivot predicate received no delta are
skipped without even a match probe (``rule_applications_skipped``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..obs import get_registry, publish_materialisation, span
from ..obs.memory import register_reporter
from .columns import ColumnStore
from .compile import FactStoreStats, Plan, PlanCache, compile_body, stats_bucket
from .compress import compress_rows
from .datalog import Program, Rule
from .dedup import elim_dup
from .frozen import SortedRows
from .joins import SubstSet, _unfold_cols, match, sjoin, xjoin
from .metafacts import FactStore, MetaFact, flat_repr_size
from .program_graph import stratify
from .util import factorize_rows, unique_rows

__all__ = ["CMatEngine", "MaterialisationStats"]

#: below this many represented facts a constant-bound ``old`` scan just
#: re-matches the meta-fact lists; above it the sorted snapshot pays off
_OLD_SNAPSHOT_MIN_ROWS = 256


class _OldPartitionSnapshots:
    """Sorted flat snapshots of per-predicate ``old`` partitions.

    In late semi-naive rounds the ``old`` partition is large and changes
    by one small delta per round; re-matching its meta-fact list on a
    constant-bound (or repeated-variable) atom unfolds and masks the
    whole partition every time.  This cache keeps a
    :class:`~repro.core.frozen.SortedRows` per predicate and *merges in*
    only the rounds that entered ``old`` since the last request, so a
    scan is one binary search + gather (ROADMAP: snapshot-backed rule
    evaluation).  Built lazily — predicates never scanned this way cost
    nothing.
    """

    def __init__(self, store: ColumnStore):
        self.store = store
        self._snap: dict[str, SortedRows] = {}
        self._upto: dict[str, int] = {}  # rounds < upto are merged

    def get(self, facts: FactStore, pred: str) -> SortedRows:
        r = facts.current_round
        sr = self._snap.get(pred)
        upto = self._upto.get(pred, 0)
        if sr is None:
            rows = facts.unfold_pred(pred, "old")
            sr = SortedRows(unique_rows(rows))
        elif upto < r:
            fresh = [
                mf for mf in facts.all(pred) if upto <= mf.round < r
            ]
            if fresh:
                cols = [
                    np.concatenate(
                        [self.store.unfold(mf.columns[j]) for mf in fresh]
                    )
                    for j in range(fresh[0].arity)
                ]
                merged = np.concatenate(
                    [sr.rows, np.stack(cols, axis=1)]
                )
                sr = SortedRows(unique_rows(merged))
        self._snap[pred] = sr
        self._upto[pred] = r
        return sr


@dataclass
class MaterialisationStats:
    rounds: int = 0
    n_rule_applications: int = 0
    #: (rule, pivot) evaluations avoided without a match probe: the pivot
    #: predicate received no delta, or a body predicate is still empty
    rule_applications_skipped: int = 0
    n_strata: int = 0
    n_meta_facts: int = 0
    n_facts: int = 0
    #: constant-bound ``old`` scans served from sorted snapshots instead
    #: of re-matching the partition's meta-fact list
    old_snapshot_scans: int = 0
    time_compress: float = 0.0
    time_match: float = 0.0
    time_join: float = 0.0
    time_dedup: float = 0.0
    time_total: float = 0.0
    per_round: list[dict] = field(default_factory=list)
    per_stratum: list[dict] = field(default_factory=list)
    plan_cache: dict = field(default_factory=dict)

    def dominant_phase(self) -> str:
        phases = {
            "compress": self.time_compress,
            "match": self.time_match,
            "join": self.time_join,
            "dedup": self.time_dedup,
        }
        return max(phases, key=phases.get)


class CMatEngine:
    """Compressed datalog materialisation (the paper's CMat, Algorithm 1)."""

    def __init__(
        self,
        program: Program,
        inplace_splits: bool = False,
        max_rounds: int = 10_000,
        dedup_index: bool = False,
        plan_bodies: bool = True,
        stratify_program: bool = True,
        plan_cache: PlanCache | None = None,
        snapshot_old_scans: bool = True,
        fused: bool = False,
        fused_max_pairs: int = 1 << 22,
    ):
        # ``inplace_splits=True`` is the paper's Algorithm 4 accounting
        # (mu(a) := b_in.b_out).  We found it unsound in general: a split
        # that reaches a leaf shared with a meta-fact whose *other* columns
        # are not co-split with the same mask silently permutes one column
        # of that meta-fact (reachable via projection heads, e.g.
        # ``P(x,y) -> W(x)``).  The sound default copies the survivors into
        # fresh leaves; fully-novel derivations still share wholesale, so
        # the headline compression results are unaffected (see DESIGN.md).
        #
        # ``plan_bodies=False`` keeps the strict left-to-right body order
        # (the reference evaluation for differential testing);
        # ``stratify_program=False`` runs every rule in every round.
        self.program = program
        self.store = ColumnStore()
        self.facts = FactStore(self.store)
        self.inplace_splits = inplace_splits
        self.max_rounds = max_rounds
        self.stats = MaterialisationStats()
        self.plan_bodies = plan_bodies
        self.stratify_program = stratify_program
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self._stats_view = FactStoreStats(self.facts)
        # snapshots record unfolding *values*; in-place shuffle splits
        # redefine node orderings mid-round, so the cache is only sound
        # in the copy-mode default
        self._old_snaps = (
            _OldPartitionSnapshots(self.store)
            if snapshot_old_scans and not inplace_splits
            else None
        )
        self._explicit: dict[str, np.ndarray] = {}
        # ``fused=True`` is the device-resident fast path retimed for the
        # host: rules whose plan ends in an xjoin skip the compress →
        # unfold → split round-trip (the measured hot spot: per-group
        # leaf creation in ``compress_grouped`` followed by ``elim_dup``
        # immediately re-unfolding those same leaves) and instead emit
        # flat head rows straight into a packed-code dedup against a
        # persistent ``FactBuffers`` index; only the genuinely-new
        # survivors are compressed, once, per predicate.  This is the
        # same join→dedup→merge dataflow as the ``fused_join_dedup`` /
        # ``merge_sorted_unique`` Pallas kernels, so on-device rounds and
        # host rounds share one shape.  ``fused_max_pairs`` caps the
        # transient flat join output; a wider join falls back to the
        # structure-shared xjoin for that rule application.
        self.fused = fused
        self.fused_max_pairs = fused_max_pairs
        # persistent sorted dedup index (speed for memory — the paper's
        # reported bottleneck is dedup re-unpacking; see DedupIndex).
        # Fused mode requires it: the flat tail's dedup IS the index.
        if fused:
            from ..kernels.buffers import FactBuffers

            self._dedup_index = FactBuffers()
        else:
            from .dedup import DedupIndex

            self._dedup_index = DedupIndex() if dedup_index else None
        # rule ids are program positions (shared by every engine and the
        # provenance journal); duplicates keep their first position
        self._rule_ids: dict[Rule, int] = {}
        for k, rule in enumerate(program):
            self._rule_ids.setdefault(rule, k)
        self._journal = None  # bound per-materialise when recording is on
        # obs.memory: the engine reports its side structures; the
        # ColumnStore and a FactBuffers dedup index self-register
        register_reporter("cmat", self)

    def memory_report(self) -> dict[str, int]:
        """obs.memory reporter: explicit rows, lazy old-partition
        snapshots, and a ``DedupIndex`` (which, unlike ``FactBuffers``,
        does not register itself)."""
        out = {
            "explicit_bytes": sum(
                int(r.nbytes) for r in self._explicit.values()
            ),
            "old_snapshot_bytes": (
                0
                if self._old_snaps is None
                else sum(sr.nbytes for sr in self._old_snaps._snap.values())
            ),
        }
        idx = self._dedup_index
        if idx is not None and not hasattr(idx, "memory_report"):
            out["dedup_index_bytes"] = idx.nbytes()
        return out

    # ------------------------------------------------------------------ #
    def load(self, dataset: dict[str, np.ndarray]) -> None:
        """Compress the explicit dataset into meta-facts (Alg. 1 lines 1-4)."""
        t0 = time.perf_counter()
        for pred, rows in dataset.items():
            rows = np.asarray(rows, dtype=np.int64)
            if rows.ndim == 1:
                rows = rows.reshape(-1, 1)
            rows = unique_rows(rows)
            self._explicit[pred] = rows
            if self._dedup_index is not None:
                self._dedup_index.seed(pred, rows)
            for cols, length in compress_rows(rows, self.store):
                self.facts.add(MetaFact(pred, cols, length, round=0))
        self.stats.time_compress += time.perf_counter() - t0

    # ------------------------------------------------------------------ #
    def materialise(self) -> MaterialisationStats:
        """Run the stratified semi-naive fixpoint (Alg. 1 lines 6-23).

        Strata are processed in dependency order; within each stratum the
        first round evaluates every rule naively over all facts derived
        so far (none of its rules has ever run), and subsequent rounds
        are standard delta-restricted semi-naive iterations."""
        t_start = time.perf_counter()
        from ..obs.provenance import get_journal

        journal = get_journal()
        self._journal = journal if journal.enabled else None
        if self._journal is not None:
            journal.attach_program(self.program)
        strata = (
            stratify(self.program)
            if self.stratify_program
            else [list(self.program)]
        )
        self.stats.n_strata = len(strata)
        round_no = 0
        with span("cmat.materialise", n_strata=len(strata)):
            for si, stratum in enumerate(strata):
                naive = True
                s_rounds = 0
                s_round0 = len(self.stats.per_round)
                with span("cmat.stratum", stratum=si, rules=len(stratum)):
                    while round_no < self.max_rounds:
                        self.facts.current_round = round_no
                        if not naive and not self.facts.has_delta():
                            break
                        round_no += 1
                        s_rounds += 1
                        with span(
                            "cmat.round", round=round_no, stratum=si
                        ) as sp:
                            round_stats = self._round(
                                round_no, stratum, naive=naive,
                                stratum_idx=si,
                            )
                            sp.set(
                                new_facts=round_stats["new_facts"],
                                rule_applications=round_stats[
                                    "rule_applications"
                                ],
                            )
                        round_stats["stratum"] = si
                        self.stats.per_round.append(round_stats)
                        naive = False
                        if round_stats["new_meta_facts"] == 0:
                            break
                self.stats.per_stratum.append(
                    {
                        "stratum": si,
                        "rounds": s_rounds,
                        "rules": len(stratum),
                        "heads": sorted({r.head.predicate for r in stratum}),
                        "rule_applications": sum(
                            r["rule_applications"]
                            for r in self.stats.per_round[s_round0:]
                        ),
                    }
                )
        self.stats.rounds = round_no
        self.stats.n_meta_facts = self.facts.n_meta_facts()
        self.stats.n_facts = self.facts.n_facts()
        self.stats.plan_cache = self.plan_cache.counters()
        self.stats.time_total = time.perf_counter() - t_start
        publish_materialisation(self.stats)
        if self._journal is not None:
            self._journal.publish()
        return self.stats

    # ------------------------------------------------------------------ #
    def _round(
        self,
        round_no: int,
        rules: list[Rule],
        naive: bool = False,
        stratum_idx: int = 0,
    ) -> dict:
        facts, store = self.facts, self.store
        candidates: dict[str, list[tuple[tuple[int, ...], int]]] = {}
        flat_candidates: dict[str, list[np.ndarray]] = {}
        match_cache: dict = {}
        n_apps = 0
        n_skipped = 0
        # provenance: one pending entry per rule application; resolved
        # into DerivationRecords after dedup assigns fresh counts
        prov: list[dict] | None = [] if self._journal is not None else None
        self._stats_view.refresh()
        if naive:
            delta_preds = {p for p in facts.predicates() if facts.all(p)}
        else:
            delta_preds = {p for p in facts.predicates() if facts.delta(p)}

        def cached_match(atom, which: str) -> SubstSet:
            # naive-round plans are compiled with pivot=None, so every
            # scan reads "all" — no delta/old partition ever reaches here
            key = (atom.predicate, atom.terms, which)
            hit = match_cache.get(key)
            if hit is None:
                t0 = time.perf_counter()
                hit = self._snapshot_old_match(atom) if which == "old" else None
                if hit is None:
                    hit = match(
                        atom,
                        getattr(facts, which)(atom.predicate),
                        store,
                        self.inplace_splits,
                    )
                self.stats.time_match += time.perf_counter() - t0
                match_cache[key] = hit
            return hit

        for rule in rules:
            if not rule.body:  # body-less fact rule: nothing to evaluate
                continue
            # the naive (first-of-stratum) round evaluates each rule once
            # over all facts; with an empty ``old`` partition that is
            # exactly the pivot-0 evaluation, so higher pivots are void
            pivots = (0,) if naive else range(len(rule.body))
            for i in pivots:
                # semi-naive prefilter: no delta on the pivot predicate
                # means this (rule, pivot) cannot derive anything new —
                # skip it without even a match probe
                if rule.body[i].predicate not in delta_preds:
                    n_skipped += 1
                    continue
                plan = self._plan(rule, i, naive)
                if plan.is_empty:
                    # a body predicate is still empty: nothing to probe
                    n_skipped += 1
                    continue
                fused_tail = (
                    self.fused
                    and plan.joins
                    and plan.joins[-1].kind == "xjoin"
                    and len(rule.head.terms) <= 2
                )
                rid = self._rule_ids.get(rule, -1)
                t_app = time.perf_counter_ns() if prov is not None else 0
                with span(
                    "cmat.rule", head=rule.head.predicate, pivot=i,
                    rule_id=rid, stratum=stratum_idx,
                ):
                    if fused_tail:
                        result = self._eval_plan_fused(
                            plan, cached_match, rule,
                            (rule, None if naive else i),
                        )
                        if isinstance(result, np.ndarray):
                            if result.shape[0]:
                                n_apps += 1
                                pred = rule.head.predicate
                                if prov is not None:
                                    prov.append({
                                        "rule_id": rid,
                                        "pivot": -1 if naive else i,
                                        "pred": pred,
                                        "path": "flat",
                                        "block": len(
                                            flat_candidates.get(pred, [])
                                        ),
                                        "n_emitted": int(result.shape[0]),
                                        "in_ids": self._pivot_mf_ids(
                                            rule, i, naive
                                        ),
                                        "time_ns": time.perf_counter_ns()
                                        - t_app,
                                    })
                                flat_candidates.setdefault(
                                    pred, []
                                ).append(result)
                            continue
                        # wide join fell back to the structure-shared path
                    else:
                        result = self._eval_plan(
                            plan, cached_match, (rule, None if naive else i)
                        )
                if result is None or result.is_empty():
                    continue
                n_apps += 1
                pred = rule.head.predicate
                g0 = len(candidates.get(pred, []))
                self._emit_head(rule, result, candidates)
                if prov is not None:
                    groups = candidates.get(pred, [])[g0:]
                    prov.append({
                        "rule_id": rid,
                        "pivot": -1 if naive else i,
                        "pred": pred,
                        "path": "mu",
                        "groups": (g0, g0 + len(groups)),
                        "n_emitted": int(sum(ln for _, ln in groups)),
                        "in_ids": self._pivot_mf_ids(rule, i, naive),
                        "time_ns": time.perf_counter_ns() - t_app,
                    })

        t0 = time.perf_counter()
        fresh_mu: dict[str, list[int]] | None = {} if prov is not None else None
        fresh_flat: dict[str, list[int]] | None = (
            {} if prov is not None else None
        )
        with span("cmat.dedup", round=round_no):
            delta = elim_dup(candidates, facts, store, round_no,
                             self.inplace_splits, index=self._dedup_index,
                             fresh_counts=fresh_mu)
            if flat_candidates:
                delta.extend(
                    self._dedup_flat(
                        flat_candidates, round_no, fresh_counts=fresh_flat
                    )
                )
        self.stats.time_dedup += time.perf_counter() - t0

        # Alg. 1 line 23: re-compress length-one meta-facts
        t0 = time.perf_counter()
        with span("cmat.recompress", round=round_no):
            delta = self._recompress_singletons(delta, round_no)
        self.stats.time_compress += time.perf_counter() - t0

        for mf in delta:
            facts.add(mf)
        if prov:
            self._record_round(
                prov, fresh_mu, fresh_flat, delta, round_no, stratum_idx
            )
        self.stats.n_rule_applications += n_apps
        self.stats.rule_applications_skipped += n_skipped
        return {
            "round": round_no,
            "new_meta_facts": len(delta),
            "new_facts": sum(mf.length for mf in delta),
            "rule_applications": n_apps,
            "rule_applications_skipped": n_skipped,
        }

    # ------------------------------------------------------------------ #
    def _plan(self, rule: Rule, pivot: int, naive: bool) -> Plan:
        """Compile (rule, pivot) through the shared body compiler, cached
        per statistics bucket.  Naive rounds read every atom from ``all``
        (pivot ``None``) and are cached under their own key."""
        sv = self._stats_view
        key = (rule, None if naive else pivot)
        bucket = stats_bucket(sv, rule.body)
        return self.plan_cache.get(
            key,
            bucket,
            lambda: compile_body(
                rule.body,
                sv,
                pivot=None if naive else pivot,
                reorder=self.plan_bodies,
            ),
        )

    # ------------------------------------------------------------------ #
    def _snapshot_old_match(self, atom) -> SubstSet | None:
        """Serve a constrained ``old``-partition scan from the sorted
        snapshot cache (``None``: take the meta-fact-list path)."""
        if self._old_snaps is None:
            return None
        vars_ = atom.variables()
        constrained = any(isinstance(t, int) for t in atom.terms) or len(
            vars_
        ) != len(atom.terms)
        if not constrained:
            return None  # pure-variable scans share columns for free
        pred = atom.predicate
        old = self.facts.old(pred)
        if not old or old[0].arity != len(atom.terms):
            return None
        if sum(mf.length for mf in old) < _OLD_SNAPSHOT_MIN_ROWS:
            return None
        rows = self._old_snaps.get(self.facts, pred).match_atom(atom)
        self.stats.old_snapshot_scans += 1
        if not vars_:
            items = [((), int(rows.shape[0]))] if rows.shape[0] else []
            return SubstSet((), items)
        first_pos = {v: atom.terms.index(v) for v in vars_}
        cols = rows[:, [first_pos[v] for v in vars_]]
        if cols.shape[0] == 0:
            return SubstSet(vars_)
        return SubstSet(vars_, compress_rows(cols, self.store))

    # ------------------------------------------------------------------ #
    def _eval_plan(
        self, plan: Plan, cached_match, plan_key=None
    ) -> SubstSet | None:
        """Evaluate a compiled body plan (Alg. 1 lines 9-19, reordered).

        Scan sources (old/delta/all) and join kind/keys/direction all
        come from the plan; the engine only drives match/sjoin/xjoin."""
        L = cached_match(plan.first.atom, plan.first.source)
        if L.is_empty():
            return None
        if plan_key is not None:
            # estimated-vs-actual feedback: a badly-missed first-scan
            # estimate recalibrates the cached plan (see PlanCache)
            self.plan_cache.note_actual(
                plan_key, plan.first.est_rows, L.n_substitutions()
            )
        for step in plan.joins:
            R = cached_match(step.scan.atom, step.scan.source)
            if R.is_empty():
                return None
            t0 = time.perf_counter()
            if step.kind == "sjoin":
                if step.filter_left:
                    L = sjoin(R, L, step.key_vars, self.store,
                              self.inplace_splits)
                else:
                    L = sjoin(L, R, step.key_vars, self.store,
                              self.inplace_splits)
            else:
                L = xjoin(L, R, step.key_vars, self.store)
            self.stats.time_join += time.perf_counter() - t0
            if L.is_empty():
                return None
        return L

    # ------------------------------------------------------------------ #
    def _eval_plan_fused(
        self, plan: Plan, cached_match, rule: Rule, plan_key=None
    ) -> np.ndarray | SubstSet | None:
        """Fused-tail evaluation: run the plan as usual up to the final
        xjoin, then emit flat head rows directly instead of compressing
        the join output into the store (``fused_join_dedup`` dataflow on
        the host: span probe → pair gather → head projection; the dedup
        half happens once per predicate in :meth:`_dedup_flat`).

        Returns an ``(n, arity)`` int64 array normally; a ``SubstSet``
        when the transient pair count exceeds ``fused_max_pairs`` (the
        structure-shared xjoin fallback); ``None`` on an empty body."""
        L = cached_match(plan.first.atom, plan.first.source)
        if L.is_empty():
            return None
        if plan_key is not None:
            self.plan_cache.note_actual(
                plan_key, plan.first.est_rows, L.n_substitutions()
            )
        for step in plan.joins[:-1]:
            R = cached_match(step.scan.atom, step.scan.source)
            if R.is_empty():
                return None
            t0 = time.perf_counter()
            if step.kind == "sjoin":
                if step.filter_left:
                    L = sjoin(R, L, step.key_vars, self.store,
                              self.inplace_splits)
                else:
                    L = sjoin(L, R, step.key_vars, self.store,
                              self.inplace_splits)
            else:
                L = xjoin(L, R, step.key_vars, self.store)
            self.stats.time_join += time.perf_counter() - t0
            if L.is_empty():
                return None
        last = plan.joins[-1]
        R = cached_match(last.scan.atom, last.scan.source)
        if R.is_empty():
            return None
        t0 = time.perf_counter()
        with span("cmat.fused_tail", head=rule.head.predicate) as sp:
            rows = self._xjoin_head_rows(L, R, last.key_vars, rule.head, sp)
            sp.set(
                rows=0 if rows is None else int(rows.shape[0]),
                fallback=rows is None,
            )
        self.stats.time_join += time.perf_counter() - t0
        if rows is None:  # too wide: fall back to the compressed xjoin
            t0 = time.perf_counter()
            out = xjoin(L, R, last.key_vars, self.store)
            self.stats.time_join += time.perf_counter() - t0
            return None if out.is_empty() else out
        return rows

    def _xjoin_head_rows(
        self,
        left: SubstSet,
        right: SubstSet,
        key_vars: tuple[str, ...],
        head,
        sp=None,
    ) -> np.ndarray | None:
        """Cross-join ``left`` x ``right`` on ``key_vars`` and project the
        rule head in one pass, returning flat ``(n, arity)`` rows — no
        compression, no leaf creation.  ``None`` when the pair total
        exceeds ``fused_max_pairs`` (caller falls back to xjoin)."""
        store = self.store
        l_key_idx = [left.vars.index(v) for v in key_vars]
        r_key_idx = [right.vars.index(v) for v in key_vars]
        l_keys = _unfold_cols(store, left.items, l_key_idx)
        r_keys = _unfold_cols(store, right.items, r_key_idx)
        codes_l, codes_r = factorize_rows(l_keys, r_keys)
        r_perm = np.argsort(codes_r, kind="stable")
        codes_r_s = codes_r[r_perm]
        lo = np.searchsorted(codes_r_s, codes_l, side="left")
        hi = np.searchsorted(codes_r_s, codes_l, side="right")
        counts = hi - lo
        total = int(counts.sum())
        if sp is not None:
            sp.set(pairs=total)
        if total == 0:
            return np.zeros((0, len(head.terms)), dtype=np.int64)
        if total > self.fused_max_pairs:
            return None
        l_rep = np.repeat(np.arange(codes_l.shape[0]), counts)
        offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
        within = np.arange(total) - np.repeat(offsets, counts)
        r_sel = r_perm[np.repeat(lo, counts) + within]
        # head projection straight from the unfolded sides
        head_vars = [t for t in head.terms if not isinstance(t, int)]
        l_cols: dict[str, np.ndarray] = {}
        r_cols: dict[str, np.ndarray] = {}
        l_need = [v for v in head_vars if v in left.vars]
        r_need = [v for v in head_vars if v not in left.vars]
        if l_need:
            unf = _unfold_cols(store, left.items,
                               [left.vars.index(v) for v in l_need])
            l_cols = {v: unf[:, j] for j, v in enumerate(l_need)}
        if r_need:
            unf = _unfold_cols(store, right.items,
                               [right.vars.index(v) for v in r_need])
            r_cols = {v: unf[:, j] for j, v in enumerate(r_need)}
        cols = []
        for t in head.terms:
            if isinstance(t, int):
                cols.append(np.full(total, t, dtype=np.int64))
            elif t in l_cols:
                cols.append(l_cols[t][l_rep])
            else:
                cols.append(r_cols[t][r_sel])
        return np.stack(cols, axis=1)

    def _pivot_mf_ids(self, rule: Rule, pivot: int, naive: bool) -> tuple:
        """Input lineage for one application: the meta-fact ids of the
        pivot predicate's source partition (capped — best-effort)."""
        pred = rule.body[pivot].predicate
        mfs = self.facts.all(pred) if naive else self.facts.delta(pred)
        return tuple(mf.mf_id for mf in mfs[:16])

    def _record_round(
        self,
        prov: list[dict],
        fresh_mu: dict[str, list[int]] | None,
        fresh_flat: dict[str, list[int]] | None,
        delta: list[MetaFact],
        round_no: int,
        stratum_idx: int,
    ) -> None:
        """Resolve the round's pending applications into journal records:
        dedup's per-group/per-block survivor counts give each record its
        ``n_new``; the stored delta gives output meta-fact ids per head
        predicate (round granularity — singleton recompression merges
        groups, so finer ownership would be fiction)."""
        from ..obs.provenance import DerivationRecord

        out_ids: dict[str, list[int]] = {}
        for mf in delta:
            out_ids.setdefault(mf.predicate, []).append(mf.mf_id)
        for p in prov:
            pred = p["pred"]
            if p["path"] == "mu":
                counts = (fresh_mu or {}).get(pred, [])
                g0, g1 = p["groups"]
                n_new = int(sum(counts[g0:g1]))
            else:
                counts = (fresh_flat or {}).get(pred, [])
                b = p["block"]
                n_new = int(counts[b]) if b < len(counts) else 0
            self._journal.record(DerivationRecord(
                kind="apply",
                engine="cmat",
                stratum=stratum_idx,
                round=round_no,
                rule_id=p["rule_id"],
                pivot=p["pivot"],
                pred=pred,
                n_emitted=p["n_emitted"],
                n_new=n_new,
                in_mf_ids=p["in_ids"],
                out_mf_ids=tuple(out_ids.get(pred, [])[:16]),
                epoch=self._journal.epoch,
                time_ns=p["time_ns"],
            ))

    def _dedup_flat(
        self,
        flat_candidates: dict[str, list[np.ndarray]],
        round_no: int,
        fresh_counts: dict[str, list[int]] | None = None,
    ) -> list[MetaFact]:
        """Dedup the round's flat head rows against the persistent
        ``FactBuffers`` index (which :func:`elim_dup` has already updated
        with this round's meta-fact survivors, so cross-path duplicates
        are caught) and compress only the genuinely-new rows — once per
        predicate, not once per leaf group."""
        delta: list[MetaFact] = []
        rows_in = rows_fresh = 0
        with span(
            "cmat.fused_dedup", round=round_no, preds=len(flat_candidates)
        ) as sp:
            for pred, blocks in sorted(flat_candidates.items()):
                rows = (
                    blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
                )
                rows_in += int(rows.shape[0])
                keep = self._dedup_index.fresh_mask(pred, rows)
                # arity <= 2 is guaranteed by the fused-tail gate, so the
                # packed fast path never falls back
                assert keep is not None, "fused tail emitted unpackable arity"
                if fresh_counts is not None:
                    counts, off = [], 0
                    for b in blocks:
                        counts.append(int(keep[off:off + b.shape[0]].sum()))
                        off += b.shape[0]
                    fresh_counts[pred] = counts
                if not keep.any():
                    continue
                rows_fresh += int(keep.sum())
                # fresh_mask already dropped in-block duplicates (first-
                # occurrence) — survivors are unique, compress sorts its way
                for cols, length in compress_rows(rows[keep], self.store):
                    delta.append(MetaFact(pred, cols, length, round=round_no))
            sp.set(rows_in=rows_in, rows_fresh=rows_fresh)
        get_registry().counter("cmat.fused_rounds").inc()
        return delta

    # ------------------------------------------------------------------ #
    def explain(self, rule: Rule, pivot: int = 0) -> str:
        """Inspectable plan for one (rule, pivot) under current stats."""
        self._stats_view.refresh()
        return compile_body(
            rule.body, self._stats_view, pivot=pivot, reorder=self.plan_bodies
        ).explain()

    def explain_fact(self, pred: str, terms, decode=None) -> dict | None:
        """Verified proof tree for a materialised fact (obs.provenance):
        explicit facts are leaves, derived facts are re-derived step by
        step with the journal as a search accelerator."""
        from ..obs.provenance import Explainer, get_journal

        ex = Explainer.from_fact_store(
            self.program, self.facts, self._explicit,
            journal=get_journal(), decode=decode,
        )
        return ex.explain(pred, terms)

    # ------------------------------------------------------------------ #
    def _emit_head(self, rule: Rule, L: SubstSet, candidates: dict) -> None:
        head = rule.head
        bucket = candidates.setdefault(head.predicate, [])
        var_idx = {v: L.vars.index(v) for v in head.variables()}
        for cols_ids, length in L.items:
            head_cols = []
            for t in head.terms:
                if isinstance(t, int):
                    head_cols.append(self.store.new_constant(t, length))
                else:
                    head_cols.append(cols_ids[var_idx[t]])
            bucket.append((tuple(head_cols), length))

    # ------------------------------------------------------------------ #
    def _recompress_singletons(
        self, delta: list[MetaFact], round_no: int
    ) -> list[MetaFact]:
        """Remove length-one meta-facts and re-compress them per predicate
        (Alg. 1 line 23) — critical for join speed in later rounds."""
        singles: dict[str, list[MetaFact]] = {}
        keep: list[MetaFact] = []
        for mf in delta:
            if mf.length == 1:
                singles.setdefault(mf.predicate, []).append(mf)
            else:
                keep.append(mf)
        for pred, mfs in singles.items():
            if len(mfs) == 1:
                keep.append(mfs[0])
                continue
            # one batched head-value gather per predicate (each column of
            # a length-one meta-fact unfolds to exactly its head value)
            cids = np.asarray([c for mf in mfs for c in mf.columns],
                              dtype=np.int64)
            rows = self.store.head_values(cids).reshape(len(mfs), -1)
            for cols, length in compress_rows(rows, self.store):
                keep.append(MetaFact(pred, cols, length, round=round_no))
        return keep

    # ------------------------------------------------------------------ #
    # reporting
    # ------------------------------------------------------------------ #
    def materialisation(self) -> dict[str, np.ndarray]:
        """Unfolded, deduplicated mat(Pi, E) — for testing/inspection."""
        return self.facts.to_dict()

    def report(self) -> dict:
        flat_mat = self.materialisation()
        explicit_size = flat_repr_size(
            {p: unique_rows(r) for p, r in self._explicit.items()}
        )
        return {
            "rounds": self.stats.rounds,
            "n_strata": self.stats.n_strata,
            "n_meta_facts": self.stats.n_meta_facts,
            "n_facts_explicit": int(sum(r.shape[0] for r in self._explicit.values())),
            "n_facts_materialised": int(
                sum(r.shape[0] for r in flat_mat.values())
            ),
            "flat_size_E": explicit_size,
            "flat_size_I": flat_repr_size(flat_mat),
            "compressed_size": self.facts.total_repr_size(),
            "mu_stats": self.facts.mu_stats(),
            "dominant_phase": self.stats.dominant_phase(),
            "rule_applications": self.stats.n_rule_applications,
            "rule_applications_skipped": self.stats.rule_applications_skipped,
            "old_snapshot_scans": self.stats.old_snapshot_scans,
            "plan_cache": dict(self.stats.plan_cache),
            "time_total": self.stats.time_total,
            "time_dedup": self.stats.time_dedup,
            "time_join": self.stats.time_join,
            "time_match": self.stats.time_match,
            "time_compress": self.stats.time_compress,
        }
