"""Algorithm 1: the CompMat semi-naive materialisation engine.

The fixpoint loop runs on the host (round count is data dependent and
small, as in the paper); per-round bulk work (compression, joins, dedup)
is vectorised column arithmetic — the numpy host path here, with the same
primitives available as Pallas TPU kernels (``repro.kernels``) and as a
``shard_map`` distributed engine (``repro.core.distributed``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .columns import ColumnStore
from .compress import compress_rows
from .datalog import Program, Rule
from .dedup import elim_dup
from .joins import SubstSet, match, sjoin, xjoin
from .metafacts import FactStore, MetaFact, flat_repr_size

__all__ = ["CMatEngine", "MaterialisationStats"]


@dataclass
class MaterialisationStats:
    rounds: int = 0
    n_rule_applications: int = 0
    n_meta_facts: int = 0
    n_facts: int = 0
    time_compress: float = 0.0
    time_match: float = 0.0
    time_join: float = 0.0
    time_dedup: float = 0.0
    time_total: float = 0.0
    per_round: list[dict] = field(default_factory=list)

    def dominant_phase(self) -> str:
        phases = {
            "compress": self.time_compress,
            "match": self.time_match,
            "join": self.time_join,
            "dedup": self.time_dedup,
        }
        return max(phases, key=phases.get)


class CMatEngine:
    """Compressed datalog materialisation (the paper's CMat, Algorithm 1)."""

    def __init__(
        self,
        program: Program,
        inplace_splits: bool = False,
        max_rounds: int = 10_000,
        dedup_index: bool = False,
    ):
        # ``inplace_splits=True`` is the paper's Algorithm 4 accounting
        # (mu(a) := b_in.b_out).  We found it unsound in general: a split
        # that reaches a leaf shared with a meta-fact whose *other* columns
        # are not co-split with the same mask silently permutes one column
        # of that meta-fact (reachable via projection heads, e.g.
        # ``P(x,y) -> W(x)``).  The sound default copies the survivors into
        # fresh leaves; fully-novel derivations still share wholesale, so
        # the headline compression results are unaffected (see DESIGN.md).
        self.program = program
        self.store = ColumnStore()
        self.facts = FactStore(self.store)
        self.inplace_splits = inplace_splits
        self.max_rounds = max_rounds
        self.stats = MaterialisationStats()
        self._explicit: dict[str, np.ndarray] = {}
        # persistent sorted dedup index (speed for memory — the paper's
        # reported bottleneck is dedup re-unpacking; see DedupIndex)
        from .dedup import DedupIndex

        self._dedup_index = DedupIndex() if dedup_index else None

    # ------------------------------------------------------------------ #
    def load(self, dataset: dict[str, np.ndarray]) -> None:
        """Compress the explicit dataset into meta-facts (Alg. 1 lines 1-4)."""
        t0 = time.perf_counter()
        for pred, rows in dataset.items():
            rows = np.asarray(rows, dtype=np.int64)
            if rows.ndim == 1:
                rows = rows.reshape(-1, 1)
            rows = np.unique(rows, axis=0)
            self._explicit[pred] = rows
            if self._dedup_index is not None:
                self._dedup_index.seed(pred, rows)
            for cols, length in compress_rows(rows, self.store):
                self.facts.add(MetaFact(pred, cols, length, round=0))
        self.stats.time_compress += time.perf_counter() - t0

    # ------------------------------------------------------------------ #
    def materialise(self) -> MaterialisationStats:
        """Run the semi-naive fixpoint (Alg. 1 lines 6-23)."""
        t_start = time.perf_counter()
        round_no = 0
        while round_no < self.max_rounds:
            self.facts.current_round = round_no
            if not self.facts.has_delta():
                break
            round_no += 1
            round_stats = self._round(round_no)
            self.stats.per_round.append(round_stats)
        self.stats.rounds = round_no
        self.stats.n_meta_facts = self.facts.n_meta_facts()
        self.stats.n_facts = self.facts.n_facts()
        self.stats.time_total = time.perf_counter() - t_start
        return self.stats

    # ------------------------------------------------------------------ #
    def _round(self, round_no: int) -> dict:
        facts, store = self.facts, self.store
        candidates: dict[str, list[tuple[tuple[int, ...], int]]] = {}
        match_cache: dict = {}
        n_apps = 0

        def cached_match(atom, which: str) -> SubstSet:
            key = (atom.predicate, atom.terms, which)
            hit = match_cache.get(key)
            if hit is None:
                t0 = time.perf_counter()
                hit = match(
                    atom,
                    getattr(facts, which)(atom.predicate),
                    store,
                    self.inplace_splits,
                )
                self.stats.time_match += time.perf_counter() - t0
                match_cache[key] = hit
            return hit

        for rule in self.program:
            n = len(rule.body)
            for i in range(n):
                # require B_i to match Delta (semi-naive restriction)
                if cached_match(rule.body[i], "delta").is_empty():
                    continue
                result = self._eval_body(rule, i, cached_match)
                if result is None or result.is_empty():
                    continue
                n_apps += 1
                self._emit_head(rule, result, candidates)

        t0 = time.perf_counter()
        delta = elim_dup(candidates, facts, store, round_no,
                         self.inplace_splits, index=self._dedup_index)
        self.stats.time_dedup += time.perf_counter() - t0

        # Alg. 1 line 23: re-compress length-one meta-facts
        t0 = time.perf_counter()
        delta = self._recompress_singletons(delta, round_no)
        self.stats.time_compress += time.perf_counter() - t0

        for mf in delta:
            facts.add(mf)
        self.stats.n_rule_applications += n_apps
        return {
            "round": round_no,
            "new_meta_facts": len(delta),
            "new_facts": sum(mf.length for mf in delta),
            "rule_applications": n_apps,
        }

    # ------------------------------------------------------------------ #
    def _eval_body(self, rule: Rule, i: int, cached_match) -> SubstSet | None:
        """Evaluate the body left-to-right (Alg. 1 lines 9-19)."""
        L: SubstSet | None = None
        V: set[str] = set()
        for j, atom in enumerate(rule.body):
            which = "old" if j < i else ("delta" if j == i else "all")
            R = cached_match(atom, which)
            if R.is_empty():
                return None
            atom_vars = set(atom.variables())
            t0 = time.perf_counter()
            if L is None:
                L = R
            elif V <= atom_vars:
                L = sjoin(L, R, tuple(v for v in R.vars if v in V), self.store,
                          self.inplace_splits)
            elif atom_vars <= V:
                L = sjoin(R, L, tuple(v for v in L.vars if v in atom_vars),
                          self.store, self.inplace_splits)
            else:
                common = tuple(v for v in L.vars if v in atom_vars)
                L = xjoin(L, R, common, self.store)
            self.stats.time_join += time.perf_counter() - t0
            V |= atom_vars
            if L.is_empty():
                return None
        return L

    # ------------------------------------------------------------------ #
    def _emit_head(self, rule: Rule, L: SubstSet, candidates: dict) -> None:
        head = rule.head
        bucket = candidates.setdefault(head.predicate, [])
        var_idx = {v: L.vars.index(v) for v in head.variables()}
        for cols_ids, length in L.items:
            head_cols = []
            for t in head.terms:
                if isinstance(t, int):
                    head_cols.append(self.store.new_constant(t, length))
                else:
                    head_cols.append(cols_ids[var_idx[t]])
            bucket.append((tuple(head_cols), length))

    # ------------------------------------------------------------------ #
    def _recompress_singletons(
        self, delta: list[MetaFact], round_no: int
    ) -> list[MetaFact]:
        """Remove length-one meta-facts and re-compress them per predicate
        (Alg. 1 line 23) — critical for join speed in later rounds."""
        singles: dict[str, list[MetaFact]] = {}
        keep: list[MetaFact] = []
        for mf in delta:
            if mf.length == 1:
                singles.setdefault(mf.predicate, []).append(mf)
            else:
                keep.append(mf)
        for pred, mfs in singles.items():
            if len(mfs) == 1:
                keep.append(mfs[0])
                continue
            rows = np.stack(
                [
                    np.asarray(
                        [self.store.head_value(c) for c in mf.columns], dtype=np.int64
                    )
                    for mf in mfs
                ]
            )
            for cols, length in compress_rows(rows, self.store):
                keep.append(MetaFact(pred, cols, length, round=round_no))
        return keep

    # ------------------------------------------------------------------ #
    # reporting
    # ------------------------------------------------------------------ #
    def materialisation(self) -> dict[str, np.ndarray]:
        """Unfolded, deduplicated mat(Pi, E) — for testing/inspection."""
        return self.facts.to_dict()

    def report(self) -> dict:
        flat_mat = self.materialisation()
        explicit_size = flat_repr_size(
            {p: np.unique(r, axis=0) for p, r in self._explicit.items()}
        )
        return {
            "rounds": self.stats.rounds,
            "n_meta_facts": self.stats.n_meta_facts,
            "n_facts_explicit": int(sum(r.shape[0] for r in self._explicit.values())),
            "n_facts_materialised": int(
                sum(r.shape[0] for r in flat_mat.values())
            ),
            "flat_size_E": explicit_size,
            "flat_size_I": flat_repr_size(flat_mat),
            "compressed_size": self.facts.total_repr_size(),
            "mu_stats": self.facts.mu_stats(),
            "dominant_phase": self.stats.dominant_phase(),
            "time_total": self.stats.time_total,
            "time_dedup": self.stats.time_dedup,
            "time_join": self.stats.time_join,
            "time_match": self.stats.time_match,
            "time_compress": self.stats.time_compress,
        }
