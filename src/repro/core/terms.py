"""Term dictionary: external RDF terms (strings) <-> dense int64 ids.

The paper requires an arbitrary but fixed total order ``<`` over constants
(Section 3, "Representation and Framework").  Like most RDF stores we
dictionary-encode terms as integers and use integer order as ``<``.
"""

from __future__ import annotations

import numpy as np

RDF_TYPE = "rdf:type"


class Dictionary:
    """Bidirectional mapping between term strings and int64 ids.

    Ids are assigned densely in first-seen order.  The total order over
    constants used by the engine is plain integer order on these ids.
    """

    __slots__ = ("_to_id", "_to_term")

    def __init__(self) -> None:
        self._to_id: dict[str, int] = {}
        self._to_term: list[str] = []

    def __len__(self) -> int:
        return len(self._to_term)

    def intern(self, term: str) -> int:
        tid = self._to_id.get(term)
        if tid is None:
            tid = len(self._to_term)
            self._to_id[term] = tid
            self._to_term.append(term)
        return tid

    def intern_many(self, terms) -> np.ndarray:
        return np.asarray([self.intern(t) for t in terms], dtype=np.int64)

    def id_of(self, term: str) -> int:
        return self._to_id[term]

    def term_of(self, tid: int) -> str:
        return self._to_term[tid]

    def __contains__(self, term: str) -> bool:
        return term in self._to_id
