"""Algorithm 2 (``compress``), vectorised.

The paper appends each lexicographically-sorted substitution to an open
meta-substitution whenever every column stays non-decreasing, creating a
fresh meta-substitution otherwise.  With a single open candidate this is
exactly *run segmentation*: walk the sorted rows, and cut a new segment at
every position where **any** column decreases.  Each segment then yields one
meta-substitution whose columns are the per-segment slices.

This is O(n) fully-vectorised work (the paper's first-fit scan is O(n*k)
serial); segmentation can emit more meta-facts than first-fit, which we
mitigate — exactly as the paper does — by sorting on the column with the
fewest distinct values first.
"""

from __future__ import annotations

import numpy as np

from .columns import ColumnStore

__all__ = [
    "sort_for_compression",
    "segment_breaks",
    "compress_rows",
    "compress_grouped",
]


def sort_for_compression(rows: np.ndarray) -> np.ndarray:
    """Lexicographically sort rows, keying first on the column with the
    fewest distinct values (paper §3: 'we consider the argument with fewer
    distinct values first to maximise the use of run-length encoding')."""
    if rows.shape[0] <= 1:
        return rows
    n_distinct = [
        np.unique(rows[:, j]).shape[0] for j in range(rows.shape[1])
    ]
    order = np.argsort(n_distinct, kind="stable")  # fewest-distinct first
    # np.lexsort keys: last key is primary
    keys = tuple(rows[:, j] for j in reversed(order))
    perm = np.lexsort(keys)
    return rows[perm]


def segment_breaks(rows: np.ndarray) -> np.ndarray:
    """Boolean array marking rows that start a new segment (row 0 included):
    a break occurs where any column strictly decreases."""
    n = rows.shape[0]
    breaks = np.zeros(n, dtype=bool)
    if n == 0:
        return breaks
    breaks[0] = True
    if n > 1:
        dec = (rows[1:] < rows[:-1]).any(axis=1)
        breaks[1:] = dec
    return breaks


def compress_rows(
    rows: np.ndarray, store: ColumnStore, presorted: bool = False
) -> list[tuple[tuple[int, ...], int]]:
    """Compress an ``(n, k)`` row set into meta-substitutions.

    Returns a list of ``(column_ids, length)`` — one entry per segment.
    """
    if rows.shape[0] == 0:
        return []
    if not presorted:
        rows = sort_for_compression(rows)
    breaks = segment_breaks(rows)
    starts = np.flatnonzero(breaks)
    ends = np.append(starts[1:], rows.shape[0])
    out = []
    for s, e in zip(starts, ends):
        cols = tuple(store.new_leaf(rows[s:e, j]) for j in range(rows.shape[1]))
        out.append((cols, int(e - s)))
    return out


def compress_grouped(
    group_starts: np.ndarray,
    group_ends: np.ndarray,
    rows: np.ndarray,
    store: ColumnStore,
) -> list[list[tuple[tuple[int, ...], int]]]:
    """Compress ``rows`` independently within each ``[start, end)`` group.

    ``rows`` must already be sorted within each group.  Used by ``xjoin``:
    the right-hand side is grouped by the join key and each group is
    compressed once, its meta-constants then shared by every matching
    left-hand row (the paper's structure-sharing cross-join).
    """
    n, k = rows.shape
    breaks = segment_breaks(rows)
    # force a break at every group start
    breaks[group_starts] = True
    seg_start_idx = np.flatnonzero(breaks)
    seg_end_idx = np.append(seg_start_idx[1:], n)
    # map segments to groups; rows outside every [start, end) are skipped
    group_of_seg = np.searchsorted(group_starts, seg_start_idx, side="right") - 1
    out: list[list[tuple[tuple[int, ...], int]]] = [
        [] for _ in range(len(group_starts))
    ]
    for s, e, g in zip(seg_start_idx, seg_end_idx, group_of_seg):
        if g < 0 or s >= group_ends[g]:
            continue  # segment not covered by any group
        # clip the segment to the group (a segment never straddles a group
        # start because of the forced breaks, but it can overhang the end)
        e = min(int(e), int(group_ends[g]))
        cols = tuple(store.new_leaf(rows[s:e, j]) for j in range(k))
        out[int(g)].append((cols, int(e - s)))
    return out
