"""Flat semi-naive datalog engine (the RDFox/VLog-style baseline).

Facts are plain ``(n, arity)`` int64 arrays per predicate; joins enumerate
every matching pair.  This is both the correctness oracle for the
compressed engine and the 'flat' baseline of the paper's Tables 1-4.

Rule bodies go through the same body compiler as the compressed engine
and the query planner (:mod:`repro.core.compile`): each (rule, pivot)
pair compiles to a delta-anchored, selectivity-ordered plan, cached per
statistics bucket.  The flat join is a generic hash equi-join, so only
the atom order and the old/delta/all source partitions of the plan are
consumed here — kind metadata drives the compressed engine.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..obs import get_registry, span
from ..obs.memory import register_reporter
from .compile import ArrayStats, PlanCache, compile_body, stats_bucket
from .datalog import Program, Rule
from .util import (
    factorize_rows,
    merge_sorted_rows_np,
    multicol_member,
    sorted_member,
    unique_rows,
)

__all__ = ["FlatEngine", "flat_seminaive"]


@dataclass
class _Table:
    """Substitution table: variable order + rows."""

    vars: tuple[str, ...]
    rows: np.ndarray  # (n, len(vars))


def _match_flat(atom, rows: np.ndarray) -> _Table | None:
    """Rows of a predicate matching an atom (constants / repeated vars)."""
    if rows.shape[0] == 0 or rows.shape[1] != len(atom.terms):
        return None
    mask = np.ones(rows.shape[0], dtype=bool)
    vars_ = atom.variables()
    first_pos = {v: atom.terms.index(v) for v in vars_}
    for pos, t in enumerate(atom.terms):
        if isinstance(t, int):
            mask &= rows[:, pos] == t
        elif pos != first_pos[t]:
            mask &= rows[:, pos] == rows[:, first_pos[t]]
    sel = rows[mask]
    if sel.shape[0] == 0:
        return None
    if not vars_:  # all-constant atom: an existence filter
        return _Table((), np.zeros((sel.shape[0], 0), dtype=np.int64))
    cols = [sel[:, first_pos[v]] for v in vars_]
    return _Table(vars_, np.stack(cols, axis=1))


def _join(left: _Table, right: _Table) -> _Table:
    """Vectorised equi-join on the shared variables (hash-join style)."""
    common = [v for v in left.vars if v in right.vars]
    out_vars = tuple(left.vars) + tuple(v for v in right.vars if v not in left.vars)
    l_idx = [left.vars.index(v) for v in common]
    r_idx = [right.vars.index(v) for v in common]
    r_extra_idx = [right.vars.index(v) for v in right.vars if v not in left.vars]

    l_keys = left.rows[:, l_idx] if common else np.zeros((left.rows.shape[0], 0), np.int64)
    r_keys = right.rows[:, r_idx] if common else np.zeros((right.rows.shape[0], 0), np.int64)
    codes_l, codes_r = factorize_rows(l_keys, r_keys)

    r_perm = np.argsort(codes_r, kind="stable")
    codes_r_s = codes_r[r_perm]
    lo = np.searchsorted(codes_r_s, codes_l, side="left")
    hi = np.searchsorted(codes_r_s, codes_l, side="right")
    counts = hi - lo
    total = int(counts.sum())
    if total == 0:
        return _Table(out_vars, np.zeros((0, len(out_vars)), dtype=np.int64))
    l_rep = np.repeat(np.arange(left.rows.shape[0]), counts)
    # per-left-row right indices: lo[i] .. hi[i)-1
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(total) - np.repeat(offsets, counts)
    r_sel = r_perm[np.repeat(lo, counts) + within]
    out = np.concatenate(
        [left.rows[l_rep], right.rows[r_sel][:, r_extra_idx]], axis=1
    )
    return _Table(out_vars, out)


class FlatEngine:
    """Semi-naive materialisation over flat fact arrays."""

    def __init__(
        self,
        program: Program,
        max_rounds: int = 10_000,
        plan_bodies: bool = True,
        plan_cache: PlanCache | None = None,
        fused: bool = True,
    ):
        # ``fused=True`` (default) runs the fused round tail: one joint
        # factorisation per (predicate, round) drives dedup (sorted
        # membership against the already-sorted fact codes — no re-sort)
        # and a positional merge of the survivors, replacing the legacy
        # per-round ``np.unique(concatenate(...))`` + full-table re-sort
        # (``fused=False``, kept as the per-step reference the benches
        # compare against).  Both paths maintain the same invariant —
        # ``facts[pred]`` lex-sorted unique — and produce bit-identical
        # materialisations.
        self.program = program
        self.max_rounds = max_rounds
        self.plan_bodies = plan_bodies
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.fused = fused
        self.facts: dict[str, np.ndarray] = {}
        self.rounds = 0
        self.time_total = 0.0
        self._rule_ids: dict[Rule, int] = {}
        for k, rule in enumerate(program):
            self._rule_ids.setdefault(rule, k)
        self._journal = None  # bound per-materialise when recording is on
        # provenance: per-predicate (round, fresh rows) append log — the
        # flat engine's round tags (facts arrays carry no per-row round)
        self._prov_fresh: dict[str, list[tuple[int, np.ndarray]]] = {}
        self._explicit: dict[str, np.ndarray] = {}
        register_reporter("flat", self)

    def memory_report(self) -> dict[str, int]:
        """obs.memory reporter: the flat baseline *is* its fact arrays."""
        return {
            "facts_bytes": sum(int(r.nbytes) for r in self.facts.values()),
            "n_predicates": len(self.facts),
        }

    def load(self, dataset: dict[str, np.ndarray]) -> None:
        for pred, rows in dataset.items():
            rows = np.asarray(rows, dtype=np.int64)
            if rows.ndim == 1:
                rows = rows.reshape(-1, 1)
            self.facts[pred] = unique_rows(rows)
            self._explicit[pred] = self.facts[pred]

    def materialise(self) -> dict[str, np.ndarray]:
        t0 = time.perf_counter()
        from ..obs.provenance import get_journal

        journal = get_journal()
        self._journal = journal if journal.enabled else None
        if self._journal is not None:
            journal.attach_program(self.program)
            self._prov_fresh = {
                p: [(0, r)] for p, r in self.facts.items()
            }
        delta = {p: r for p, r in self.facts.items()}
        rounds = 0
        with span("flat.materialise"):
            while delta and rounds < self.max_rounds:
                rounds += 1
                with span("flat.round", round=rounds):
                    stats_view = ArrayStats(self.facts)
                    derived: dict[str, list[np.ndarray]] = {}
                    pending: list[dict] = []
                    for rule in self.program:
                        for i in range(len(rule.body)):
                            t_app = (
                                time.perf_counter_ns()
                                if self._journal is not None
                                else 0
                            )
                            rows = self._eval(rule, i, delta, stats_view)
                            if rows is not None and rows.shape[0]:
                                if self._journal is not None:
                                    pending.append({
                                        "rule_id": self._rule_ids.get(
                                            rule, -1
                                        ),
                                        "pivot": i,
                                        "pred": rule.head.predicate,
                                        "rows": rows,
                                        "time_ns": time.perf_counter_ns()
                                        - t_app,
                                    })
                                derived.setdefault(
                                    rule.head.predicate, []
                                ).append(rows)
                    watermarks = (
                        {
                            p: self.facts.get(p, np.zeros((0, 1))).shape[0]
                            for p in derived
                        }
                        if self._journal is not None
                        else {}
                    )
                    if self.fused:
                        delta = self._absorb_fused(derived)
                    else:
                        delta = self._absorb_per_step(derived)
                    if self._journal is not None:
                        self._record_round(
                            pending, delta, watermarks, rounds
                        )
        self.rounds = rounds
        self.time_total = time.perf_counter() - t0
        reg = get_registry()
        reg.counter("flat.rounds").inc(rounds)
        reg.counter("flat.time_total").inc(self.time_total)
        if self.fused:
            reg.counter("flat.fused_rounds").inc(rounds)
        if self._journal is not None:
            self._journal.publish()
        return self.facts

    def _record_round(
        self,
        pending: list[dict],
        fresh: dict[str, np.ndarray],
        watermarks: dict[str, int],
        round_no: int,
    ) -> None:
        """Resolve the round's rule applications into journal records.
        ``n_new`` credits each application with the fresh rows it emitted
        (co-deriving rules both get credit); ``row_span`` carries the
        predicate's sorted-table watermarks across the absorb."""
        from ..obs.provenance import DerivationRecord

        for pred, rows in fresh.items():
            self._prov_fresh.setdefault(pred, []).append((round_no, rows))
        for p in pending:
            pred = p["pred"]
            f = fresh.get(pred)
            if f is None or f.shape[0] == 0:
                n_new = 0
            else:
                n_new = int(multicol_member(f, p["rows"]).sum())
            after = self.facts.get(pred)
            self._journal.record(DerivationRecord(
                kind="apply",
                engine="flat",
                stratum=-1,  # the flat oracle runs unstratified
                round=round_no,
                rule_id=p["rule_id"],
                pivot=p["pivot"],
                pred=pred,
                n_emitted=int(p["rows"].shape[0]),
                n_new=n_new,
                row_span=(
                    watermarks.get(pred, 0),
                    0 if after is None else int(after.shape[0]),
                ),
                epoch=self._journal.epoch,
                time_ns=p["time_ns"],
            ))

    def explain_fact(self, pred: str, terms, decode=None) -> dict | None:
        """Verified proof tree over the flat materialisation (the
        per-round fresh log supplies round tags when recording was on;
        without it every fact falls back to round 0 and recursive
        explanations may be unavailable)."""
        from ..obs.provenance import Explainer, get_journal

        ex = Explainer.from_flat(
            self.program, self.facts,
            fresh_log=self._prov_fresh or None,
            explicit=self._explicit,
            journal=get_journal(), decode=decode,
        )
        return ex.explain(pred, terms)

    def _absorb_per_step(self, derived: dict) -> dict[str, np.ndarray]:
        """Legacy round tail: dedup via a fresh ``np.unique`` of the
        concatenated candidates and a full-table re-sort per predicate —
        the per-step reference the fused path is benched against."""
        new_delta: dict[str, np.ndarray] = {}
        for pred, blocks in derived.items():
            cand = np.unique(np.concatenate(blocks), axis=0)
            old = self.facts.get(pred)
            if old is not None and old.shape[0]:
                fresh = cand[~multicol_member(cand, old)]
            else:
                fresh = cand
            if fresh.shape[0]:
                new_delta[pred] = fresh
                self.facts[pred] = (
                    np.concatenate([old, fresh])
                    if old is not None and old.size
                    else fresh
                )
        # facts stay sorted-unique per predicate
        for pred in new_delta:
            self.facts[pred] = np.unique(self.facts[pred], axis=0)
        return new_delta

    def _absorb_fused(self, derived: dict) -> dict[str, np.ndarray]:
        """Fused round tail (host analogue of the ``fused_join_dedup`` +
        ``merge_sorted_unique`` kernel pair): the facts table is kept
        lex-sorted unique across rounds, so one joint factorisation per
        predicate yields (a) the anti-join — a sorted-membership probe
        against the *already sorted* fact codes, no re-sort — and (b)
        the placement positions for an O(n+m) positional merge of the
        survivors.  The full-table ``np.unique`` re-sort the per-step
        path pays every round disappears entirely."""
        new_delta: dict[str, np.ndarray] = {}
        rows_in = rows_fresh = 0
        with span("flat.fused_absorb", preds=len(derived)) as sp:
            for pred, blocks in derived.items():
                cand = unique_rows(
                    blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
                )
                rows_in += int(cand.shape[0])
                old = self.facts.get(pred)
                if old is None or old.shape[0] == 0:
                    if cand.shape[0]:
                        rows_fresh += int(cand.shape[0])
                        new_delta[pred] = cand
                        self.facts[pred] = cand
                    continue
                codes_cand, codes_old = factorize_rows(cand, old)
                # facts are lex-sorted and factorize codes are order-
                # consistent, so codes_old is already ascending
                keep = ~sorted_member(codes_cand, codes_old)
                if not keep.any():
                    continue
                fresh = cand[keep]
                rows_fresh += int(fresh.shape[0])
                new_delta[pred] = fresh
                self.facts[pred] = merge_sorted_rows_np(
                    old, fresh, codes_old, codes_cand[keep]
                )
            sp.set(rows_in=rows_in, rows_fresh=rows_fresh)
        return new_delta

    def _source_rows(self, pred: str, source: str, delta: dict) -> np.ndarray | None:
        """The plan's old/delta/all partitions over flat arrays."""
        if source == "delta":
            return delta.get(pred)
        allr = self.facts.get(pred)
        if source == "all" or allr is None:
            return allr
        # old = M \ Delta: facts minus the delta rows
        d = delta.get(pred)
        if d is None or d.shape[0] == 0:
            return allr
        return allr[~multicol_member(allr, d)]

    def _eval(
        self, rule: Rule, i: int, delta: dict, stats_view: ArrayStats
    ) -> np.ndarray | None:
        plan = self.plan_cache.get(
            (rule, i),
            stats_bucket(stats_view, rule.body),
            lambda: compile_body(
                rule.body, stats_view, pivot=i, reorder=self.plan_bodies
            ),
        )
        if plan.is_empty:
            return None
        L: _Table | None = None
        for step in [plan.first] + [j.scan for j in plan.joins]:
            source = self._source_rows(step.atom.predicate, step.source, delta)
            if source is None or source.shape[0] == 0:
                return None
            R = _match_flat(step.atom, source)
            if R is None:
                return None
            L = R if L is None else _join(L, R)
            if L.rows.shape[0] == 0:
                return None
        head = rule.head
        cols = []
        for t in head.terms:
            if isinstance(t, int):
                cols.append(np.full(L.rows.shape[0], t, dtype=np.int64))
            else:
                cols.append(L.rows[:, L.vars.index(t)])
        return np.stack(cols, axis=1)


def flat_seminaive(program: Program, dataset: dict[str, np.ndarray]):
    """Convenience wrapper returning the deduplicated materialisation."""
    eng = FlatEngine(program)
    eng.load(dataset)
    return eng.materialise()
