"""One body compiler: rule bodies and query bodies are the same problem.

A rule body under semi-naive evaluation and a BGP query body are both
conjunctions of atoms to be joined in some order; the only differences
are (a) a rule evaluation is anchored on a *delta pivot* — the atom that
must match the facts derived in the previous round, which is the small
side and therefore the right anchor — and (b) each rule atom reads a
*source partition* of the fact store (``old`` / ``delta`` / ``all``,
Algorithm 1's ``M \\ Delta`` bookkeeping) determined by its original
position relative to the pivot.

This module owns the pieces both sides share (column-oriented VLog,
arXiv 1511.08915, makes the same rule-body-as-query move):

* :class:`ScanStep` / :class:`JoinStep` / :class:`Plan` — the ordered,
  ``explain()``-able physical plan,
* :func:`estimate_rows` — per-atom cardinality estimation from cheap
  per-predicate statistics,
* :func:`compile_body` — greedy connected-selectivity ordering with
  per-step join-kind selection (semi-join when one side's variables
  cover the other's, structure-sharing cross-join otherwise) and, for
  single-key equi-joins, a *partition key* annotation telling the
  distributed executor which variable to co-partition the join on (a
  side whose stored first column already is that variable skips its
  pre-join ``all_to_all``),
* :func:`stats_bucket` / :class:`PlanCache` — plans are cached per
  (rule, pivot) and re-planned only when a body predicate's cardinality
  moves to a different power-of-two bucket,
* :class:`ArrayStats` / :class:`FactStoreStats` — statistics adapters so
  the flat, compressed, and distributed engines feed the same planner
  that :class:`~repro.core.frozen.FrozenFacts` feeds at query time.

Any statistics provider must offer ``n_rows(pred)``, ``arity(pred)``,
and ``selectivity(pred, pos, value)`` — the ``FrozenFacts`` contract.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .datalog import Atom

__all__ = [
    "SCAN_SHARE",
    "SCAN_INDEX",
    "SRC_ALL",
    "SRC_DELTA",
    "SRC_OLD",
    "ScanStep",
    "JoinStep",
    "Plan",
    "estimate_rows",
    "compile_body",
    "stats_bucket",
    "PlanCache",
    "ArrayStats",
    "FactStoreStats",
]

#: selectivity discount for a repeated variable inside one atom
_REPEAT_DISCOUNT = 0.1

# scan modes ------------------------------------------------------------- #
#: share meta-fact columns wholesale (pure-variable atom, zero unfolding)
SCAN_SHARE = "share"
#: binary-search the frozen snapshot on the most selective constant
SCAN_INDEX = "index"

# fact-store source partitions (semi-naive bookkeeping) ------------------ #
SRC_ALL = "all"
SRC_DELTA = "delta"
SRC_OLD = "old"


def _atom_str(atom: Atom) -> str:
    terms = (f"?{t}" if isinstance(t, str) else str(t) for t in atom.terms)
    return f"{atom.predicate}({', '.join(terms)})"


@dataclass(frozen=True)
class ScanStep:
    atom: Atom
    mode: str  # SCAN_SHARE | SCAN_INDEX
    est_rows: float
    #: which partition of the fact store this atom reads (semi-naive);
    #: queries always read SRC_ALL
    source: str = SRC_ALL
    #: original position of the atom in the conjunction (-1: unknown)
    body_index: int = -1

    def __str__(self) -> str:
        src = "" if self.source == SRC_ALL else f" {self.source}"
        return (
            f"scan[{self.mode}]{src} {_atom_str(self.atom)} "
            f"(~{self.est_rows:.0f} rows)"
        )


@dataclass(frozen=True)
class JoinStep:
    scan: ScanStep
    kind: str  # "sjoin" | "xjoin"
    key_vars: tuple[str, ...]
    #: semi-join direction: True = the new atom filters the pipeline,
    #: False = the pipeline filters the new atom
    filter_left: bool = False
    #: the variable a distributed executor should co-partition both sides
    #: on for this join (the single equi-join key; ``None`` for cartesian
    #: or multi-key steps).  A side whose relation is already stored
    #: partitioned on this variable — it owns the atom's first term —
    #: needs no exchange before the local join.
    partition_key: str | None = None

    def __str__(self) -> str:
        key = ", ".join(self.key_vars) if self.key_vars else "(cartesian)"
        direction = ""
        if self.kind == "sjoin":
            direction = " filter=atom" if self.filter_left else " filter=pipeline"
        return f"{self.kind} on [{key}]{direction} <- {self.scan}"


@dataclass
class Plan:
    """Ordered physical plan over a conjunction of atoms.

    Shared by the query executor and all three materialisation engines;
    ``query``/``projection`` are populated on the request path only.
    """

    atoms: tuple[Atom, ...]  # the conjunction in original order
    first: ScanStep | None  # None => provably empty under current stats
    joins: list[JoinStep] = field(default_factory=list)
    pivot: int | None = None  # delta-anchored rule plans only
    projection: tuple[str, ...] | None = None
    query: object | None = None  # the Query on the request path

    @property
    def is_empty(self) -> bool:
        return self.first is None

    def atom_order(self) -> list[Atom]:
        if self.first is None:
            return []
        return [self.first.atom] + [j.scan.atom for j in self.joins]

    def explain(self) -> str:
        if self.query is not None:
            header = f"plan for: {self.query}"
        else:
            body = ", ".join(_atom_str(a) for a in self.atoms)
            pivot = f" [pivot={self.pivot}]" if self.pivot is not None else ""
            header = f"plan for body: {body}{pivot}"
        lines = [header]
        if self.first is None:
            lines.append("  <empty: body atom over an empty/unknown predicate>")
            return "\n".join(lines)
        lines.append(f"  1. {self.first}")
        for i, j in enumerate(self.joins, start=2):
            lines.append(f"  {i}. {j}")
        if self.projection is not None:
            lines.append(
                f"  {len(self.joins) + 2}. project ["
                + ", ".join(self.projection)
                + "]"
            )
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.explain()


# --------------------------------------------------------------------- #
# estimation
# --------------------------------------------------------------------- #
def estimate_rows(stats, atom: Atom) -> float:
    """Estimated matching rows for one atom (0 if the predicate is absent
    or its stored arity disagrees with the atom's)."""
    n = stats.n_rows(atom.predicate)
    if n == 0 or stats.arity(atom.predicate) != atom.arity:
        return 0.0
    est = float(n)
    vars_seen: set[str] = set()
    for pos, t in enumerate(atom.terms):
        if isinstance(t, int):
            est *= stats.selectivity(atom.predicate, pos, t)
        elif t in vars_seen:
            est *= _REPEAT_DISCOUNT
        else:
            vars_seen.add(t)
    return est


def _scan_step(atom: Atom, est: float, source: str, body_index: int) -> ScanStep:
    constrained = any(isinstance(t, int) for t in atom.terms) or len(
        set(atom.variables())
    ) != len(atom.terms)
    mode = SCAN_INDEX if constrained else SCAN_SHARE
    return ScanStep(atom, mode, est, source, body_index)


def _join_kind(bound: set[str], atom_vars: set[str]) -> tuple[str, bool]:
    """The join-kind dispatch shared by queries and rule evaluation."""
    if bound <= atom_vars:
        # the pipeline's vars are all in the new atom: pipeline filters
        # the atom's substitutions (semi-join keeps the atom side)
        return "sjoin", False
    if atom_vars <= bound:
        # the new atom only restricts existing bindings
        return "sjoin", True
    return "xjoin", False


# --------------------------------------------------------------------- #
# compilation
# --------------------------------------------------------------------- #
def compile_body(
    atoms: tuple[Atom, ...],
    stats,
    *,
    pivot: int | None = None,
    reorder: bool = True,
    projection: tuple[str, ...] | None = None,
    query=None,
) -> Plan:
    """Compile a conjunction of atoms into an ordered :class:`Plan`.

    ``pivot`` marks the delta atom of a semi-naive rule evaluation: it
    anchors the plan (the delta is the small side) and fixes each atom's
    source partition from its original position (``old`` before the
    pivot, ``delta`` at it, ``all`` after — Algorithm 1 lines 9-19).
    ``reorder=False`` keeps the original left-to-right order (the
    reference evaluation for differential testing) while still using the
    shared join-kind dispatch.
    """
    atoms = tuple(atoms)

    def source_of(j: int) -> str:
        if pivot is None:
            return SRC_ALL
        if j == pivot:
            return SRC_DELTA
        return SRC_OLD if j < pivot else SRC_ALL

    estimates = {i: estimate_rows(stats, a) for i, a in enumerate(atoms)}
    plan = Plan(atoms, None, pivot=pivot, projection=projection, query=query)
    if not atoms or any(
        stats.n_rows(a.predicate) == 0 or stats.arity(a.predicate) != a.arity
        for a in atoms
    ):
        return plan

    remaining = list(enumerate(atoms))
    if pivot is not None and reorder:
        # the delta atom anchors the plan: under semi-naive it is the
        # small side, so everything else joins against it
        first_idx, first_atom = remaining.pop(pivot)
    elif not reorder:
        first_idx, first_atom = remaining.pop(0)
    else:
        # constant-bound atoms outrank pure-variable ones (an indexed
        # scan touches only matching rows whatever the predicate size),
        # then most selective first (ties by body position)
        def _anchor_key(ia):
            i, a = ia
            has_const = any(isinstance(t, int) for t in a.terms)
            return (0 if has_const else 1, estimates[i], i)

        remaining.sort(key=_anchor_key)
        first_idx, first_atom = remaining.pop(0)

    plan.first = _scan_step(
        first_atom, estimates[first_idx], source_of(first_idx), first_idx
    )
    bound: set[str] = set(first_atom.variables())

    while remaining:
        if reorder:
            connected = [
                (i, a) for i, a in remaining if bound & set(a.variables())
            ]
            pool = connected if connected else remaining
            pool.sort(key=lambda ia: (estimates[ia[0]], ia[0]))
            idx, atom = pool[0]
            remaining.remove((idx, atom))
        else:
            idx, atom = remaining.pop(0)

        atom_vars = set(atom.variables())
        shared = tuple(v for v in atom.variables() if v in bound)
        kind, filter_left = _join_kind(bound, atom_vars)
        plan.joins.append(
            JoinStep(
                _scan_step(atom, estimates[idx], source_of(idx), idx),
                kind,
                shared,
                filter_left,
                partition_key=shared[0] if len(shared) == 1 else None,
            )
        )
        bound |= atom_vars
    return plan


# --------------------------------------------------------------------- #
# plan caching
# --------------------------------------------------------------------- #
def stats_bucket(stats, atoms) -> tuple[int, ...]:
    """Power-of-two cardinality bucket per body atom's predicate.  Plans
    stay valid while every predicate stays inside its bucket; a bucket
    shift (cardinalities moved materially) triggers a re-plan."""
    return tuple(int(stats.n_rows(a.predicate)).bit_length() for a in atoms)


#: estimated-vs-actual cardinality ratio beyond which a cached plan is
#: recalibrated (dropped, so the next ``get`` re-plans with fresh stats)
_FEEDBACK_RATIO = 4.0


class PlanCache:
    """Plans keyed by (rule, pivot), guarded by a statistics bucket.

    ``get`` returns the cached plan while the bucket matches; a changed
    bucket re-plans in place (counted as ``replans``).  Shareable across
    engines — the differential tests drive a warm cache through a second
    engine to prove cache hits cannot change results.

    **Feedback recalibration.**  Executors report per-plan actuals via
    :meth:`note_actual` (today: the first scan's matched substitutions
    against its ``est_rows``).  When the estimate is off by more than
    ``_FEEDBACK_RATIO`` in either direction, the entry is dropped so the
    next ``get`` re-plans against current statistics — catching drift
    *within* a power-of-two bucket, which the bucket guard cannot see.
    Each key recalibrates at most once per bucket (re-planning with
    unchanged stats reproduces the estimate, so repeating would thrash);
    the observed log2 ratio is kept in ``est_log2_ratio`` for reporting.
    """

    def __init__(self):
        self._plans: dict = {}
        self._calibrated: dict = {}  # key -> bucket already recalibrated
        self.est_log2_ratio: dict = {}  # key -> last observed log2 ratio
        self.hits = 0
        self.misses = 0
        self.replans = 0
        self.feedback_replans = 0

    def get(self, key, bucket: tuple[int, ...], build) -> Plan:
        entry = self._plans.get(key)
        if entry is not None and entry[0] == bucket:
            self.hits += 1
            return entry[1]
        if entry is None:
            self.misses += 1
        else:
            self.replans += 1
        plan = build()
        self._plans[key] = (bucket, plan)
        return plan

    def note_actual(self, key, est_rows: float, actual_rows: int) -> None:
        """Record a plan's estimated-vs-actual first-scan cardinality;
        drop the cached entry when the estimate is off by more than
        ``_FEEDBACK_RATIO`` (once per statistics bucket)."""
        entry = self._plans.get(key)
        if entry is None:
            return
        ratio = max(float(actual_rows), 1.0) / max(float(est_rows), 1.0)
        self.est_log2_ratio[key] = float(np.log2(ratio))
        if 1.0 / _FEEDBACK_RATIO <= ratio <= _FEEDBACK_RATIO:
            return
        bucket = entry[0]
        if self._calibrated.get(key) == bucket:
            return  # already recalibrated in this bucket; don't thrash
        self._calibrated[key] = bucket
        del self._plans[key]
        self.feedback_replans += 1

    def __len__(self) -> int:
        return len(self._plans)

    def counters(self) -> dict:
        return {
            "plan_hits": self.hits,
            "plan_misses": self.misses,
            "plan_replans": self.replans,
            "plan_feedback_replans": self.feedback_replans,
            "plans": len(self._plans),
        }


# --------------------------------------------------------------------- #
# statistics adapters (the FrozenFacts contract for the other engines)
# --------------------------------------------------------------------- #
class ArrayStats:
    """Planner statistics over flat ``{pred: (n, arity) array}`` facts
    (FlatEngine working set, DistributedEngine host-side dataset)."""

    def __init__(self, facts: dict[str, np.ndarray]):
        self.facts = facts
        self._distinct: dict[tuple[str, int], int] = {}

    def n_rows(self, pred: str) -> int:
        rows = self.facts.get(pred)
        return 0 if rows is None else int(rows.shape[0])

    def arity(self, pred: str) -> int:
        rows = self.facts.get(pred)
        return 0 if rows is None or rows.shape[0] == 0 else int(rows.shape[1])

    def selectivity(self, pred: str, pos: int, value: int) -> float:
        n = self.n_rows(pred)
        if n == 0:
            return 0.0
        key = (pred, pos)
        distinct = self._distinct.get(key)
        if distinct is None:
            distinct = max(int(np.unique(self.facts[pred][:, pos]).shape[0]), 1)
            self._distinct[key] = distinct
        return 1.0 / distinct

    def refresh(self) -> None:
        self._distinct.clear()


class FactStoreStats:
    """Planner statistics over a live (mid-materialisation)
    :class:`~repro.core.metafacts.FactStore` — represented fact counts
    and RLE-run distinct estimates, computed without any unfolding
    (the same estimates :class:`~repro.core.frozen.FrozenFacts` serves
    before a snapshot exists).  ``refresh()`` once per round."""

    def __init__(self, facts):
        self.facts = facts
        self._n_rows: dict[str, int] = {}
        self._runs: dict[tuple[str, int], int] = {}

    def n_rows(self, pred: str) -> int:
        cached = self._n_rows.get(pred)
        if cached is None:
            cached = sum(mf.length for mf in self.facts.all(pred))
            self._n_rows[pred] = cached
        return cached

    def arity(self, pred: str) -> int:
        mfs = self.facts.all(pred)
        return mfs[0].arity if mfs else 0

    def selectivity(self, pred: str, pos: int, value: int) -> float:
        if self.n_rows(pred) == 0:
            return 0.0
        key = (pred, pos)
        runs = self._runs.get(key)
        if runs is None:
            store = self.facts.store
            runs = max(
                sum(store.n_runs(mf.columns[pos]) for mf in self.facts.all(pred)),
                1,
            )
            self._runs[key] = runs
        return 1.0 / runs

    def refresh(self) -> None:
        self._n_rows.clear()
        self._runs.clear()
