"""Column store: the paper's meta-constant mapping ``mu``.

A *meta-constant* names a vector of constants.  Following Appendix A, the
mapping ``mu`` sends a meta-constant to either

* a **leaf**: a non-decreasing vector of constants, stored run-length
  encoded (``run_values`` / ``run_counts``), or
* a **composite**: a vector of child meta-constants (``Concat``).

Composites provide structure sharing: a leaf produced by one derivation can
be referenced from arbitrarily many meta-facts while being stored once.

The paper's ``shuffle`` (Algorithm 4) splits a leaf ``a`` into ``b_in`` /
``b_out`` and *redefines* ``mu(a) := b_in . b_out`` so that the surviving
constants are stored exactly once.  We implement that redefinition
faithfully (see :meth:`ColumnStore.split`), with transitive unfold-cache
invalidation through parent links.

Representation-size accounting follows Section 4 of the paper: a mapping
entry with ``m`` RLE runs costs ``1 + 2*m`` symbols.
"""

from __future__ import annotations

import numpy as np

from ..obs.memory import register_reporter, split_owned_backed

__all__ = ["ColumnStore", "rle_encode"]


def rle_encode(values: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Run-length encode a 1-D array (returns run_values, run_counts)."""
    values = np.asarray(values, dtype=np.int64)
    n = values.shape[0]
    if n == 0:
        return values[:0], np.zeros(0, dtype=np.int64)
    change = np.empty(n, dtype=bool)
    change[0] = True
    np.not_equal(values[1:], values[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    run_values = values[starts]
    ends = np.append(starts[1:], n)
    run_counts = (ends - starts).astype(np.int64)
    return run_values, run_counts


class _Leaf:
    __slots__ = ("run_values", "run_counts", "length")

    def __init__(self, run_values: np.ndarray, run_counts: np.ndarray):
        self.run_values = run_values
        self.run_counts = run_counts
        self.length = int(run_counts.sum()) if run_counts.size else 0


class _Concat:
    __slots__ = ("children", "length")

    def __init__(self, children: list[int], length: int):
        self.children = children
        self.length = length


class ColumnStore:
    """The mapping ``mu``: meta-constant id -> Leaf | Concat node."""

    def __init__(self) -> None:
        self._nodes: dict[int, object] = {}
        self._parents: dict[int, set[int]] = {}
        self._unfold_cache: dict[int, np.ndarray] = {}
        self._next_id = 0
        # running counters for instrumentation
        self.n_splits = 0
        self.n_inplace_redefs = 0
        # running byte accounting (O(1) memory_report; the invariant
        # owned + backed == total_nbytes() is pinned in tests).  Backed
        # = views into a snapshot blob (see obs.memory double-count
        # rules); per-id backed bytes remembered for removal.
        self._nbytes_owned = 0
        self._nbytes_backed = 0
        self._backed_by_id: dict[int, int] = {}
        self._cache_nbytes = 0
        register_reporter("columns", self)

    # ------------------------------------------------------------------ #
    # byte accounting (obs.memory reporter protocol)
    # ------------------------------------------------------------------ #
    @staticmethod
    def _node_nbytes_of(node) -> int:
        if isinstance(node, _Leaf):
            return int(node.run_values.nbytes + node.run_counts.nbytes)
        return 8 * len(node.children)

    def _account_add(self, cid: int, node) -> None:
        if isinstance(node, _Leaf):
            owned, backed = split_owned_backed(
                (node.run_values, node.run_counts)
            )
        else:
            owned, backed = 8 * len(node.children), 0
        self._nbytes_owned += owned
        self._nbytes_backed += backed
        if backed:
            self._backed_by_id[cid] = backed

    def _account_remove(self, cid: int, node) -> None:
        backed = self._backed_by_id.pop(cid, 0)
        self._nbytes_backed -= backed
        self._nbytes_owned -= self._node_nbytes_of(node) - backed

    def _cache_set(self, cid: int, values: np.ndarray) -> None:
        prev = self._unfold_cache.get(cid)
        if prev is not None:
            self._cache_nbytes -= int(prev.nbytes)
        self._unfold_cache[cid] = values
        self._cache_nbytes += int(values.nbytes)

    def _cache_drop(self, cid: int) -> None:
        prev = self._unfold_cache.pop(cid, None)
        if prev is not None:
            self._cache_nbytes -= int(prev.nbytes)

    def recount_bytes(self) -> None:
        """Rebuild the running counters from the node table — used after
        compaction swaps the guts of a store wholesale."""
        self._nbytes_owned = 0
        self._nbytes_backed = 0
        self._backed_by_id = {}
        for cid, node in self._nodes.items():
            self._account_add(cid, node)
        self._cache_nbytes = sum(
            int(a.nbytes) for a in self._unfold_cache.values()
        )

    def memory_report(self) -> dict[str, int]:
        """O(1) byte report (obs.memory): owned node payload bytes,
        snapshot-backed node bytes (views into a blob, counted once),
        unfold-cache bytes, and the node count."""
        return {
            "nodes_bytes": self._nbytes_owned,
            "nodes_snapshot_backed_bytes": self._nbytes_backed,
            "unfold_cache_bytes": self._cache_nbytes,
            "n_nodes": len(self._nodes),
        }

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    def _fresh(self) -> int:
        cid = self._next_id
        self._next_id += 1
        return cid

    def new_leaf(self, values: np.ndarray) -> int:
        """Create a leaf meta-constant from a constant vector (stored RLE).

        Leaves created by ``compress`` are non-decreasing (the paper's
        sortedness invariant); leaves created by splits inherit the order
        of the parent so that positional alignment across the columns of a
        meta-fact is preserved.
        """
        values = np.asarray(values, dtype=np.int64)
        rv, rc = rle_encode(values)
        cid = self._fresh()
        node = _Leaf(rv, rc)
        self._nodes[cid] = node
        self._account_add(cid, node)
        self._cache_set(cid, values)
        return cid

    def new_leaf_rle(self, run_values: np.ndarray, run_counts: np.ndarray) -> int:
        cid = self._fresh()
        node = _Leaf(
            np.asarray(run_values, dtype=np.int64),
            np.asarray(run_counts, dtype=np.int64),
        )
        self._nodes[cid] = node
        self._account_add(cid, node)
        return cid

    def new_constant(self, value: int, count: int) -> int:
        """RLE leaf ``value * count`` (the paper's ``d * n`` notation)."""
        return self.new_leaf_rle(
            np.asarray([value], dtype=np.int64), np.asarray([count], dtype=np.int64)
        )

    def new_concat(self, children: list[int]) -> int:
        if len(children) == 1:
            return children[0]
        length = sum(self.length(c) for c in children)
        cid = self._fresh()
        node = _Concat(list(children), length)
        self._nodes[cid] = node
        self._account_add(cid, node)
        for c in children:
            self._parents.setdefault(c, set()).add(cid)
        return cid

    # ------------------------------------------------------------------ #
    # accessors
    # ------------------------------------------------------------------ #
    def node(self, cid: int):
        return self._nodes[cid]

    def is_leaf(self, cid: int) -> bool:
        return isinstance(self._nodes[cid], _Leaf)

    def length(self, cid: int) -> int:
        return self._nodes[cid].length

    def tail(self, cid: int) -> int:
        """Last constant in the unfolding (the paper's ``tail``)."""
        node = self._nodes[cid]
        while isinstance(node, _Concat):
            node = self._nodes[node.children[-1]]
        return int(node.run_values[-1])

    def head_value(self, cid: int) -> int:
        node = self._nodes[cid]
        while isinstance(node, _Concat):
            node = self._nodes[node.children[0]]
        return int(node.run_values[0])

    def head_values(self, cids: np.ndarray) -> np.ndarray:
        """Batched :meth:`head_value`: each distinct id is resolved once,
        then one gather maps the values back onto the input order (the
        singleton-recompression fast path — length-one columns unfold to
        exactly their head value)."""
        cids = np.asarray(cids, dtype=np.int64)
        if cids.size == 0:
            return np.zeros(0, dtype=np.int64)
        uniq, inv = np.unique(cids, return_inverse=True)
        vals = np.empty(uniq.shape[0], dtype=np.int64)
        for k, cid in enumerate(uniq):
            vals[k] = self.head_value(int(cid))
        return vals[inv]

    def depth(self, cid: int) -> int:
        """Meta-constant depth per Appendix B (leaf = 1)."""
        node = self._nodes[cid]
        if isinstance(node, _Leaf):
            return 1
        return 1 + max(self.depth(c) for c in node.children)

    def n_runs(self, cid: int) -> int:
        """Number of RLE runs in ``mu(cid)`` (leaf: constant runs; composite:
        runs over the child-id sequence)."""
        node = self._nodes[cid]
        if isinstance(node, _Leaf):
            return int(node.run_values.shape[0])
        rv, _ = rle_encode(np.asarray(node.children, dtype=np.int64))
        return int(rv.shape[0])

    def repr_size(self, cid: int, adaptive: bool = True) -> int:
        """Paper metric: ``1 + 2*m`` for ``m`` RLE-encoded entries.

        ``adaptive=True`` (beyond-paper, strictly better): incompressible
        leaves (runs ~ length) are charged as plain vectors ``1 + n``
        instead — the RLE pair accounting otherwise *doubles* the cost of
        all-distinct data (observed on transitive closure; see
        EXPERIMENTS.md).  A real store would pick the cheaper encoding per
        leaf exactly like this.
        """
        rle = 1 + 2 * self.n_runs(cid)
        if not adaptive:
            return rle
        node = self._nodes[cid]
        plain = 1 + (
            node.length if isinstance(node, _Leaf) else len(node.children)
        )
        return min(rle, plain)

    def reachable(self, roots) -> set[int]:
        seen: set[int] = set()
        stack = list(roots)
        while stack:
            cid = stack.pop()
            if cid in seen:
                continue
            seen.add(cid)
            node = self._nodes[cid]
            if isinstance(node, _Concat):
                stack.extend(node.children)
        return seen

    def topo_order(self, roots) -> list[int]:
        """Reachable node ids, children before parents — the traversal
        order snapshot export and compaction need to rebuild the DAG
        bottom-up (a node is emitted only after all of its children)."""
        order: list[int] = []
        seen: set[int] = set()
        # iterative post-order; (cid, expanded) frames avoid recursion
        # limits on deep Concat chains
        stack: list[tuple[int, bool]] = [(cid, False) for cid in roots]
        while stack:
            cid, expanded = stack.pop()
            if expanded:
                order.append(cid)
                continue
            if cid in seen:
                continue
            seen.add(cid)
            stack.append((cid, True))
            node = self._nodes[cid]
            if isinstance(node, _Concat):
                stack.extend(
                    (c, False) for c in node.children if c not in seen
                )
        return order

    def leaf_payload(self, cid: int) -> tuple[np.ndarray, np.ndarray]:
        """RLE payload ``(run_values, run_counts)`` of a leaf — the unit
        of content-hash deduplication in snapshots and compaction."""
        node = self._nodes[cid]
        assert isinstance(node, _Leaf)
        return node.run_values, node.run_counts

    def children(self, cid: int) -> list[int]:
        node = self._nodes[cid]
        return list(node.children) if isinstance(node, _Concat) else []

    def node_nbytes(self, cid: int) -> int:
        """Resident bytes of one node's structural payload (RLE arrays
        for leaves, the child-id vector for composites)."""
        node = self._nodes[cid]
        if isinstance(node, _Leaf):
            return int(node.run_values.nbytes + node.run_counts.nbytes)
        return 8 * len(node.children)

    def total_nbytes(self) -> int:
        """Resident bytes across *all* live nodes (reachable or not) —
        together with :meth:`reachable` this yields the dead-node
        accounting that drives compaction epochs."""
        return sum(self.node_nbytes(cid) for cid in self._nodes)

    def live_ids(self):
        """Ids of all live nodes (view; do not mutate while iterating)."""
        return self._nodes.keys()

    # ------------------------------------------------------------------ #
    # on-demand deep stats (compression effectiveness; O(n) — called at
    # compaction epochs and by the memory bench, never per-sample)
    # ------------------------------------------------------------------ #
    def leaf_rle_stats(self, ids) -> tuple[int, int]:
        """``(cells, runs)`` over the leaf nodes among ``ids`` — the RLE
        ratio ``cells / runs`` is the average run length."""
        cells = runs = 0
        for cid in ids:
            node = self._nodes[cid]
            if isinstance(node, _Leaf):
                cells += node.length
                runs += int(node.run_values.shape[0])
        return cells, runs

    def expanded_nbytes(self, roots) -> int:
        """Tree-expanded bytes: each node counted once per *path* from
        the roots — what storage would cost with no DAG sharing.  The
        ratio against the deduplicated byte count is the sharing factor."""
        memo: dict[int, int] = {}
        total = 0
        for root in roots:
            stack: list[tuple[int, bool]] = [(root, False)]
            while stack:
                cid, expanded = stack.pop()
                if not expanded and cid in memo:
                    continue
                node = self._nodes[cid]
                if isinstance(node, _Leaf):
                    memo[cid] = self._node_nbytes_of(node)
                elif expanded:
                    memo[cid] = 8 * len(node.children) + sum(
                        memo[c] for c in node.children
                    )
                else:
                    stack.append((cid, True))
                    stack.extend(
                        (c, False) for c in node.children if c not in memo
                    )
            total += memo[root]
        return total

    def live_dead_nbytes(self, roots) -> tuple[int, int]:
        """``(live_bytes, dead_bytes)``: bytes reachable from ``roots``
        vs bytes of garbage nodes compaction would reclaim."""
        live = sum(self.node_nbytes(c) for c in self.reachable(roots))
        return live, self.total_nbytes() - live

    def dedup_savings_bytes(self) -> int:
        """Bytes duplicate leaf payloads currently waste — what the
        compactor's content-hash resharing would reclaim."""
        seen: set[tuple[bytes, bytes]] = set()
        save = 0
        for node in self._nodes.values():
            if not isinstance(node, _Leaf):
                continue
            key = (node.run_values.tobytes(), node.run_counts.tobytes())
            if key in seen:
                save += self._node_nbytes_of(node)
            else:
                seen.add(key)
        return save

    # ------------------------------------------------------------------ #
    # unfolding
    # ------------------------------------------------------------------ #
    def unfold(self, cid: int) -> np.ndarray:
        """Recursively unfold a meta-constant into its constant vector."""
        cached = self._unfold_cache.get(cid)
        if cached is not None:
            return cached
        node = self._nodes[cid]
        if isinstance(node, _Leaf):
            out = np.repeat(node.run_values, node.run_counts)
        else:
            parts = [self.unfold(c) for c in node.children]
            out = np.concatenate(parts) if parts else np.zeros(0, dtype=np.int64)
        self._cache_set(cid, out)
        return out

    def drop_caches(self) -> None:
        self._unfold_cache.clear()
        self._cache_nbytes = 0

    def _invalidate_up(self, cid: int) -> None:
        stack = [cid]
        while stack:
            c = stack.pop()
            self._cache_drop(c)
            stack.extend(self._parents.get(c, ()))

    # ------------------------------------------------------------------ #
    # the paper's shuffle split (Algorithm 4, lines 47-52)
    # ------------------------------------------------------------------ #
    def split(self, cid: int, keep: np.ndarray, inplace: bool = True) -> int:
        """Split a column by a boolean mask over its unfolding.

        Every touched leaf ``a`` is split into fresh leaves ``b_in`` /
        ``b_out`` and ``mu(a)`` is redefined as ``b_in . b_out`` (the
        paper's in-place redefinition, which stores the constants exactly
        once).  Returns the meta-constant holding the surviving positions
        (a single leaf, or a Concat of the per-leaf ``b_in`` parts).

        With ``inplace=False`` (or when the same node occurs twice under
        one split root, where in-place redefinition of the first occurrence
        would misalign the offsets of the second) a fresh copy of the
        surviving constants is returned instead — always sound, slightly
        larger representation.
        """
        keep = np.asarray(keep, dtype=bool)
        assert keep.shape[0] == self.length(cid)
        self.n_splits += 1
        if not inplace or self._has_shared_occurrence(cid):
            # Order must be preserved: sibling columns of the same
            # meta-substitution are split with the same mask, and tuple
            # alignment is positional.
            return self.new_leaf(self.unfold(cid)[keep])
        visited: dict[int, int] = {}
        in_id = self._split_rec(cid, keep, 0, visited)
        return in_id

    def _has_shared_occurrence(self, cid: int) -> bool:
        """True iff some node occurs more than once in the tree under cid."""
        seen: set[int] = set()
        stack = [cid]
        while stack:
            c = stack.pop()
            node = self._nodes[c]
            if isinstance(node, _Concat):
                for ch in node.children:
                    if ch in seen:
                        return True
                    seen.add(ch)
                    stack.append(ch)
        return False

    def _split_rec(
        self, cid: int, keep: np.ndarray, offset: int, visited: dict[int, int]
    ) -> int:
        node = self._nodes[cid]
        n = node.length
        sub = keep[offset : offset + n]
        if not sub.any():
            return -1  # nothing survives under this node
        if sub.all():
            return cid  # full sharing, no split needed
        if isinstance(node, _Leaf):
            if cid in visited:
                # The same leaf appears twice under one split root (possible
                # via shared children).  In-place redefinition already
                # happened for the first occurrence; fall back to a fresh
                # copy for this occurrence (sound, slightly larger).
                vals = np.repeat(node.run_values, node.run_counts)
                return self.new_leaf(vals[sub])
            vals = np.repeat(node.run_values, node.run_counts)
            b_in = self.new_leaf(vals[sub])
            b_out = self.new_leaf(vals[~sub])
            visited[cid] = b_in
            # redefine mu(cid) := b_in . b_out  (paper, Alg. 4 line 51)
            self._account_remove(cid, node)
            redefined = _Concat([b_in, b_out], n)
            self._nodes[cid] = redefined
            self._account_add(cid, redefined)
            self._parents.setdefault(b_in, set()).add(cid)
            self._parents.setdefault(b_out, set()).add(cid)
            self._invalidate_up(cid)
            self.n_inplace_redefs += 1
            return b_in
        # composite: recurse into children, concatenating the b_in parts
        parts: list[int] = []
        off = offset
        for child in node.children:
            cl = self.length(child)
            # note: child length may have been *structurally* rewritten but
            # lengths never change under split, so offsets stay valid.
            part = self._split_rec(child, keep, off, visited)
            if part >= 0:
                parts.append(part)
            off += cl
        if len(parts) == 1:
            return parts[0]
        return self.new_concat(parts)

    # ------------------------------------------------------------------ #
    # scratch regions (query-time allocations; see DESIGN.md §Query)
    # ------------------------------------------------------------------ #
    def mark(self) -> int:
        """Checkpoint the id counter; nodes created from here on form a
        scratch region that :meth:`release` can reclaim wholesale."""
        return self._next_id

    def release(self, mark: int) -> None:
        """Drop every node with id >= ``mark``.

        Sound only under the frozen-store contract: no node below ``mark``
        has been redefined in place since the checkpoint (query evaluation
        guarantees this by always splitting with ``inplace=False``), and no
        surviving meta-fact references a dropped id.
        """
        for cid in range(mark, self._next_id):
            node = self._nodes.pop(cid, None)
            if node is None:
                continue
            self._account_remove(cid, node)
            self._cache_drop(cid)
            self._parents.pop(cid, None)
            if isinstance(node, _Concat):
                for child in node.children:
                    parents = self._parents.get(child)
                    if parents is not None:
                        parents.discard(cid)
        self._next_id = mark

    # ------------------------------------------------------------------ #
    # stats
    # ------------------------------------------------------------------ #
    def n_nodes(self) -> int:
        return len(self._nodes)
