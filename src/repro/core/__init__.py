"""CompMat-JAX core: datalog materialisation over compressed RDF KBs.

Implements Hu, Urbani, Motik, Horrocks — "Datalog Reasoning over Compressed
RDF Knowledge Bases" (CIKM 2019): meta-facts, structure sharing via the
mu-mapping, compressed semi-naive evaluation (Algorithms 1-6), plus a flat
reference engine and a shard_map-distributed variant.
"""

from .columns import ColumnStore, rle_encode
from .compile import JoinStep, Plan, PlanCache, ScanStep, compile_body
from .datalog import Atom, Program, Rule, parse_program, vertical_partition
from .engine import CMatEngine, MaterialisationStats
from .flat import FlatEngine, flat_seminaive
from .frozen import FrozenFacts, SortedRows
from .metafacts import FactStore, MetaFact, flat_repr_size
from .program_graph import explain_strata, is_recursive, stratify
from .terms import Dictionary

__all__ = [
    "Atom",
    "CMatEngine",
    "ColumnStore",
    "Dictionary",
    "FactStore",
    "FlatEngine",
    "FrozenFacts",
    "JoinStep",
    "MaterialisationStats",
    "MetaFact",
    "Plan",
    "PlanCache",
    "Program",
    "Rule",
    "ScanStep",
    "SortedRows",
    "compile_body",
    "explain_strata",
    "flat_repr_size",
    "flat_seminaive",
    "is_recursive",
    "parse_program",
    "rle_encode",
    "stratify",
    "vertical_partition",
]
