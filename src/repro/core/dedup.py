"""Duplicate elimination (Algorithm 6), vectorised.

The paper reports duplicate elimination as the dominant cost of CompMat
("our system spends most of the time in duplicate elimination", §4): its
merge anti-join unpacks and compares meta-facts element by element.  Our
beyond-paper adaptation keeps the same semantics but runs it as one sorted
anti-join per predicate:

* all candidate meta-facts are unfolded once into a row block,
* `first_occurrence_mask` removes duplicates *within* the round,
* a sorted-membership test against the unfolded current materialisation
  removes facts already in ``M``,
* survivors are re-expressed with the paper's ``shuffle`` so that
  fully-novel meta-facts keep their (shared) columns untouched.

On device this maps onto the ``sorted_member`` Pallas kernel.
"""

from __future__ import annotations

import numpy as np

from .columns import ColumnStore
from .metafacts import FactStore, MetaFact
from .util import factorize_rows, first_occurrence_mask, sorted_member

__all__ = ["elim_dup", "DedupIndex"]


class DedupIndex:
    """Persistent per-predicate sorted fact index (speed/memory tradeoff).

    The paper's dedup re-unpacks the whole materialisation every round
    (their dominant cost).  This index keeps each predicate's facts as a
    sorted packed-int64 array maintained incrementally: per round the
    anti-join is ``searchsorted`` against the index plus one merge of the
    survivors — O((n+m) log) total instead of re-unfolding O(|I|) per
    round.  Costs O(|I|) extra memory, which is exactly the flat-storage
    cost the paper avoids; enable it when speed matters more than memory
    (``CMatEngine(dedup_index=True)``).

    Packing: arity-1 facts use the id itself; arity-2 packs
    ``(a << 32) | b`` (ids < 2^31 — guaranteed by the dictionary).
    Higher arities fall back to joint factorisation per round.
    """

    def __init__(self):
        self._packed: dict[str, np.ndarray] = {}

    @staticmethod
    def pack(rows: np.ndarray) -> np.ndarray | None:
        if rows.shape[1] == 1:
            return rows[:, 0].astype(np.int64)
        if rows.shape[1] == 2:
            return (rows[:, 0].astype(np.int64) << 32) | rows[:, 1].astype(
                np.int64
            )
        return None  # arity > 2: caller falls back

    def seed(self, pred: str, rows: np.ndarray) -> None:
        packed = self.pack(rows)
        if packed is not None:
            existing = self._packed.get(pred)
            merged = packed if existing is None else np.concatenate(
                [existing, packed]
            )
            self._packed[pred] = np.unique(merged)

    def fresh_mask(self, pred: str, rows: np.ndarray) -> np.ndarray | None:
        """keep-mask (not-in-index AND first occurrence); None = fallback."""
        packed = self.pack(rows)
        if packed is None:
            return None
        index = self._packed.get(pred)
        if index is None or index.shape[0] == 0:
            not_in = np.ones(rows.shape[0], dtype=bool)
        else:
            not_in = ~sorted_member(packed, index)
        keep = not_in & first_occurrence_mask(packed)
        # merge survivors into the index
        survivors = packed[keep]
        if survivors.shape[0]:
            index = survivors if index is None else np.concatenate(
                [index, survivors]
            )
            self._packed[pred] = np.sort(index)
        return keep

    def nbytes(self) -> int:
        """Resident bytes of the packed index — the O(|I|) extra memory
        this speed trade costs (obs.memory accounting)."""
        return sum(int(a.nbytes) for a in self._packed.values())


def elim_dup(
    candidates: dict[str, list[tuple[tuple[int, ...], int]]],
    facts: FactStore,
    store: ColumnStore,
    round_tag: int,
    inplace_splits: bool = False,
    index: "DedupIndex | None" = None,
    fresh_counts: dict[str, list[int]] | None = None,
) -> list[MetaFact]:
    """Return meta-facts for every candidate fact not already in ``M``.

    ``candidates`` maps predicate -> list of (column ids, length).
    With ``index`` (a :class:`DedupIndex`) the anti-join runs against the
    persistent sorted index instead of re-unfolding ``M`` each round.
    When ``fresh_counts`` is given, the per-candidate-group survivor
    counts are appended to ``fresh_counts[pred]`` in candidate order
    (provenance attribution: group i of ``candidates[pred]`` kept
    ``fresh_counts[pred][i]`` fresh facts).
    """
    delta: list[MetaFact] = []
    for pred, cand in candidates.items():
        if not cand:
            continue
        arity = len(cand[0][0])
        # unfold all candidates into one (n, arity) block
        if arity == 0:
            continue
        cols = [
            np.concatenate([store.unfold(c[j]) for c, _ in cand])
            for j in range(arity)
        ]
        rows = np.stack(cols, axis=1)

        keep = index.fresh_mask(pred, rows) if index is not None else None
        if keep is None:
            m_rows = facts.unfold_pred(pred)
            if m_rows.shape[0] and m_rows.shape[1] != arity:
                raise ValueError(f"arity mismatch for {pred}")

            if m_rows.shape[0]:
                codes_new, codes_m = factorize_rows(rows, m_rows)
                not_in_m = ~sorted_member(codes_new, np.sort(codes_m))
            else:
                codes_new = factorize_rows(rows)[0]
                not_in_m = np.ones(rows.shape[0], dtype=bool)
            keep = not_in_m & first_occurrence_mask(codes_new)

        counts_out = (
            fresh_counts.setdefault(pred, []) if fresh_counts is not None else None
        )
        off = 0
        for cand_cols, length in cand:
            sub = keep[off : off + length]
            off += length
            if counts_out is not None:
                counts_out.append(int(sub.sum()))
            if sub.all():
                delta.append(MetaFact(pred, cand_cols, length, round_tag))
            elif sub.any():
                # split each distinct column id exactly once (a head like
                # ``P(x, x)`` repeats one id; double-splitting would apply
                # a stale mask to the already-redefined node)
                split_of = {
                    c: store.split(c, sub, inplace=inplace_splits)
                    for c in dict.fromkeys(cand_cols)
                }
                new_cols = tuple(split_of[c] for c in cand_cols)
                delta.append(MetaFact(pred, new_cols, int(sub.sum()), round_tag))
    return delta
