"""Meta-facts and the fact store ``M`` (with semi-naive round tags).

A meta-fact ``P(a1, ..., an)`` pairs a predicate with ``n`` meta-constants
of equal unfolding length; it represents the ``length`` ordinary facts read
off positionally from the unfoldings of its columns.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .columns import ColumnStore

__all__ = ["MetaFact", "FactStore"]


@dataclass
class MetaFact:
    predicate: str
    columns: tuple[int, ...]  # meta-constant ids
    length: int
    round: int = 0  # semi-naive round in which it was derived
    mf_id: int = -1  # store-assigned lineage id (-1 = not yet stored)

    @property
    def arity(self) -> int:
        return len(self.columns)


class FactStore:
    """Per-predicate lists of meta-facts, tagged by derivation round.

    Semi-naive bookkeeping (Algorithm 1): during round ``r``,

    * ``old(pred)``   = meta-facts with round < r-? ... facts derived before
      the previous round (``M \\ Delta``),
    * ``delta(pred)`` = facts derived in the previous round (``Delta``),
    * ``all(pred)``   = their union (``M``).
    """

    def __init__(self, store: ColumnStore):
        self.store = store
        self._facts: dict[str, list[MetaFact]] = {}
        self.current_round = 0
        self._next_mf_id = 0

    # ------------------------------------------------------------------ #
    def add(self, mf: MetaFact) -> None:
        if mf.mf_id < 0:
            mf.mf_id = self._next_mf_id
            self._next_mf_id += 1
        self._facts.setdefault(mf.predicate, []).append(mf)

    def predicates(self):
        return self._facts.keys()

    def all(self, pred: str) -> list[MetaFact]:
        return self._facts.get(pred, [])

    def delta(self, pred: str) -> list[MetaFact]:
        r = self.current_round
        return [mf for mf in self._facts.get(pred, []) if mf.round == r]

    def old(self, pred: str) -> list[MetaFact]:
        r = self.current_round
        return [mf for mf in self._facts.get(pred, []) if mf.round < r]

    def replace(self, pred: str, facts: list[MetaFact]) -> None:
        self._facts[pred] = facts

    def has_delta(self) -> bool:
        r = self.current_round
        return any(
            mf.round == r for lst in self._facts.values() for mf in lst
        )

    # ------------------------------------------------------------------ #
    # unfolding / statistics
    # ------------------------------------------------------------------ #
    def unfold_pred(self, pred: str, which: str = "all") -> np.ndarray:
        """Unfold all meta-facts of a predicate into an ``(n, arity)`` array."""
        facts = getattr(self, which)(pred)
        if not facts:
            return np.zeros((0, 1), dtype=np.int64)
        arity = facts[0].arity
        cols = []
        for j in range(arity):
            cols.append(
                np.concatenate([self.store.unfold(mf.columns[j]) for mf in facts])
            )
        return np.stack(cols, axis=1)

    def n_meta_facts(self) -> int:
        return sum(len(v) for v in self._facts.values())

    def n_facts(self) -> int:
        """Number of represented facts (with multiplicity)."""
        return sum(mf.length for lst in self._facts.values() for mf in lst)

    def freeze(self):
        """Snapshot view for query answering (DESIGN.md §Query).

        After freezing, the meta-facts and every node currently in the
        column store must not be redefined; query evaluation allocates
        only scratch nodes above the freeze mark and releases them.
        """
        from .frozen import FrozenFacts

        return FrozenFacts(self)

    def to_dict(self) -> dict[str, np.ndarray]:
        """Unfold the whole store into flat per-predicate fact arrays
        (duplicates removed) — used for equivalence testing."""
        out = {}
        for pred in self._facts:
            rows = self.unfold_pred(pred)
            out[pred] = np.unique(rows, axis=0)
        return out

    # ------------------------------------------------------------------ #
    # representation-size metric (paper Section 4)
    # ------------------------------------------------------------------ #
    def meta_repr_size(self) -> int:
        """``||M||`` = sum over predicates of ``1 + arity * #meta-facts``."""
        total = 0
        for lst in self._facts.values():
            if not lst:
                continue
            total += 1 + lst[0].arity * len(lst)
        return total

    def mu_repr_size(self, adaptive: bool = True) -> int:
        """``||mu||`` over meta-constants reachable from the store."""
        roots = [c for lst in self._facts.values() for mf in lst for c in mf.columns]
        reach = self.store.reachable(roots)
        return sum(self.store.repr_size(c, adaptive) for c in reach)

    def total_repr_size(self, adaptive: bool = True) -> int:
        """``||<M, mu>||`` (``adaptive=False`` = paper-exact accounting)."""
        return self.meta_repr_size() + self.mu_repr_size(adaptive)

    def mu_stats(self) -> dict:
        """avg/max unfolding length and max depth of reachable meta-constants."""
        roots = [c for lst in self._facts.values() for mf in lst for c in mf.columns]
        reach = self.store.reachable(roots)
        if not reach:
            return {"avg_len": 0.0, "max_len": 0, "max_depth": 0, "n_meta_constants": 0}
        lens = [self.store.length(c) for c in reach]
        depth = max(self.store.depth(c) for c in reach)
        return {
            "avg_len": float(np.mean(lens)),
            "max_len": int(max(lens)),
            "max_depth": int(depth),
            "n_meta_constants": len(reach),
        }


def flat_repr_size(facts: dict[str, np.ndarray]) -> int:
    """``||I||`` of a flat dataset: sum of ``1 + arity * m_i`` (paper §4)."""
    total = 0
    for rows in facts.values():
        if rows.shape[0] == 0:
            continue
        total += 1 + rows.shape[1] * rows.shape[0]
    return total
