"""Synthetic RDF knowledge-base generators.

The paper's evaluation datasets (LUBM-1K, Reactome, Claros) are not
redistributable here, so we generate structurally-analogous KBs:

* :func:`paper_example` — the exact running example of Section 3.
* :func:`lubm_like` — a university-domain KB with the regularity LUBM has
  (departments, students, courses, advisors) and a recursive L-style
  program; highly regular -> high compressibility (paper's LUBM row).
* :func:`chain` — transitive closure over a path: quadratic derivation
  count from linear input (paper's Claros_LE 'difficult rules' regime).
* :func:`star` / :func:`bipartite` — join-heavy shapes exercising xjoin.
* :func:`random_kb` — randomised KBs for property-based testing.
"""

from __future__ import annotations

import numpy as np

from .datalog import Program, parse_program
from .terms import Dictionary

__all__ = [
    "paper_example",
    "lubm_like",
    "chain",
    "star",
    "bipartite",
    "random_kb",
]


def paper_example(n: int = 4, m: int = 3):
    """The running example of Section 3 (facts (1)-(4), rules (5)-(6)).

    Constants are laid out exactly in the paper's order:
    ``a_1 < ... < a_2n < b_1 < ... < b_m < c_1 < ... < c_m < d < e_*``.
    """
    d = Dictionary()
    a = [d.intern(f"a{i}") for i in range(1, 2 * n + 1)]
    b = [d.intern(f"b{i}") for i in range(1, m + 1)]
    c = [d.intern(f"c{i}") for i in range(1, m + 1)]
    dd = d.intern("d")
    e = [d.intern(f"e{i}") for i in range(1, m + 1)]

    P = np.asarray(
        [[ai, dd] for ai in a] + [[bi, ci] for bi, ci in zip(b, c)], dtype=np.int64
    )
    R = np.asarray([[a[2 * i - 1]] for i in range(1, n + 1)], dtype=np.int64)
    T = np.asarray([[dd, ei] for ei in e], dtype=np.int64)

    program = parse_program(
        """
        P(x, y), R(x) -> S(x, y)
        S(x, y), T(y, z) -> P(x, z)
        """
    )
    return program, {"P": P, "R": R, "T": T}, d


def lubm_like(n_dept: int = 20, n_students: int = 200, n_courses: int = 25, seed: int = 0):
    """University-domain KB with LUBM-style regularity.

    Schema (vertically partitioned predicates):
      memberOf(student, dept), subOrganizationOf(dept, univ),
      takesCourse(student, course), teacherOf(prof, course),
      advisor(student, prof), GraduateStudent(s), Professor(p)

    Recursive program (lower-bound style): the bulk of LUBM_L's rules are
    taxonomic (subclass / subproperty / domain / range) — these produce
    the paper's headline compression because every derived level shares
    the source columns wholesale — plus joins and a recursive clique.
    """
    rng = np.random.default_rng(seed)
    d = Dictionary()
    univ = d.intern("univ0")
    depts = d.intern_many([f"dept{i}" for i in range(n_dept)])
    students = d.intern_many([f"student{i}" for i in range(n_students)])
    profs = d.intern_many([f"prof{i}" for i in range(max(2, n_dept * 2))])
    courses = d.intern_many([f"course{i}" for i in range(n_courses)])

    member_of = np.stack(
        [students, depts[rng.integers(0, n_dept, n_students)]], axis=1
    )
    sub_org = np.stack([depts, np.full(n_dept, univ)], axis=1)
    takes = np.stack(
        [
            np.repeat(students, 3),
            courses[rng.integers(0, n_courses, 3 * n_students)],
        ],
        axis=1,
    )
    teacher_of = np.stack([profs[rng.integers(0, len(profs), n_courses)], courses], axis=1)
    advisor = np.stack(
        [students, profs[rng.integers(0, len(profs), n_students)]], axis=1
    )
    grad = students[rng.random(n_students) < 0.4].reshape(-1, 1)

    program = parse_program(
        """
        memberOf(x, dv), subOrganizationOf(dv, u) -> memberOfOrg(x, u)
        takesCourse(s, cv), teacherOf(p, cv) -> taughtBy(s, p)
        taughtBy(s, p) -> knows(s, p)
        advisor(s, p) -> knows(s, p)
        # taxonomic chains (the LUBM_L profile: most rules are unary)
        GraduateStudent(s) -> Student(s)
        Student(s) -> Person(s)
        Person(s) -> Agent(s)
        Agent(s) -> Thing(s)
        # domain/range derivations
        advisor(s, p) -> Student(s)
        advisor(s, p) -> Professor(p)
        Professor(p) -> Faculty(p)
        Faculty(p) -> Employee(p)
        Employee(p) -> Person(p)
        teacherOf(p, cv) -> Professor(p)
        teacherOf(p, cv) -> Course(cv)
        takesCourse(s, cv) -> Course(cv)
        memberOf(x, dv) -> Organization(dv)
        subOrganizationOf(dv, u) -> Organization(dv)
        subOrganizationOf(dv, u) -> Organization(u)
        # subproperty
        advisor(s, p) -> worksWith(s, p)
        taughtBy(s, p) -> worksWith(s, p)
        Student(s), memberOfOrg(s, u) -> OrgMember(s)
        knows(x, y), knows(y, z) -> connected(x, z)
        connected(x, y) -> knows(x, y)
        """
    )
    dataset = {
        "memberOf": member_of,
        "subOrganizationOf": sub_org,
        "takesCourse": np.unique(takes, axis=0),
        "teacherOf": teacher_of,
        "advisor": advisor,
        "GraduateStudent": grad,
    }
    return program, dataset, d


def chain(n: int = 200):
    """Transitive closure over a path graph — O(n^2) derived facts from
    O(n) input (the paper's Claros_LE 'difficult rules' regime)."""
    d = Dictionary()
    nodes = d.intern_many([f"v{i:06d}" for i in range(n + 1)])
    edge = np.stack([nodes[:-1], nodes[1:]], axis=1)
    program = parse_program(
        """
        edge(x, y) -> path(x, y)
        path(x, y), edge(y, z) -> path(x, z)
        """
    )
    return program, {"edge": edge}, d


def star(n_spokes: int = 1000, n_hubs: int = 3):
    """Hub-and-spoke KB: semi-join heavy (the paper's rule (5) pattern)."""
    d = Dictionary()
    hubs = d.intern_many([f"hub{i}" for i in range(n_hubs)])
    spokes = d.intern_many([f"s{i:06d}" for i in range(n_spokes)])
    P = np.stack(
        [np.tile(spokes, n_hubs), np.repeat(hubs, n_spokes)], axis=1
    )
    R = spokes[::2].reshape(-1, 1)
    T = np.stack(
        [np.repeat(hubs, 4), d.intern_many([f"t{i}" for i in range(4 * n_hubs)])],
        axis=1,
    )
    program = parse_program(
        """
        P(x, y), R(x) -> S(x, y)
        S(x, y), T(y, z) -> Q(x, z)
        """
    )
    return program, {"P": P, "R": R, "T": T}, d


def bipartite(n_left: int = 300, n_right: int = 300, seed: int = 1):
    """Dense bipartite cross-join workload (worst case for flat storage)."""
    rng = np.random.default_rng(seed)
    d = Dictionary()
    left = d.intern_many([f"l{i:05d}" for i in range(n_left)])
    right = d.intern_many([f"r{i:05d}" for i in range(n_right)])
    mid = d.intern("mid")
    A = np.stack([left, np.full(n_left, mid)], axis=1)
    B = np.stack([np.full(n_right, mid), right], axis=1)
    program = parse_program("A(x, y), B(y, z) -> C(x, z)")
    _ = rng
    return program, {"A": A, "B": B}, d


def random_kb(
    rng: np.random.Generator,
    n_constants: int = 12,
    n_facts: int = 40,
    n_rules: int = 4,
    predicates=("P", "Q", "R", "S"),
):
    """Random small KB + recursive program for property-based testing."""
    from .datalog import Atom, Rule

    arity = {p: int(rng.integers(1, 3)) for p in predicates}
    dataset = {}
    for p in predicates:
        k = arity[p]
        rows = rng.integers(0, n_constants, size=(n_facts, k)).astype(np.int64)
        dataset[p] = np.unique(rows, axis=0)

    variables = ["x", "y", "z", "w"]
    rules = []
    attempts = 0
    while len(rules) < n_rules and attempts < 200:
        attempts += 1
        n_body = int(rng.integers(1, 4))
        body = []
        for _ in range(n_body):
            p = predicates[int(rng.integers(0, len(predicates)))]
            terms = tuple(
                variables[int(rng.integers(0, len(variables)))]
                for _ in range(arity[p])
            )
            body.append(Atom(p, terms))
        body_vars = [v for a in body for v in a.variables()]
        if not body_vars:
            continue
        hp = predicates[int(rng.integers(0, len(predicates)))]
        head_terms = tuple(
            body_vars[int(rng.integers(0, len(body_vars)))] for _ in range(arity[hp])
        )
        try:
            rules.append(Rule(tuple(body), Atom(hp, head_terms)))
        except ValueError:
            continue
    return Program(rules), dataset
