"""Distributed semi-naive materialisation under ``shard_map``.

The paper's engine is single-node.  To make the technique deployable at
cluster scale we add the standard distributed-datalog construction
(hash-partition + exchange), mapped onto JAX-native collectives:

* every relation is **hash-partitioned on its first argument** across the
  ``data`` axis of the device mesh;
* each round evaluates rules locally on each shard (naive iteration; the
  semi-naive delta restriction is a host-path feature — the distributed
  variant trades redundant local work for static shapes);
* derivations whose head key hashes to another shard are exchanged with a
  single ``all_to_all`` per round (this is the only communication);
* termination is detected with an ``all_reduce`` OR of "any new facts".

Facts live in fixed-capacity padded buffers (JAX static shapes): a
``(capacity, arity)`` int32 array plus a validity count; empty slots hold
``EMPTY = -1``.  Join/dedup primitives are the jnp twins of the numpy host
path in :mod:`repro.core.util` and are what the Pallas kernels accelerate.

The same code lowers on the 1-device CPU mesh (tests), the 256-chip
single-pod mesh, and the 512-chip multi-pod mesh (dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .compile import ArrayStats, compile_body
from .datalog import Program

EMPTY = jnp.int32(-1)

__all__ = ["DistributedEngine", "ShardedRelation", "local_round"]


@dataclass
class ShardedRelation:
    """Padded fact buffer: rows (capacity, arity) int32, count scalar."""

    rows: jax.Array
    count: jax.Array  # int32 scalar (per shard under shard_map)


def _hash_shard(keys: jax.Array, n_shards: int) -> jax.Array:
    """Multiplicative hash -> shard id (stable across rounds)."""
    h = (keys.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(16)
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


# --------------------------------------------------------------------- #
# jnp primitives (device twins of core.util; kernels/ accelerates these)
# --------------------------------------------------------------------- #
def sorted_member_jnp(a: jax.Array, b_sorted: jax.Array) -> jax.Array:
    """Membership of a[i] in sorted b (EMPTY-padded b allowed at the end)."""
    idx = jnp.searchsorted(b_sorted, a)
    idx = jnp.minimum(idx, b_sorted.shape[0] - 1)
    return b_sorted[idx] == a


def sorted_member_kernel(a: jax.Array, b_sorted: jax.Array) -> jax.Array:
    """Pallas-kernel membership (``repro.kernels.sorted_member``) — the
    TPU device path for the dedup anti-join.  interpret=True here (CPU
    container); on TPU pass interpret=False through ``ops.member``."""
    from ..kernels import ops

    return ops.member(a, b_sorted, interpret=True)


#: x64 is disabled by default in JAX, so packed fact keys live in int32:
#: binary facts use 15/16-bit halves, constraining the *distributed* path
#: to dictionaries of < 32768 constants (the host engine keeps full int64).
MAX_DIST_CONST = 1 << 15
BIG = jnp.int32(np.iinfo(np.int32).max)


def pack_pairs(rows: jax.Array) -> jax.Array:
    """Pack (n, 2) int32 rows into sortable int32 keys; (n, 1) passes through."""
    if rows.shape[1] == 1:
        return rows[:, 0]
    hi = rows[:, 0]
    lo = rows[:, 1]
    return (hi << 16) | (lo & 0xFFFF)


def unpack_pairs(keys: jax.Array, arity: int) -> jax.Array:
    if arity == 1:
        return keys[:, None]
    hi = keys >> 16
    lo = jnp.bitwise_and(keys, 0xFFFF)
    return jnp.stack([hi, lo], axis=1)


def dedup_against(
    new_keys: jax.Array, new_valid: jax.Array, old_keys_sorted: jax.Array,
    member_fn=sorted_member_jnp,
) -> jax.Array:
    """Valid-mask of new facts that are not already present in old."""
    member = member_fn(new_keys, old_keys_sorted)
    # first-occurrence within new: sort, compare neighbours, scatter back
    masked = jnp.where(new_valid, new_keys, BIG)
    order = jnp.argsort(masked)
    ks = masked[order]
    first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), ks[1:] != ks[:-1]]
    )
    first = jnp.zeros_like(first_sorted).at[order].set(first_sorted)
    return new_valid & first & (~member)


def join_on_key(
    l_keys: jax.Array,
    l_valid: jax.Array,
    l_payload: jax.Array,
    r_keys: jax.Array,
    r_valid: jax.Array,
    r_payload: jax.Array,
    out_capacity: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Equi-join with bounded output (static shapes).

    Returns (left payload, right payload, valid) for up to ``out_capacity``
    matching pairs, enumerated as (left row) x (matching right rows).
    """
    r_sort_key = jnp.where(r_valid, r_keys, BIG)
    order = jnp.argsort(r_sort_key)
    r_keys_s = r_sort_key[order]
    r_payload_s = r_payload[order]

    lo = jnp.searchsorted(r_keys_s, jnp.where(l_valid, l_keys, BIG - 1), side="left")
    hi = jnp.searchsorted(r_keys_s, jnp.where(l_valid, l_keys, BIG - 1), side="right")
    counts = jnp.where(l_valid, hi - lo, 0)
    offsets = jnp.cumsum(counts) - counts
    total = jnp.sum(counts)

    out_idx = jnp.arange(out_capacity)
    # which left row does output slot i belong to?
    l_of = jnp.searchsorted(offsets + counts, out_idx, side="right")
    l_of = jnp.minimum(l_of, l_keys.shape[0] - 1)
    within = out_idx - offsets[l_of]
    r_of = jnp.minimum(lo[l_of] + within, r_keys.shape[0] - 1)
    valid = out_idx < total
    return l_payload[l_of], r_payload_s[r_of], valid


# --------------------------------------------------------------------- #
# the distributed engine
# --------------------------------------------------------------------- #
class DistributedEngine:
    """Hash-partitioned semi-naive materialisation for binary datalog.

    Supports the rule shapes that cover RDF/OWL-RL style programs after
    vertical partitioning (arity <= 2): single-atom rules and two-atom
    chain joins ``A(x,y), B(y,z) -> H(x,z)`` (plus their unary variants).
    The host drives rounds; each round is one jitted ``shard_map`` call.
    """

    def __init__(
        self,
        program: Program,
        mesh: Mesh,
        axis: str = "data",
        capacity: int = 1 << 14,
        join_capacity: int | None = None,
        use_pallas_kernels: bool = False,
    ):
        self.program = program
        self.mesh = mesh
        self.axis = axis
        self.capacity = capacity
        self.join_capacity = join_capacity or capacity
        self.n_shards = mesh.shape[axis]
        self._compiled_round = None
        #: shared-compiler plans per rule (populated by ``materialise``;
        #: the naive distributed rounds have no delta pivot, so plans are
        #: compiled with ``pivot=None`` over host-side dataset stats)
        self._plans: dict = {}
        # TPU device path: dedup membership through the Pallas kernel
        self._member_fn = (
            sorted_member_kernel if use_pallas_kernels else sorted_member_jnp
        )

    # -------------------------------------------------------------- #
    def shard_dataset(self, dataset: dict[str, np.ndarray]) -> dict:
        """Partition a host dataset into per-shard padded buffers, laid out
        as global arrays sharded on the leading (shard) axis."""
        n, cap = self.n_shards, self.capacity
        out = {}
        for pred, rows in dataset.items():
            rows = np.asarray(rows, dtype=np.int32)
            if rows.ndim == 1:
                rows = rows.reshape(-1, 1)
            arity = rows.shape[1]
            shard = np.asarray(
                (rows[:, 0].astype(np.uint32) * np.uint32(2654435761)) >> np.uint32(16)
            ) % np.uint32(n)
            buf = np.full((n, cap, arity), -1, dtype=np.int32)
            cnt = np.zeros((n,), dtype=np.int32)
            for s in range(n):
                mine = rows[shard == s]
                if mine.shape[0] > cap:
                    raise ValueError(f"capacity {cap} too small for shard {s}")
                buf[s, : mine.shape[0]] = mine
                cnt[s] = mine.shape[0]
            out[pred] = (buf, cnt)
        return out

    # -------------------------------------------------------------- #
    def _round_fn(self, preds: tuple[str, ...], arities: dict[str, int]):
        """Build the jitted one-round function over fixed predicate order."""
        program, axis, n_shards = self.program, self.axis, self.n_shards
        cap, jcap = self.capacity, self.join_capacity

        def body(*flat):
            # flat: rows_0, cnt_0, rows_1, cnt_1, ...  — shard_map hands us
            # blocks with a leading axis of size 1; squeeze it here and
            # restore it on the way out.
            rels = {}
            for k, pred in enumerate(preds):
                rels[pred] = ShardedRelation(flat[2 * k][0], flat[2 * k + 1][0])

            derived: dict[str, list[tuple[jax.Array, jax.Array]]] = {}
            total_dropped = jnp.zeros((), jnp.int32)

            def emit(pred, rows, valid):
                derived.setdefault(pred, []).append((rows, valid))

            for rule in program:
                d = self._eval_rule_local(rule, rels, emit, arities)
                total_dropped = total_dropped + d

            # merge + rekey + exchange + dedup per head predicate
            new_flat = []
            any_new = jnp.zeros((), dtype=jnp.int32)
            for pred in preds:
                rel = rels[pred]
                arity = arities[pred]
                blocks = derived.get(pred, [])
                if not blocks:
                    new_flat.extend([rel.rows[None], rel.count[None]])
                    continue
                rows = jnp.concatenate([b[0] for b in blocks])
                valid = jnp.concatenate([b[1] for b in blocks])
                rows = jnp.where(valid[:, None], rows, EMPTY)

                # exchange: route each row to the shard owning its key
                rows, valid, d = self._exchange(rows, valid, n_shards)
                total_dropped = total_dropped + d

                # dedup against local store
                keys = pack_pairs(rows)
                old_keys = pack_pairs(rel.rows)
                slot_valid = jnp.arange(cap) < rel.count
                old_sorted = jnp.sort(jnp.where(slot_valid, old_keys, BIG))
                fresh = dedup_against(keys, valid, old_sorted,
                                      member_fn=self._member_fn)

                # append fresh rows into the padded buffer
                n_fresh = jnp.sum(fresh.astype(jnp.int32))
                dest = rel.count + jnp.cumsum(fresh.astype(jnp.int32)) - 1
                dest = jnp.where(fresh, dest, cap - 1)  # park invalid writes
                new_rows = rel.rows.at[dest].set(
                    jnp.where(fresh[:, None], rows, rel.rows[dest])
                )
                new_count = jnp.minimum(rel.count + n_fresh, cap)
                rels[pred] = ShardedRelation(new_rows, new_count)
                any_new = any_new + n_fresh
                new_flat.extend([new_rows[None], new_count[None]])

            total_new = jax.lax.psum(any_new, axis)
            total_dropped = jax.lax.psum(total_dropped, axis)
            return tuple(new_flat) + (total_new, total_dropped)

        in_specs = []
        for pred in preds:
            in_specs.extend([P(axis, None, None), P(axis)])
        out_specs = tuple(in_specs) + (P(), P())

        shmapped = shard_map(
            body,
            mesh=self.mesh,
            in_specs=tuple(in_specs),
            out_specs=out_specs,
            # pallas_call outputs have no varying-axes metadata; disable
            # the vma check so the kernel dedup path can run under
            # shard_map (the specs above still pin the layouts)
            check_vma=False,
        )
        return jax.jit(shmapped)

    # -------------------------------------------------------------- #
    def _exchange(self, rows, valid, n_shards, keys=None):
        """Route rows to ``hash(key)`` owner shards with one all_to_all.

        ``keys`` defaults to the first column (relation-ownership routing
        for derived facts); joins pass the join-key column so both sides
        are co-partitioned before the local merge (classic distributed
        semi-naive re-keying).  Returns (rows, valid, n_dropped): rows
        past the per-bucket capacity are dropped and *counted* so the
        host can fail loudly instead of silently under-deriving.
        """
        if keys is None:
            keys = rows[:, 0]
        if n_shards == 1:
            return rows, valid, jnp.zeros((), jnp.int32)
        cap = rows.shape[0]
        per = max(cap // n_shards, 1)
        shard_of = jnp.where(valid, _hash_shard(keys, n_shards), n_shards)
        # stable sort by destination; bucket i occupies slots [i*per,(i+1)*per)
        order = jnp.argsort(shard_of, stable=True)
        rows_s = rows[order]
        shard_s = shard_of[order]
        idx = jnp.arange(cap)
        # position within bucket (prefix count of same destination)
        pos_in_bucket = idx - jnp.searchsorted(shard_s, shard_s, side="left")
        ok = (pos_in_bucket < per) & (shard_s < n_shards)
        dropped = jnp.sum(((~ok) & (shard_s < n_shards)).astype(jnp.int32))
        slot = jnp.where(ok, shard_s * per + pos_in_bucket, n_shards * per)
        buckets = jnp.full(
            (n_shards * per + 1, rows.shape[1]), EMPTY, dtype=rows.dtype
        )
        buckets = buckets.at[slot].set(
            jnp.where(ok[:, None], rows_s, EMPTY)
        )[: n_shards * per]
        buckets = buckets.reshape(n_shards, per, rows.shape[1])
        exchanged = jax.lax.all_to_all(
            buckets, self.axis, split_axis=0, concat_axis=0, tiled=False
        )
        exchanged = exchanged.reshape(n_shards * per, rows.shape[1])
        valid_out = exchanged[:, 0] != EMPTY
        return exchanged, valid_out, dropped

    # -------------------------------------------------------------- #
    def _eval_rule_local(self, rule, rels, emit, arities):
        """Evaluate one rule on the local shard; returns dropped-row count
        from the join-key re-partitioning (0 when no exchange happens)."""
        head = rule.head
        cap = self.capacity
        zero = jnp.zeros((), jnp.int32)
        # the shared compiler orders the body (small side anchors); the
        # dryrun path calls _round_fn without a dataset, where no plan
        # exists and the textual order is kept
        plan = self._plans.get(rule)
        body = (
            tuple(plan.atom_order())
            if plan is not None and not plan.is_empty
            else rule.body
        )

        def rows_valid(pred):
            rel = rels.get(pred)
            if rel is None:
                return None
            v = jnp.arange(rel.rows.shape[0]) < rel.count
            return rel.rows, v

        if len(body) == 1:
            src = rows_valid(body[0].predicate)
            if src is None:
                return zero
            rows, valid = src
            rows, valid = _apply_atom_constraints(body[0], rows, valid)
            out = _project_head(body[0].variables(), rows, head)
            if out is not None:
                emit(head.predicate, out, valid)
            return zero
        elif len(body) == 2:
            a, b = body
            sa, sb = rows_valid(a.predicate), rows_valid(b.predicate)
            if sa is None or sb is None:
                return zero
            ra, va = _apply_atom_constraints(a, *sa)
            rb, vb = _apply_atom_constraints(b, *sb)
            va_vars, vb_vars = a.variables(), b.variables()
            common = [v for v in va_vars if v in vb_vars]
            if len(common) != 1:
                raise NotImplementedError(
                    "distributed engine supports single-key two-atom joins"
                )
            key = common[0]
            # re-partition both sides on the join key: facts live on the
            # shard of their *first* argument, which is generally not the
            # join variable — without this exchange only same-shard pairs
            # would ever join (caught by the 4-shard integration test)
            dropped = jnp.zeros((), jnp.int32)
            ra = jnp.where(va[:, None], ra, EMPTY)
            rb = jnp.where(vb[:, None], rb, EMPTY)
            ra, va, d1 = self._exchange(
                ra, va, self.n_shards, keys=ra[:, va_vars.index(key)]
            )
            rb, vb, d2 = self._exchange(
                rb, vb, self.n_shards, keys=rb[:, vb_vars.index(key)]
            )
            dropped = dropped + d1 + d2
            ka = ra[:, va_vars.index(key)]
            kb = rb[:, vb_vars.index(key)]
            lpay, rpay, valid = join_on_key(
                ka, va, ra, kb, vb, rb, self.join_capacity
            )
            var_cols = {}
            for i, v in enumerate(va_vars):
                var_cols[v] = lpay[:, i]
            for i, v in enumerate(vb_vars):
                var_cols.setdefault(v, rpay[:, i])
            cols = []
            for t in head.terms:
                if isinstance(t, int):
                    cols.append(jnp.full((self.join_capacity,), t, jnp.int32))
                else:
                    cols.append(var_cols[t])
            emit(head.predicate, jnp.stack(cols, axis=1), valid)
            return dropped
        else:
            raise NotImplementedError(
                "distributed engine supports bodies of <= 2 atoms"
            )

    # -------------------------------------------------------------- #
    def materialise(self, dataset: dict[str, np.ndarray], max_rounds: int = 64):
        """Run rounds to fixpoint; returns per-predicate host arrays."""
        preds = tuple(
            sorted(set(dataset) | self.program.predicates())
        )
        arities = {}
        for p in preds:
            if p in dataset:
                r = np.asarray(dataset[p])
                arities[p] = 1 if r.ndim == 1 else r.shape[1]
        for rule in self.program:
            for atom in (rule.head, *rule.body):
                arities.setdefault(atom.predicate, atom.arity)
        full = {
            p: dataset.get(p, np.zeros((0, arities[p]), dtype=np.int32))
            for p in preds
        }
        # compile each rule body through the shared compiler over the
        # host-side dataset statistics: for the supported <= 2-atom
        # bodies this picks which side anchors the local join (a plan
        # over an initially-empty IDB predicate stays unordered)
        stats_view = ArrayStats(full)
        self._plans = {
            rule: compile_body(rule.body, stats_view) for rule in self.program
        }
        sharded = self.shard_dataset(full)
        flat = []
        for p in preds:
            buf, cnt = sharded[p]
            flat.extend([jnp.asarray(buf), jnp.asarray(cnt)])

        round_fn = self._round_fn(preds, arities)
        rounds = 0
        for _ in range(max_rounds):
            out = round_fn(*flat)
            flat, total_new, dropped = list(out[:-2]), out[-2], out[-1]
            rounds += 1
            if int(dropped) > 0:
                raise RuntimeError(
                    f"exchange overflow: {int(dropped)} rows dropped — "
                    f"increase capacity/join_capacity (skewed join keys)"
                )
            if int(total_new) == 0:
                break

        result = {}
        for k, p in enumerate(preds):
            buf = np.asarray(flat[2 * k])
            cnt = np.asarray(flat[2 * k + 1])
            rows = np.concatenate(
                [buf[s, : cnt[s]] for s in range(self.n_shards)]
            )
            result[p] = np.unique(rows.astype(np.int64), axis=0)
        self.rounds = rounds
        return result


def _apply_atom_constraints(atom, rows, valid):
    """Constants / repeated variables as validity-mask filters."""
    vars_ = atom.variables()
    first = {v: atom.terms.index(v) for v in vars_}
    for pos, t in enumerate(atom.terms):
        if isinstance(t, int):
            valid = valid & (rows[:, pos] == t)
        elif pos != first[t]:
            valid = valid & (rows[:, pos] == rows[:, first[t]])
    cols = [rows[:, first[v]] for v in vars_]
    return jnp.stack(cols, axis=1), valid


def _project_head(body_vars, rows, head):
    cols = []
    for t in head.terms:
        if isinstance(t, int):
            cols.append(jnp.full((rows.shape[0],), t, dtype=rows.dtype))
        elif t in body_vars:
            cols.append(rows[:, body_vars.index(t)])
        else:
            return None
    return jnp.stack(cols, axis=1)


def local_round(*args, **kwargs):  # pragma: no cover - convenience alias
    raise NotImplementedError("use DistributedEngine.materialise")
