"""Distributed semi-naive materialisation under ``shard_map``.

The paper's engine is single-node.  To make the technique deployable at
cluster scale we add the standard distributed-datalog construction
(hash-partition + dynamic data exchange, after Ajileye, Motik & Horrocks
arXiv 2001.10206), mapped onto JAX-native collectives:

* every relation is **hash-partitioned on its first argument** across the
  ``data`` axis of the device mesh;
* each shard keeps ``old``/``delta`` partitions per predicate (mirroring
  :class:`~repro.core.metafacts.FactStore`'s semi-naive bookkeeping): a
  padded row buffer plus a count and a delta watermark — rows in
  ``[lo, count)`` are the last round's delta, rows in ``[0, lo)`` are old;
* each round evaluates one compiled ``(rule, pivot)`` plan per delta
  pivot — plans come from the shared body compiler
  (:mod:`repro.core.compile`), which also picks the **exchange key**: a
  join side whose stored first column already is the planned join
  variable skips its pre-join ``all_to_all`` entirely;
* derivations whose head key hashes to another shard are exchanged with
  one ``all_to_all`` per head predicate per round (skipped too when the
  planner proves every emitted row is already on its owner shard);
* the fixpoint runs stratum-by-stratum over the SCC condensation
  (:mod:`repro.core.program_graph`); ``(rule, pivot)`` pairs whose pivot
  predicate received no delta are skipped on the host without tracing
  (``rule_applications_skipped``, as in the host engines);
* per-shard exchange capacity **grows on overflow** (the round is retried
  with doubled padding, counted in ``exchange_regrows``) instead of
  aborting the fixpoint.

Beyond materialisation the engine is *incrementally maintainable*:
:meth:`DistributedEngine.apply` routes overdelete / rederive / insert
batches through the same ``all_to_all`` exchange, mirroring the DRed
phases of :mod:`repro.incremental.dred` set-at-a-time over the shards,
and :meth:`DistributedEngine.check_integrity` differentially compares
the result against a host :class:`~repro.incremental.IncrementalStore`.

Facts live in fixed-capacity padded buffers (JAX static shapes): a
``(capacity, arity)`` int32 array plus validity counts; empty slots hold
``EMPTY = -1``.  Join/dedup primitives are the jnp twins of the numpy
host path in :mod:`repro.core.util` and are what the Pallas kernels
accelerate.  The same code lowers on the 1-device CPU mesh (tests), the
forced 4-device CPU mesh (CI matrix), and the multi-pod mesh (dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..obs import instant, publish_distributed, span
from .compile import SRC_DELTA, SRC_OLD, PlanCache, compile_body, stats_bucket
from .datalog import Program
from .engine import MaterialisationStats
from .program_graph import stratify, stratum_predicates
from .util import unique_rows

EMPTY = jnp.int32(-1)

__all__ = ["DistributedEngine", "DistributedStats"]


@dataclass
class DistributedStats(MaterialisationStats):
    """Materialisation/maintenance statistics with the exchange-layer
    counters the host engines have no analogue for."""

    #: matching pairs enumerated by the local joins (the paper's "work")
    rows_joined: int = 0
    #: all_to_all calls issued (pre-join re-keying + head routing)
    exchanges: int = 0
    #: all_to_all calls avoided because the planner's partition key
    #: matched the storage sharding (or every head row was emitted on
    #: its owner shard)
    exchanges_skipped: int = 0
    #: rounds retried with doubled exchange/join padding after overflow
    exchange_regrows: int = 0
    # incremental maintenance (apply) counters, IncrementalStats-aligned
    epoch: int = 0
    n_del_explicit: int = 0
    n_add_explicit: int = 0
    n_overdeleted: int = 0
    n_rederived: int = 0
    n_deleted: int = 0
    n_inserted: int = 0


def _hash_shard(keys: jax.Array, n_shards: int) -> jax.Array:
    """Multiplicative hash -> shard id (stable across rounds)."""
    h = (keys.astype(jnp.uint32) * jnp.uint32(2654435761)) >> jnp.uint32(16)
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


def _hash_shard_np(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Host twin of :func:`_hash_shard` (batch routing, dataset loads)."""
    h = (keys.astype(np.uint32) * np.uint32(2654435761)) >> np.uint32(16)
    return (h % np.uint32(n_shards)).astype(np.int32)


# --------------------------------------------------------------------- #
# jnp primitives (device twins of core.util; kernels/ accelerates these)
# --------------------------------------------------------------------- #
def sorted_member_jnp(a: jax.Array, b_sorted: jax.Array) -> jax.Array:
    """Membership of a[i] in sorted b (EMPTY-padded b allowed at the end)."""
    idx = jnp.searchsorted(b_sorted, a)
    idx = jnp.minimum(idx, b_sorted.shape[0] - 1)
    return b_sorted[idx] == a


def sorted_member_kernel(a: jax.Array, b_sorted: jax.Array) -> jax.Array:
    """Pallas-kernel membership (``repro.kernels.sorted_member``) — the
    TPU device path for the dedup anti-join.  ``interpret`` is backend-
    detected (interpret on CPU, compiled on TPU; override with
    ``REPRO_PALLAS_INTERPRET`` — see ``repro.kernels.backend``)."""
    from ..kernels import ops

    return ops.member(a, b_sorted, interpret=None)


#: x64 is disabled by default in JAX, so packed fact keys live in int32:
#: binary facts use 15/16-bit halves, constraining the *distributed* path
#: to dictionaries of < 32768 constants (the host engine keeps full int64).
MAX_DIST_CONST = 1 << 15
BIG = jnp.int32(np.iinfo(np.int32).max)


def pack_pairs(rows: jax.Array) -> jax.Array:
    """Pack (n, 2) int32 rows into sortable int32 keys; (n, 1) passes through."""
    if rows.shape[1] == 1:
        return rows[:, 0]
    hi = rows[:, 0]
    lo = rows[:, 1]
    return (hi << 16) | (lo & 0xFFFF)


def unpack_pairs(keys: jax.Array, arity: int) -> jax.Array:
    if arity == 1:
        return keys[:, None]
    hi = keys >> 16
    lo = jnp.bitwise_and(keys, 0xFFFF)
    return jnp.stack([hi, lo], axis=1)


def dedup_against(
    new_keys: jax.Array, new_valid: jax.Array, old_keys_sorted: jax.Array,
    member_fn=sorted_member_jnp,
) -> jax.Array:
    """Valid-mask of new facts that are not already present in old."""
    member = member_fn(new_keys, old_keys_sorted)
    # first-occurrence within new: sort, compare neighbours, scatter back
    masked = jnp.where(new_valid, new_keys, BIG)
    order = jnp.argsort(masked)
    ks = masked[order]
    first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), ks[1:] != ks[:-1]]
    )
    first = jnp.zeros_like(first_sorted).at[order].set(first_sorted)
    return new_valid & first & (~member)


def join_on_key(
    l_keys: jax.Array,
    l_valid: jax.Array,
    l_payload: jax.Array,
    r_keys: jax.Array,
    r_valid: jax.Array,
    r_payload: jax.Array,
    out_capacity: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Equi-join with bounded output (static shapes).

    Returns ``(left payload, right payload, valid, total)`` for up to
    ``out_capacity`` matching pairs, enumerated as (left row) x (matching
    right rows); ``total`` is the true join size so the caller can detect
    truncation (and regrow) instead of silently under-deriving.
    """
    r_sort_key = jnp.where(r_valid, r_keys, BIG)
    order = jnp.argsort(r_sort_key)
    r_keys_s = r_sort_key[order]
    r_payload_s = r_payload[order]

    lo = jnp.searchsorted(r_keys_s, jnp.where(l_valid, l_keys, BIG - 1), side="left")
    hi = jnp.searchsorted(r_keys_s, jnp.where(l_valid, l_keys, BIG - 1), side="right")
    counts = jnp.where(l_valid, hi - lo, 0)
    offsets = jnp.cumsum(counts) - counts
    total = jnp.sum(counts)

    out_idx = jnp.arange(out_capacity)
    # which left row does output slot i belong to?
    l_of = jnp.searchsorted(offsets + counts, out_idx, side="right")
    l_of = jnp.minimum(l_of, l_keys.shape[0] - 1)
    within = out_idx - offsets[l_of]
    r_of = jnp.minimum(lo[l_of] + within, r_keys.shape[0] - 1)
    valid = out_idx < total
    return l_payload[l_of], r_payload_s[r_of], valid, total


def _apply_atom_constraints(atom, rows, valid):
    """Constants / repeated variables as validity-mask filters."""
    vars_ = atom.variables()
    first = {v: atom.terms.index(v) for v in vars_}
    for pos, t in enumerate(atom.terms):
        if isinstance(t, int):
            valid = valid & (rows[:, pos] == t)
        elif pos != first[t]:
            valid = valid & (rows[:, pos] == rows[:, first[t]])
    cols = [rows[:, first[v]] for v in vars_]
    return jnp.stack(cols, axis=1), valid


def _project_head(body_vars, rows, head):
    cols = []
    for t in head.terms:
        if isinstance(t, int):
            cols.append(jnp.full((rows.shape[0],), t, dtype=rows.dtype))
        elif t in body_vars:
            cols.append(rows[:, body_vars.index(t)])
        else:
            return None
    return jnp.stack(cols, axis=1)


class _SchemaStats:
    """Planner statistics from host-tracked global row counts.

    Cardinalities are clamped ``>= 1`` (a delta/maintenance plan must
    never compile to the empty plan just because a partition is
    currently empty — real emptiness is a host-side scheduling decision,
    the same contract :class:`repro.incremental.eval.PhaseStats` keeps);
    arities come from the program/dataset schema."""

    def __init__(self, counts: dict[str, int], arities: dict[str, int]):
        self.counts = counts
        self.arities = arities

    def n_rows(self, pred: str) -> int:
        return max(int(self.counts.get(pred, 0)), 1)

    def arity(self, pred: str) -> int:
        return self.arities.get(pred, 0)

    def selectivity(self, pred: str, pos: int, value: int) -> float:
        return 1.0 / max(float(np.sqrt(self.n_rows(pred))), 1.0)


@dataclass
class _Variant:
    """One traced round function + its static exchange schedule."""

    fn: object
    n_exchanges: int
    n_exchanges_skipped: int


# --------------------------------------------------------------------- #
# the distributed engine
# --------------------------------------------------------------------- #
class DistributedEngine:
    """Hash-partitioned semi-naive materialisation for binary datalog.

    Supports the rule shapes that cover RDF/OWL-RL style programs after
    vertical partitioning (arity <= 2): single-atom rules and two-atom
    single-key joins ``A(x,y), B(y,z) -> H(x,z)`` (plus unary variants).
    The host drives rounds; each round is one jitted ``shard_map`` call.

    ``seminaive=False`` reproduces the legacy naive iteration (every
    rule re-joins its full relations each round) — the baseline the
    benchmarks compare against; ``planner_exchange_keys=False`` disables
    the alignment-based exchange elision.
    """

    def __init__(
        self,
        program: Program,
        mesh: Mesh,
        axis: str = "data",
        capacity: int = 1 << 14,
        join_capacity: int | None = None,
        use_pallas_kernels: bool = False,
        seminaive: bool = True,
        planner_exchange_keys: bool = True,
        max_regrows: int = 8,
    ):
        self.program = program
        self.mesh = mesh
        self.axis = axis
        self.capacity = capacity
        self.join_capacity = join_capacity or capacity
        self.n_shards = mesh.shape[axis]
        self.seminaive = seminaive
        self.planner_exchange_keys = planner_exchange_keys
        self.max_regrows = max_regrows
        # TPU device path: dedup membership through the Pallas kernel
        self._member_fn = (
            sorted_member_kernel if use_pallas_kernels else sorted_member_jnp
        )
        self._plan_cache = PlanCache()
        self._variants: dict = {}
        #: per-predicate sharded state: pred -> [rows, count, delta_lo]
        self._state: dict[str, list] | None = None
        self._preds: tuple[str, ...] = ()
        self._arities: dict[str, int] = {}
        self._counts: dict[str, int] = {}
        #: host-side explicit fact set (int64 rows; the apply() contract)
        self.explicit: dict[str, np.ndarray] = {}
        self.stats = DistributedStats()
        self.rounds = 0
        self.epoch = 0
        #: exchange/join padding multiplier, doubled on overflow retries
        self._factor = 1
        #: True while an apply() sweep is in flight: a mid-sweep failure
        #: leaves shards and the explicit set inconsistent, so further
        #: applies are refused until the next materialise()
        self._dirty = False
        # provenance (obs.provenance): rule ids are program positions —
        # the id namespace shared with the host engines and the journal
        self._rule_ids: dict = {}
        for k, rule in enumerate(program):
            self._rule_ids.setdefault(rule, k)
        self._pjournal = None  # bound per-materialise/apply when enabled

    def _record_dist(
        self,
        kind: str,
        pred: str,
        *,
        stratum: int = -1,
        round_no: int = 0,
        rule_id: int = -1,
        pivot: int = -1,
        n_new: int = 0,
        shard: int = -1,
    ) -> None:
        """Journal one host-visible distributed event (no-op when
        recording is off).  Per-shard growth records carry the shard tag
        and are coalesced by ``journal.merge_shard_records()`` at
        differential verify; per-(rule, pivot) schedule records carry
        the rule lineage (device kernels do not expose per-rule emit
        counts, so counts live on the shard records)."""
        j = self._pjournal
        if j is None:
            return
        from ..obs.provenance import DerivationRecord

        j.record(DerivationRecord(
            kind=kind,
            engine="dist",
            stratum=stratum,
            round=round_no,
            rule_id=rule_id,
            pivot=pivot,
            pred=pred,
            n_new=int(n_new),
            shard=int(shard),
            epoch=j.epoch,
        ))

    # -------------------------------------------------------------- #
    # sharding / routing
    # -------------------------------------------------------------- #
    def _route(self, rows_by_pred: dict[str, np.ndarray]) -> dict:
        """Hash-partition host rows on their first column into per-shard
        padded buffers ``(n_shards, capacity, arity)`` + counts."""
        n, cap = self.n_shards, self.capacity
        out = {}
        for pred, rows in rows_by_pred.items():
            rows = np.asarray(rows)
            if rows.ndim == 1:
                rows = rows.reshape(-1, 1)
            self._check_const_range(pred, rows)
            rows = rows.astype(np.int32)
            arity = rows.shape[1]
            shard = _hash_shard_np(rows[:, 0], n)
            buf = np.full((n, cap, arity), -1, dtype=np.int32)
            cnt = np.zeros((n,), dtype=np.int32)
            for s in range(n):
                mine = rows[shard == s]
                if mine.shape[0] > cap:
                    raise ValueError(f"capacity {cap} too small for shard {s}")
                buf[s, : mine.shape[0]] = mine
                cnt[s] = mine.shape[0]
            out[pred] = (buf, cnt)
        return out

    @staticmethod
    def _check_const_range(pred: str, rows: np.ndarray) -> None:
        """Load-bearing for pack_pairs/BIG-sentinel correctness:
        out-of-range ids would silently corrupt packed join/dedup keys."""
        if rows.size and (
            int(rows.min()) < 0 or int(rows.max()) >= MAX_DIST_CONST
        ):
            raise ValueError(
                f"distributed engine requires constants in "
                f"[0, {MAX_DIST_CONST}) — {pred!r} has values in "
                f"[{int(rows.min())}, {int(rows.max())}]"
            )

    def _flat_state(self) -> list:
        out = []
        for p in self._preds:
            out.extend(self._state[p])
        return out

    def _delta_count(self, pred: str) -> int:
        _, cnt, lo = self._state[pred]
        return int((np.asarray(cnt) - np.asarray(lo)).sum())


    # -------------------------------------------------------------- #
    # planning
    # -------------------------------------------------------------- #
    def _plan(self, rule, pivot, frozen: bool = False):
        """Compile (rule, pivot) through the shared body compiler.

        ``frozen`` plans (the apply sweeps) are compiled once and never
        re-planned: a cardinality drift that flips the greedy anchor
        would change the plan signature and force a fresh XLA trace,
        which costs far more than the slightly stale join order."""
        sv = _SchemaStats(self._counts, self._arities)
        if frozen:
            plan = self._plan_cache.get(
                (rule, pivot, "frozen"),
                (0,),
                lambda: compile_body(rule.body, sv, pivot=pivot),
            )
        else:
            plan = self._plan_cache.get(
                (rule, pivot),
                stats_bucket(sv, rule.body),
                lambda: compile_body(rule.body, sv, pivot=pivot),
            )
        self._check_supported(rule, plan)
        return plan

    @staticmethod
    def supports_rule(rule) -> bool:
        """True iff the rule is in the engine's fragment: <= 2-atom body,
        and a two-atom body joins on exactly one shared variable.  The
        single place callers (serve, benches, tests) filter programs —
        keep in sync with :meth:`_check_supported`."""
        if len(rule.body) > 2:
            return False
        if len(rule.body) == 2:
            common = set(rule.body[0].variables()) & set(
                rule.body[1].variables()
            )
            if len(common) != 1:
                return False
        return True

    @classmethod
    def supported_program(cls, program: Program) -> Program:
        """The sub-program inside the distributed fragment."""
        return type(program)([r for r in program if cls.supports_rule(r)])

    @staticmethod
    def _check_supported(rule, plan) -> None:
        if len(rule.body) > 2:
            raise NotImplementedError(
                "distributed engine supports bodies of <= 2 atoms"
            )
        if plan.is_empty:
            raise AssertionError("schema stats must never compile empty plans")
        if plan.joins and (
            len(plan.joins[0].key_vars) != 1
            or plan.joins[0].partition_key is None
        ):
            raise NotImplementedError(
                "distributed engine supports single-key two-atom joins"
            )
        for atom in (rule.head, *rule.body):
            for t in atom.terms:
                # rule constants are emitted on device (jnp.full) and
                # never pass through _route's range guard — check here
                if isinstance(t, int) and not 0 <= t < MAX_DIST_CONST:
                    raise ValueError(
                        f"distributed engine requires constants in "
                        f"[0, {MAX_DIST_CONST}); rule {rule} uses {t}"
                    )

    def _resolve(self, rule_pivots, frozen: bool = False) -> tuple:
        return tuple(
            (rule, pivot, self._plan(rule, pivot, frozen=frozen))
            for rule, pivot in rule_pivots
        )

    # -------------------------------------------------------------- #
    # the exchange (one all_to_all; padding grows with self._factor)
    # -------------------------------------------------------------- #
    def _exchange(self, rows, valid, factor, keys=None):
        """Route rows to ``hash(key)`` owner shards with one all_to_all.

        ``keys`` defaults to the first column (relation-ownership routing
        for derived facts); joins pass the planned partition-key column
        so both sides are co-partitioned before the local merge.  Returns
        ``(rows, valid, n_dropped)``: rows past the per-bucket capacity
        are dropped and *counted* so the host can regrow the padding and
        retry the round instead of silently under-deriving."""
        if keys is None:
            keys = rows[:, 0]
        n_shards = self.n_shards
        if n_shards == 1:
            return rows, valid, jnp.zeros((), jnp.int32)
        rows = jnp.where(valid[:, None], rows, EMPTY)
        cap = rows.shape[0]
        # bucket capacity grows linearly with the regrow factor but never
        # past the input size — once a single bucket can hold every row,
        # no skew pattern can drop, so the regrow loop always terminates
        # (and buffers stay bounded by n_shards x input)
        per = min(max((cap * factor) // n_shards, 1), cap)
        shard_of = jnp.where(valid, _hash_shard(keys, n_shards), n_shards)
        # stable sort by destination; bucket i occupies slots [i*per,(i+1)*per)
        order = jnp.argsort(shard_of, stable=True)
        rows_s = rows[order]
        shard_s = shard_of[order]
        idx = jnp.arange(cap)
        # position within bucket (prefix count of same destination)
        pos_in_bucket = idx - jnp.searchsorted(shard_s, shard_s, side="left")
        ok = (pos_in_bucket < per) & (shard_s < n_shards)
        dropped = jnp.sum(((~ok) & (shard_s < n_shards)).astype(jnp.int32))
        slot = jnp.where(ok, shard_s * per + pos_in_bucket, n_shards * per)
        buckets = jnp.full(
            (n_shards * per + 1, rows.shape[1]), EMPTY, dtype=rows.dtype
        )
        buckets = buckets.at[slot].set(
            jnp.where(ok[:, None], rows_s, EMPTY)
        )[: n_shards * per]
        buckets = buckets.reshape(n_shards, per, rows.shape[1])
        exchanged = jax.lax.all_to_all(
            buckets, self.axis, split_axis=0, concat_axis=0, tiled=False
        )
        exchanged = exchanged.reshape(n_shards * per, rows.shape[1])
        valid_out = exchanged[:, 0] != EMPTY
        return exchanged, valid_out, dropped

    def _side_aligned(self, atom, key) -> bool:
        """True when a join side's stored partitioning (hash of the first
        term) already equals the planner's partition key — no exchange."""
        return bool(atom.terms) and atom.terms[0] == key

    # -------------------------------------------------------------- #
    # one (rule, pivot) plan, traced into a round
    # -------------------------------------------------------------- #
    def _trace_pair(self, rule, plan, part, emit, factor):
        """Trace one compiled (rule, pivot) body over the shard-local
        partitions; returns (dropped, rows_joined) tracers."""
        head = rule.head
        zero = jnp.zeros((), jnp.int32)
        steps = [plan.first] + [j.scan for j in plan.joins]
        if len(steps) == 1:
            st = steps[0]
            rows, valid = part(st.atom.predicate, st.source)
            rows, valid = _apply_atom_constraints(st.atom, rows, valid)
            out = _project_head(st.atom.variables(), rows, head)
            if out is not None:
                emit(head.predicate, out, valid,
                     head.terms[0] == st.atom.terms[0])
            return zero, zero

        a_step, b_step = steps
        key = plan.joins[0].partition_key
        dropped = zero
        sides = []
        for step in (a_step, b_step):
            rows, valid = part(step.atom.predicate, step.source)
            rows, valid = _apply_atom_constraints(step.atom, rows, valid)
            vars_ = step.atom.variables()
            # re-partition on the planned join key — unless this side's
            # storage sharding already is the key (planner-chosen
            # exchange keys: the annotation on JoinStep.partition_key)
            if self.n_shards > 1 and not (
                self.planner_exchange_keys and self._side_aligned(step.atom, key)
            ):
                rows, valid, d = self._exchange(
                    rows, valid, factor, keys=rows[:, vars_.index(key)]
                )
                dropped = dropped + d
            sides.append((rows, valid, vars_))
        (ra, va, va_vars), (rb, vb, vb_vars) = sides
        ka = ra[:, va_vars.index(key)]
        kb = rb[:, vb_vars.index(key)]
        jcap = self.join_capacity * factor
        lpay, rpay, valid, total = join_on_key(ka, va, ra, kb, vb, rb, jcap)
        dropped = dropped + jnp.maximum(total - jcap, 0).astype(jnp.int32)
        var_cols = {v: lpay[:, i] for i, v in enumerate(va_vars)}
        for i, v in enumerate(vb_vars):
            var_cols.setdefault(v, rpay[:, i])
        cols = [
            jnp.full((jcap,), t, jnp.int32) if isinstance(t, int)
            else var_cols[t]
            for t in head.terms
        ]
        emit(head.predicate, jnp.stack(cols, axis=1), valid,
             head.terms[0] == key)
        return dropped, total.astype(jnp.int32)

    def _static_exchange_counts(self, pairs) -> tuple[int, int]:
        """Host mirror of the trace's static exchange decisions: how many
        all_to_all calls one round issues, and how many the planner's
        partition keys elide."""
        if self.n_shards == 1:
            return 0, 0
        n_ex = n_sk = 0
        head_aligned: dict[str, bool] = {}
        for rule, _pivot, plan in pairs:
            steps = [plan.first] + [j.scan for j in plan.joins]
            if len(steps) == 2:
                key = plan.joins[0].partition_key
                for st in steps:
                    if self.planner_exchange_keys and self._side_aligned(
                        st.atom, key
                    ):
                        n_sk += 1
                    else:
                        n_ex += 1
                al = rule.head.terms[0] == key
            else:
                al = rule.head.terms[0] == steps[0].atom.terms[0]
            p = rule.head.predicate
            head_aligned[p] = head_aligned.get(p, True) and al
        for al in head_aligned.values():
            if self.planner_exchange_keys and al:
                n_sk += 1
            else:
                n_ex += 1
        return n_ex, n_sk

    # -------------------------------------------------------------- #
    # round builders (jitted shard_map variants, cached per schedule)
    # -------------------------------------------------------------- #
    def _variant(self, tag, build) -> _Variant:
        rec = self._variants.get(tag)
        if rec is None:
            rec = build()
            self._variants[tag] = rec
        return rec

    def _evict_stale_factors(self) -> None:
        """Drop round variants traced at superseded padding factors
        (their keys end in the int factor).  A regrow retraces the live
        schedules at the new factor; keeping every historical factor's
        compiled executables alive would be a slow memory leak on
        long-running update loops."""
        self._variants = {
            k: v
            for k, v in self._variants.items()
            if not isinstance(k[-1], int) or k[-1] == self._factor
        }

    @staticmethod
    def _plan_signature(rule, plan) -> tuple:
        """Everything about a plan that shapes its trace: atom order,
        source partitions, and the exchange key.  Re-plans that land on
        the same physical plan (the common case after a cardinality
        bucket shift) therefore reuse the compiled round."""
        steps = [plan.first] + [j.scan for j in plan.joins]
        return (
            rule.head,
            tuple((s.atom, s.source) for s in steps),
            plan.joins[0].partition_key if plan.joins else None,
        )

    def _pair_key(self, pairs) -> tuple:
        # the predicate tuple keys the buffer layout, so the variant
        # cache survives re-materialisation over the same schema
        # (warm fixpoints time rounds, not re-tracing)
        return (self._preds,) + tuple(
            self._plan_signature(r, pl) for r, _pv, pl in pairs
        )

    def _spec3(self):
        return [P(self.axis, None, None), P(self.axis), P(self.axis)]

    def _spec2(self):
        return [P(self.axis, None, None), P(self.axis)]

    def _shmap(self, body, in_specs, out_specs, donate_argnums=()):
        return jax.jit(shard_map(
            body,
            mesh=self.mesh,
            in_specs=tuple(in_specs),
            out_specs=tuple(out_specs),
            # pallas_call outputs have no varying-axes metadata; disable
            # the vma check so the kernel dedup path can run under
            # shard_map (the specs above still pin the layouts)
            check_vma=False,
        ), donate_argnums=tuple(donate_argnums))

    def _state_donation(self):
        """Argnums of the per-predicate state buffers, for variants that
        consume-and-replace the state exactly once per call (delete /
        merge — NOT the fixpoint rounds, which retry the *same* inputs
        on exchange overflow and so must never donate).  Donation lets
        XLA reuse the old buffers for the outputs, so steady-state
        maintenance allocates nothing; it is a no-op (with a warning)
        on CPU, so only engage it on backends that honour it."""
        from ..kernels.backend import backend_name

        if backend_name() == "cpu":
            return ()
        return tuple(range(3 * len(self._preds)))

    def _merge_block(self, trows, tcnt, rows, valid, restrict=None):
        """Dedup candidate rows against a target buffer (and optionally
        restrict them to a membership set), then append — the shared
        tail of every round/seed.  Returns (rows', cnt', fresh, overflow)."""
        cap = trows.shape[0]
        keys = pack_pairs(rows)
        tvalid = jnp.arange(cap) < tcnt
        tsorted = jnp.sort(jnp.where(tvalid, pack_pairs(trows), BIG))
        fresh = dedup_against(keys, valid, tsorted, member_fn=self._member_fn)
        if restrict is not None:
            rrows, rcnt = restrict
            rsorted = jnp.sort(jnp.where(
                jnp.arange(rrows.shape[0]) < rcnt, pack_pairs(rrows), BIG
            ))
            fresh = fresh & self._member_fn(keys, rsorted)
        n_fresh = jnp.sum(fresh.astype(jnp.int32))
        overflow = jnp.maximum(tcnt + n_fresh - cap, 0)
        dest = tcnt + jnp.cumsum(fresh.astype(jnp.int32)) - 1
        ok = fresh & (dest < cap)
        # park non-fresh writes *out of bounds* so the scatter drops
        # them: parking at cap-1 would collide with a fresh write there
        # whenever an append exactly fills the buffer (duplicate-index
        # scatter order is undefined, and the stale value could win)
        dest = jnp.where(ok, dest, cap)
        nrows = trows.at[dest].set(
            jnp.where(ok[:, None], rows, EMPTY), mode="drop"
        )
        ncnt = jnp.minimum(tcnt + n_fresh, cap)
        return nrows, ncnt, n_fresh, overflow

    def _build_round(self, pairs, *, acc_mode, union_acc, use_restrict, factor):
        """One fixpoint round: evaluate every scheduled (rule, pivot)
        plan locally, exchange derivations to their owner shards, dedup,
        append into the delta partitions.

        ``acc_mode`` evaluates against a read-only *base* (the current
        materialisation) while accumulating into separate per-predicate
        buffers — the overdelete/rederive phases of ``apply`` (with
        ``union_acc`` the accumulator is unioned into old/all reads, and
        ``use_restrict`` keeps only candidates inside a membership set).
        """
        preds, axis = self._preds, self.axis

        def body(*flat):
            k = 0
            base: dict = {}
            accs: dict = {}
            restrict: dict = {}
            if acc_mode:
                for p in preds:
                    base[p] = (flat[k][0], flat[k + 1][0])
                    k += 2
                for p in preds:
                    accs[p] = (flat[k][0], flat[k + 1][0], flat[k + 2][0])
                    k += 3
                if use_restrict:
                    for p in preds:
                        restrict[p] = (flat[k][0], flat[k + 1][0])
                        k += 2
            else:
                for p in preds:
                    base[p] = (flat[k][0], flat[k + 1][0], flat[k + 2][0])
                    k += 3

            def part(pred, src):
                if not acc_mode:
                    rows, cnt, lo = base[pred]
                    idx = jnp.arange(rows.shape[0])
                    if src == SRC_DELTA:
                        return rows, (idx >= lo) & (idx < cnt)
                    if src == SRC_OLD:
                        return rows, idx < lo
                    return rows, idx < cnt
                arows, acnt, alo = accs[pred]
                aidx = jnp.arange(arows.shape[0])
                if src == SRC_DELTA:
                    return arows, (aidx >= alo) & (aidx < acnt)
                brows, bcnt = base[pred]
                bvalid = jnp.arange(brows.shape[0]) < bcnt
                if union_acc:
                    return (
                        jnp.concatenate([brows, arows]),
                        jnp.concatenate([bvalid, aidx < acnt]),
                    )
                return brows, bvalid

            derived: dict[str, list] = {}

            def emit(pred, rows, valid, aligned):
                derived.setdefault(pred, []).append((rows, valid, aligned))

            dropped = jnp.zeros((), jnp.int32)
            joined = jnp.zeros((), jnp.int32)
            for rule, _pivot, plan in pairs:
                d, j = self._trace_pair(rule, plan, part, emit, factor)
                dropped = dropped + d
                joined = joined + j

            new_flat = []
            total_new = jnp.zeros((), jnp.int32)
            overflow = jnp.zeros((), jnp.int32)
            for pred in preds:
                if acc_mode:
                    trows, tcnt, _tlo = accs[pred]
                else:
                    trows, tcnt, _tlo = base[pred]
                blocks = derived.get(pred, [])
                if not blocks:
                    # no derivations: the delta still gets consumed
                    new_flat.extend([trows[None], tcnt[None], tcnt[None]])
                    continue
                rows = jnp.concatenate([b[0] for b in blocks])
                valid = jnp.concatenate([b[1] for b in blocks])
                aligned = all(b[2] for b in blocks)
                rows = jnp.where(valid[:, None], rows, EMPTY)
                # route each derivation to the shard owning its head key
                if self.n_shards > 1 and not (
                    self.planner_exchange_keys and aligned
                ):
                    rows, valid, d = self._exchange(rows, valid, factor)
                    dropped = dropped + d
                nrows, ncnt, n_fresh, of = self._merge_block(
                    trows, tcnt, rows, valid,
                    restrict=restrict.get(pred) if use_restrict else None,
                )
                total_new = total_new + n_fresh
                overflow = overflow + of
                new_flat.extend([nrows[None], ncnt[None], tcnt[None]])

            return tuple(new_flat) + (
                jax.lax.psum(total_new, axis),
                jax.lax.psum(dropped, axis),
                jax.lax.psum(overflow, axis),
                jax.lax.psum(joined, axis),
            )

        in_specs: list = []
        if acc_mode:
            for _ in preds:
                in_specs.extend(self._spec2())
            for _ in preds:
                in_specs.extend(self._spec3())
            if use_restrict:
                for _ in preds:
                    in_specs.extend(self._spec2())
        else:
            for _ in preds:
                in_specs.extend(self._spec3())
        out_specs: list = []
        for _ in preds:
            out_specs.extend(self._spec3())
        out_specs.extend([P(), P(), P(), P()])
        n_ex, n_sk = self._static_exchange_counts(pairs)
        return _Variant(self._shmap(body, in_specs, out_specs), n_ex, n_sk)

    def _build_delete(self):
        """Per-shard deletion: drop routed rows from every predicate's
        buffer and compact survivors to the front (delta emptied)."""
        preds = self._preds
        member_fn = self._member_fn

        def body(*flat):
            k = 0
            st: dict = {}
            de: dict = {}
            for p in preds:
                st[p] = (flat[k][0], flat[k + 1][0], flat[k + 2][0])
                k += 3
            for p in preds:
                de[p] = (flat[k][0], flat[k + 1][0])
                k += 2
            out = []
            for p in preds:
                rows, cnt, _lo = st[p]
                drows, dcnt = de[p]
                cap = rows.shape[0]
                idx = jnp.arange(cap)
                slot = idx < cnt
                keys = jnp.where(slot, pack_pairs(rows), BIG)
                dsorted = jnp.sort(jnp.where(
                    jnp.arange(drows.shape[0]) < dcnt, pack_pairs(drows), BIG
                ))
                keep = slot & ~member_fn(keys, dsorted)
                n_keep = jnp.sum(keep.astype(jnp.int32))
                perm = jnp.argsort(jnp.where(keep, idx, cap + idx))
                nrows = jnp.where((idx < n_keep)[:, None], rows[perm], EMPTY)
                out.extend([nrows[None], n_keep[None], n_keep[None]])
            return tuple(out)

        in_specs: list = []
        for _ in preds:
            in_specs.extend(self._spec3())
        for _ in preds:
            in_specs.extend(self._spec2())
        out_specs: list = []
        for _ in preds:
            out_specs.extend(self._spec3())
        return _Variant(self._shmap(body, in_specs, out_specs), 0, 0)

    def _build_merge(self):
        """Per-shard seed/fold-in: dedup routed host rows against each
        predicate's buffer and append them as the new delta."""
        preds, axis = self._preds, self.axis

        def body(*flat):
            k = 0
            st: dict = {}
            ad: dict = {}
            for p in preds:
                st[p] = (flat[k][0], flat[k + 1][0], flat[k + 2][0])
                k += 3
            for p in preds:
                ad[p] = (flat[k][0], flat[k + 1][0])
                k += 2
            out = []
            total_new = jnp.zeros((), jnp.int32)
            overflow = jnp.zeros((), jnp.int32)
            for p in preds:
                rows, cnt, _lo = st[p]
                arows, acnt = ad[p]
                avalid = jnp.arange(arows.shape[0]) < acnt
                nrows, ncnt, n_fresh, of = self._merge_block(
                    rows, cnt, arows, avalid
                )
                total_new = total_new + n_fresh
                overflow = overflow + of
                out.extend([nrows[None], ncnt[None], cnt[None]])
            return tuple(out) + (
                jax.lax.psum(total_new, axis),
                jax.lax.psum(overflow, axis),
            )

        in_specs: list = []
        for _ in preds:
            in_specs.extend(self._spec3())
        for _ in preds:
            in_specs.extend(self._spec2())
        out_specs: list = []
        for _ in preds:
            out_specs.extend(self._spec3())
        out_specs.extend([P(), P()])
        return _Variant(
            self._shmap(
                body, in_specs, out_specs,
                donate_argnums=self._state_donation(),
            ),
            0, 0,
        )

    # -------------------------------------------------------------- #
    # round execution with exchange-regrow retries
    # -------------------------------------------------------------- #
    def _run_round(self, build_variant, flat):
        """Run one jitted round; on exchange/join overflow, double the
        padding factor and retry the *same* inputs (rounds are pure, so
        nothing was committed).  Returns the raw outputs."""
        regrew = False
        for _ in range(self.max_regrows + 1):
            rec = build_variant()
            out = rec.fn(*flat)
            total_new, dropped, overflow, joined = (
                int(x) for x in out[-4:]
            )
            if overflow > 0:
                raise RuntimeError(
                    f"relation buffer overflow: {overflow} rows past "
                    f"capacity {self.capacity} — increase capacity"
                )
            if dropped == 0:
                if regrew:
                    self._evict_stale_factors()
                self.stats.exchanges += rec.n_exchanges
                self.stats.exchanges_skipped += rec.n_exchanges_skipped
                self.stats.rows_joined += joined
                return out, total_new, joined
            self._factor *= 2
            regrew = True
            self.stats.exchange_regrows += 1
            instant("dist.exchange_regrow", factor=self._factor)
        raise RuntimeError(
            "exchange overflow persists after "
            f"{self.max_regrows} regrows — increase capacity/join_capacity"
        )

    def _mat_round(self, pairs):
        """One materialise/insert round over the live partitions."""
        pkey = self._pair_key(pairs)

        def build():
            return self._variant(
                ("mat", pkey, self._factor),
                lambda: self._build_round(
                    pairs, acc_mode=False, union_acc=False,
                    use_restrict=False, factor=self._factor,
                ),
            )

        out, total_new, joined = self._run_round(build, self._flat_state())
        for i, p in enumerate(self._preds):
            self._state[p] = list(out[3 * i : 3 * i + 3])
            self._counts[p] = int(np.asarray(out[3 * i + 1]).sum())
        return total_new, joined

    def _acc_round(self, acc, pairs, *, union_acc, restrict):
        """One accumulator round (overdelete / rederive phases)."""
        pkey = self._pair_key(pairs)
        flat = []
        for p in self._preds:
            flat.extend(self._state[p][:2])
        for p in self._preds:
            flat.extend(acc[p])
        if restrict is not None:
            for p in self._preds:
                flat.extend(restrict[p])

        def build():
            return self._variant(
                ("acc", pkey, union_acc, restrict is not None, self._factor),
                lambda: self._build_round(
                    pairs, acc_mode=True, union_acc=union_acc,
                    use_restrict=restrict is not None, factor=self._factor,
                ),
            )

        out, total_new, _joined = self._run_round(build, flat)
        for i, p in enumerate(self._preds):
            acc[p] = list(out[3 * i : 3 * i + 3])
        return total_new

    # -------------------------------------------------------------- #
    # host-side scheduling (the semi-naive skip logic)
    # -------------------------------------------------------------- #
    def _schedule(self, stratum, entry: bool, stable: bool = False):
        """(rule, pivot) pairs to evaluate this round + pairs skipped
        without a probe (no delta on the pivot, or an empty body
        predicate) — the host-side mirror of CMatEngine._round.

        ``stable=True`` (the apply sweeps) schedules every pair so each
        stratum traces one round variant regardless of which predicates
        the batch happened to touch; materialisation keeps the
        fine-grained skip (its delta patterns are stable per stratum, so
        the skip saves device work without trace churn)."""
        pairs = []
        skipped = 0
        if stable:
            pairs = [
                (rule, i)
                for rule in stratum
                for i in range(len(rule.body))
            ]
            return self._resolve(pairs, frozen=True), 0
        if entry:
            # first round of a stratum: nothing of it ever ran, evaluate
            # each rule once over everything derived so far (pivot=None)
            for rule in stratum:
                if not rule.body:
                    continue
                if any(
                    self._counts.get(a.predicate, 0) == 0 for a in rule.body
                ):
                    skipped += 1
                    continue
                pairs.append((rule, None))
            return self._resolve(pairs), skipped
        delta_preds = {
            p for p in self._preds if self._delta_count(p) > 0
        }
        for rule in stratum:
            for i, atom in enumerate(rule.body):
                if atom.predicate not in delta_preds:
                    skipped += 1
                    continue
                if any(
                    self._counts.get(a.predicate, 0) == 0 for a in rule.body
                ):
                    skipped += 1
                    continue
                pairs.append((rule, i))
        return self._resolve(pairs), skipped

    def _stratum_fixpoint(
        self, si, stratum, max_rounds, *, naive_entry, sweep_lo=None,
        stable=False,
    ) -> tuple[int, bool]:
        """Run one stratum to its fixpoint; returns ``(rounds used,
        converged)`` — ``converged=False`` means the round budget ran out
        with work still pending (the caller must raise, never silently
        return an incomplete materialisation).

        ``sweep_lo`` (incremental insertion sweeps) re-marks everything
        appended since the sweep started as this stratum's incoming
        delta — each stratum sees the net additions of the strata below.
        """
        heads, body_preds = stratum_predicates(stratum)
        if sweep_lo is not None:
            for p in self._preds:
                self._state[p][2] = sweep_lo[p]
        entry = naive_entry
        rounds = 0
        r0 = len(self.stats.per_round)
        with span("dist.stratum", stratum=si, rules=len(stratum)):
            while rounds < max_rounds:
                if not entry and self.seminaive:
                    if not any(
                        self._delta_count(p) > 0
                        for p in body_preds
                        if p in self._state
                    ):
                        break
                pairs, skipped = self._schedule(stratum, entry, stable=stable)
                self.stats.rule_applications_skipped += skipped
                if not pairs:
                    break
                round_no = len(self.stats.per_round) + 1
                rule_ids = sorted({
                    self._rule_ids.get(rule, -1) for rule, _p, _pl in pairs
                })
                counts_before = (
                    {
                        p: np.asarray(self._state[p][1]).copy()
                        for p in self._preds
                    }
                    if self._pjournal is not None
                    else None
                )
                with span(
                    "dist.round",
                    round=round_no,
                    stratum=si,
                    rule_applications=len(pairs),
                    rule_ids=rule_ids,
                ) as sp:
                    total_new, joined = self._mat_round(pairs)
                    sp.set(new_facts=total_new, rows_joined=joined)
                if counts_before is not None:
                    for rule, pivot, _plan in pairs:
                        self._record_dist(
                            "schedule", rule.head.predicate,
                            stratum=si, round_no=round_no,
                            rule_id=self._rule_ids.get(rule, -1),
                            pivot=-1 if pivot is None else pivot,
                        )
                    for p in self._preds:
                        grow = (
                            np.asarray(self._state[p][1]) - counts_before[p]
                        )
                        for s in np.nonzero(grow)[0]:
                            self._record_dist(
                                "apply", p, stratum=si, round_no=round_no,
                                n_new=int(grow[s]), shard=int(s),
                            )
                rounds += 1
                self.stats.n_rule_applications += len(pairs)
                self.stats.per_round.append(
                    {
                        "round": len(self.stats.per_round) + 1,
                        "stratum": si,
                        "new_facts": total_new,
                        "rows_joined": joined,
                        "rule_applications": len(pairs),
                        "rule_applications_skipped": skipped,
                    }
                )
                if self.seminaive:
                    entry = False
                if total_new == 0:
                    break
        self.stats.per_stratum.append(
            {
                "stratum": si,
                "rounds": rounds,
                "rules": len(stratum),
                "heads": sorted(heads),
                "rule_applications": sum(
                    r["rule_applications"]
                    for r in self.stats.per_round[r0:]
                ),
            }
        )
        # budget exhausted with work pending?  (the loop breaks on empty
        # schedules / empty rounds, so exiting via the while-condition
        # means the last round still derived facts, or it never ran)
        pending = False
        if rounds >= max_rounds:
            if entry:
                pairs, _ = self._schedule(stratum, True, stable=stable)
                pending = bool(pairs)
            else:
                pending = any(
                    self._delta_count(p) > 0
                    for p in body_preds
                    if p in self._state
                )
        return rounds, not pending

    # -------------------------------------------------------------- #
    # materialisation
    # -------------------------------------------------------------- #
    def _prepare(self, dataset: dict[str, np.ndarray]) -> None:
        preds = tuple(sorted(set(dataset) | self.program.predicates()))
        arities: dict[str, int] = {}
        for p in preds:
            if p in dataset:
                r = np.asarray(dataset[p])
                arities[p] = 1 if r.ndim == 1 else r.shape[1]
        for rule in self.program:
            for atom in (rule.head, *rule.body):
                arities.setdefault(atom.predicate, atom.arity)
        for p, a in arities.items():
            if a > 2:
                raise NotImplementedError(
                    f"distributed engine supports arity <= 2 ({p!r} has {a})"
                )
        full = {}
        for p in preds:
            rows = np.asarray(
                dataset.get(p, np.zeros((0, arities[p]))), dtype=np.int64
            )
            if rows.ndim == 1:
                rows = rows.reshape(-1, 1)
            full[p] = unique_rows(rows) if rows.shape[0] else rows
        self._preds = preds
        self._arities = arities
        self._counts = {p: int(full[p].shape[0]) for p in preds}
        self.explicit = {
            p: rows for p, rows in full.items() if rows.shape[0]
        }
        self._factor = 1
        self._dirty = False
        routed = self._route(
            {p: rows.astype(np.int32) for p, rows in full.items()}
        )
        self._state = {}
        for p in preds:
            buf, cnt = routed[p]
            cnt = jnp.asarray(cnt)
            self._state[p] = [jnp.asarray(buf), cnt, jnp.zeros_like(cnt)]

    def materialise(self, dataset: dict[str, np.ndarray], max_rounds: int = 64):
        """Run rounds to fixpoint; returns per-predicate host arrays."""
        self._prepare(dataset)
        self.stats = DistributedStats()
        from ..obs.provenance import get_journal

        journal = get_journal()
        self._pjournal = journal if journal.enabled else None
        if self._pjournal is not None:
            self._pjournal.attach_program(self.program)
        strata = (
            stratify(self.program) if self.seminaive else [list(self.program)]
        )
        self.stats.n_strata = len(strata)
        rounds = 0
        with span(
            "dist.materialise", n_strata=len(strata), n_shards=self.n_shards
        ):
            for si, stratum in enumerate(strata):
                used, converged = self._stratum_fixpoint(
                    si, stratum, max_rounds - rounds, naive_entry=True
                )
                rounds += used
                if not converged:
                    raise RuntimeError(
                        f"materialisation did not reach a fixpoint within "
                        f"max_rounds={max_rounds} (stratum {si} still has "
                        f"pending deltas) — increase max_rounds"
                    )
        self.rounds = rounds
        self.stats.rounds = rounds
        self.stats.plan_cache = self._plan_cache.counters()
        publish_distributed(self.stats)
        if self._pjournal is not None:
            self._pjournal.publish()
        result = {}
        for p in self._preds:
            rows, cnt, _lo = self._state[p]
            buf = np.asarray(rows)
            c = np.asarray(cnt)
            flat_rows = np.concatenate(
                [buf[s, : c[s]] for s in range(self.n_shards)]
            )
            result[p] = unique_rows(flat_rows.astype(np.int64))
        return result

    # -------------------------------------------------------------- #
    # incremental maintenance: deltas through the exchange
    # -------------------------------------------------------------- #
    def _new_acc(self, seeds: dict[str, np.ndarray] | None = None) -> dict:
        acc = {}
        routed = self._route(
            {
                p: np.asarray(r, np.int64).astype(np.int32)
                for p, r in (seeds or {}).items()
                if np.asarray(r).shape[0]
            }
        )
        for p in self._preds:
            if p in routed:
                buf, cnt = routed[p]
                cnt = jnp.asarray(cnt)
                acc[p] = [jnp.asarray(buf), cnt, jnp.zeros_like(cnt)]
            else:
                acc[p] = [
                    jnp.full(
                        (self.n_shards, self.capacity, self._arities[p]),
                        -1, jnp.int32,
                    ),
                    jnp.zeros((self.n_shards,), jnp.int32),
                    jnp.zeros((self.n_shards,), jnp.int32),
                ]
        return acc

    def _pull_acc(self, acc: dict) -> dict[str, np.ndarray]:
        out = {}
        for p in self._preds:
            buf = np.asarray(acc[p][0])
            cnt = np.asarray(acc[p][1])
            if cnt.sum() == 0:
                continue
            rows = np.concatenate(
                [buf[s, : cnt[s]] for s in range(self.n_shards)]
            )
            out[p] = unique_rows(rows.astype(np.int64))
        return out

    def _route_pairs(self, rows_by_pred: dict) -> dict:
        """(rows, cnt) jnp buffers per predicate (zero-filled when the
        predicate has no rows in the batch)."""
        routed = self._route(
            {
                p: np.asarray(r, np.int64).astype(np.int32)
                for p, r in rows_by_pred.items()
                if np.asarray(r).shape[0]
            }
        )
        out = {}
        for p in self._preds:
            if p in routed:
                buf, cnt = routed[p]
                out[p] = [jnp.asarray(buf), jnp.asarray(cnt)]
            else:
                out[p] = [
                    jnp.full(
                        (self.n_shards, self.capacity, self._arities[p]),
                        -1, jnp.int32,
                    ),
                    jnp.zeros((self.n_shards,), jnp.int32),
                ]
        return out

    def _schedule_acc(self, rules, *, one_step: bool):
        """(rule, pivot) pairs for an accumulator round: the pivot reads
        the accumulator's delta (or ``None`` for the one-step
        rederivability check, which re-evaluates whole bodies).

        Deliberately *stable* — every pair is scheduled regardless of
        which predicates currently hold deltas, so each apply phase
        traces exactly one round variant and every later batch reuses
        it.  An empty delta partition joins to nothing on device, which
        costs far less than re-tracing per delta combination (update
        batches hit arbitrary predicate subsets)."""
        if one_step:
            pairs = [(rule, None) for rule in rules if rule.body]
        else:
            pairs = [
                (rule, i)
                for rule in rules
                for i in range(len(rule.body))
            ]
        return self._resolve(pairs, frozen=True)

    def apply(
        self,
        additions: dict[str, np.ndarray] | None = None,
        deletions: dict[str, np.ndarray] | None = None,
    ) -> DistributedStats:
        """Incrementally maintain the sharded materialisation for
        ``E' = (E \\ deletions) ∪ additions``.

        Deletion batches run the DRed phases of
        :mod:`repro.incremental.dred` set-at-a-time over the shards —
        overdelete / delete / rederive deltas all ship through the same
        ``all_to_all`` exchange as materialisation rounds — and addition
        batches run the stratified semi-naive insertion sweep.  Batches
        are clamped against the explicit set exactly like the host
        :class:`~repro.incremental.IncrementalStore` (idempotence), so
        the two stay differentially comparable via
        :meth:`check_integrity`.
        """
        import time

        from ..incremental.store import effective_updates, normalise_batch

        if self._state is None:
            raise RuntimeError("materialise() must run before apply()")
        if self._dirty:
            raise RuntimeError(
                "a previous apply() failed mid-sweep; the sharded state "
                "is inconsistent — materialise() again before applying"
            )
        t0 = time.perf_counter()
        st = DistributedStats()
        self.stats = st
        from ..obs.provenance import get_journal

        journal = get_journal()
        self._pjournal = journal if journal.enabled else None
        if self._pjournal is not None:
            self._pjournal.begin_epoch(self.epoch + 1)
            self._pjournal.attach_program(self.program)
        adds = normalise_batch(additions)
        dels = normalise_batch(deletions)
        unknown = (set(adds) | set(dels)) - set(self._preds)
        if unknown:
            raise NotImplementedError(
                f"apply() over predicates absent at materialise time: "
                f"{sorted(unknown)}"
            )
        # validate the whole batch BEFORE any mutation: a rejection after
        # effective_updates has touched self.explicit would permanently
        # desynchronise the explicit set from the shards
        for batch in (adds, dels):
            for pred, rows in batch.items():
                self._check_const_range(pred, rows)
        # E := E \ D, swept before the additions clamp (same phase order
        # as IncrementalStore.apply)
        self._dirty = True
        with span(
            "dist.apply",
            n_additions=sum(int(r.shape[0]) for r in adds.values()),
            n_deletions=sum(int(r.shape[0]) for r in dels.values()),
        ):
            _, eff_dels = effective_updates(self.explicit, {}, dels)
            st.n_del_explicit += sum(
                int(r.shape[0]) for r in eff_dels.values()
            )
            if eff_dels:
                self._deletion_sweep(eff_dels, st)
            eff_adds, _ = effective_updates(self.explicit, adds, {})
            st.n_add_explicit += sum(
                int(r.shape[0]) for r in eff_adds.values()
            )
            if eff_adds:
                self._insertion_sweep(eff_adds, st)
        self._dirty = False
        self.epoch += 1
        st.epoch = self.epoch
        st.plan_cache = self._plan_cache.counters()
        st.time_total = time.perf_counter() - t0
        publish_distributed(st)
        if self._pjournal is not None:
            self._pjournal.publish()
        return st

    def _deletion_sweep(self, dels: dict[str, np.ndarray], st) -> None:
        """DRed over the shards: overdelete (delta exchange over the
        pre-deletion view), physical delete, rederive (explicit
        restores + one-step check + forward propagation)."""
        from ..incremental.dred import explicit_restores
        from ..incremental.index import setdiff_rows

        rules = [r for r in self.program if r.body]
        # --- overdelete: propagate the deleted delta ------------------- #
        with span("dist.overdelete") as sp:
            over_acc = self._new_acc(dels)
            while True:
                pairs = self._schedule_acc(rules, one_step=False)
                if not pairs:
                    break
                st.n_rule_applications += len(pairs)
                total_new = self._acc_round(
                    over_acc, pairs, union_acc=False,
                    restrict={p: self._state[p][:2] for p in self._preds},
                )
                if total_new == 0:
                    break
            over = self._pull_acc(over_acc)
            n_over = sum(int(r.shape[0]) for r in over.values())
            st.n_overdeleted += n_over
            sp.set(n_overdeleted=n_over)
            for pred, rows in over.items():
                if rows.shape[0]:
                    self._record_dist(
                        "overdelete", pred, n_new=int(rows.shape[0])
                    )

        # --- delete: drop overdeleted rows from every shard ------------ #
        with span("dist.delete"):
            routed = self._route_pairs(over)
            flat = self._flat_state()
            for p in self._preds:
                flat.extend(routed[p])
            rec = self._variant(("delete", self._preds), self._build_delete)
            out = rec.fn(*flat)
            for i, p in enumerate(self._preds):
                self._state[p] = list(out[3 * i : 3 * i + 3])
                self._counts[p] = int(np.asarray(out[3 * i + 1]).sum())

        # --- rederive: explicit restores, one-step check, forward ------ #
        with span("dist.rederive") as sp:
            restored0 = explicit_restores(over, self.explicit)
            missing = {
                p: setdiff_rows(rows, restored0[p]) if p in restored0 else rows
                for p, rows in over.items()
            }
            missing = {p: r for p, r in missing.items() if r.shape[0]}
            red_acc = self._new_acc(restored0)
            if missing and rules:
                restrict = self._route_pairs(missing)
                pairs = self._schedule_acc(rules, one_step=True)
                if pairs:
                    st.n_rule_applications += len(pairs)
                    self._acc_round(
                        red_acc, pairs, union_acc=True, restrict=restrict
                    )
                while True:
                    pairs = self._schedule_acc(rules, one_step=False)
                    if not pairs:
                        break
                    st.n_rule_applications += len(pairs)
                    total_new = self._acc_round(
                        red_acc, pairs, union_acc=True, restrict=restrict
                    )
                    if total_new == 0:
                        break
            restored = self._pull_acc(red_acc)
            n_restored = sum(int(r.shape[0]) for r in restored.values())
            st.n_rederived += n_restored
            sp.set(n_rederived=n_restored)
            for pred, rows in restored.items():
                if rows.shape[0]:
                    self._record_dist(
                        "rederive", pred, n_new=int(rows.shape[0])
                    )

            # --- fold restorations back into the base partitions ------- #
            if n_restored:
                self._merge_host_rows(restored, st, count_inserted=False)
            st.n_deleted += (
                sum(int(r.shape[0]) for r in over.values()) - n_restored
            )

    def _merge_host_rows(self, rows_by_pred, st, *, count_inserted) -> int:
        """Route host rows to their owner shards and dedup-append them as
        the new delta; returns the number of genuinely fresh facts."""
        routed = self._route_pairs(rows_by_pred)
        flat = self._flat_state()
        for p in self._preds:
            flat.extend(routed[p])
        rec = self._variant(("merge", self._preds), self._build_merge)
        out = rec.fn(*flat)
        fresh, overflow = int(out[-2]), int(out[-1])
        if overflow > 0:
            raise RuntimeError(
                f"relation buffer overflow: {overflow} rows past capacity "
                f"{self.capacity} — increase capacity"
            )
        for i, p in enumerate(self._preds):
            self._state[p] = list(out[3 * i : 3 * i + 3])
            self._counts[p] = int(np.asarray(out[3 * i + 1]).sum())
        if count_inserted:
            st.n_inserted += fresh
        return fresh

    def _insertion_sweep(self, adds: dict[str, np.ndarray], st) -> None:
        """Stratified semi-naive insertion: the added facts are the
        incoming delta; every stratum re-marks the sweep's net additions
        as its delta (the ``sweep_lo`` watermark), so derived facts of
        earlier strata propagate without host-side seed bookkeeping."""
        with span("dist.insert") as sp:
            sweep_lo = {p: self._state[p][1] for p in self._preds}
            self._merge_host_rows(adds, st, count_inserted=True)
            strata = (
                stratify(self.program)
                if self.seminaive
                else [list(self.program)]
            )
            r0 = len(self.stats.per_round)
            for si, stratum in enumerate(strata):
                _, converged = self._stratum_fixpoint(
                    si, stratum, 512, naive_entry=False, sweep_lo=sweep_lo,
                    stable=True,
                )
                if not converged:
                    raise RuntimeError(
                        f"insertion sweep did not reach a fixpoint in "
                        f"stratum {si} within 512 rounds"
                    )
            st.n_inserted += sum(
                r["new_facts"] for r in self.stats.per_round[r0:]
            )
            st.rounds += len(self.stats.per_round) - r0
            sp.set(n_inserted=st.n_inserted)

    # -------------------------------------------------------------- #
    # read side / differential checking
    # -------------------------------------------------------------- #
    def to_dict(self) -> dict[str, np.ndarray]:
        """Flat per-predicate materialisation (sorted unique int64 rows,
        empty predicates omitted — the IncrementalStore contract)."""
        out = {}
        for p in self._preds:
            rows, cnt, _lo = self._state[p]
            buf = np.asarray(rows)
            c = np.asarray(cnt)
            if c.sum() == 0:
                continue
            flat_rows = np.concatenate(
                [buf[s, : c[s]] for s in range(self.n_shards)]
            )
            out[p] = unique_rows(flat_rows.astype(np.int64))
        return out

    def check_integrity(self, host) -> None:
        """Differentially compare the sharded materialisation against a
        host engine maintained with the same batches (an
        :class:`~repro.incremental.IncrementalStore`, or any object with
        ``to_dict()``, or a plain ``{pred: rows}`` dict)."""
        if self._pjournal is not None:
            self._pjournal.merge_shard_records()
        want = host.to_dict() if hasattr(host, "to_dict") else dict(host)
        got = self.to_dict()
        want = {p: r for p, r in want.items() if np.asarray(r).shape[0]}
        errs = []
        for p in sorted(set(want) | set(got)):
            a = {tuple(map(int, r)) for r in np.asarray(want.get(p, [])).reshape(-1, self._arities.get(p, 1))} if p in want else set()
            b = {tuple(map(int, r)) for r in got[p]} if p in got else set()
            if a != b:
                errs.append(
                    f"{p!r}: host-only={len(a - b)} shard-only={len(b - a)}"
                )
        if errs:
            raise AssertionError(
                "distributed materialisation diverged from host: "
                + "; ".join(errs)
            )

    # -------------------------------------------------------------- #
    # lowering hook (dryrun/roofline)
    # -------------------------------------------------------------- #
    def abstract_round(self, preds, arities):
        """One jitted naive round + its abstract input shapes, for HLO
        lowering without any data (``launch.dryrun_datalog``)."""
        self._preds = tuple(preds)
        self._arities = dict(arities)
        self._counts = {p: self.capacity for p in preds}
        self._variants = {}
        pairs = self._resolve(
            [(r, None) for r in self.program if r.body]
        )
        rec = self._build_round(
            pairs, acc_mode=False, union_acc=False,
            use_restrict=False, factor=1,
        )
        shapes = []
        for p in self._preds:
            shapes.append(
                jax.ShapeDtypeStruct(
                    (self.n_shards, self.capacity, self._arities[p]), np.int32
                )
            )
            shapes.append(jax.ShapeDtypeStruct((self.n_shards,), np.int32))
            shapes.append(jax.ShapeDtypeStruct((self.n_shards,), np.int32))
        return rec.fn, shapes
