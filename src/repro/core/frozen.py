"""Frozen post-materialisation snapshot of a :class:`FactStore`.

The paper frames materialisation as a *preprocessing step* so queries can
later be answered by lookup.  :class:`FrozenFacts` is the read side of
that contract (DESIGN.md §Query): once the fixpoint is reached the store
is frozen and

* the meta-facts and the mu-mapping below the freeze mark are never
  redefined again (query-time splits always copy, ``inplace=False``),
* per-predicate **sorted dedup snapshots** are built lazily and cached,
  so repeated queries never re-unpack the same columns,
* cheap selectivity statistics (fact counts, RLE-run distinct estimates,
  exact constant frequencies once a snapshot exists) feed the query
  planner without forcing any unfolding.

Everything a query allocates lives above :meth:`ColumnStore.mark` and is
reclaimed with :meth:`ColumnStore.release` after the answers are
extracted, so the store does not grow across a query stream.
"""

from __future__ import annotations

import numpy as np

from .metafacts import FactStore

__all__ = ["FrozenFacts"]


class FrozenFacts:
    """Read-only view over a materialised fact store + lazy flat indexes."""

    def __init__(self, facts: FactStore):
        self.facts = facts
        self.store = facts.store
        self.freeze_mark = self.store.mark()
        # lazy caches --------------------------------------------------- #
        self._rows: dict[str, np.ndarray] = {}  # sorted unique (n, arity)
        self._col_order: dict[tuple[str, int], np.ndarray] = {}
        self._sorted_col: dict[tuple[str, int], np.ndarray] = {}
        self._n_rows: dict[str, int] = {}
        # instrumentation: cells unfolded while *building* snapshots —
        # a one-time warmup cost, reported separately from per-query work.
        self.snapshot_cells = 0

    # ------------------------------------------------------------------ #
    # compressed access
    # ------------------------------------------------------------------ #
    def predicates(self):
        return self.facts.predicates()

    def meta_facts(self, pred: str):
        return self.facts.all(pred)

    def arity(self, pred: str) -> int:
        mfs = self.facts.all(pred)
        return mfs[0].arity if mfs else 0

    def n_rows(self, pred: str) -> int:
        """Represented fact count (with multiplicity) — O(#meta-facts)."""
        cached = self._n_rows.get(pred)
        if cached is None:
            cached = sum(mf.length for mf in self.facts.all(pred))
            self._n_rows[pred] = cached
        return cached

    def approx_distinct(self, pred: str, pos: int) -> int:
        """Upper-bound distinct-value estimate for one argument position:
        the total RLE run count of that column — no unfolding needed."""
        total = 0
        for mf in self.facts.all(pred):
            total += self.store.n_runs(mf.columns[pos])
        return max(total, 1)

    # ------------------------------------------------------------------ #
    # sorted dedup snapshots (lazy, cached)
    # ------------------------------------------------------------------ #
    def snapshot(self, pred: str) -> np.ndarray:
        """Sorted, duplicate-free ``(n, arity)`` rows of a predicate."""
        rows = self._rows.get(pred)
        if rows is None:
            unfolded = self.facts.unfold_pred(pred)
            self.snapshot_cells += int(unfolded.size)
            rows = np.unique(unfolded, axis=0)
            self._rows[pred] = rows
        return rows

    def has_snapshot(self, pred: str) -> bool:
        return pred in self._rows

    def col_order(self, pred: str, pos: int) -> np.ndarray:
        """Stable argsort of the snapshot on column ``pos``."""
        key = (pred, pos)
        order = self._col_order.get(key)
        if order is None:
            order = np.argsort(self.snapshot(pred)[:, pos], kind="stable")
            self._col_order[key] = order
        return order

    def sorted_col(self, pred: str, pos: int) -> np.ndarray:
        key = (pred, pos)
        col = self._sorted_col.get(key)
        if col is None:
            col = self.snapshot(pred)[:, pos][self.col_order(pred, pos)]
            self._sorted_col[key] = col
        return col

    def count_eq(self, pred: str, pos: int, value: int) -> int:
        """Exact number of snapshot rows with ``col[pos] == value``."""
        col = self.sorted_col(pred, pos)
        lo = np.searchsorted(col, value, side="left")
        hi = np.searchsorted(col, value, side="right")
        return int(hi - lo)

    def eq_slice(self, pred: str, pos: int, value: int) -> np.ndarray:
        """Snapshot rows with ``col[pos] == value`` — touches only the
        matching rows (one binary search + a gather)."""
        col = self.sorted_col(pred, pos)
        lo = np.searchsorted(col, value, side="left")
        hi = np.searchsorted(col, value, side="right")
        idx = self.col_order(pred, pos)[lo:hi]
        return self.snapshot(pred)[idx]

    # ------------------------------------------------------------------ #
    def selectivity(self, pred: str, pos: int, value: int) -> float:
        """Estimated fraction of rows with ``col[pos] == value``.

        Exact when a snapshot already exists; otherwise the uniform
        1/distinct estimate over RLE runs (never forces an unfold)."""
        n = self.n_rows(pred)
        if n == 0:
            return 0.0
        if self.has_snapshot(pred):
            return self.count_eq(pred, pos, value) / max(
                self.snapshot(pred).shape[0], 1
            )
        return 1.0 / self.approx_distinct(pred, pos)
