"""Frozen post-materialisation snapshot of a :class:`FactStore`.

The paper frames materialisation as a *preprocessing step* so queries can
later be answered by lookup.  :class:`FrozenFacts` is the read side of
that contract (DESIGN.md §Query): once the fixpoint is reached the store
is frozen and

* the meta-facts and the mu-mapping below the freeze mark are never
  redefined again (query-time splits always copy, ``inplace=False``),
* per-predicate **sorted dedup snapshots** are built lazily and cached,
  so repeated queries never re-unpack the same columns,
* cheap selectivity statistics (fact counts, RLE-run distinct estimates,
  exact constant frequencies once a snapshot exists) feed the query
  planner without forcing any unfolding.

Everything a query allocates lives above :meth:`ColumnStore.mark` and is
reclaimed with :meth:`ColumnStore.release` after the answers are
extracted, so the store does not grow across a query stream.

:class:`SortedRows` is the reusable core of a snapshot — sorted unique
rows plus lazy per-column sort orders with binary-searched equality
slices.  Besides backing :class:`FrozenFacts` it serves the engines'
``old``-partition scans (late semi-naive rounds re-read a large, slowly
changing partition; see ``CMatEngine``) and the incremental subsystem's
rederivation probes.
"""

from __future__ import annotations

import numpy as np

from ..obs.memory import array_is_backed, register_reporter, split_owned_backed
from .metafacts import FactStore

__all__ = ["FrozenFacts", "SortedRows"]


class SortedRows:
    """Sorted, duplicate-free ``(n, arity)`` rows + lazy per-column
    sort orders for binary-searched equality slices."""

    def __init__(self, rows: np.ndarray):
        self.rows = rows
        self._col_order: dict[int, np.ndarray] = {}
        self._sorted_col: dict[int, np.ndarray] = {}

    @property
    def n_rows(self) -> int:
        return int(self.rows.shape[0])

    @property
    def nbytes(self) -> int:
        """Resident bytes: rows plus any lazily built per-column orders
        (what a snapshot-backed restore avoids re-deriving)."""
        total = int(self.rows.nbytes)
        total += sum(a.nbytes for a in self._col_order.values())
        total += sum(a.nbytes for a in self._sorted_col.values())
        return total

    @property
    def snapshot_backed(self) -> bool:
        """True when ``rows`` is a view into a decompressed snapshot
        blob rather than an owned copy (see obs.memory double-count
        rules — such bytes are reported separately so a blob shared
        with the mu-DAG counts each region once)."""
        return array_is_backed(self.rows)

    def memory_report(self) -> dict[str, int]:
        """obs.memory reporter: ``sum(parts) == self.nbytes`` (pinned in
        tests).  Lazily built orders are always owned (argsort/gather
        allocate fresh arrays); only ``rows`` can be snapshot-backed."""
        owned, backed = split_owned_backed((self.rows,))
        lazy = sum(int(a.nbytes) for a in self._col_order.values())
        lazy += sum(int(a.nbytes) for a in self._sorted_col.values())
        return {
            "rows_bytes": owned,
            "rows_snapshot_backed_bytes": backed,
            "lazy_order_bytes": lazy,
        }

    def col_order(self, pos: int) -> np.ndarray:
        """Stable argsort of the rows on column ``pos``."""
        order = self._col_order.get(pos)
        if order is None:
            order = np.argsort(self.rows[:, pos], kind="stable")
            self._col_order[pos] = order
        return order

    def sorted_col(self, pos: int) -> np.ndarray:
        col = self._sorted_col.get(pos)
        if col is None:
            col = self.rows[:, pos][self.col_order(pos)]
            self._sorted_col[pos] = col
        return col

    def count_eq(self, pos: int, value: int) -> int:
        """Exact number of rows with ``col[pos] == value``."""
        col = self.sorted_col(pos)
        lo = np.searchsorted(col, value, side="left")
        hi = np.searchsorted(col, value, side="right")
        return int(hi - lo)

    def eq_slice(self, pos: int, value: int) -> np.ndarray:
        """Rows with ``col[pos] == value`` — touches only the matching
        rows (one binary search + a gather)."""
        col = self.sorted_col(pos)
        lo = np.searchsorted(col, value, side="left")
        hi = np.searchsorted(col, value, side="right")
        idx = self.col_order(pos)[lo:hi]
        return self.rows[idx]

    def match_atom(self, atom) -> np.ndarray:
        """Rows matching an atom's constants / repeated variables,
        anchored on the most selective constant (binary search); residual
        constraints filter the candidate slice only."""
        const_pos = [
            (pos, t) for pos, t in enumerate(atom.terms) if isinstance(t, int)
        ]
        if const_pos:
            best_pos, best_val = min(
                const_pos, key=lambda pt: self.count_eq(pt[0], pt[1])
            )
            rows = self.eq_slice(best_pos, best_val)
        else:
            best_pos = -1
            rows = self.rows
        mask = np.ones(rows.shape[0], dtype=bool)
        for pos, value in const_pos:
            if pos != best_pos:
                mask &= rows[:, pos] == value
        vars_ = atom.variables()
        first_pos = {v: atom.terms.index(v) for v in vars_}
        for pos, t in enumerate(atom.terms):
            if isinstance(t, str) and pos != first_pos[t]:
                mask &= rows[:, pos] == rows[:, first_pos[t]]
        return rows if mask.all() else rows[mask]


class FrozenFacts:
    """Read-only view over a materialised fact store + lazy flat indexes."""

    def __init__(
        self,
        facts: FactStore,
        seed_rows: dict[str, np.ndarray] | None = None,
        *,
        pin_meta: bool = False,
    ):
        self.facts = facts
        self.store = facts.store
        self.freeze_mark = self.store.mark()
        # lazy caches --------------------------------------------------- #
        self._sorted: dict[str, SortedRows] = {}
        self._n_rows: dict[str, int] = {}
        # MVCC pinning: capture the per-predicate meta-fact lists *now*
        # so later ``facts.replace()`` calls (incremental applies) do not
        # leak post-freeze facts into this snapshot.  Deletion splits are
        # copy-mode and the row index snapshots its arrays, so a pinned
        # list stays valid until a compaction swaps the node table — the
        # serving tier defers compaction while any epoch is pinned.
        self._pinned_mfs: dict[str, list] | None = (
            {p: list(facts.all(p)) for p in facts.predicates()}
            if pin_meta
            else None
        )
        # instrumentation: cells unfolded while *building* snapshots —
        # a one-time warmup cost, reported separately from per-query work.
        self.snapshot_cells = 0
        register_reporter("frozen", self)
        if seed_rows:
            # pre-built snapshots (the incremental store maintains sorted
            # unique rows across epochs — freezing then costs nothing)
            for pred, rows in seed_rows.items():
                self._sorted[pred] = SortedRows(rows)

    # ------------------------------------------------------------------ #
    # compressed access
    # ------------------------------------------------------------------ #
    @property
    def pinned(self) -> bool:
        """True when the meta-fact lists were captured at freeze time
        (epoch-stable reads while the live store keeps mutating)."""
        return self._pinned_mfs is not None

    def predicates(self):
        if self._pinned_mfs is not None:
            return list(self._pinned_mfs)
        return self.facts.predicates()

    def meta_facts(self, pred: str):
        if self._pinned_mfs is not None:
            return self._pinned_mfs.get(pred, [])
        return self.facts.all(pred)

    def arity(self, pred: str) -> int:
        mfs = self.meta_facts(pred)
        return mfs[0].arity if mfs else 0

    def n_rows(self, pred: str) -> int:
        """Represented fact count (with multiplicity) — O(#meta-facts)."""
        cached = self._n_rows.get(pred)
        if cached is None:
            cached = sum(mf.length for mf in self.meta_facts(pred))
            self._n_rows[pred] = cached
        return cached

    def approx_distinct(self, pred: str, pos: int) -> int:
        """Upper-bound distinct-value estimate for one argument position:
        the total RLE run count of that column — no unfolding needed."""
        total = 0
        for mf in self.meta_facts(pred):
            total += self.store.n_runs(mf.columns[pos])
        return max(total, 1)

    # ------------------------------------------------------------------ #
    # sorted dedup snapshots (lazy, cached)
    # ------------------------------------------------------------------ #
    def sorted_rows(self, pred: str) -> SortedRows:
        sr = self._sorted.get(pred)
        if sr is None:
            mfs = self.meta_facts(pred)
            if mfs:
                unfolded = np.stack(
                    [
                        np.concatenate(
                            [self.store.unfold(mf.columns[j]) for mf in mfs]
                        )
                        for j in range(mfs[0].arity)
                    ],
                    axis=1,
                )
            else:
                unfolded = np.zeros((0, 1), dtype=np.int64)
            self.snapshot_cells += int(unfolded.size)
            sr = SortedRows(np.unique(unfolded, axis=0))
            self._sorted[pred] = sr
        return sr

    def snapshot(self, pred: str) -> np.ndarray:
        """Sorted, duplicate-free ``(n, arity)`` rows of a predicate."""
        return self.sorted_rows(pred).rows

    def has_snapshot(self, pred: str) -> bool:
        return pred in self._sorted

    def snapshot_resident_bytes(self) -> int:
        """Bytes *owned* by the sorted snapshots built so far.

        Snapshot-backed rows (``frombuffer`` views into a restore blob)
        are excluded — those bytes belong to the shared blob that also
        backs the mu-DAG leaves, and counting them here as well as in
        ``ColumnStore.total_nbytes`` double-counted restored stores.
        They are reported separately (:meth:`snapshot_backed_bytes`);
        ``snapshot_resident_bytes + snapshot_backed_bytes`` equals the
        old all-in total."""
        return sum(
            sum(sr.memory_report()[k] for k in ("rows_bytes", "lazy_order_bytes"))
            for sr in self._sorted.values()
        )

    def snapshot_backed_bytes(self) -> int:
        """Bytes of snapshot rows that are views into a restore blob."""
        return sum(
            sr.memory_report()["rows_snapshot_backed_bytes"]
            for sr in self._sorted.values()
        )

    def memory_report(self) -> dict[str, int]:
        """obs.memory reporter, aggregated over the built snapshots."""
        merged = {
            "snapshots_bytes": 0,
            "snapshots_snapshot_backed_bytes": 0,
            "n_snapshots": len(self._sorted),
        }
        for sr in self._sorted.values():
            parts = sr.memory_report()
            merged["snapshots_bytes"] += (
                parts["rows_bytes"] + parts["lazy_order_bytes"]
            )
            merged["snapshots_snapshot_backed_bytes"] += parts[
                "rows_snapshot_backed_bytes"
            ]
        return merged

    def col_order(self, pred: str, pos: int) -> np.ndarray:
        """Stable argsort of the snapshot on column ``pos``."""
        return self.sorted_rows(pred).col_order(pos)

    def sorted_col(self, pred: str, pos: int) -> np.ndarray:
        return self.sorted_rows(pred).sorted_col(pos)

    def count_eq(self, pred: str, pos: int, value: int) -> int:
        """Exact number of snapshot rows with ``col[pos] == value``."""
        return self.sorted_rows(pred).count_eq(pos, value)

    def eq_slice(self, pred: str, pos: int, value: int) -> np.ndarray:
        """Snapshot rows with ``col[pos] == value`` — touches only the
        matching rows (one binary search + a gather)."""
        return self.sorted_rows(pred).eq_slice(pos, value)

    # ------------------------------------------------------------------ #
    def selectivity(self, pred: str, pos: int, value: int) -> float:
        """Estimated fraction of rows with ``col[pos] == value``.

        Exact when a snapshot already exists; otherwise the uniform
        1/distinct estimate over RLE runs (never forces an unfold)."""
        n = self.n_rows(pred)
        if n == 0:
            return 0.0
        if self.has_snapshot(pred):
            return self.count_eq(pred, pos, value) / max(
                self.snapshot(pred).shape[0], 1
            )
        return 1.0 / self.approx_distinct(pred, pos)
