"""OWL 2 RL-style datalog rule templates (Grosof et al. lower-bound style).

The paper obtains its test programs by applying the sound-but-incomplete
transformation of Grosof et al. [7] to OWL ontologies (without
axiomatising owl:sameAs).  This module provides the same template rules so
users can build `lower bound` programs from schema triples:

    subClassOf(C, D):        C(x) -> D(x)
    subPropertyOf(P, Q):     P(x, y) -> Q(x, y)
    domain(P, C):            P(x, y) -> C(x)
    range(P, C):             P(x, y) -> C(y)
    transitive(P):           P(x, y), P(y, z) -> P(x, z)
    symmetric(P):            P(x, y) -> P(y, x)
    inverseOf(P, Q):         P(x, y) -> Q(y, x)
    someValuesFrom(P, C, D): P(x, y), C(y) -> D(x)   (Grosof clause)
    intersectionOf(C, D, E): C(x), D(x) -> E(x)
"""

from __future__ import annotations

from .datalog import Atom, Program, Rule

__all__ = ["OntologyBuilder"]


class OntologyBuilder:
    """Accumulates schema axioms and emits the lower-bound program."""

    def __init__(self) -> None:
        self.rules: list[Rule] = []

    # class axioms ---------------------------------------------------- #
    def sub_class_of(self, c: str, d: str) -> "OntologyBuilder":
        self.rules.append(Rule((Atom(c, ("x",)),), Atom(d, ("x",))))
        return self

    def intersection_of(self, c: str, d: str, e: str) -> "OntologyBuilder":
        self.rules.append(
            Rule((Atom(c, ("x",)), Atom(d, ("x",))), Atom(e, ("x",)))
        )
        return self

    def some_values_from(self, p: str, c: str, d: str) -> "OntologyBuilder":
        self.rules.append(
            Rule((Atom(p, ("x", "y")), Atom(c, ("y",))), Atom(d, ("x",)))
        )
        return self

    # property axioms -------------------------------------------------- #
    def sub_property_of(self, p: str, q: str) -> "OntologyBuilder":
        self.rules.append(Rule((Atom(p, ("x", "y")),), Atom(q, ("x", "y"))))
        return self

    def domain(self, p: str, c: str) -> "OntologyBuilder":
        self.rules.append(Rule((Atom(p, ("x", "y")),), Atom(c, ("x",))))
        return self

    def range(self, p: str, c: str) -> "OntologyBuilder":
        self.rules.append(Rule((Atom(p, ("x", "y")),), Atom(c, ("y",))))
        return self

    def transitive(self, p: str) -> "OntologyBuilder":
        self.rules.append(
            Rule(
                (Atom(p, ("x", "y")), Atom(p, ("y", "z"))),
                Atom(p, ("x", "z")),
            )
        )
        return self

    def symmetric(self, p: str) -> "OntologyBuilder":
        self.rules.append(Rule((Atom(p, ("x", "y")),), Atom(p, ("y", "x"))))
        return self

    def inverse_of(self, p: str, q: str) -> "OntologyBuilder":
        self.rules.append(Rule((Atom(p, ("x", "y")),), Atom(q, ("y", "x"))))
        self.rules.append(Rule((Atom(q, ("x", "y")),), Atom(p, ("y", "x"))))
        return self

    def property_chain(self, p: str, q: str, r: str) -> "OntologyBuilder":
        """p o q -> r (OWL 2 RL property chain)."""
        self.rules.append(
            Rule(
                (Atom(p, ("x", "y")), Atom(q, ("y", "z"))),
                Atom(r, ("x", "z")),
            )
        )
        return self

    def build(self) -> Program:
        return Program(list(self.rules))
