"""Predicate-dependency graph and fixpoint stratification.

The head→body dependency graph of a datalog program tells the fixpoint
which rules can possibly fire when: a rule whose body predicates all
belong to already-completed strata can never derive anything new once
its stratum's fixpoint is reached.  Running the semi-naive loop
stratum-by-stratum (strongly connected components of the dependency
graph, in topological order) therefore skips whole rule groups in every
round — the paper's "fewer rule applications" goal lifted from the
per-round delta check to the program structure.

For positive datalog (this repo's fragment) stratification is purely an
evaluation-order optimisation: the materialisation is identical, which
the differential tests in ``tests/test_compile.py`` pin down.
"""

from __future__ import annotations

from .datalog import Program, Rule

__all__ = [
    "dependency_graph",
    "condensation",
    "stratify",
    "explain_strata",
    "is_recursive",
    "stratum_predicates",
]


def dependency_graph(program: Program) -> dict[str, set[str]]:
    """``edges[b] = {h, ...}``: body predicate ``b`` feeds head ``h``.

    Every predicate mentioned anywhere in the program appears as a node
    (possibly with no outgoing edges)."""
    edges: dict[str, set[str]] = {}
    for rule in program:
        edges.setdefault(rule.head.predicate, set())
        for atom in rule.body:
            edges.setdefault(atom.predicate, set()).add(rule.head.predicate)
    return edges


def _tarjan_sccs(edges: dict[str, set[str]]) -> list[list[str]]:
    """Iterative Tarjan.  SCCs are emitted in reverse topological order
    of the condensation (every SCC after all SCCs it has edges into)."""
    index: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    sccs: list[list[str]] = []
    counter = 0

    for root in sorted(edges):  # deterministic traversal
        if root in index:
            continue
        work = [(root, iter(sorted(edges[root])))]
        index[root] = low[root] = counter
        counter += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            node, it = work[-1]
            advanced = False
            for nxt in it:
                if nxt not in index:
                    index[nxt] = low[nxt] = counter
                    counter += 1
                    stack.append(nxt)
                    on_stack.add(nxt)
                    work.append((nxt, iter(sorted(edges[nxt]))))
                    advanced = True
                    break
                if nxt in on_stack:
                    low[node] = min(low[node], index[nxt])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == node:
                        break
                sccs.append(scc)
    return sccs


def condensation(program: Program) -> list[list[str]]:
    """SCCs of the dependency graph in topological order: every
    component's body-side dependencies come before it."""
    edges = dependency_graph(program)
    # Tarjan emits successors (heads) first; heads must run *after*
    # their body predicates, so reverse into bodies-first order.
    return list(reversed(_tarjan_sccs(edges)))


def stratify(program: Program) -> list[list[Rule]]:
    """Partition the rules into strata to run in order.

    A rule belongs to the stratum of its head predicate's SCC; since a
    body predicate ``b`` has an edge into the head, ``b``'s component is
    never later than the head's, so by the time a stratum runs, every
    body predicate from earlier strata is fully materialised and only
    the stratum's own (mutually recursive) predicates still iterate.
    Components that head no rule (EDB-only predicates) yield no stratum.
    Rule order inside a stratum follows the program text (determinism).
    """
    comps = condensation(program)
    stratum_of = {
        pred: k for k, comp in enumerate(comps) for pred in comp
    }
    buckets: dict[int, list[Rule]] = {}
    for rule in program:
        buckets.setdefault(stratum_of[rule.head.predicate], []).append(rule)
    return [buckets[k] for k in sorted(buckets)]


def explain_strata(program: Program) -> str:
    """Human-readable stratification report."""
    strata = stratify(program)
    lines = [f"{len(strata)} strata over {len(program)} rules"]
    for k, rules in enumerate(strata):
        heads = sorted({r.head.predicate for r in rules})
        tag = " (recursive)" if is_recursive(rules) else ""
        lines.append(
            f"  stratum {k}: {len(rules)} rule(s), heads [{', '.join(heads)}]{tag}"
        )
    return "\n".join(lines)


def stratum_predicates(rules: list[Rule]) -> tuple[set[str], set[str]]:
    """``(heads, body_preds)`` of one stratum's rules — the predicates a
    fixpoint driver must watch for deltas (bodies) and the predicates the
    stratum can change (heads).  Shared by the incremental sweeps and the
    distributed stratum scheduler."""
    heads = {r.head.predicate for r in rules}
    bodies = {a.predicate for r in rules for a in r.body}
    return heads, bodies


def is_recursive(rules: list[Rule]) -> bool:
    """True iff a stratum's rules feed their own heads (mutual recursion).

    Non-recursive strata reach fixpoint in one round, and — used by the
    incremental subsystem — admit *exact* derivation-count maintenance;
    recursive strata fall back to Delete/Rederive."""
    heads = {r.head.predicate for r in rules}
    return any(a.predicate in heads for r in rules for a in r.body)
