"""Exporters: Chrome trace-event JSON for spans, flat JSON for metrics.

``chrome_trace(tracer)`` renders the recorded spans as the Chrome
trace-event format (the JSON Perfetto and ``chrome://tracing`` load
directly): one ``"ph": "X"`` *complete* event per span with
microsecond ``ts``/``dur`` relative to the tracer origin, plus process
/ thread ``"M"`` metadata events naming the timeline.  Instants
(``dur_ns == 0`` markers) become ``"ph": "i"`` events.

``write_metrics(registry, path)`` dumps one flat ``{name: scalar}``
snapshot — the same dict :meth:`MetricsRegistry.snapshot` returns — so
the file diffs cleanly across runs and the bench gate can read single
keys without a schema walk.
"""

from __future__ import annotations

import json

from .metrics import MetricsRegistry, get_registry
from .trace import Tracer, get_tracer

__all__ = [
    "chrome_trace",
    "write_chrome_trace",
    "write_metrics",
]

_PID = 1  # single-process system; one process row in the UI


def chrome_trace(tracer: Tracer | None = None) -> dict:
    """The trace as a JSON-ready dict (Chrome trace-event format)."""
    tracer = tracer if tracer is not None else get_tracer()
    tids: dict[int, int] = {}
    events: list[dict] = [
        {
            "ph": "M",
            "pid": _PID,
            "tid": 0,
            "name": "process_name",
            "args": {"name": "repro"},
        }
    ]
    for rec in tracer.sorted_events():
        tid = tids.get(rec.tid)
        if tid is None:
            tid = tids[rec.tid] = len(tids)
            events.append(
                {
                    "ph": "M",
                    "pid": _PID,
                    "tid": tid,
                    "name": "thread_name",
                    "args": {"name": f"host-{tid}"},
                }
            )
        ts_us = (rec.start_ns - tracer.origin_ns) / 1e3
        ev = {
            "name": rec.name,
            "cat": rec.name.split(".", 1)[0],
            "pid": _PID,
            "tid": tid,
            "ts": ts_us,
        }
        if rec.dur_ns < 0:
            ev["ph"] = "i"
            ev["s"] = "t"  # thread-scoped instant
        else:
            ev["ph"] = "X"
            ev["dur"] = rec.dur_ns / 1e3
        if rec.args:
            ev["args"] = dict(rec.args)
        events.append(ev)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "origin_unix_s": tracer.origin_unix_s,
            "dropped_events": tracer.dropped,
            "misnested_spans": tracer.misnested,
        },
    }


def write_chrome_trace(path: str, tracer: Tracer | None = None) -> int:
    """Write the trace JSON; returns the number of span/instant events
    (metadata events excluded)."""
    doc = chrome_trace(tracer)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return sum(1 for e in doc["traceEvents"] if e["ph"] != "M")


def write_metrics(
    path: str,
    registry: MetricsRegistry | None = None,
    prefix: str = "",
) -> dict:
    """Write (and return) a flat metrics snapshot as JSON."""
    registry = registry if registry is not None else get_registry()
    snap = registry.snapshot(prefix)
    with open(path, "w") as fh:
        json.dump(snap, fh, indent=2, sort_keys=True, default=float)
        fh.write("\n")
    return snap
