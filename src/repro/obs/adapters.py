"""Thin adapters: legacy stats objects -> canonical registry metrics.

The engines keep their existing dataclasses (``MaterialisationStats``,
``DistributedStats``, ``IncrementalStats``, the query-engine cache
counters) — those are the per-call return values tests and benchmarks
already consume.  What changes is that every completed
materialise/apply *also* publishes its numbers here, under one
canonical dotted name per metric, so any consumer can take one
registry snapshot instead of chasing four stats shapes.

Counters are **incremented** by the published value (a registry scope
accumulates across batches/runs until its owner resets it); levels
(fact counts, epochs, byte sizes) are gauges and overwrite.  Field
names are preserved under the prefix — ``cmat.rounds`` is literally
``MaterialisationStats.rounds`` — so the adapter-parity test can diff
the snapshot against the dataclass mechanically.
"""

from __future__ import annotations

from .metrics import MetricsRegistry, get_registry

__all__ = [
    "publish_materialisation",
    "publish_incremental",
    "publish_distributed",
    "publish_query_cache",
    "publish_serving",
    "MATERIALISATION_COUNTERS",
    "MATERIALISATION_GAUGES",
    "INCREMENTAL_COUNTERS",
    "DISTRIBUTED_COUNTERS",
    "SERVING_GAUGES",
]

#: ServingTier.stats() keys mirrored as gauges (lifetime-cumulative on
#: the tier, so re-publishing is idempotent — same convention as
#: :func:`publish_query_cache`)
SERVING_GAUGES = (
    "queries",
    "batches",
    "mean_batch",
    "max_batch",
    "grouped_queries",
    "single_queries",
    "cache_hits",
    "dedup_hits",
    "groups",
    "stale_reads",
    "applies",
    "checkpoints",
    "compactions",
    "compactions_deferred",
    "max_queue_depth",
    "epoch_lag_max",
    "epochs_published",
    "epochs_retired",
    "epochs_live",
    "epochs_pinned",
    "epoch",
)

#: MaterialisationStats fields that accumulate (counter semantics)
MATERIALISATION_COUNTERS = (
    "rounds",
    "n_rule_applications",
    "rule_applications_skipped",
    "old_snapshot_scans",
    "time_compress",
    "time_match",
    "time_join",
    "time_dedup",
    "time_total",
)

#: MaterialisationStats fields that are levels (gauge semantics)
MATERIALISATION_GAUGES = ("n_strata", "n_meta_facts", "n_facts")

#: IncrementalStats extras (per-batch deltas -> counters)
INCREMENTAL_COUNTERS = (
    "n_del_explicit",
    "n_add_explicit",
    "n_overdeleted",
    "n_rederived",
    "n_deleted",
    "n_inserted",
    "n_count_updates",
    "counting_strata",
    "dred_strata",
    "time_overdelete",
    "time_delete",
    "time_rederive",
    "time_counting",
    "time_insert",
)

#: DistributedStats extras beyond the materialisation base
DISTRIBUTED_COUNTERS = (
    "rows_joined",
    "exchanges",
    "exchanges_skipped",
    "exchange_regrows",
    "n_del_explicit",
    "n_add_explicit",
    "n_overdeleted",
    "n_rederived",
    "n_deleted",
    "n_inserted",
)


def _publish_rule_scope(reg: MetricsRegistry, stats) -> None:
    """Mirror the per-stratum breakdown and the host (rule, pivot) skip
    counter under the ``rule.*`` scope (shared with the provenance
    journal's per-rule cost gauges, so one snapshot prefix answers
    "where did rule work go").  Per-stratum entries are levels of the
    *last* run — gauges, republish-idempotent."""
    for s in getattr(stats, "per_stratum", ()) or ():
        si = s.get("stratum", 0)
        for f in ("rounds", "rules", "rule_applications"):
            if f in s:
                reg.gauge(f"rule.stratum{si}.{f}").set(s[f])
    reg.counter("rule.applications_skipped").inc(
        getattr(stats, "rule_applications_skipped", 0)
    )


def _publish_plan_cache(
    reg: MetricsRegistry, prefix: str, plan_cache: dict
) -> None:
    # plan-cache counters are cumulative on the cache object; gauges
    # keep 'last seen' semantics so repeated publishes don't double
    for key, val in (plan_cache or {}).items():
        reg.gauge(f"{prefix}.plan_cache.{key}").set(val)


def publish_materialisation(
    stats, registry: MetricsRegistry | None = None, prefix: str = "cmat"
) -> None:
    """Publish a :class:`~repro.core.engine.MaterialisationStats` (the
    CMat/Flat engines call this at the end of ``materialise``)."""
    reg = registry if registry is not None else get_registry()
    for f in MATERIALISATION_COUNTERS:
        reg.counter(f"{prefix}.{f}").inc(getattr(stats, f))
    for f in MATERIALISATION_GAUGES:
        reg.gauge(f"{prefix}.{f}").set(getattr(stats, f))
    _publish_rule_scope(reg, stats)
    _publish_plan_cache(reg, prefix, stats.plan_cache)


def publish_incremental(
    stats, registry: MetricsRegistry | None = None, prefix: str = "inc"
) -> None:
    """Publish an :class:`~repro.incremental.IncrementalStats` (the
    host store calls this after every ``apply`` batch)."""
    reg = registry if registry is not None else get_registry()
    reg.counter(f"{prefix}.batches").inc()
    for f in INCREMENTAL_COUNTERS + ("n_rule_applications", "time_total"):
        reg.counter(f"{prefix}.{f}").inc(getattr(stats, f))
    reg.gauge(f"{prefix}.epoch").set(stats.epoch)
    reg.gauge(f"{prefix}.n_facts").set(stats.n_facts)
    reg.gauge(f"{prefix}.n_meta_facts").set(stats.n_meta_facts)
    reg.gauge(f"{prefix}.journal_bytes").set(stats.journal_bytes)
    reg.histogram(f"{prefix}.apply_s").observe(stats.time_total)
    _publish_plan_cache(reg, prefix, stats.plan_cache)


def publish_distributed(
    stats, registry: MetricsRegistry | None = None, prefix: str = "dist"
) -> None:
    """Publish a :class:`~repro.core.distributed.DistributedStats`
    (after ``materialise`` and after every ``apply``)."""
    reg = registry if registry is not None else get_registry()
    for f in MATERIALISATION_COUNTERS:
        reg.counter(f"{prefix}.{f}").inc(getattr(stats, f))
    for f in MATERIALISATION_GAUGES:
        reg.gauge(f"{prefix}.{f}").set(getattr(stats, f))
    for f in DISTRIBUTED_COUNTERS:
        reg.counter(f"{prefix}.{f}").inc(getattr(stats, f))
    reg.gauge(f"{prefix}.epoch").set(stats.epoch)
    _publish_rule_scope(reg, stats)
    _publish_plan_cache(reg, prefix, stats.plan_cache)


def publish_serving(
    tier, registry: MetricsRegistry | None = None, prefix: str = "serve.tier"
) -> None:
    """Publish a :class:`~repro.serving.ServingTier`'s lifetime stats
    under ``serve.tier.*`` gauges.  The tier's live counters/histograms
    (batch sizes, admission latency, epoch lag) already stream into the
    registry under ``serve.*`` — the roll-up takes its own sub-scope so
    gauge names never collide with those counters."""
    reg = registry if registry is not None else get_registry()
    stats = tier.stats()
    for key in SERVING_GAUGES:
        if key in stats:
            reg.gauge(f"{prefix}.{key}").set(stats[key])


def publish_query_cache(
    engine, registry: MetricsRegistry | None = None, prefix: str = "query"
) -> None:
    """Publish a :class:`~repro.query.QueryEngine`'s cache counters.
    The engine's counts are lifetime-cumulative, so these are gauges —
    re-publishing is idempotent."""
    reg = registry if registry is not None else get_registry()
    for key, val in engine.cache_stats().items():
        reg.gauge(f"{prefix}.{key}").set(val)
    reg.gauge(f"{prefix}.epoch").set(engine.epoch)
