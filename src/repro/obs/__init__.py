"""Unified observability: spans, a metrics registry, exporters.

The one telemetry layer every subsystem reports through
(DESIGN.md §Observability):

* :func:`span` / :func:`instant` — nested host-side tracing spans
  (``perf_counter_ns``; free when disabled).  Emitted for fixpoint
  rounds, strata, (rule, pivot) applications, exchange rounds, DRed
  phases, WAL appends, checkpoints/restores, compaction epochs, and
  served queries/apply batches.
* :func:`get_registry` — named counters/gauges/histograms with one
  canonical name per number, one snapshot call, one (per-scope) reset.
  The legacy stats dataclasses publish into it via
  :mod:`repro.obs.adapters`.
* :func:`write_chrome_trace` / :func:`write_metrics` — Chrome
  trace-event / Perfetto JSON and a flat metrics snapshot, wired into
  ``serve_datalog --trace-out/--metrics-out`` and
  ``benchmarks/run.py --json``.

Spans must never fire inside traced/jitted code — instrument at host
boundaries, where the engines already count rounds.
"""

from .adapters import (
    publish_distributed,
    publish_incremental,
    publish_materialisation,
    publish_query_cache,
    publish_serving,
)
from .export import chrome_trace, write_chrome_trace, write_metrics
from .memory import (
    MemoryAccountant,
    MemoryReporter,
    MemorySampler,
    get_accountant,
    publish_predicate_effectiveness,
    register_reporter,
    rss_bytes,
    sample_memory,
    set_accountant,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    set_registry,
)
from .provenance import (
    DerivationJournal,
    DerivationRecord,
    Explainer,
    get_journal,
    proof_to_dot,
    proof_to_json,
)
from .trace import Tracer, get_tracer, instant, set_tracer, span

__all__ = [
    "Tracer",
    "get_tracer",
    "set_tracer",
    "span",
    "instant",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "get_registry",
    "set_registry",
    "MemoryAccountant",
    "MemoryReporter",
    "MemorySampler",
    "get_accountant",
    "set_accountant",
    "register_reporter",
    "sample_memory",
    "rss_bytes",
    "publish_predicate_effectiveness",
    "chrome_trace",
    "write_chrome_trace",
    "write_metrics",
    "publish_materialisation",
    "publish_incremental",
    "publish_distributed",
    "publish_query_cache",
    "publish_serving",
    "DerivationJournal",
    "DerivationRecord",
    "Explainer",
    "get_journal",
    "proof_to_json",
    "proof_to_dot",
]
