"""Derivation provenance: lineage journal, verified proof trees, rule costs.

The paper's central trick — applying a rule to *many* facts at once and
structure-sharing the result — means one derivation step justifies
thousands of triples.  Provenance therefore records at the **meta-fact**
level: one compact :class:`DerivationRecord` per rule application
``(stratum, round, rule_id, pivot, input mf ids / row ranges) ->
output mf ids``, never one per triple, so structure sharing extends to
lineage (VLog keeps derivations segregated per (rule, step) for the
same reason).

Three layers live here:

* :class:`DerivationJournal` — a bounded, epoch-aware append log shared
  by all four engines (CMat / Flat / Distributed / Incremental).
  Recording is **off by default** and free when off; the buffer is a
  ``deque(maxlen=...)`` so memory is bounded and eviction is counted,
  never silent.  The journal registers a ``memory_report()`` with the
  PR-8 accountant and survives checkpoint/restore via
  :meth:`DerivationJournal.to_payload` / :meth:`load_payload`.
* :class:`Explainer` — ``explain(pred, terms)`` reconstructs a minimal
  proof tree for a materialised fact by walking the journal for
  candidate rules and **re-running the rule bodies restricted to the
  queried fact** (lower strata unrestricted, same stratum restricted to
  strictly smaller rounds, so recursion is well-founded).  Every step
  is independently re-checked by re-derivation from exactly its chosen
  body facts — explanations are *verified, not trusted* — and the
  journal is only a search accelerator: eviction or a fresh journal
  after restore degrades to trying all rules with a matching head,
  never to a wrong proof.
* per-rule cost attribution — :meth:`DerivationJournal.publish` sets
  ``rule.<id>.{derived,redundant,time_ns,rounds_active}`` gauges on the
  metrics registry (gauges, so re-publishing after each fixpoint is
  idempotent), the feed for ``serve_datalog --hot-rules`` and the
  ROADMAP's adaptive-storage chooser.

Core modules are imported lazily inside functions: ``repro.core.*``
imports ``repro.obs`` at module load, so a top-level import here would
be circular.
"""

from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from .memory import register_reporter
from .metrics import get_registry

__all__ = [
    "DerivationRecord",
    "DerivationJournal",
    "Explainer",
    "get_journal",
    "proof_to_json",
    "proof_to_dot",
]

#: cap on input/output meta-fact ids kept per record — lineage stays
#: O(1) per rule application even when a round touches thousands of mfs
MAX_IDS_PER_RECORD = 16

#: default bounded-buffer size (records, not triples)
DEFAULT_MAX_RECORDS = 100_000


@dataclass(slots=True)
class DerivationRecord:
    """One rule application (or maintenance phase step), meta-fact granular.

    ``kind`` is ``"apply"`` for fixpoint rounds and one of
    ``"insert" | "overdelete" | "rederive" | "survive_explicit" |
    "survive_backward"`` for incremental-maintenance phases (the DRed
    records answer *why a fact survived* a deletion batch).
    """

    kind: str
    engine: str  # cmat | flat | dist | inc
    stratum: int
    round: int
    rule_id: int  # index into the attached program; -1 = no rule (explicit)
    pivot: int  # delta-anchored body position; -1 = naive / whole-body
    pred: str  # head predicate the record derived into
    n_emitted: int = 0  # rows emitted by the rule body
    n_new: int = 0  # rows surviving dedup (fresh facts)
    in_mf_ids: tuple = ()  # input meta-fact ids (capped, best effort)
    out_mf_ids: tuple = ()  # output meta-fact ids (capped)
    row_span: tuple = ()  # flat mode: (watermark_before, watermark_after)
    shard: int = -1  # distributed: shard tag; -1 = host
    epoch: int = 0  # incremental epoch the record belongs to
    time_ns: int = 0

    def key(self) -> tuple:
        """Identity ignoring shard/counters — used by shard merging."""
        return (
            self.kind,
            self.engine,
            self.stratum,
            self.round,
            self.rule_id,
            self.pivot,
            self.pred,
            self.epoch,
        )

    def to_list(self) -> list:
        return [
            self.kind,
            self.engine,
            self.stratum,
            self.round,
            self.rule_id,
            self.pivot,
            self.pred,
            self.n_emitted,
            self.n_new,
            list(self.in_mf_ids),
            list(self.out_mf_ids),
            list(self.row_span),
            self.shard,
            self.epoch,
            self.time_ns,
        ]

    @classmethod
    def from_list(cls, row: list) -> DerivationRecord:
        return cls(
            kind=row[0],
            engine=row[1],
            stratum=int(row[2]),
            round=int(row[3]),
            rule_id=int(row[4]),
            pivot=int(row[5]),
            pred=row[6],
            n_emitted=int(row[7]),
            n_new=int(row[8]),
            in_mf_ids=tuple(row[9]),
            out_mf_ids=tuple(row[10]),
            row_span=tuple(row[11]),
            shard=int(row[12]),
            epoch=int(row[13]),
            time_ns=int(row[14]),
        )


@dataclass
class _RuleCost:
    derived: int = 0
    redundant: int = 0
    time_ns: int = 0
    rounds: set = field(default_factory=set)


class DerivationJournal:
    """Bounded, epoch-aware derivation log (off by default).

    Engines call :meth:`record` once per rule application; when
    ``enabled`` is ``False`` every hook short-circuits before building a
    record, so the disabled journal costs one attribute read per
    application.  The buffer is bounded (``deque(maxlen=...)``):
    ``dropped`` counts evictions, and :class:`Explainer` treats journal
    misses as "try all candidate rules", so eviction can never make an
    explanation wrong — only slower.
    """

    def __init__(self, max_records: int = DEFAULT_MAX_RECORDS):
        self.enabled = False
        self.max_records = int(max_records)
        self.records: deque[DerivationRecord] = deque(maxlen=self.max_records)
        self.n_recorded = 0  # total ever recorded (>= len(records))
        self.epoch = 0
        self.rule_strs: dict[int, str] = {}
        self.costs: dict[int, _RuleCost] = {}

    # ------------------------------------------------------------------ #
    # configuration / lifecycle
    # ------------------------------------------------------------------ #
    def configure(self, max_records: int) -> None:
        """Resize the bounded buffer, keeping the newest records."""
        max_records = int(max_records)
        if max_records == self.max_records:
            return
        self.max_records = max_records
        self.records = deque(self.records, maxlen=max_records)

    def attach_program(self, program) -> None:
        """Remember rule strings so reports can show rules, not ids.

        ``rule_id`` is the rule's position in ``program.rules`` — the
        iteration order every engine shares.
        """
        for i, rule in enumerate(program):
            self.rule_strs[i] = str(rule)

    def begin_epoch(self, epoch: int) -> None:
        self.epoch = int(epoch)

    def clear(self) -> None:
        self.records.clear()
        self.n_recorded = 0
        self.costs.clear()

    @property
    def dropped(self) -> int:
        return self.n_recorded - len(self.records)

    # ------------------------------------------------------------------ #
    # recording
    # ------------------------------------------------------------------ #
    def record(self, rec: DerivationRecord) -> None:
        if not self.enabled:
            return
        self.records.append(rec)
        self.n_recorded += 1
        if rec.rule_id >= 0:
            c = self.costs.setdefault(rec.rule_id, _RuleCost())
            c.derived += rec.n_new
            c.redundant += max(0, rec.n_emitted - rec.n_new)
            c.time_ns += rec.time_ns
            c.rounds.add((rec.stratum, rec.round))

    # ------------------------------------------------------------------ #
    # lookup (the Explainer's search accelerator)
    # ------------------------------------------------------------------ #
    def lookup(self, pred: str, round_no: int | None = None) -> list[DerivationRecord]:
        """Records that derived into ``pred`` (optionally at one round)."""
        out = []
        for rec in self.records:
            if rec.pred != pred:
                continue
            if round_no is not None and rec.round != round_no:
                continue
            out.append(rec)
        return out

    def rule_ids_for(self, pred: str, round_no: int | None = None) -> list[int]:
        """Distinct rule ids recorded for (pred, round), newest bias last."""
        seen: list[int] = []
        for rec in self.lookup(pred, round_no):
            if rec.rule_id >= 0 and rec.rule_id not in seen:
                seen.append(rec.rule_id)
        return seen

    # ------------------------------------------------------------------ #
    # shard merging (distributed verify)
    # ------------------------------------------------------------------ #
    def merge_shard_records(self) -> int:
        """Coalesce records identical up to shard/counters into host rows.

        Called at distributed verify: per-shard records with the same
        :meth:`DerivationRecord.key` sum their counters and drop the
        shard tag (``shard=-1``).  Returns the number of rows removed.
        """
        merged: dict[tuple, DerivationRecord] = {}
        order: list[tuple] = []
        for rec in self.records:
            k = rec.key()
            if k in merged:
                m = merged[k]
                m.n_emitted += rec.n_emitted
                m.n_new += rec.n_new
                m.time_ns += rec.time_ns
                m.in_mf_ids = (m.in_mf_ids + rec.in_mf_ids)[:MAX_IDS_PER_RECORD]
                m.out_mf_ids = (m.out_mf_ids + rec.out_mf_ids)[:MAX_IDS_PER_RECORD]
                m.shard = -1
            else:
                merged[k] = DerivationRecord(**{
                    s: getattr(rec, s) for s in DerivationRecord.__slots__
                })
                order.append(k)
        removed = len(self.records) - len(order)
        self.records = deque(
            (merged[k] for k in order), maxlen=self.max_records
        )
        return removed

    # ------------------------------------------------------------------ #
    # cost attribution -> metrics registry
    # ------------------------------------------------------------------ #
    def publish(self, registry=None) -> None:
        """Set ``rule.<id>.*`` gauges (idempotent across re-publishes)."""
        reg = registry if registry is not None else get_registry()
        for rid, c in self.costs.items():
            reg.gauge(f"rule.{rid}.derived").set(c.derived)
            reg.gauge(f"rule.{rid}.redundant").set(c.redundant)
            reg.gauge(f"rule.{rid}.time_ns").set(c.time_ns)
            reg.gauge(f"rule.{rid}.rounds_active").set(len(c.rounds))
        reg.gauge("rule.journal.records").set(len(self.records))
        reg.gauge("rule.journal.dropped").set(self.dropped)

    def hot_rules(self, n: int = 10) -> list[dict]:
        """Top-n rules by recorded wall time, with derived/redundant."""
        rows = []
        for rid, c in sorted(
            self.costs.items(), key=lambda kv: kv[1].time_ns, reverse=True
        )[:n]:
            rows.append({
                "rule_id": rid,
                "rule": self.rule_strs.get(rid, f"<rule {rid}>"),
                "derived": c.derived,
                "redundant": c.redundant,
                "time_ns": c.time_ns,
                "rounds_active": len(c.rounds),
            })
        return rows

    # ------------------------------------------------------------------ #
    # persistence (checkpoint sidecar) + memory accounting
    # ------------------------------------------------------------------ #
    def to_payload(self) -> dict:
        return {
            "version": 1,
            "epoch": self.epoch,
            "max_records": self.max_records,
            "n_recorded": self.n_recorded,
            "rule_strs": {str(k): v for k, v in self.rule_strs.items()},
            "records": [r.to_list() for r in self.records],
            "costs": {
                str(rid): {
                    "derived": c.derived,
                    "redundant": c.redundant,
                    "time_ns": c.time_ns,
                    "rounds": sorted([list(t) for t in c.rounds]),
                }
                for rid, c in self.costs.items()
            },
        }

    def load_payload(self, payload: dict) -> None:
        """Restore journal state from a checkpoint sidecar (additive-free:
        replaces records/costs wholesale so restore is deterministic)."""
        self.epoch = int(payload.get("epoch", 0))
        self.configure(int(payload.get("max_records", self.max_records)))
        self.records = deque(
            (DerivationRecord.from_list(r) for r in payload.get("records", [])),
            maxlen=self.max_records,
        )
        self.n_recorded = int(payload.get("n_recorded", len(self.records)))
        self.rule_strs = {
            int(k): v for k, v in payload.get("rule_strs", {}).items()
        }
        self.costs = {}
        for rid, c in payload.get("costs", {}).items():
            self.costs[int(rid)] = _RuleCost(
                derived=int(c["derived"]),
                redundant=int(c["redundant"]),
                time_ns=int(c["time_ns"]),
                rounds={tuple(t) for t in c.get("rounds", [])},
            )

    def memory_report(self) -> dict[str, int]:
        """PR-8 accountant reporter: owned bytes of the record buffer."""
        # a record is a slotted object: ~15 scalar slots + two small
        # tuples of ints; 160B flat + 8B per kept id is a close estimate
        id_bytes = sum(
            8 * (len(r.in_mf_ids) + len(r.out_mf_ids)) for r in self.records
        )
        return {
            "journal_bytes": 160 * len(self.records) + id_bytes,
            "n_records": len(self.records),
            "n_dropped": self.dropped,
        }


#: process-wide journal (module global: the strong ref that keeps the
#: weakly-registered memory reporter alive)
_JOURNAL: DerivationJournal | None = None


def get_journal() -> DerivationJournal:
    global _JOURNAL
    if _JOURNAL is None:
        _JOURNAL = DerivationJournal()
        register_reporter("provenance", _JOURNAL)
    return _JOURNAL


# --------------------------------------------------------------------- #
# verified explanation
# --------------------------------------------------------------------- #
class Explainer:
    """Reconstruct and *verify* proof trees for materialised facts.

    Works over flat per-predicate tables ``{pred: (rows, rounds)}`` where
    ``rounds[i]`` is the semi-naive round that first derived ``rows[i]``
    (0 / explicit for input facts).  Build one with
    :meth:`from_fact_store` (compressed engines, incremental store) or
    :meth:`from_flat` (flat engine).

    Well-foundedness: every engine in this repo only derives a fact from
    body facts in strictly lower strata, or in the same stratum with
    strictly smaller rounds (semi-naive reads the pre-round state; DRed
    re-insertions bump the round counter before tagging).  ``_derive``
    restricts same-stratum body sources to rounds ``< r``, so recursion
    terminates and the tree bottoms out in explicit facts.
    """

    def __init__(
        self,
        program,
        tables: dict[str, tuple[np.ndarray, np.ndarray]],
        explicit: dict[str, np.ndarray] | None = None,
        journal: DerivationJournal | None = None,
        max_depth: int = 64,
        decode=None,
    ):
        from ..core.program_graph import stratify

        self.program = program
        self.rules = list(program)
        self.tables = tables
        self.explicit = explicit if explicit is not None else {}
        self.journal = journal
        self.max_depth = max_depth
        self.decode = decode
        self.stratum_of: dict[str, int] = {}
        for si, stratum in enumerate(stratify(program)):
            for rule in stratum:
                self.stratum_of[rule.head.predicate] = si
        self._memo: dict[tuple, dict] = {}

    # ------------------------------------------------------------------ #
    # table builders
    # ------------------------------------------------------------------ #
    @staticmethod
    def build_tables(store) -> dict[str, tuple[np.ndarray, np.ndarray]]:
        """Unfold a :class:`FactStore` into ``{pred: (rows, rounds)}``
        with duplicates collapsed to their **minimum** round (a fact's
        first derivation — the minimal-proof anchor)."""
        tables: dict[str, tuple[np.ndarray, np.ndarray]] = {}
        for pred in store.predicates():
            mfs = store.all(pred)
            if not mfs:
                continue
            rows = store.unfold_pred(pred)
            rounds = np.concatenate(
                [np.full(mf.length, mf.round, dtype=np.int64) for mf in mfs]
            )
            tables[pred] = _dedup_min_round(rows, rounds)
        return tables

    @classmethod
    def from_fact_store(
        cls,
        program,
        store,
        explicit: dict[str, np.ndarray] | None = None,
        **kw,
    ) -> Explainer:
        return cls(program, cls.build_tables(store), explicit, **kw)

    @classmethod
    def from_flat(
        cls,
        program,
        facts: dict[str, np.ndarray],
        fresh_log: dict[str, list[tuple[int, np.ndarray]]] | None = None,
        explicit: dict[str, np.ndarray] | None = None,
        **kw,
    ) -> Explainer:
        """Build from a :class:`FlatEngine`: ``facts`` are the final
        sorted tables; ``fresh_log`` (the engine's provenance log of
        per-round fresh rows) supplies rounds, defaulting to 0."""
        tables: dict[str, tuple[np.ndarray, np.ndarray]] = {}
        for pred, rows in facts.items():
            if fresh_log and pred in fresh_log:
                blocks = fresh_log[pred]
                all_rows = np.concatenate([b for _, b in blocks])
                rounds = np.concatenate(
                    [np.full(b.shape[0], rno, dtype=np.int64) for rno, b in blocks]
                )
                tables[pred] = _dedup_min_round(all_rows, rounds)
            else:
                tables[pred] = (rows, np.zeros(rows.shape[0], dtype=np.int64))
        return cls(program, tables, explicit, **kw)

    # ------------------------------------------------------------------ #
    # public API
    # ------------------------------------------------------------------ #
    def explain(self, pred: str, terms) -> dict | None:
        """Verified proof tree for ``pred(terms)`` or ``None`` if the
        fact is not in the materialisation."""
        terms = tuple(int(t) for t in terms)
        self._memo.clear()
        return self._explain(pred, terms, stack=set(), depth=0)

    # ------------------------------------------------------------------ #
    # internals
    # ------------------------------------------------------------------ #
    def _fact_str(self, pred: str, terms: tuple) -> str:
        if self.decode is not None:
            shown = ", ".join(str(self.decode(t)) for t in terms)
        else:
            shown = ", ".join(str(t) for t in terms)
        return f"{pred}({shown})"

    def _is_explicit(self, pred: str, terms: tuple) -> bool:
        rows = self.explicit.get(pred)
        if rows is None or rows.shape[0] == 0:
            return False
        if rows.ndim == 1:
            rows = rows.reshape(-1, 1)
        if rows.shape[1] != len(terms):
            return False
        return bool((rows == np.asarray(terms, dtype=np.int64)).all(axis=1).any())

    def _round_of(self, pred: str, terms: tuple) -> int | None:
        tab = self.tables.get(pred)
        if tab is None:
            return None
        rows, rounds = tab
        if rows.shape[0] == 0 or rows.shape[1] != len(terms):
            return None
        hit = (rows == np.asarray(terms, dtype=np.int64)).all(axis=1)
        if not hit.any():
            return None
        return int(rounds[hit].min())

    def _source_rows(
        self, pred: str, head_stratum: int, max_round: int
    ) -> np.ndarray | None:
        """Rows of ``pred`` usable as body facts under the proof of a
        head in ``head_stratum`` first derived at ``max_round``."""
        tab = self.tables.get(pred)
        if tab is None:
            rows = self.explicit.get(pred)
            if rows is None:
                return None
            rows = np.asarray(rows, dtype=np.int64)
            return rows.reshape(-1, 1) if rows.ndim == 1 else rows
        rows, rounds = tab
        if self.stratum_of.get(pred, -1) == head_stratum:
            rows = rows[rounds < max_round]
        return rows

    def _explain(
        self, pred: str, terms: tuple, stack: set, depth: int
    ) -> dict | None:
        key = (pred, terms)
        if key in self._memo:
            return self._memo[key]
        if self._is_explicit(pred, terms):
            node = {
                "fact": self._fact_str(pred, terms),
                "pred": pred,
                "terms": list(terms),
                "kind": "explicit",
                "verified": True,
                "children": [],
            }
            self._memo[key] = node
            return node
        r = self._round_of(pred, terms)
        if r is None:
            return None  # fact not in the materialisation
        if depth >= self.max_depth or key in stack:
            return None
        stack = stack | {key}
        strat = self.stratum_of.get(pred, -1)
        for rid in self._candidate_rules(pred, r):
            rule = self.rules[rid]
            step = self._derive(rule, terms, strat, r)
            if step is None:
                continue
            body_facts, verified = step
            children = []
            ok = verified
            for b_pred, b_terms in body_facts:
                child = self._explain(b_pred, b_terms, stack, depth + 1)
                if child is None:
                    ok = False
                    break
                children.append(child)
            if not ok:
                continue
            node = {
                "fact": self._fact_str(pred, terms),
                "pred": pred,
                "terms": list(terms),
                "kind": "derived",
                "rule_id": rid,
                "rule": str(rule),
                "round": r,
                "verified": verified and all(c["verified"] for c in children),
                "children": children,
            }
            self._memo[key] = node
            return node
        return None

    def _candidate_rules(self, pred: str, r: int) -> list[int]:
        """Journal-guided rule order with exhaustive fallback: journal
        hits for (pred, round) first, then (pred, any round), then every
        rule with a matching head — so journal eviction / a restored KB
        with a fresh journal still explains, just with more search."""
        ordered: list[int] = []
        if self.journal is not None and self.journal.records:
            for rid in self.journal.rule_ids_for(pred, r):
                if rid < len(self.rules) and rid not in ordered:
                    ordered.append(rid)
            for rid in self.journal.rule_ids_for(pred):
                if rid < len(self.rules) and rid not in ordered:
                    ordered.append(rid)
        for rid, rule in enumerate(self.rules):
            if rule.head.predicate == pred and rid not in ordered:
                ordered.append(rid)
        return ordered

    def _derive(self, rule, terms: tuple, strat: int, r: int):
        """Try to re-derive ``head(terms)`` with ``rule`` under the
        round restriction; returns ``(body_facts, verified)`` or None.

        Search: substitute the head binding into the body and join the
        restricted sources; the first solution row fixes one concrete
        fact per body atom.  Verify: re-run the rule on *exactly those
        facts* and check the head projects back to ``terms``.
        """
        from ..core.datalog import Atom
        from ..core.flat import _Table, _join, _match_flat

        head = rule.head
        if len(head.terms) != len(terms):
            return None
        binding: dict[str, int] = {}
        for t, v in zip(head.terms, terms):
            if isinstance(t, int):
                if t != v:
                    return None
            elif binding.setdefault(t, v) != v:
                return None

        def bound(atom):
            return Atom(
                atom.predicate,
                tuple(binding.get(t, t) if isinstance(t, str) else t
                      for t in atom.terms),
            )

        L: _Table | None = None
        for atom in rule.body:
            src = self._source_rows(atom.predicate, strat, r)
            if src is None or src.shape[0] == 0:
                return None
            R = _match_flat(bound(atom), src)
            if R is None:
                return None
            L = R if L is None else _join(L, R)
            if L.rows.shape[0] == 0:
                return None
        # first solution fixes the substitution
        theta = dict(binding)
        if L is not None and L.vars:
            sol = L.rows[0]
            for v, val in zip(L.vars, sol):
                theta[v] = int(val)
        body_facts = []
        for atom in rule.body:
            fact = tuple(
                theta[t] if isinstance(t, str) else int(t) for t in atom.terms
            )
            body_facts.append((atom.predicate, fact))
        verified = self._check_step(rule, terms, body_facts)
        return (body_facts, verified) if verified else None

    def _check_step(self, rule, terms: tuple, body_facts: list) -> bool:
        """Independent re-derivation: apply the rule to exactly the
        chosen body facts (one row per atom) and check the head equals
        the queried fact.  No journal, no tables — pure rule semantics."""
        from ..core.flat import _Table, _join, _match_flat

        L: _Table | None = None
        for atom, (_, fact) in zip(rule.body, body_facts):
            rows = np.asarray([fact], dtype=np.int64)
            R = _match_flat(atom, rows)
            if R is None:
                return False
            L = R if L is None else _join(L, R)
            if L.rows.shape[0] == 0:
                return False
        for sol in L.rows if (L is not None and L.vars) else [np.zeros(0)]:
            theta = {v: int(val) for v, val in zip(L.vars, sol)} if L else {}
            out = tuple(
                theta[t] if isinstance(t, str) else int(t)
                for t in rule.head.terms
            )
            if out == terms:
                return True
        return False


def _dedup_min_round(
    rows: np.ndarray, rounds: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Collapse duplicate rows to their minimum round."""
    if rows.shape[0] == 0:
        return rows, rounds
    uniq, inv = np.unique(rows, axis=0, return_inverse=True)
    min_rounds = np.full(uniq.shape[0], np.iinfo(np.int64).max, dtype=np.int64)
    np.minimum.at(min_rounds, inv.ravel(), rounds)
    return uniq, min_rounds


# --------------------------------------------------------------------- #
# exporters
# --------------------------------------------------------------------- #
def proof_to_json(node: dict, indent: int | None = 2) -> str:
    return json.dumps(node, indent=indent)


def proof_to_dot(node: dict, title: str = "proof") -> str:
    """Graphviz DOT rendering: facts are boxes, rule applications are
    small circles labelled with the rule id."""
    lines = [
        f'digraph "{title}" {{',
        "  rankdir=BT;",
        '  node [fontname="monospace", fontsize=10];',
    ]
    counter = [0]

    def emit(n: dict) -> str:
        nid = f"f{counter[0]}"
        counter[0] += 1
        shape = "box" if n["kind"] == "derived" else "box, style=filled, fillcolor=lightgrey"
        check = "✓" if n.get("verified") else "?"
        lines.append(f'  {nid} [label="{n["fact"]} {check}", shape={shape}];')
        if n.get("children"):
            rnode = f"r{counter[0]}"
            counter[0] += 1
            rid = n.get("rule_id", -1)
            lines.append(
                f'  {rnode} [label="R{rid}", shape=circle, width=0.3];'
            )
            lines.append(f"  {rnode} -> {nid};")
            for child in n["children"]:
                cid = emit(child)
                lines.append(f"  {cid} -> {rnode};")
        return nid

    emit(node)
    lines.append("}")
    return "\n".join(lines)


def now_ns() -> int:
    """Monotonic ns clock for record timing (one indirection so tests
    can monkeypatch timing out)."""
    return time.perf_counter_ns()
