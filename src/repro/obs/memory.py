"""Process-wide memory accountant: byte reports, peaks, effectiveness.

The paper's headline claim is *space* — structure sharing and RLE "can
require less space" than flat storage — so bytes get the same treatment
wall-time got in DESIGN.md §Observability: one canonical accounting
protocol, one roll-up, one gate.

Three layers (DESIGN.md §Observability / Memory Accounting):

* **Reporters.**  Every byte-holding subsystem implements
  :class:`MemoryReporter` — ``memory_report() -> dict[str, int]`` — and
  registers itself (weakly) with the process-wide
  :class:`MemoryAccountant` under a *kind* (``columns``, ``frozen``,
  ``buffers``, ``inc``, ``cmat``, ``flat``, ``storage``).  Reports from
  live instances of a kind are summed part-wise, so gauge names stay
  stable however many engines a process creates.

  Conventions (the double-count rules):

  - Keys ending ``_bytes`` are resident payload bytes and sum into
    ``mem.resident_bytes``; other keys (``n_nodes``, ``regrows``, ...)
    are auxiliary integers.
  - Keys ending ``_disk_bytes`` are on-disk (WAL, snapshot files) —
    published as gauges but excluded from the resident roll-up.
  - Each reporter reports only arrays *it* owns; containers never
    re-count a child that registers itself (an engine reports its
    explicit rows, not its ``ColumnStore``).
  - Arrays that are views into a decompressed snapshot blob
    (``OWNDATA == False``) are reported under ``*snapshot_backed_bytes``
    parts, never mixed into owned counts.  Backed parts are excluded
    from ``mem.resident_bytes`` (on-disk payload dedup lets many leaves
    view one blob region, so summing views would over-count) and roll
    into their own ``mem.snapshot_backed_bytes`` gauge — an upper bound
    on the shared blob's footprint.

* **Sampler.**  :class:`MemorySampler` is the opt-in peak tracker: it
  attaches a tracer *hook* (:meth:`Tracer.add_hook`) and re-samples the
  accountant + RSS at phase/round span boundaries — never inside
  jitted code — recording high-water marks per phase (materialise,
  apply, restore, compact, serve_batch).  It meters its own cost
  (``time_ns``) so the <2% overhead budget is asserted, not assumed.

* **Effectiveness.**  :func:`publish_predicate_effectiveness` computes,
  per predicate, mu-DAG bytes vs the flat-equivalent bytes, the DAG
  sharing factor (tree bytes / DAG bytes), and the RLE ratio (cells per
  run) as ``mem.pred.*`` gauges — re-sampled at compaction epochs.
  These are the observed inputs the ROADMAP's adaptive hybrid storage
  item needs to pick layouts per predicate.
"""

from __future__ import annotations

import os
import time
import weakref
from typing import Protocol, runtime_checkable

from .metrics import MetricsRegistry, get_registry
from .trace import Tracer, get_tracer

__all__ = [
    "MemoryReporter",
    "MemoryAccountant",
    "MemorySampler",
    "get_accountant",
    "set_accountant",
    "register_reporter",
    "sample_memory",
    "rss_bytes",
    "array_is_backed",
    "split_owned_backed",
    "predicate_effectiveness",
    "publish_predicate_effectiveness",
    "PHASE_SPANS",
    "ROUND_SPANS",
]


@runtime_checkable
class MemoryReporter(Protocol):
    """Anything that can say where its bytes live."""

    def memory_report(self) -> dict[str, int]:  # pragma: no cover - protocol
        ...


# --------------------------------------------------------------------- #
# array classification helpers (the double-count rules)
# --------------------------------------------------------------------- #
def array_is_backed(arr) -> bool:
    """True when ``arr`` is a view over a buffer it does not own — e.g.
    a ``np.frombuffer`` slice of a decompressed snapshot blob.  Such
    arrays keep the whole base alive; accounting splits them out so a
    shared blob is never counted once per view-holder as owned bytes."""
    flags = getattr(arr, "flags", None)
    if flags is None:  # device arrays own their buffers
        return False
    return not flags["OWNDATA"] and arr.base is not None


def split_owned_backed(arrays) -> tuple[int, int]:
    """Sum ``(owned_bytes, snapshot_backed_bytes)`` over arrays."""
    owned = backed = 0
    for a in arrays:
        if a is None:
            continue
        if array_is_backed(a):
            backed += int(a.nbytes)
        else:
            owned += int(a.nbytes)
    return owned, backed


# --------------------------------------------------------------------- #
# RSS (stdlib only; psutil is not a dependency)
# --------------------------------------------------------------------- #
_PAGE_SIZE = None


def rss_bytes() -> int:
    """Current resident set size.  Linux: ``/proc/self/statm`` (cheap —
    one read + split).  Fallback: ``ru_maxrss`` (the *peak*, close
    enough for the platforms without procfs).  0 if neither works."""
    global _PAGE_SIZE
    try:
        with open("/proc/self/statm", "rb") as f:
            resident_pages = int(f.read().split()[1])
        if _PAGE_SIZE is None:
            _PAGE_SIZE = os.sysconf("SC_PAGE_SIZE")
        return resident_pages * _PAGE_SIZE
    except (OSError, ValueError, IndexError):
        try:
            import resource

            return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
        except Exception:  # pragma: no cover - exotic platforms
            return 0


# --------------------------------------------------------------------- #
# the accountant
# --------------------------------------------------------------------- #
def _is_resident_key(key: str) -> bool:
    """``*_bytes`` parts roll into ``mem.resident_bytes`` except disk
    bytes (not RAM) and snapshot-backed bytes (views over a shared
    decompressed blob: on-disk payload dedup means several leaves can
    view one region, so summing views would over-count the blob — they
    get their own ``mem.snapshot_backed_bytes`` roll-up instead, an
    upper bound on the blob's footprint)."""
    return (
        key.endswith("_bytes")
        and not key.endswith("_disk_bytes")
        and not key.endswith("_snapshot_backed_bytes")
    )


class MemoryAccountant:
    """Weak registry of :class:`MemoryReporter` instances, grouped by
    kind; one :meth:`sample` rolls everything up into ``mem.*`` gauges.

    Reporters are held by ``weakref`` — registration never extends a
    lifetime, and dead instances silently leave the roll-up (their kind
    keeps publishing, at zero, so leak checks can see it drain)."""

    def __init__(self):
        self._kinds: dict[str, list[weakref.ref]] = {}

    # ------------------------------------------------------------------ #
    def register(self, kind: str, reporter: MemoryReporter) -> None:
        refs = self._kinds.setdefault(kind, [])
        if not any(r() is reporter for r in refs):
            refs.append(weakref.ref(reporter))

    def unregister(self, kind: str, reporter: MemoryReporter) -> None:
        refs = self._kinds.get(kind, [])
        self._kinds[kind] = [r for r in refs if r() is not reporter]

    def live(self) -> dict[str, list]:
        """Live reporters per kind (prunes dead weakrefs in place)."""
        out: dict[str, list] = {}
        for kind, refs in self._kinds.items():
            objs = [o for o in (r() for r in refs) if o is not None]
            self._kinds[kind] = [weakref.ref(o) for o in objs]
            out[kind] = objs
        return out

    def clear(self) -> None:
        self._kinds.clear()

    # ------------------------------------------------------------------ #
    def collect(self) -> dict[str, dict[str, int]]:
        """Part-wise sums of ``memory_report()`` over live reporters,
        per kind.  Kinds with no survivors report ``{}`` (still listed,
        so their gauges are driven back to zero)."""
        out: dict[str, dict[str, int]] = {}
        for kind, objs in self.live().items():
            merged: dict[str, int] = {}
            for obj in objs:
                for key, val in obj.memory_report().items():
                    merged[key] = merged.get(key, 0) + int(val)
            out[kind] = merged
        return out

    def resident_bytes(self, collected: dict | None = None) -> int:
        if collected is None:
            collected = self.collect()
        return sum(
            val
            for parts in collected.values()
            for key, val in parts.items()
            if _is_resident_key(key)
        )

    # ------------------------------------------------------------------ #
    def sample(
        self,
        registry: MetricsRegistry | None = None,
        phase: str | None = None,
        rss: bool = True,
    ) -> dict[str, int]:
        """One roll-up: publish ``mem.<kind>.<part>`` gauges, the
        ``mem.resident_bytes`` total, RSS, and max-update the peak
        gauges (globally and, when ``phase`` is given, per phase)."""
        reg = registry if registry is not None else get_registry()
        collected = self.collect()
        flat: dict[str, int] = {}
        for kind, parts in collected.items():
            stale = self._known_parts(kind)
            for key in stale - parts.keys():
                reg.gauge(f"mem.{kind}.{key}").set(0)
            for key, val in parts.items():
                reg.gauge(f"mem.{kind}.{key}").set(val)
                flat[f"{kind}.{key}"] = val
            self._remember_parts(kind, parts.keys())
        resident = self.resident_bytes(collected)
        backed = sum(
            val
            for parts in collected.values()
            for key, val in parts.items()
            if key.endswith("_snapshot_backed_bytes")
        )
        reg.gauge("mem.resident_bytes").set(resident)
        reg.gauge("mem.snapshot_backed_bytes").set(backed)
        _gauge_max(reg, "mem.peak_resident_bytes", resident)
        flat["resident_bytes"] = resident
        flat["snapshot_backed_bytes"] = backed
        if phase:
            _gauge_max(reg, f"mem.peak.{phase}.resident_bytes", resident)
        if rss:
            r = rss_bytes()
            reg.gauge("mem.rss_bytes").set(r)
            _gauge_max(reg, "mem.peak_rss_bytes", r)
            if phase:
                _gauge_max(reg, f"mem.peak.{phase}.rss_bytes", r)
            flat["rss_bytes"] = r
        return flat

    # parts seen per kind, so gauges of dead parts are zeroed not stale
    def _known_parts(self, kind: str) -> set[str]:
        return getattr(self, "_parts_seen", {}).get(kind, set())

    def _remember_parts(self, kind: str, keys) -> None:
        seen = getattr(self, "_parts_seen", None)
        if seen is None:
            seen = self._parts_seen = {}
        seen.setdefault(kind, set()).update(keys)


def _gauge_max(reg: MetricsRegistry, name: str, value) -> None:
    g = reg.gauge(name)
    if value > g.value:
        g.set(value)


#: the process-wide accountant every subsystem registers with
_ACCOUNTANT = MemoryAccountant()


def get_accountant() -> MemoryAccountant:
    return _ACCOUNTANT


def set_accountant(acc: MemoryAccountant) -> MemoryAccountant:
    """Swap the process-wide accountant (returns the previous one)."""
    global _ACCOUNTANT
    prev = _ACCOUNTANT
    _ACCOUNTANT = acc
    return prev


def register_reporter(kind: str, reporter: MemoryReporter) -> None:
    """Register with the *current* process-wide accountant (the call
    every ``__init__`` uses — re-reads the global, so tests can swap)."""
    _ACCOUNTANT.register(kind, reporter)


def sample_memory(phase: str | None = None, rss: bool = True) -> dict:
    """One-shot roll-up on the process-wide accountant + registry."""
    return _ACCOUNTANT.sample(phase=phase, rss=rss)


# --------------------------------------------------------------------- #
# the peak sampler (tracer-hook driven)
# --------------------------------------------------------------------- #
#: span names that *are* a phase: sampling at their exit records the
#: phase's closing watermark under ``mem.peak.<phase>.*``
PHASE_SPANS: dict[str, str] = {
    "cmat.materialise": "materialise",
    "flat.materialise": "materialise",
    "dist.stratum": "materialise",
    "inc.seminaive_insert": "apply",
    "inc.insertion_sweep": "apply",
    "inc.deletion_sweep": "apply",
    "inc.counting_insert": "apply",
    "inc.counting_delete": "apply",
    "inc.dred_stratum": "apply",
    "storage.restore": "restore",
    "storage.compact": "compact",
    "serve.update_batch": "serve_batch",
}

#: intra-phase boundaries: sampled too (peaks live *inside* a fixpoint,
#: not at its end), attributed to the innermost enclosing phase span
ROUND_SPANS: frozenset = frozenset(
    {"cmat.round", "flat.round", "cmat.recompress"}
)


class MemorySampler:
    """Opt-in peak tracker riding span boundaries (module docstring).

    ``attach()`` registers a hook on the tracer (enabling it if it was
    off; ``detach()`` restores the flag).  The hook fires only for span
    names in ``PHASE_SPANS`` / ``ROUND_SPANS`` — one set lookup for
    every other span — and each firing is self-metered into
    ``time_ns`` / ``samples`` so the overhead budget is testable.

    The hook path is deliberately light: it only folds the accountant's
    resident total (and RSS) into in-memory peak dicts — no gauge
    traffic per round.  ``detach()`` then publishes one full roll-up
    plus the accumulated ``mem.peak.<phase>.*`` watermarks.

    On top of that the hook is **self-throttling**: after a sample that
    cost ``c`` ns, the next hook sample is allowed no sooner than
    ``c / budget`` ns later (default budget 1 %).  Workloads whose span
    cadence outpaces the sampling cost — tiny KBs with many rounds —
    skip intermediate boundaries instead of taxing the fixpoint, so the
    sampler's share of wall time is bounded by ``budget`` no matter the
    workload shape.  Skips are counted in ``throttled``."""

    def __init__(
        self,
        accountant: MemoryAccountant | None = None,
        registry: MetricsRegistry | None = None,
        extra_spans: dict[str, str] | None = None,
        rss: bool = True,
        budget: float = 0.01,
    ):
        self._accountant = accountant
        self._registry = registry
        self._rss = rss
        self._budget = budget
        self._next_ns = 0
        self._phases = dict(PHASE_SPANS)
        if extra_spans:
            self._phases.update(extra_spans)
        self._watch = frozenset(self._phases) | ROUND_SPANS
        self.samples = 0
        self.throttled = 0
        self.time_ns = 0
        self.peaks: dict[str, int] = {}
        self._rss_peaks: dict[str, int] = {}
        self._tracer: Tracer | None = None
        self._was_enabled = False

    # ------------------------------------------------------------------ #
    def attach(self, tracer: Tracer | None = None) -> MemorySampler:
        self._tracer = tracer if tracer is not None else get_tracer()
        self._was_enabled = self._tracer.enabled
        self._tracer.enable()
        self._tracer.add_hook(self._hook)
        self.sample()  # baseline watermark before any phase runs
        return self

    def detach(self) -> None:
        if self._tracer is None:
            return
        self._tracer.remove_hook(self._hook)
        if not self._was_enabled:
            self._tracer.disable()
        self._tracer = None
        self._publish()

    def __enter__(self) -> MemorySampler:
        return self.attach()

    def __exit__(self, *exc) -> bool:
        self.detach()
        return False

    # ------------------------------------------------------------------ #
    def _hook(self, tracer: Tracer, rec) -> None:
        name = rec.name
        if name not in self._watch:
            return
        t0 = time.perf_counter_ns()
        if t0 < self._next_ns:
            self.throttled += 1
            return
        phase = self._phases.get(name)
        if phase is None:
            # round boundary: attribute to the innermost open phase —
            # children exit before parents, so the phase span is still
            # on the live stack
            for live in reversed(tracer._stack()):
                phase = self._phases.get(live.name)
                if phase is not None:
                    break
        self._sample_light(phase)
        cost = time.perf_counter_ns() - t0
        self.time_ns += cost
        if self._budget > 0:
            self._next_ns = t0 + cost + int(cost / self._budget)

    def _sample_light(self, phase: str | None) -> None:
        """Hook-path sample: peaks only, no per-part gauge traffic."""
        acc = self._accountant if self._accountant is not None else get_accountant()
        self.samples += 1
        key = phase or "(unphased)"
        resident = acc.resident_bytes()
        if resident > self.peaks.get(key, -1):
            self.peaks[key] = resident
        if self._rss:
            r = rss_bytes()
            if r > self._rss_peaks.get(key, -1):
                self._rss_peaks[key] = r

    def sample(self, phase: str | None = None) -> dict:
        """Full roll-up (gauges included) — the explicit-call path."""
        acc = self._accountant if self._accountant is not None else get_accountant()
        reg = self._registry if self._registry is not None else get_registry()
        flat = acc.sample(registry=reg, phase=phase, rss=self._rss)
        self.samples += 1
        resident = flat.get("resident_bytes", 0)
        key = phase or "(unphased)"
        if resident > self.peaks.get(key, -1):
            self.peaks[key] = resident
        if self._rss:
            r = flat.get("rss_bytes", 0)
            if r > self._rss_peaks.get(key, -1):
                self._rss_peaks[key] = r
        reg.gauge("mem.sampler.samples").set(self.samples)
        reg.gauge("mem.sampler.throttled").set(self.throttled)
        reg.gauge("mem.sampler.time_s").set(self.time_ns / 1e9)
        return flat

    def _publish(self) -> None:
        """One full roll-up + the accumulated per-phase watermarks."""
        acc = self._accountant if self._accountant is not None else get_accountant()
        reg = self._registry if self._registry is not None else get_registry()
        acc.sample(registry=reg, rss=self._rss)
        for key, v in self.peaks.items():
            _gauge_max(reg, "mem.peak_resident_bytes", v)
            if key != "(unphased)":
                _gauge_max(reg, f"mem.peak.{key}.resident_bytes", v)
        for key, v in self._rss_peaks.items():
            _gauge_max(reg, "mem.peak_rss_bytes", v)
            if key != "(unphased)":
                _gauge_max(reg, f"mem.peak.{key}.rss_bytes", v)
        reg.gauge("mem.sampler.samples").set(self.samples)
        reg.gauge("mem.sampler.throttled").set(self.throttled)
        reg.gauge("mem.sampler.time_s").set(self.time_ns / 1e9)


# --------------------------------------------------------------------- #
# per-predicate compression effectiveness
# --------------------------------------------------------------------- #
def predicate_effectiveness(facts) -> dict[str, dict[str, float]]:
    """Per-predicate compression statistics over a ``FactStore``:

    - ``flat_bytes``       — rows x arity x 8, the flat-equivalent
    - ``mu_bytes``         — bytes of mu-DAG nodes reachable from the
      predicate's columns (each node once)
    - ``compression_ratio``— flat / mu (higher = compression winning)
    - ``sharing_factor``   — tree-expanded bytes / mu bytes (how much
      DAG sharing saves over a no-sharing tree; 1.0 = no sharing)
    - ``rle_ratio``        — unfolded cells per stored run over the
      reachable leaves (average run length; 1.0 = RLE not helping)

    A ``_total`` pseudo-predicate summarises the whole store with the
    **cross-predicate** view: derived predicates mostly reference the
    source predicate's column nodes wholesale (the paper's taxonomic
    rules), so per-predicate reachable bytes charge each shared node to
    every predicate that uses it, while ``_total``'s ``mu_bytes`` counts
    it once.  Its ``sharing_factor`` is the sum of per-predicate
    ``mu_bytes`` over the global deduplicated ``mu_bytes`` — how many
    predicates, on average, each byte of the store serves.
    """
    store = facts.store
    out: dict[str, dict[str, float]] = {}
    all_roots: list[int] = []
    sum_pred_mu = 0
    for pred in facts.predicates():
        mfs = facts.all(pred)
        if not mfs:
            continue
        arity = mfs[0].arity
        n_rows = sum(mf.length for mf in mfs)
        flat_bytes = n_rows * arity * 8
        roots = [c for mf in mfs for c in mf.columns]
        all_roots.extend(roots)
        reach = store.reachable(roots)
        mu_bytes = sum(store.node_nbytes(c) for c in reach)
        sum_pred_mu += mu_bytes
        cells, runs = store.leaf_rle_stats(reach)
        tree_bytes = store.expanded_nbytes(roots)
        out[pred] = {
            "flat_bytes": flat_bytes,
            "mu_bytes": mu_bytes,
            "compression_ratio": flat_bytes / mu_bytes if mu_bytes else 0.0,
            "sharing_factor": tree_bytes / mu_bytes if mu_bytes else 0.0,
            "rle_ratio": cells / runs if runs else 0.0,
        }
    if out:
        reach = store.reachable(all_roots)
        mu_total = sum(store.node_nbytes(c) for c in reach)
        cells, runs = store.leaf_rle_stats(reach)
        flat_total = sum(int(p["flat_bytes"]) for p in out.values())
        out["_total"] = {
            "flat_bytes": flat_total,
            "mu_bytes": mu_total,
            "compression_ratio": flat_total / mu_total if mu_total else 0.0,
            "sharing_factor": sum_pred_mu / mu_total if mu_total else 0.0,
            "rle_ratio": cells / runs if runs else 0.0,
        }
    return out


def publish_predicate_effectiveness(
    facts, registry: MetricsRegistry | None = None
) -> dict[str, dict[str, float]]:
    """Publish :func:`predicate_effectiveness` as ``mem.pred.*`` gauges
    (called after load/materialise and re-sampled at every compaction
    epoch, so the stats track resharing)."""
    reg = registry if registry is not None else get_registry()
    stats = predicate_effectiveness(facts)
    for pred, parts in stats.items():
        for key, val in parts.items():
            reg.gauge(f"mem.pred.{pred}.{key}").set(
                round(val, 4) if isinstance(val, float) else val
            )
    return stats
